//! Full KVTuner pipeline end to end: profile → prune → cluster → MOO search
//! → emit config → validate the chosen config on the *PJRT* engine (not just
//! the reference engine the search ran on).
//!
//!   cargo run --release --example tune_e2e [evals]

use std::sync::Arc;

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::engine::Engine;
use kvtuner::model::Weights;
use kvtuner::runtime::Runtime;
use kvtuner::tuner::{self, calib, MooOptions, TuneOptions};
use kvtuner::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let weights = Weights::load(&manifest, &cfg.name)?;
    let evals = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(80usize);

    let opts = TuneOptions {
        mode: Mode::Token,
        n_prompts: 6,
        prompt_len: 40,
        horizon: 24,
        moo: MooOptions { evaluations: evals, population: 12, ..Default::default() },
        ..Default::default()
    };
    println!("running KVTuner pipeline ({} evals)...", opts.moo.evaluations);
    let t0 = std::time::Instant::now();
    let result = tuner::run_pipeline(&cfg, &weights, &opts)?;
    println!(
        "pipeline: {} groups, {} front points, {} evals in {:.1}s",
        result.groups.len(),
        result.front.len(),
        result.evals,
        t0.elapsed().as_secs_f64()
    );

    let mut t = Table::new("Pareto frontier", &["equiv bits", "fidelity acc"]);
    for p in &result.front {
        t.row(vec![format!("{:.2}", p.bits), format!("{:.4}", p.accuracy)]);
    }
    t.print();

    let Some(best) = result.configs.first() else {
        anyhow::bail!("no config met the bit constraints");
    };
    let out = std::env::temp_dir().join("kvtuner_tuned.json");
    best.save(&out)?;
    println!("\nselected {} ({:.2} bits), saved to {}", best.label, best.equivalent_bits, out.display());

    // validate on the real serving engine: compare against the fp PJRT arm
    println!("validating on the PJRT engine...");
    let rt = Arc::new(Runtime::load(&dir)?);
    let prompts = calib::calib_set(cfg.vocab, 4, 40, 777);
    let horizon = 24;

    let mut fp_eng = Engine::new(
        rt.clone(), &cfg.name,
        LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
        1, 256, 32,
    )?;
    let mut tuned_eng = Engine::new(rt.clone(), &cfg.name, best.specs.clone(), 1, 256, 32)?;
    let mut kv2_eng = Engine::new(
        rt, &cfg.name,
        LayerSpec::uniform(Mode::Token, PrecisionPair::new(2, 2), cfg.n_layers),
        1, 256, 32,
    )?;

    let (mut agree_tuned, mut agree_kv2, mut total) = (0usize, 0usize, 0usize);
    for p in &prompts {
        let fp = fp_eng.generate(0, p, horizon)?;
        let tu = tuned_eng.generate(0, p, horizon)?;
        let k2 = kv2_eng.generate(0, p, horizon)?;
        agree_tuned += fp.iter().zip(&tu).filter(|(a, b)| a == b).count();
        agree_kv2 += fp.iter().zip(&k2).filter(|(a, b)| a == b).count();
        total += fp.len();
    }
    println!(
        "PJRT validation: tuned {} fidelity {:.3} | uniform KV2 fidelity {:.3} (n={total})",
        best.label,
        agree_tuned as f64 / total as f64,
        agree_kv2 as f64 / total as f64
    );
    anyhow::ensure!(agree_tuned >= agree_kv2, "tuned config should beat uniform KV2");
    println!("OK: searched config validated on the serving engine");
    Ok(())
}
