//! Quickstart: load the AOT artifacts, build a mixed-precision engine, and
//! generate a few tokens.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use kvtuner::config::{LayerSpec, Mode, PrecisionPair};
use kvtuner::engine::Engine;
use kvtuner::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    println!("loading artifacts from {}", dir.display());
    let rt = Arc::new(Runtime::load(&dir)?);
    let cfg = rt.manifest.config.clone();
    println!(
        "model config: {} layers, d_model={}, {} kv heads x {} dims, vocab={}",
        cfg.n_layers, cfg.d_model, cfg.n_kv_heads, cfg.head_dim, cfg.vocab
    );

    // a layer-wise mixed precision map, the way a KVTuner config would set it:
    // sensitive ends of the stack at K8V4 (kivi), the middle at K4V2.
    let mut specs = Vec::new();
    for l in 0..cfg.n_layers {
        let pair = if l == 0 || l == cfg.n_layers - 1 {
            PrecisionPair::new(8, 4)
        } else {
            PrecisionPair::new(4, 2)
        };
        specs.push(LayerSpec { mode: Mode::Kivi, pair });
    }
    let mut engine = Engine::new(rt, &cfg.name, specs, 1, 256, 32)?;
    println!(
        "engine ready: equivalent {:.2}-bit KV cache, {:.1} KiB cache buffers",
        engine.equivalent_bits(),
        engine.kv_bytes() as f64 / 1024.0
    );

    let prompt: Vec<i32> = (0..24).map(|i| (i * 11) % cfg.vocab as i32).collect();
    let out = engine.generate(0, &prompt, 16)?;
    println!("prompt:    {prompt:?}");
    println!("generated: {out:?}");
    println!(
        "exec stats: {} PJRT executions, compile {:?}",
        engine.exec_count.load(std::sync::atomic::Ordering::Relaxed),
        engine.rt.compile_stats.lock().unwrap().clone()
    );
    Ok(())
}
