//! Table-1 analogue: show how low-bit KV quantization errors accumulate
//! during generation until the token stream flips and diverges from the
//! full-precision output (the paper's GSM8K 20-4-4 → 20+4+4 case study).
//!
//!   cargo run --release --example error_accumulation

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::model::{RefEngine, Weights};
use kvtuner::tuner::calib;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let weights = Weights::load(&manifest, &cfg.name)?;

    let prompt = calib::calib_set(cfg.vocab, 3, 48, 12345).remove(1); // periodic motif
    let horizon = 48;
    let cap = prompt.len() + horizon + 1;

    let fp = {
        let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
        RefEngine::new(&cfg, &weights, specs, cap)?.generate(&prompt, horizon)?
    };
    println!("prompt ({} tokens): {:?}...", prompt.len(), &prompt[..8.min(prompt.len())]);
    println!("\n{:>10}: {}", "FP16", fmt(&fp, &fp));

    for (label, pair) in [
        ("KV8", PrecisionPair::new(8, 8)),
        ("KV4", PrecisionPair::new(4, 4)),
        ("K4V2", PrecisionPair::new(4, 2)),
        ("K2V4", PrecisionPair::new(2, 4)),
        ("KV2", PrecisionPair::new(2, 2)),
    ] {
        let specs = LayerSpec::uniform(Mode::Token, pair, cfg.n_layers);
        let out = RefEngine::new(&cfg, &weights, specs, cap)?.generate(&prompt, horizon)?;
        let div = fp.iter().zip(&out).take_while(|(a, b)| a == b).count();
        let agree = fp.iter().zip(&out).filter(|(a, b)| a == b).count();
        println!(
            "{label:>10}: {}  [diverges at token {div}, agreement {agree}/{}]",
            fmt(&out, &fp),
            fp.len()
        );
    }
    println!(
        "\nLike the paper's Table 1: high-precision pairs reproduce the FP stream; \
         K-first pairs (K4V2) generally survive longer than V-first pairs (K2V4) at \
         equal memory; 2-bit keys flip a token early and the remainder diverges."
    );
    Ok(())
}

fn fmt(out: &[i32], reference: &[i32]) -> String {
    out.iter()
        .zip(reference)
        .map(|(t, r)| {
            if t == r {
                format!("{t:>3}")
            } else {
                format!("*{t:>2}")
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}
