//! End-to-end serving driver (the E2E validation run recorded in
//! EXPERIMENTS.md): starts the multi-engine router — a KV8 "high" engine and
//! a mixed-precision tuned "balanced" engine — submits a batch of requests
//! with mixed accuracy classes, and reports per-engine throughput/latency.
//!
//!   cargo run --release --example serve_demo

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::coordinator::{AccuracyClass, Router, WorkerSpec};
use kvtuner::engine::BackendKind;
use kvtuner::util::bench::Table;
use kvtuner::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let batch = *manifest.decode_batches().last().unwrap_or(&1);
    let n_requests = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12usize);

    // tuned-style mixed map: K8V4 on the outer layers, K4V2 inside
    let tuned: Vec<LayerSpec> = (0..cfg.n_layers)
        .map(|l| LayerSpec {
            mode: Mode::Kivi,
            pair: if l == 0 || l + 1 == cfg.n_layers {
                PrecisionPair::new(8, 4)
            } else {
                PrecisionPair::new(4, 2)
            },
        })
        .collect();

    let workers = vec![
        WorkerSpec {
            name: "kv8-high".into(),
            model: cfg.name.clone(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers),
            class: AccuracyClass::High,
            batch,
            s_max: 256,
            prefill_chunk: 32,
            backend: BackendKind::Xla,
            ..WorkerSpec::default()
        },
        WorkerSpec {
            name: "tuned-balanced".into(),
            model: cfg.name.clone(),
            specs: tuned,
            class: AccuracyClass::Balanced,
            batch,
            s_max: 256,
            prefill_chunk: 32,
            backend: BackendKind::Xla,
            ..WorkerSpec::default()
        },
    ];

    eprintln!("starting router with {} engine workers (batch={batch})...", workers.len());
    let t0 = std::time::Instant::now();
    let router = Router::start(dir, workers)?;
    eprintln!("workers ready in {:.1}s", t0.elapsed().as_secs_f64());

    let mut rng = Rng::seed(99);
    let classes = [AccuracyClass::High, AccuracyClass::Balanced];
    let t_load = std::time::Instant::now();
    let mut subs = Vec::new();
    for i in 0..n_requests {
        let plen = rng.range(16, 80);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        subs.push(router.submit(prompt, 24, classes[i % 2])?);
    }
    let mut done = 0usize;
    let mut tok_total = 0usize;
    let mut t = Table::new("serve_demo — request results", &["id", "engine", "tokens", "ttft ms", "total ms"]);
    for sub in subs {
        let r = sub.wait()?;
        anyhow::ensure!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        done += 1;
        tok_total += r.tokens.len();
        t.row(vec![
            r.id.to_string(),
            r.engine,
            r.tokens.len().to_string(),
            format!("{:.1}", r.ttft.as_secs_f64() * 1e3),
            format!("{:.1}", r.total.as_secs_f64() * 1e3),
        ]);
    }
    let wall = t_load.elapsed().as_secs_f64();
    t.print();

    let mut tm = Table::new("serve_demo — per-engine metrics", &["engine", "eq bits", "summary"]);
    for r in router.shutdown()? {
        let bits = if r.name.starts_with("kv8") { 8.0 } else { 4.5 };
        tm.row(vec![r.name, format!("{bits:.2}"), r.snapshot.to_string()]);
    }
    tm.print();
    println!(
        "\nE2E: {done}/{n_requests} requests, {tok_total} tokens in {wall:.2}s wall \
         ({:.1} tok/s aggregate)",
        tok_total as f64 / wall
    );
    Ok(())
}
