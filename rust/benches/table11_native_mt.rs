//! Bench: the threaded, tiled native backend — decode and prefill
//! throughput across kernel-pool widths, with bit-exactness asserted every
//! arm. Runs with zero artifacts (`Weights::synthetic`) and without the
//! `xla` feature.
//!
//! Three prefill arms per precision setting:
//!
//! * `tokenwise ×1` — token-by-token prefill on one thread: exactly the
//!   engine as it existed before the parallel execution layer (the
//!   `--threads 1` scalar baseline).
//! * `block ×1` — group-blocked prefill (fused QKV matmul +
//!   `attend_block`), still one thread: isolates the tiling win (each
//!   weight matrix read once per group instead of once per token).
//! * `block ×4` — the same plus the thread pool.
//!
//! Decode runs the same argmax chain at pool widths {1, 2, 4}. Every arm's
//! token stream and final logits must be bit-for-bit identical — the
//! determinism-by-output-partitioning contract — and the speedup floors
//! (≥4× prefill, ≥2× decode at 4 threads vs the scalar baseline) are
//! asserted whenever the host actually has ≥4 hardware threads; narrower
//! hosts assert a reduced tiling-only floor and report the rest.
//!
//! Run: `cargo bench --bench table11_native_mt`

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::coordinator::{AccuracyClass, Metrics, Request, Scheduler, SchedulerOptions};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kvcache::PagedOptions;
use kvtuner::model::Weights;
use kvtuner::obs::ProbeConfig;
use kvtuner::util::bench::Table;

/// Counting wrapper over the system allocator: total bytes requested, for
/// the decode-hot-path allocation regression below. Counts every alloc in
/// the process, so windows are compared byte-for-byte between two runs with
/// identical per-step work — not asserted to be zero.
struct CountingAlloc;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

const S_MAX: usize = 256;
const PROMPT_LEN: usize = 160; // 5 full groups of 32
const DECODE_STEPS: usize = 40;
const DECODE_THREADS: [usize; 3] = [1, 2, 4];
/// Each arm is measured this many times and the best tokens/sec kept, so a
/// single scheduling hiccup on a shared CI runner cannot fail the floors.
const REPS: usize = 3;

fn best_of(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
    (0..reps).map(|_| f()).fold(f64::MIN, f64::max)
}

/// Large enough that weight streaming dominates prefill and the lm head
/// dominates decode — the regimes the parallel layer targets.
fn sim_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim-mt".into(),
        n_layers: 6,
        d_model: 128,
        n_heads: 8,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 512,
        vocab: 8192,
        rope_theta: 10000.0,
        group: 32, // page = block size
        residual: 32,
        rms_eps: 1e-5,
    }
}

fn engine(cfg: &ModelConfig, w: &Weights, specs: &[LayerSpec], threads: usize) -> NativeEngine {
    NativeEngine::new(
        cfg,
        w.clone(),
        specs.to_vec(),
        1,
        S_MAX,
        32,
        threads,
        Some(PagedOptions::default()),
    )
    .unwrap()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() -> anyhow::Result<()> {
    let cfg = sim_cfg();
    let w = Weights::synthetic(&cfg, 11);
    let prompt: Vec<i32> = (0..PROMPT_LEN).map(|j| ((j * 31 + 7) % cfg.vocab) as i32).collect();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let nl = cfg.n_layers;
    let settings: Vec<(String, Vec<LayerSpec>)> = vec![
        ("KV8".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), nl)),
        ("K4V2".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), nl)),
        ("KVTuner-style mix".into(), kvtuner::tuned_style_map(nl)),
    ];

    let mut t = Table::with_headers(
        &format!(
            "table11_native_mt — threaded/tiled native backend ({nl} layers, d={}, vocab={}, \
             group={}, prompt={PROMPT_LEN}, {DECODE_STEPS} decode steps, host threads={hw})",
            cfg.d_model, cfg.vocab, cfg.group
        ),
        vec![
            "setting".into(),
            "prefill tok/s ×1 tokenwise".into(),
            "×1 block".into(),
            "×4 block".into(),
            "prefill speedup".into(),
            "decode tok/s ×1".into(),
            "×2".into(),
            "×4".into(),
            "decode speedup".into(),
            "probe ovh ×2".into(),
        ],
    );

    for (label, specs) in &settings {
        // --- prefill arms (best of REPS each; bit-asserts run every rep) --
        let mut first = 0i32;
        let mut base_bits: Vec<u32> = Vec::new();
        let tokenwise_tps = best_of(REPS, || {
            let mut e = engine(&cfg, &w, specs, 1);
            let t0 = Instant::now();
            first = e.prefill_tokenwise(0, &prompt).unwrap();
            let tps = PROMPT_LEN as f64 / t0.elapsed().as_secs_f64();
            base_bits = bits(e.logits(0));
            tps
        });

        let measure_block = |th: usize| -> f64 {
            best_of(REPS, || {
                let mut e = engine(&cfg, &w, specs, th);
                let t1 = Instant::now();
                let f = e.prefill(0, &prompt).unwrap();
                let tps = PROMPT_LEN as f64 / t1.elapsed().as_secs_f64();
                assert_eq!(f, first, "{label}: block prefill ×{th} changed the next token");
                assert_eq!(
                    bits(e.logits(0)),
                    base_bits,
                    "{label}: block prefill ×{th} logits differ from the tokenwise scalar arm"
                );
                tps
            })
        };
        let mut prefill_tps = vec![measure_block(1), measure_block(4)];
        let mut prefill_speedup = prefill_tps[1] / tokenwise_tps;

        // --- decode arms --------------------------------------------------
        let mut chain: Option<(Vec<i32>, Vec<u32>)> = None;
        let mut measure_decode = |th: usize| -> f64 {
            best_of(REPS, || {
                let mut e = engine(&cfg, &w, specs, th);
                e.prefill(0, &prompt).unwrap();
                let mut tok = first;
                let mut stream = Vec::with_capacity(DECODE_STEPS);
                let t2 = Instant::now();
                for _ in 0..DECODE_STEPS {
                    tok = e.decode_step(&[tok], &[true]).unwrap()[0];
                    stream.push(tok);
                }
                let tps = DECODE_STEPS as f64 / t2.elapsed().as_secs_f64();
                let sig = (stream, bits(e.logits(0)));
                if chain.is_none() {
                    chain = Some(sig);
                } else {
                    let want = chain.as_ref().unwrap();
                    assert_eq!(want.0, sig.0, "{label}: decode stream diverged at ×{th}");
                    assert_eq!(want.1, sig.1, "{label}: decode logit bits diverged at ×{th}");
                }
                tps
            })
        };
        let mut decode_tps: Vec<f64> =
            DECODE_THREADS.iter().map(|&th| measure_decode(th)).collect();
        let mut decode_speedup = decode_tps[2] / decode_tps[0];

        // --- floors -------------------------------------------------------
        if hw >= 4 {
            // one re-measure of the threaded arm before declaring failure:
            // shared CI runners can stall a whole best-of round
            if prefill_speedup < 4.0 {
                prefill_tps[1] = prefill_tps[1].max(measure_block(4));
                prefill_speedup = prefill_tps[1] / tokenwise_tps;
            }
            if decode_speedup < 2.0 {
                decode_tps[2] = decode_tps[2].max(measure_decode(4));
                decode_speedup = decode_tps[2] / decode_tps[0];
            }
            assert!(
                prefill_speedup >= 4.0,
                "{label}: block ×4 prefill must be ≥4× the ×1 tokenwise baseline \
                 (got {prefill_speedup:.2}×)"
            );
            assert!(
                decode_speedup >= 2.0,
                "{label}: ×4 decode must be ≥2× the ×1 baseline (got {decode_speedup:.2}×)"
            );
        } else {
            // narrow host: threading cannot express itself, but the tiling
            // win (one weight pass per group) must still show up
            assert!(
                prefill_tps[0] / tokenwise_tps >= 1.5,
                "{label}: block ×1 prefill must beat tokenwise ×1 by ≥1.5× \
                 (got {:.2}×)",
                prefill_tps[0] / tokenwise_tps
            );
            eprintln!(
                "[table11_native_mt] host has {hw} threads (<4): skipping the 4-thread \
                 speedup floors, reporting measurements only"
            );
        }

        // --- profiled arm: instrumentation must not change a single bit ---
        // (The floors above double as the profiler- and probe-disabled
        // overhead guard: every unprofiled arm runs the instrumented engine
        // with both off, so the disabled paths' cost is bounded by the same
        // ×1-scalar-baseline floors that predate the instrumentation.)
        {
            let mut e = engine(&cfg, &w, specs, 2);
            e.set_profiling(true);
            e.prefill(0, &prompt).unwrap();
            let mut tok = first;
            let mut stream = Vec::with_capacity(DECODE_STEPS);
            for _ in 0..DECODE_STEPS {
                tok = e.decode_step(&[tok], &[true]).unwrap()[0];
                stream.push(tok);
            }
            let want = chain.as_ref().unwrap();
            assert_eq!(want.0, stream, "{label}: profiling changed the decode stream");
            assert_eq!(
                want.1,
                bits(e.logits(0)),
                "{label}: profiling changed the final logits"
            );
            let p = e.profile().expect("profiling was enabled");
            assert!(p.total_nanos() > 0, "{label}: profiled run recorded no phase time");
            assert!(
                p.layers[0].kv_live_peak > 0,
                "{label}: profiled run recorded no live KV bytes"
            );
        }

        // --- probe arm: fp-shadow sampling is read-only, and its decode
        // overhead vs the matching ×2 baseline goes into the BENCH_JSON line
        let probe_ovh_pct = {
            let tps = best_of(REPS, || {
                let mut e = engine(&cfg, &w, specs, 2);
                e.set_probe(ProbeConfig { every: 1, ..ProbeConfig::default() });
                e.prefill(0, &prompt).unwrap();
                let mut tok = first;
                let mut stream = Vec::with_capacity(DECODE_STEPS);
                let t3 = Instant::now();
                for _ in 0..DECODE_STEPS {
                    tok = e.decode_step(&[tok], &[true]).unwrap()[0];
                    stream.push(tok);
                }
                let tps = DECODE_STEPS as f64 / t3.elapsed().as_secs_f64();
                let want = chain.as_ref().unwrap();
                assert_eq!(want.0, stream, "{label}: the probe changed the decode stream");
                assert_eq!(
                    want.1,
                    bits(e.logits(0)),
                    "{label}: the probe changed the final logits"
                );
                let snap = EngineCore::sensitivity(&e).expect("probe was armed");
                assert!(snap.samples() > 0, "{label}: armed probe sampled nothing");
                tps
            });
            (decode_tps[1] / tps - 1.0) * 100.0
        };

        t.row(vec![
            label.clone(),
            format!("{tokenwise_tps:.0}"),
            format!("{:.0}", prefill_tps[0]),
            format!("{:.0}", prefill_tps[1]),
            format!("{prefill_speedup:.2}x"),
            format!("{:.1}", decode_tps[0]),
            format!("{:.1}", decode_tps[1]),
            format!("{:.1}", decode_tps[2]),
            format!("{decode_speedup:.2}x"),
            format!("{probe_ovh_pct:.1}%"),
        ]);
        eprintln!("[table11_native_mt] {label} done");
    }
    // --- allocation regression: decode_step_into's per-step allocations
    // must not scale with the configured batch. A steady-state 16-step
    // window with one active slot allocates exactly the same bytes whether
    // the engine was built for batch 1 or batch 32 — any `vec![...; batch]`
    // (or per-slot buffer) sneaking back onto the hot path breaks the
    // byte-equality. (The remaining per-step bytes are the quantizer's
    // commit staging, identical across windows because both runs commit at
    // the same positions.)
    {
        let specs = &settings[0].1;
        let window = |batch: usize| -> u64 {
            let mut e = NativeEngine::new(
                &cfg,
                w.clone(),
                specs.clone(),
                batch,
                S_MAX,
                32,
                1,
                Some(PagedOptions::default()),
            )
            .unwrap();
            let mut tok = e.prefill(0, &prompt).unwrap();
            let mut tokens = vec![0i32; batch];
            let mut active = vec![false; batch];
            active[0] = true;
            let mut out = vec![0i32; batch];
            // warm-up: lazily grown buffers (gather lists, block tables)
            // reach steady state before the measured window opens
            for _ in 0..8 {
                tokens[0] = tok;
                e.decode_step_into(&tokens, &active, &mut out).unwrap();
                tok = out[0];
            }
            let start = ALLOC_BYTES.load(Ordering::Relaxed);
            for _ in 0..16 {
                tokens[0] = tok;
                e.decode_step_into(&tokens, &active, &mut out).unwrap();
                tok = out[0];
            }
            ALLOC_BYTES.load(Ordering::Relaxed) - start
        };
        let (b1, b32) = (window(1), window(32));
        assert_eq!(
            b1, b32,
            "decode_step_into allocations scale with batch ({b1} bytes at batch 1 vs \
             {b32} at batch 32): a per-batch buffer returned to the decode hot path"
        );
        eprintln!(
            "[table11_native_mt] decode alloc window: {b1} bytes over 16 steps, \
             batch-size independent"
        );
    }

    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());

    // --- unarmed fault-injection overhead guard: the scheduler's injection
    // points compile in unconditionally, so a serving path with no fault
    // plan must (a) produce the bit-identical stream + final logits of a
    // direct engine drive and (b) pay only scheduler bookkeeping, tracked
    // here as an explicit overhead column for bench_compare.
    {
        let specs = &settings[0].1; // KV8
        let mut direct_sig: Option<(Vec<i32>, Vec<u32>)> = None;
        let direct_tps = best_of(REPS, || {
            let mut e = engine(&cfg, &w, specs, 2);
            let first = e.prefill(0, &prompt).unwrap();
            let mut tok = first;
            let mut stream = vec![first];
            let t0 = Instant::now();
            for _ in 0..DECODE_STEPS {
                tok = e.decode_step(&[tok], &[true]).unwrap()[0];
                stream.push(tok);
            }
            let tps = DECODE_STEPS as f64 / t0.elapsed().as_secs_f64();
            let sig = (stream, bits(e.logits(0)));
            match &direct_sig {
                None => direct_sig = Some(sig),
                Some(want) => assert_eq!(*want, sig, "direct drive diverged between reps"),
            }
            tps
        });
        let want = direct_sig.as_ref().unwrap();

        let sched_tps = best_of(REPS, || {
            let e = engine(&cfg, &w, specs, 2);
            let metrics = Arc::new(Metrics::default());
            let mut sched = Scheduler::new(
                Box::new(e),
                "bench",
                SchedulerOptions {
                    capture_logits: true,
                    // faults: None — every injection point is one never-taken
                    // branch; this arm prices exactly that
                    ..SchedulerOptions::default()
                },
                metrics.clone(),
            );
            let (tx, rx) = mpsc::channel();
            assert!(sched.submit(Request {
                id: 0,
                prompt: prompt.clone(),
                max_new_tokens: DECODE_STEPS + 1,
                class: AccuracyClass::Balanced,
                arrival: Instant::now(),
                deadline: None,
                respond: tx,
            }));
            let mut ticks = 0u32;
            while !sched.is_idle() {
                sched.tick().unwrap();
                ticks += 1;
                assert!(ticks < 20_000, "scheduler failed to drain");
            }
            let r = rx.try_recv().unwrap();
            assert!(r.error.is_none(), "unarmed scheduler run failed: {:?}", r.error);
            assert_eq!(
                r.tokens, want.0,
                "unarmed injection changed the token stream vs the direct drive"
            );
            assert_eq!(
                bits(r.final_logits.as_ref().unwrap()),
                want.1,
                "unarmed injection changed the final logits vs the direct drive"
            );
            let snap = metrics.snapshot();
            assert_eq!(snap.faults_injected, 0, "no plan armed, nothing may inject");
            assert_eq!(snap.failures_total(), 0);
            snap.tokens_per_sec_decode
        });
        let ovh_pct = (direct_tps / sched_tps - 1.0) * 100.0;

        let mut tf = Table::with_headers(
            &format!(
                "table11_faults_unarmed — serving-path overhead with fault injection \
                 compiled in but unarmed (KV8, {DECODE_STEPS} decode steps, ×2 threads)"
            ),
            vec![
                "setting".into(),
                "direct decode tok/s".into(),
                "scheduler decode tok/s".into(),
                "unarmed ovh %".into(),
            ],
        );
        tf.row(vec![
            "KV8".into(),
            format!("{direct_tps:.1}"),
            format!("{sched_tps:.1}"),
            format!("{ovh_pct:.1}%"),
        ]);
        tf.print();
        println!("BENCH_JSON {}", tf.to_json().to_string_compact());
    }
    println!(
        "\nall arms bit-identical: block prefill == token-by-token prefill, every pool \
         width produces the same logits (outputs are partitioned, never accumulation \
         order), and neither the per-layer profiler nor the sensitivity probe changes \
         stream or logits."
    );
    Ok(())
}
