//! Bench: staged-gather vs block-table-direct decode attention over the
//! paged mixed-precision KV cache, across precision pairs and context
//! lengths. Runs with zero artifacts (and without the `xla` feature).
//!
//! Two arms compute the *same* attention output from the *same* quantized
//! pages:
//!
//! * `staged` — what the XLA backend's paged arm does before every layer
//!   step: `gather_slot` copies live pages into dense artifact-layout
//!   staging buffers (O(s_max) bytes, valid or not), then attention reads
//!   the staged copy. The staged bytes per step are measured and checked
//!   against the `staged_bytes` accounting that feeds the serving metric.
//! * `direct` — the native kernel: `kv_view` + `attend_one` walk the block
//!   tables in place, dequantizing inside the accumulation loops. Staging
//!   bytes are structurally zero.
//!
//! Both arms must agree bit-for-bit (same codes, same `code*scale+zero`
//! fold), which this bench asserts every iteration — it is a perf
//! comparison that doubles as a correctness check. A final end-to-end
//! sanity: a `NativeEngine` decode loop reports `gather_bytes() == 0`.
//!
//! Run: `cargo bench --bench table10_kernel`

use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kernel;
use kvtuner::kvcache::{CacheBackend, KvView, PageAddr, PagedKvCache, PagedOptions};
use kvtuner::model::Weights;
use kvtuner::quant::packed_width;
use kvtuner::tensor::Tensor;
use kvtuner::util::bench::Table;
use kvtuner::util::rng::Rng;

const S_MAX: usize = 512;
const CTX_LENS: [usize; 3] = [128, 256, 448];
const ITERS: usize = 30;

fn sim_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        n_layers: 4,
        d_model: 64,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 32,
        d_ff: 128,
        vocab: 256,
        rope_theta: 10000.0,
        group: 32, // page size
        residual: 32,
        rms_eps: 1e-5,
    }
}

/// Fill one slot with `n` tokens of natively quantized kivi content through
/// the real residual/commit scatter path.
fn fill(cache: &mut PagedKvCache, cfg: &ModelConfig, specs: &[LayerSpec], n: usize) {
    let (h, dh, g) = (cfg.n_kv_heads, cfg.head_dim, cfg.group);
    let mut r = Rng::seed(42);
    for _ in 0..n {
        for (l, sp) in specs.iter().enumerate() {
            let k: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
            let v: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
            let kt = Tensor::f32(&[1, h, 1, dh], k);
            let vt = Tensor::f32(&[1, h, 1, dh], v);
            let commit = cache.append_kivi_residual(l, 0, &kt, &vt, &[1]).unwrap();
            if commit[0] {
                let (kc, vc) = cache.residual_chunk(l, 0).unwrap();
                let (ko, vo) = kernel::kivi_commit_outputs(&kc, &vc, h, g, dh, sp.pair).unwrap();
                cache.commit_kivi_chunk(l, 0, &ko, &vo).unwrap();
            }
        }
        cache.advance_pos(0, 1);
    }
}

/// `KvView` over `gather_slot`'s staged dense tensors (kivi layout), so the
/// staged arm runs the identical dequant-fold attention — the only
/// difference between the arms is the staging copy itself.
fn staged_view<'a>(
    cfg: &ModelConfig,
    spec: LayerSpec,
    tensors: &'a [Tensor],
    cache_len: usize,
    res_len: usize,
) -> KvView<'a> {
    let (h, dh, g) = (cfg.n_kv_heads, cfg.head_dim, cfg.group);
    KvView {
        spec,
        h,
        dh,
        kp: packed_width(dh, spec.pair.k_bits).unwrap(),
        vp: packed_width(dh, spec.pair.v_bits).unwrap(),
        page: g,
        cache_len,
        res_len,
        addr: PageAddr::Dense { slot: 0, s_max: S_MAX },
        k_codes: tensors[0].as_u8().unwrap(),
        k_scale: tensors[1].as_f32().unwrap(),
        k_zero: tensors[2].as_f32().unwrap(),
        v_codes: tensors[3].as_u8().unwrap(),
        v_scale: tensors[4].as_f32().unwrap(),
        v_zero: tensors[5].as_f32().unwrap(),
        k_fp: &[],
        v_fp: &[],
        k_res: tensors[6].as_f32().unwrap(),
        v_res: tensors[7].as_f32().unwrap(),
        res_cap: cfg.residual,
    }
}

struct ArmResult {
    us_per_step: f64,
    staged_bytes_per_step: usize,
}

fn main() -> anyhow::Result<()> {
    let cfg = sim_cfg();
    let nl = cfg.n_layers;
    let (hq, dh) = (cfg.n_heads, cfg.head_dim);
    let mixed: Vec<LayerSpec> = (0..nl)
        .map(|l| LayerSpec {
            mode: Mode::Kivi,
            pair: if l == 0 || l + 1 == nl {
                PrecisionPair::new(8, 4)
            } else {
                PrecisionPair::new(4, 2)
            },
        })
        .collect();
    let settings: Vec<(String, Vec<LayerSpec>)> = vec![
        ("KV8".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), nl)),
        ("K8V4".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), nl)),
        ("KV4".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), nl)),
        ("K4V2".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), nl)),
        ("KV2".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(2, 2), nl)),
        ("KVTuner-style mix".into(), mixed),
    ];

    let mut t = Table::with_headers(
        &format!(
            "table10_kernel — staged-gather vs block-direct decode attention \
             ({nl} layers, {hq} q-heads, dh={dh}, s_max={S_MAX}, {ITERS} iters)"
        ),
        vec![
            "setting".into(),
            "ctx".into(),
            "staged us/step".into(),
            "direct us/step".into(),
            "speedup".into(),
            "staged KiB/step".into(),
            "direct staging B".into(),
        ],
    );

    let mut rq = Rng::seed(7);
    for (label, specs) in &settings {
        for &ctx in &CTX_LENS {
            let mut cache =
                PagedKvCache::new(&cfg, specs, 1, S_MAX, &PagedOptions::default())?;
            fill(&mut cache, &cfg, specs, ctx);
            let q: Vec<f32> = (0..hq * dh).map(|_| rq.normal() as f32).collect();
            let mut out_staged = vec![0f32; hq * dh];
            let mut out_direct = vec![0f32; hq * dh];

            // staged arm: gather every layer into dense staging buffers,
            // then attend over the staged copy
            let mut staged_bytes = 0usize;
            let t0 = Instant::now();
            for it in 0..ITERS {
                let mut step_bytes = 0usize;
                for (l, sp) in specs.iter().enumerate() {
                    let tensors = cache.gather_slot(l, 0)?;
                    step_bytes += tensors.iter().map(|t| t.size_bytes()).sum::<usize>();
                    let view = staged_view(
                        &cfg,
                        *sp,
                        &tensors,
                        cache.cache_len(l, 0) as usize,
                        cache.res_len(l, 0) as usize,
                    );
                    kernel::attend_one(&q, hq, &view, &mut out_staged)?;
                }
                if it == 0 {
                    staged_bytes = step_bytes;
                    // the serving metric's accounting must match reality
                    let accounted: usize =
                        (0..specs.len()).map(|l| cache.staged_bytes(l, 1)).sum();
                    assert_eq!(accounted, step_bytes, "staged_bytes accounting drifted");
                }
            }
            let staged_us = t0.elapsed().as_secs_f64() * 1e6 / ITERS as f64;

            // direct arm: walk the block tables in place — zero staging
            let t1 = Instant::now();
            for _ in 0..ITERS {
                for l in 0..specs.len() {
                    let view = cache.kv_view(l, 0)?;
                    kernel::attend_one(&q, hq, &view, &mut out_direct)?;
                }
            }
            let direct_us = t1.elapsed().as_secs_f64() * 1e6 / ITERS as f64;

            assert_eq!(
                out_staged, out_direct,
                "{label} ctx={ctx}: staged and block-direct attention must agree bit-for-bit"
            );
            assert!(staged_bytes > 0, "staged arm must move staging bytes");

            t.row(vec![
                label.clone(),
                ctx.to_string(),
                format!("{staged_us:.1}"),
                format!("{direct_us:.1}"),
                format!("{:.2}x", staged_us / direct_us),
                format!("{:.1}", staged_bytes as f64 / 1024.0),
                "0".into(),
            ]);
        }
        eprintln!("[table10_kernel] {label} done");
    }
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());

    // end-to-end: a native engine decode loop never stages
    let w = Weights::synthetic(&cfg, 3);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), nl);
    let mut eng =
        NativeEngine::new(&cfg, w, specs, 1, 128, 32, 1, Some(PagedOptions::default()))?;
    let prompt: Vec<i32> = (0..48).map(|j| (j * 5 % cfg.vocab) as i32).collect();
    eng.generate(0, &prompt, 16)?;
    assert_eq!(
        EngineCore::gather_bytes(&eng),
        0,
        "native engine must report zero gather bytes"
    );
    println!(
        "\nstaging bytes per decode step: staged arm copies the full dense artifact layout \
         (O(s_max) per layer, whether valid or not); the block-direct kernel reads pages in \
         place and moved 0 bytes — the same is true end-to-end: NativeEngine gather_bytes=0."
    );
    Ok(())
}
