//! Bench: regenerate Table 2 — pseudo-perplexity of uniform KV precision
//! pairs across the synthetic model family (robust / default / sensitive),
//! the analogue of the paper's wikitext word-perplexity sweep.
//! Run: `cargo bench --bench table2_ppl`

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::model::Weights;
use kvtuner::tuner::{self, calib};
use kvtuner::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table2: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let manifest = Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let pairs = [
        PrecisionPair::new(8, 8), PrecisionPair::new(8, 4), PrecisionPair::new(8, 2),
        PrecisionPair::new(4, 8), PrecisionPair::new(4, 4), PrecisionPair::new(4, 2),
        PrecisionPair::new(2, 8), PrecisionPair::new(2, 4), PrecisionPair::new(2, 2),
    ];

    for mode in [Mode::Kivi, Mode::Token] {
        let mut t = Table::with_headers(
            &format!("Table 2 — pseudo-perplexity, {} mode", mode.as_str()),
            {
                let mut h = vec!["model".to_string(), "FP".into()];
                h.extend(pairs.iter().map(|p| p.label()));
                h
            },
        );
        for model in manifest.models.keys() {
            let w = Weights::load(&manifest, model)?;
            let prompts = calib::calib_set(cfg.vocab, 6, 32, 77);
            let reference = tuner::build_reference(&cfg, &w, &prompts, 24)?;
            let mut row = vec![model.clone()];
            let fp_specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
            row.push(format!("{:.3}", tuner::pseudo_perplexity(&cfg, &w, &reference, &fp_specs)?));
            for pair in pairs {
                let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
                row.push(format!("{:.3}", tuner::pseudo_perplexity(&cfg, &w, &reference, &specs)?));
            }
            t.row(row);
            eprintln!("[table2] {model} / {} done", mode.as_str());
        }
        t.print();
        println!("BENCH_JSON {}", t.to_json().to_string_compact());
    }
    println!(
        "\npaper shape check: KV8 ≈ K8V4 ≈ FP; K4V8/K2V4 blow up before K8V4/K4V2 \
         (key precision dominates); the sensitive model degrades earliest."
    );
    Ok(())
}
