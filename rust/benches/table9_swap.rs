//! Bench: recompute-only vs swap-enabled preemption under an oversubscribed
//! page pool with a mixed short/long-context workload.
//!
//! The pool is sized well below the steady-state page demand of the slot
//! count, so the scheduler policy must shed load mid-flight. Three arms per
//! precision map:
//!
//! * `recompute` — `--swap-policy off`: every victim drops its pages and is
//!   later re-prefilled (prompt + generated-so-far), PR 1 behavior but with
//!   the new cost-aware victim selection.
//! * `swap-auto` — per-victim cost model: long contexts (quadratic re-prefill
//!   traffic) swap to the host tier; short ones recompute.
//! * `swap-always` — every victim swaps while the host arena has room.
//!
//! The sim drives the real allocator, prefix index, swap arena and the real
//! scheduler decision functions (`victim_score`, `choose_preempt_action`) —
//! page writes stand in for PJRT layer steps, so this runs with or without
//! artifacts. Every successful swap-in is checked bit-exact against a gather
//! snapshot taken at swap-out: a swapped-and-resumed sequence must be
//! indistinguishable from one that was never evicted.
//! Run: `cargo bench --bench table9_swap`

use std::collections::VecDeque;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::coordinator::{choose_preempt_action, victim_score, PreemptAction};
use kvtuner::kvcache::{CacheBackend, PagedKvCache, PagedOptions, SwapHandle, SwapPolicy};
use kvtuner::quant::packed_width;
use kvtuner::tensor::Tensor;
use kvtuner::util::bench::Table;

const S_MAX: usize = 512;
const SLOTS: usize = 6;
const POOL_BLOCKS: usize = 24;
const PREFILL_CHUNK: usize = 32;
const N_REQUESTS: usize = 14;

fn sim_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        n_layers: 4,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 32,
        d_ff: 128,
        vocab: 256,
        rope_theta: 10000.0,
        group: 32, // page size
        residual: 32,
        rms_eps: 1e-5,
    }
}

struct SimReq {
    id: usize,
    prompt: Vec<i32>,
    gen_target: usize,
    generated: usize,
    arrived: usize,
}

/// Mixed workload: every 4th-ish request is a long-context one (KVQuant-style
/// re-prefill-unaffordable), every 3rd shares a 64-token system prefix, the
/// rest are unique mid-size prompts. Arrivals are staggered 2 ticks apart.
fn workload(vocab: usize) -> VecDeque<SimReq> {
    let system: Vec<i32> = (0..64).map(|i| (i * 7 % vocab) as i32).collect();
    (0..N_REQUESTS)
        .map(|i| {
            let (prompt, gen_target) = if i % 4 == 2 {
                // long context: 7 prompt pages, grows to 9
                ((0..224).map(|j| ((j * 11 + i * 131) % vocab) as i32).collect::<Vec<i32>>(), 64)
            } else if i % 3 == 0 {
                let mut p = system.clone();
                p.extend((0..26).map(|j| ((j * 13 + i * 17) % vocab) as i32));
                (p, 30)
            } else {
                ((0..90).map(|j| ((j * 11 + i * 53) % vocab) as i32).collect::<Vec<i32>>(), 30)
            };
            SimReq { id: i, prompt, gen_target, generated: 0, arrived: 2 * i }
        })
        .collect()
}

/// Token value at absolute position `pos` of a request's context: prompt
/// tokens, then deterministic "generated" tokens — so a recompute re-prefill
/// reproduces the same context and prefix pages stay content-consistent.
fn token_at(req: &SimReq, pos: usize) -> i32 {
    if pos < req.prompt.len() {
        req.prompt[pos]
    } else {
        ((req.id * 31 + (pos - req.prompt.len()) * 7) % 256) as i32
    }
}

/// Single-token append tensors for one layer, seeded by (layer, position,
/// token value): distinctive content so the bit-exactness checks are
/// meaningful, identical across requests sharing a prefix.
fn step_outs(cfg: &ModelConfig, spec: &LayerSpec, layer: usize, pos: usize, tv: i32) -> Vec<Tensor> {
    let (h, dh) = (cfg.n_kv_heads, cfg.head_dim);
    let kp = packed_width(dh, spec.pair.k_bits).unwrap();
    let vp = packed_width(dh, spec.pair.v_bits).unwrap();
    let mut x = (layer as u64 + 1)
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add((pos as u64) << 32 | tv as u64)
        | 1;
    let mut next = move || {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        x
    };
    let bytes = |n: usize, next: &mut dyn FnMut() -> u64| -> Vec<u8> {
        (0..n).map(|_| (next() % 251) as u8).collect()
    };
    let floats = |n: usize, next: &mut dyn FnMut() -> u64| -> Vec<f32> {
        (0..n).map(|_| (next() % 1000) as f32 / 250.0 - 2.0).collect()
    };
    vec![
        Tensor::u8(&[1, h, 1, kp], bytes(h * kp, &mut next)),
        Tensor::f32(&[1, h, 1], floats(h, &mut next)),
        Tensor::f32(&[1, h, 1], floats(h, &mut next)),
        Tensor::u8(&[1, h, 1, vp], bytes(h * vp, &mut next)),
        Tensor::f32(&[1, h, 1], floats(h, &mut next)),
        Tensor::f32(&[1, h, 1], floats(h, &mut next)),
    ]
}

struct Waiting {
    req: SimReq,
    swap: Option<SwapHandle>,
    /// Per-layer gather snapshot at swap-out, for the bit-exactness check.
    snapshot: Vec<Vec<Tensor>>,
}

#[derive(Default)]
struct SimOutcome {
    completed: usize,
    ticks: usize,
    preemptions: u64,
    swap_outs: u64,
    swap_ins: u64,
    swap_fallbacks: u64,
    /// Tokens re-run through prefill to resume preempted requests.
    reprefill_tokens: u64,
    prefix_tokens: u64,
    bitexact_checks: u64,
    peak_host_bytes: usize,
    p99_latency_ticks: usize,
}

/// Append `ctx[from..]` into `slot` through the real scatter path.
fn append_ctx(
    cache: &mut PagedKvCache,
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    slot: usize,
    req: &SimReq,
    from: usize,
    to: usize,
) -> anyhow::Result<()> {
    for pos in from..to {
        let tv = token_at(req, pos);
        for (l, sp) in specs.iter().enumerate() {
            let outs = step_outs(cfg, sp, l, pos, tv);
            cache.append_token_outputs(l, slot, &outs, &[1])?;
        }
        cache.advance_pos(slot, 1);
    }
    Ok(())
}

fn run_sim(
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    policy: SwapPolicy,
    swap_mib: Option<f64>,
) -> anyhow::Result<SimOutcome> {
    let mut cache = PagedKvCache::new(
        cfg,
        specs,
        SLOTS,
        S_MAX,
        &PagedOptions {
            total_blocks: Some(POOL_BLOCKS),
            swap_mib,
            swap_policy: policy,
            ..PagedOptions::default()
        },
    )?;
    let mut arrivals = workload(cfg.vocab);
    let mut pending: VecDeque<SimReq> = VecDeque::new();
    let mut resume: VecDeque<Waiting> = VecDeque::new();
    let mut slots: Vec<Option<(SimReq, u64)>> = (0..SLOTS).map(|_| None).collect();
    let mut out = SimOutcome::default();
    let mut latencies: Vec<usize> = Vec::new();
    let mut admit_seq = 0u64;

    while out.completed < N_REQUESTS {
        let tick = out.ticks;
        out.ticks += 1;
        anyhow::ensure!(out.ticks < 100_000, "sim wedged");
        while arrivals.front().map(|r| r.arrived <= tick).unwrap_or(false) {
            pending.push_back(arrivals.pop_front().unwrap());
        }

        // admission: swapped/preempted resumptions first (FIFO), then fresh
        while let Some(slot) = slots.iter().position(|s| s.is_none()) {
            let busy = slots.iter().filter(|s| s.is_some()).count();
            if let Some(mut w) = resume.pop_front() {
                if let Some(h) = w.swap.take() {
                    let mut restored = false;
                    if cache.can_swap_in(&h) {
                        match cache.swap_in(slot, &h) {
                            Ok(()) => {
                                // the tentpole claim: swapped-and-resumed
                                // state is bit-exact vs never-evicted
                                for (l, snap) in w.snapshot.iter().enumerate() {
                                    let now = cache.gather_slot(l, slot)?;
                                    anyhow::ensure!(
                                        &now == snap,
                                        "swap round trip diverged (layer {l})"
                                    );
                                    out.bitexact_checks += 1;
                                }
                                cache.release_swap(h);
                                out.swap_ins += 1;
                                restored = true;
                            }
                            Err(_) => {
                                // linked prefix pages recycled: recompute
                                cache.release_swap(h);
                                out.swap_fallbacks += 1;
                            }
                        }
                    } else if busy > 0 {
                        w.swap = Some(h);
                        resume.push_front(w);
                        break;
                    } else {
                        cache.release_swap(h);
                        out.swap_fallbacks += 1; // recompute below
                    }
                    if restored {
                        admit_seq += 1;
                        slots[slot] = Some((w.req, admit_seq));
                        continue;
                    }
                }
                // recompute resume
                let ctx_len = w.req.prompt.len() + w.req.generated;
                if !cache.can_admit(ctx_len, w.req.gen_target - w.req.generated) {
                    anyhow::ensure!(busy > 0, "sim pool too small for one request");
                    resume.push_front(w);
                    break;
                }
                let ctx: Vec<i32> = (0..ctx_len).map(|p| token_at(&w.req, p)).collect();
                let reused = cache.prefill_reuse(slot, &ctx);
                out.prefix_tokens += reused as u64;
                append_ctx(&mut cache, cfg, specs, slot, &w.req, reused, ctx_len)?;
                cache.register_prefix(slot, &ctx);
                out.reprefill_tokens += (ctx_len - reused) as u64;
                admit_seq += 1;
                slots[slot] = Some((w.req, admit_seq));
                continue;
            }
            let Some(req) = pending.front() else { break };
            if !cache.can_admit(req.prompt.len(), req.gen_target) {
                anyhow::ensure!(
                    busy > 0 || !resume.is_empty(),
                    "sim pool too small for one request"
                );
                break;
            }
            let req = pending.pop_front().unwrap();
            let reused = cache.prefill_reuse(slot, &req.prompt);
            out.prefix_tokens += reused as u64;
            append_ctx(&mut cache, cfg, specs, slot, &req, reused, req.prompt.len())?;
            cache.register_prefix(slot, &req.prompt);
            admit_seq += 1;
            slots[slot] = Some((req, admit_seq));
        }

        // preemption: cost-aware victim, swap-vs-recompute per victim
        loop {
            let active: Vec<usize> =
                slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|_| i)).collect();
            if active.is_empty() || cache.decode_block_shortfall(&active) == 0 {
                break;
            }
            anyhow::ensure!(active.len() > 1, "sim pool too small for one request");
            let victim = *active
                .iter()
                .max_by_key(|&&i| {
                    let (req, seq) = slots[i].as_ref().unwrap();
                    (victim_score(cache.slot_pages(i), req.gen_target - req.generated), *seq)
                })
                .unwrap();
            let (req, _) = slots[victim].take().unwrap();
            let action = choose_preempt_action(
                policy,
                cache.swap_enabled(),
                cache.swap_out_bytes(victim),
                req.prompt.len() + req.generated.saturating_sub(1),
                cache.per_token_kv_bytes(),
                PREFILL_CHUNK,
            );
            out.preemptions += 1;
            let mut swapped = None;
            if action == PreemptAction::SwapOut {
                let snapshot: Vec<Vec<Tensor>> = (0..specs.len())
                    .map(|l| cache.gather_slot(l, victim))
                    .collect::<anyhow::Result<_>>()?;
                match cache.swap_out(victim) {
                    Ok(h) => {
                        out.swap_outs += 1;
                        swapped = Some((h, snapshot));
                    }
                    Err(_) => out.swap_fallbacks += 1, // host arena full
                }
            }
            match swapped {
                Some((h, snapshot)) => {
                    resume.push_back(Waiting { req, swap: Some(h), snapshot });
                }
                None => {
                    cache.reset_slot(victim);
                    resume.push_back(Waiting { req, swap: None, snapshot: Vec::new() });
                }
            }
        }

        // decode tick: one token per active slot via the real scatter path
        let active: Vec<usize> =
            slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|_| i)).collect();
        out.peak_host_bytes = out.peak_host_bytes.max(cache.mem_stats().host_bytes_used);
        for &i in &active {
            let (pos, tv) = {
                let (req, _) = slots[i].as_ref().unwrap();
                let pos = req.prompt.len() + req.generated;
                (pos, token_at(req, pos))
            };
            for (l, sp) in specs.iter().enumerate() {
                let outs = step_outs(cfg, sp, l, pos, tv);
                cache.append_token_outputs(l, i, &outs, &[1])?;
            }
            cache.advance_pos(i, 1);
            let done = {
                let (req, _) = slots[i].as_mut().unwrap();
                req.generated += 1;
                req.generated >= req.gen_target
            };
            if done {
                let (req, _) = slots[i].take().unwrap();
                latencies.push(tick - req.arrived);
                cache.reset_slot(i);
                out.completed += 1;
            }
        }
    }
    latencies.sort_unstable();
    out.p99_latency_ticks = latencies[((latencies.len() - 1) as f64 * 0.99).round() as usize];
    let st = cache.swap_stats();
    anyhow::ensure!(st.swap_outs == out.swap_outs && st.swap_ins == out.swap_ins);
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let cfg = sim_cfg();
    let nl = cfg.n_layers;
    let tuned: Vec<LayerSpec> = (0..nl)
        .map(|l| LayerSpec {
            mode: Mode::Token,
            pair: if l == 0 || l + 1 == nl {
                PrecisionPair::new(8, 4)
            } else {
                PrecisionPair::new(4, 2)
            },
        })
        .collect();
    let settings: Vec<(String, Vec<LayerSpec>)> = vec![
        ("K8V4".into(), LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 4), nl)),
        ("KVTuner-style mix".into(), tuned),
    ];
    let arms: [(&str, SwapPolicy, Option<f64>); 3] = [
        ("recompute", SwapPolicy::Off, None),
        ("swap-auto", SwapPolicy::Auto, Some(2.0)),
        ("swap-always", SwapPolicy::Always, Some(2.0)),
    ];

    let mut t = Table::with_headers(
        &format!(
            "table9_swap — preemption policy under an oversubscribed pool \
             ({POOL_BLOCKS} pages, {SLOTS} slots, {N_REQUESTS} mixed reqs, s_max={S_MAX})"
        ),
        vec![
            "setting".into(),
            "arm".into(),
            "completed".into(),
            "ticks".into(),
            "p99 lat".into(),
            "preempt".into(),
            "swap out/in".into(),
            "reprefill tok".into(),
            "reuse tok".into(),
            "host peak KiB".into(),
        ],
    );

    for (label, specs) in &settings {
        let mut per_arm: Vec<SimOutcome> = Vec::new();
        for (arm, policy, swap_mib) in &arms {
            let o = run_sim(&cfg, specs, *policy, *swap_mib)?;
            t.row(vec![
                label.clone(),
                arm.to_string(),
                o.completed.to_string(),
                o.ticks.to_string(),
                o.p99_latency_ticks.to_string(),
                o.preemptions.to_string(),
                format!("{}/{}", o.swap_outs, o.swap_ins),
                o.reprefill_tokens.to_string(),
                o.prefix_tokens.to_string(),
                format!("{:.0}", o.peak_host_bytes as f64 / 1024.0),
            ]);
            per_arm.push(o);
        }
        let (off, auto) = (&per_arm[0], &per_arm[1]);
        // the acceptance claims, checked on every run
        assert_eq!(off.completed, N_REQUESTS, "{label}: recompute arm must drain");
        assert_eq!(auto.completed, N_REQUESTS, "{label}: swap arm must drain");
        assert!(off.preemptions >= 1, "{label}: workload must exercise preemption");
        assert!(
            off.reprefill_tokens > 0,
            "{label}: recompute-only preemption must pay re-prefill tokens"
        );
        assert!(auto.swap_ins >= 1, "{label}: cost model must swap at least one victim");
        assert!(
            auto.bitexact_checks >= 1,
            "{label}: swapped resumes must be verified bit-exact"
        );
        assert!(
            auto.reprefill_tokens < off.reprefill_tokens,
            "{label}: swapping must save re-prefill tokens ({} vs {})",
            auto.reprefill_tokens,
            off.reprefill_tokens
        );
        eprintln!(
            "[table9_swap] {label}: swap-auto re-prefilled {} tokens vs {} recompute-only \
             ({} swaps, {} bit-exact checks, p99 {} vs {} ticks)",
            auto.reprefill_tokens,
            off.reprefill_tokens,
            auto.swap_ins,
            auto.bitexact_checks,
            auto.p99_latency_ticks,
            off.p99_latency_ticks,
        );
    }
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());
    println!(
        "\nswap arm: preemption victims are chosen by pages_held x remaining_tokens and \
         evicted to a host arena in packed quantized form; prefix-indexed pages re-link \
         on resume instead of copying. Recompute-only preemption re-runs the whole \
         context through prefill per resume — the re-prefill token column is the work \
         the host tier saves."
    );
    Ok(())
}
