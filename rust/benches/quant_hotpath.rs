//! Bench: hot-path micro-benchmarks — Rust-native quantization/packing
//! (tuner substrate), PJRT layer-step latency per precision pair, and the
//! KIVI commit path. The §Perf iteration log in EXPERIMENTS.md is driven by
//! these numbers. Run: `cargo bench --bench quant_hotpath`

use std::sync::Arc;

use kvtuner::config::{LayerSpec, Mode, PrecisionPair};
use kvtuner::engine::Engine;
use kvtuner::kvcache::CacheBackend;
use kvtuner::quant::{quantize_per_channel, quantize_per_token};
use kvtuner::runtime::Runtime;
use kvtuner::util::bench::{bench, BenchStats};
use kvtuner::util::json::{arr, num, obj, s};
use kvtuner::util::rng::Rng;

/// One machine-readable line for the collected stats (the table benches emit
/// `Table::to_json`; this bench has no table, so it serializes the stats).
fn emit(stats: &[BenchStats]) {
    let doc = obj(vec![
        ("title", s("quant_hotpath")),
        (
            "stats",
            arr(stats.iter().map(|b| {
                obj(vec![
                    ("name", s(b.name.as_str())),
                    ("mean", num(b.mean)),
                    ("p50", num(b.p50)),
                    ("p95", num(b.p95)),
                    ("min", num(b.min)),
                    ("iters", num(b.iters as f64)),
                ])
            })),
        ),
    ]);
    println!("BENCH_JSON {}", doc.to_string_compact());
}

fn main() -> anyhow::Result<()> {
    let mut stats = Vec::new();
    // ---- Rust-native quant substrate (profiler hot path) ----
    let (t, dh) = (512usize, 64usize);
    let mut rng = Rng::seed(3);
    let x: Vec<f32> = (0..t * dh).map(|_| rng.normal() as f32).collect();
    for bits in [2u8, 4, 8] {
        stats.push(bench(&format!("quantize_per_token {t}x{dh} @{bits}bit"), 3, 30, || {
            let q = quantize_per_token(&x, t, dh, bits).unwrap();
            std::hint::black_box(&q.codes);
        }));
        stats.push(bench(&format!("quantize_per_channel {t}x{dh} @{bits}bit"), 3, 30, || {
            let q = quantize_per_channel(&x, t, dh, bits).unwrap();
            std::hint::black_box(&q.codes);
        }));
    }
    let q = quantize_per_token(&x, t, dh, 4).unwrap();
    stats.push(bench(&format!("dequantize {t}x{dh} @4bit"), 3, 30, || {
        std::hint::black_box(q.dequantize());
    }));

    // ---- PJRT engine step latency per precision pair ----
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP PJRT benches: artifacts missing");
        emit(&stats);
        return Ok(());
    }
    let rt = Arc::new(Runtime::load(&dir)?);
    let cfg = rt.manifest.config.clone();
    let batch = *rt.manifest.decode_batches().last().unwrap_or(&1);
    for (label, mode, k, v) in [
        ("fp16", Mode::Fp, 16u8, 16u8),
        ("token KV8", Mode::Token, 8, 8),
        ("token KV2", Mode::Token, 2, 2),
        ("kivi K4V2", Mode::Kivi, 4, 2),
    ] {
        let specs = LayerSpec::uniform(mode, PrecisionPair::new(k, v), cfg.n_layers);
        let mut eng = Engine::new(rt.clone(), &cfg.name, specs, batch, 256, 32)?;
        // half-full cache
        for slot in 0..batch {
            eng.cache.synthetic_fill(slot, 128)?;
        }
        let tokens = vec![1i32; batch];
        let active = vec![true; batch];
        eng.decode_step(&tokens, &active)?;
        stats.push(bench(&format!("decode_step b{batch} s256 fill128 [{label}]"), 2, 20, || {
            eng.decode_step(&tokens, &active).unwrap();
        }));
    }

    // ---- prefill path ----
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let mut eng = Engine::new(rt.clone(), &cfg.name, specs, batch, 256, 32)?;
    let prompt: Vec<i32> = (0..96).map(|i| (i % cfg.vocab) as i32).collect();
    stats.push(bench("prefill 96 tokens (kivi K4V2, chunked 32)", 1, 10, || {
        eng.cache.reset_slot(0);
        eng.prefill(0, &prompt).unwrap();
    }));
    emit(&stats);
    Ok(())
}
