//! Bench: regenerate Table 3 + Fig 3 — model-averaged and layer-wise
//! relative attention output error e_o per precision pair (offline
//! simulation, no accumulation). Run: `cargo bench --bench table3_eo`

use kvtuner::config::{Mode, PrecisionPair};
use kvtuner::model::Weights;
use kvtuner::tuner::{calib, profiler};
use kvtuner::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table3: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let manifest = kvtuner::config::Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let w = Weights::load(&manifest, &cfg.name)?;
    let prompts = calib::calib_set(cfg.vocab, 6, 48, 2024);
    let modes = [Mode::Token, Mode::Kivi];
    let prof = profiler::profile(&cfg, &w, &prompts, &modes)?;

    let pairs = [
        PrecisionPair::new(8, 8), PrecisionPair::new(8, 4), PrecisionPair::new(8, 2),
        PrecisionPair::new(4, 8), PrecisionPair::new(4, 4), PrecisionPair::new(4, 2),
        PrecisionPair::new(2, 8), PrecisionPair::new(2, 4), PrecisionPair::new(2, 2),
    ];

    // Table 3 — model-averaged e_o per pair
    for mode in modes {
        let mut t = Table::with_headers(
            &format!("Table 3 — relative attention output error e_o ({})", mode.as_str()),
            {
                let mut h = vec!["metric".to_string()];
                h.extend(pairs.iter().map(|p| p.label()));
                h
            },
        );
        let mut row = vec!["e_o".to_string()];
        for pair in pairs {
            row.push(format!("{:.3}", prof.model_avg(mode, pair).e_o));
        }
        t.row(row);
        t.print();
        println!("BENCH_JSON {}", t.to_json().to_string_compact());
    }

    // Fig 3 — layer-wise e_a per key precision (value at 8-bit)
    let mut tf = Table::with_headers("Fig 3 — layer-wise attention score error e_a (per-token-asym)", {
        let mut h = vec!["key bits".to_string()];
        h.extend((0..cfg.n_layers).map(|l| format!("L{l}")));
        h
    });
    for kb in [8u8, 4, 2] {
        let series = prof.layer_series_ea(Mode::Token, PrecisionPair::new(kb, 8));
        let mut row = vec![format!("K{kb}")];
        row.extend(series.iter().map(|v| format!("{v:.5}")));
        tf.row(row);
    }
    tf.print();
    println!("BENCH_JSON {}", tf.to_json().to_string_compact());

    // paper shape checks (report the measured direction honestly)
    let k4v2 = prof.model_avg(Mode::Token, PrecisionPair::new(4, 2)).e_o;
    let k2v4 = prof.model_avg(Mode::Token, PrecisionPair::new(2, 4)).e_o;
    println!(
        "\npaper shape check (token mode): K4V2 e_o = {k4v2:.3} vs K2V4 e_o = {k2v4:.3} — {}",
        if k4v2 < k2v4 { "key matters more ✓" } else { "≈ tie on this substrate" }
    );
    let k4v8 = prof.model_avg(Mode::Kivi, PrecisionPair::new(4, 8)).e_o;
    let k8v4 = prof.model_avg(Mode::Kivi, PrecisionPair::new(8, 4)).e_o;
    println!(
        "kivi mode: K4V8 e_o = {k4v8:.3} vs K8V4 e_o = {k8v4:.3} — {}",
        if k4v8 < k8v4 {
            "per-channel keys tolerate 4-bit (paper Table 4's K4V8-preferring layers) ✓"
        } else {
            "K8V4 preferred"
        }
    );
    let e8 = prof.model_avg(Mode::Token, PrecisionPair::new(8, 8)).e_a;
    let e4 = prof.model_avg(Mode::Token, PrecisionPair::new(4, 4)).e_a;
    let e2 = prof.model_avg(Mode::Token, PrecisionPair::new(2, 2)).e_a;
    println!(
        "attention score error degradation: 8->4 bit = {:.1}x, 4->2 bit = {:.1}x \
         (paper Fig 3: 13.9x and 4.6x)",
        e4 / e8.max(1e-12),
        e2 / e4.max(1e-12)
    );
    Ok(())
}
