//! Bench: dense vs paged KV cache capacity at a fixed memory budget.
//!
//! For each precision map, the dense arm's footprint at `DENSE_SLOTS` slots
//! defines the `kv_bytes` budget; the paged arm gets the *same* budget as a
//! page pool but runs `PAGED_SLOTS` scheduler slots. A synthetic open-loop
//! workload (mixed prompt lengths, a shared system prefix, a couple of
//! long-running generations) is driven through the real allocator and the
//! real admission/preemption/prefix policies — exactly the scheduler's
//! logic, with page writes instead of PJRT layer steps, so this runs with or
//! without artifacts. Run: `cargo bench --bench table8_paged`
//!
//! The claim under test: at equal kv_bytes, the paged arm keeps more
//! requests in flight than the dense arm has slots, exercising preemption
//! and prefix reuse along the way.

use std::collections::VecDeque;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::kvcache::{CacheBackend, KvCache, PagedKvCache, PagedOptions};
use kvtuner::quant::packed_width;
use kvtuner::tensor::Tensor;
use kvtuner::util::bench::Table;

const S_MAX: usize = 256;
const DENSE_SLOTS: usize = 2;
const PAGED_SLOTS: usize = 6;

fn sim_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        n_layers: 4,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 32,
        d_ff: 128,
        vocab: 256,
        rope_theta: 10000.0,
        group: 32,
        residual: 32,
        rms_eps: 1e-5,
    }
}

struct SimReq {
    prompt: Vec<i32>,
    gen_target: usize,
    generated: usize,
}

struct SimOutcome {
    completed: usize,
    peak_inflight: usize,
    preemptions: u64,
    prefix_tokens: u64,
    peak_frag: usize,
    ticks: usize,
}

/// Mixed workload: common 64-token system prefix on every third request, a
/// couple of long generations that force page-pool pressure mid-flight.
fn workload(vocab: usize) -> VecDeque<SimReq> {
    let system: Vec<i32> = (0..64).map(|i| (i * 7 % vocab) as i32).collect();
    (0..16)
        .map(|i| {
            let mut prompt = if i % 3 == 0 {
                system.clone()
            } else {
                (0..48 + (i % 4) * 16).map(|j| ((j * 11 + i) % vocab) as i32).collect()
            };
            prompt.extend((0..8).map(|j| ((j + i * 13) % vocab) as i32));
            SimReq { prompt, gen_target: if i % 7 == 3 { 128 } else { 32 }, generated: 0 }
        })
        .collect()
}

/// Per-layer single-token append tensors (token mode), content irrelevant.
fn decode_outs(cfg: &ModelConfig, spec: &LayerSpec) -> anyhow::Result<Vec<Tensor>> {
    let (h, dh) = (cfg.n_kv_heads, cfg.head_dim);
    let kp = packed_width(dh, spec.pair.k_bits)?;
    let vp = packed_width(dh, spec.pair.v_bits)?;
    Ok(vec![
        Tensor::u8(&[1, h, 1, kp], vec![3; h * kp]),
        Tensor::f32(&[1, h, 1], vec![0.5; h]),
        Tensor::f32(&[1, h, 1], vec![0.1; h]),
        Tensor::u8(&[1, h, 1, vp], vec![5; h * vp]),
        Tensor::f32(&[1, h, 1], vec![0.5; h]),
        Tensor::f32(&[1, h, 1], vec![0.1; h]),
    ])
}

/// Drive the scheduler's admission/preemption/prefix policy against a cache
/// backend, slot-for-slot, with page writes standing in for layer steps.
fn run_sim(
    cache: &mut dyn CacheBackend,
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    n_slots: usize,
) -> anyhow::Result<SimOutcome> {
    let outs: Vec<Vec<Tensor>> =
        specs.iter().map(|sp| decode_outs(cfg, sp)).collect::<anyhow::Result<_>>()?;
    let mut queue = workload(cfg.vocab);
    let mut resume: VecDeque<SimReq> = VecDeque::new();
    let mut slots: Vec<Option<(SimReq, u64)>> = (0..n_slots).map(|_| None).collect();
    let mut out = SimOutcome {
        completed: 0,
        peak_inflight: 0,
        preemptions: 0,
        prefix_tokens: 0,
        peak_frag: 0,
        ticks: 0,
    };
    let mut admit_seq = 0u64;
    let total = queue.len();

    while out.completed < total {
        out.ticks += 1;
        anyhow::ensure!(out.ticks < 100_000, "sim wedged");

        // admission: resumptions first, then FIFO; gate on page availability
        while let Some(slot) = slots.iter().position(|s| s.is_none()) {
            let from_resume = !resume.is_empty();
            let Some(req) = (if from_resume { resume.front() } else { queue.front() }) else {
                break;
            };
            let ctx_len = req.prompt.len() + req.generated;
            if !cache.can_admit(ctx_len, req.gen_target - req.generated) {
                break;
            }
            let req = if from_resume {
                resume.pop_front().unwrap()
            } else {
                queue.pop_front().unwrap()
            };
            let mut ctx = req.prompt.clone();
            ctx.extend((0..req.generated).map(|i| (i % cfg.vocab) as i32));
            let reused = cache.prefill_reuse(slot, &ctx);
            out.prefix_tokens += reused as u64;
            cache.synthetic_fill(slot, ctx.len())?;
            cache.register_prefix(slot, &req.prompt);
            admit_seq += 1;
            slots[slot] = Some((req, admit_seq));
        }

        // preemption: evict the youngest until the decode step fits
        loop {
            let active: Vec<usize> =
                slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|_| i)).collect();
            if active.is_empty() || cache.decode_block_shortfall(&active) == 0 {
                break;
            }
            anyhow::ensure!(active.len() > 1, "sim pool too small for one request");
            let victim = *active
                .iter()
                .max_by_key(|&&i| slots[i].as_ref().unwrap().1)
                .unwrap();
            let (req, _) = slots[victim].take().unwrap();
            cache.reset_slot(victim);
            resume.push_front(req);
            out.preemptions += 1;
        }

        // decode tick: one token per active slot, via the real scatter path
        let active: Vec<usize> =
            slots.iter().enumerate().filter_map(|(i, s)| s.as_ref().map(|_| i)).collect();
        out.peak_inflight = out.peak_inflight.max(active.len());
        out.peak_frag = out.peak_frag.max(cache.mem_stats().frag_bytes);
        for &i in &active {
            for (l, o) in outs.iter().enumerate() {
                cache.append_token_outputs(l, i, o, &[1])?;
            }
            let done = {
                let (req, _) = slots[i].as_mut().unwrap();
                req.generated += 1;
                req.generated >= req.gen_target
            };
            if done {
                slots[i] = None;
                cache.reset_slot(i);
                out.completed += 1;
            }
        }
    }
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let cfg = sim_cfg();
    let nl = cfg.n_layers;
    let tuned: Vec<LayerSpec> = (0..nl)
        .map(|l| LayerSpec {
            mode: Mode::Token,
            pair: if l == 0 || l + 1 == nl {
                PrecisionPair::new(8, 4)
            } else {
                PrecisionPair::new(4, 2)
            },
        })
        .collect();
    let settings: Vec<(String, Vec<LayerSpec>)> = vec![
        ("K8V4".into(), LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 4), nl)),
        ("K4V2".into(), LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 2), nl)),
        ("KVTuner-style mix".into(), tuned),
    ];

    let mut t = Table::with_headers(
        &format!(
            "table8_paged — capacity at equal kv_bytes (dense {DENSE_SLOTS} slots vs \
             paged {PAGED_SLOTS} slots, s_max={S_MAX})"
        ),
        vec![
            "setting".into(),
            "budget KiB".into(),
            "arm".into(),
            "peak in-flight".into(),
            "completed".into(),
            "preempt".into(),
            "reuse tok".into(),
            "peak frag KiB".into(),
        ],
    );

    for (label, specs) in &settings {
        // the dense arm's footprint IS the shared budget
        let mut dense = KvCache::new(&cfg, specs, DENSE_SLOTS, S_MAX)?;
        let budget = CacheBackend::kv_bytes(&dense);
        let d = run_sim(&mut dense, &cfg, specs, DENSE_SLOTS)?;
        t.row(vec![
            label.clone(),
            format!("{:.0}", budget as f64 / 1024.0),
            "dense".into(),
            d.peak_inflight.to_string(),
            d.completed.to_string(),
            d.preemptions.to_string(),
            d.prefix_tokens.to_string(),
            format!("{:.0}", d.peak_frag as f64 / 1024.0),
        ]);

        let mut paged = PagedKvCache::new(
            &cfg,
            specs,
            PAGED_SLOTS,
            S_MAX,
            &PagedOptions {
                budget_mib: Some(budget as f64 / (1024.0 * 1024.0)),
                ..PagedOptions::default()
            },
        )?;
        assert!(
            CacheBackend::kv_bytes(&paged) <= budget,
            "paged arm must fit the dense budget"
        );
        let p = run_sim(&mut paged, &cfg, specs, PAGED_SLOTS)?;
        t.row(vec![
            label.clone(),
            format!("{:.0}", CacheBackend::kv_bytes(&paged) as f64 / 1024.0),
            "paged".into(),
            p.peak_inflight.to_string(),
            p.completed.to_string(),
            p.preemptions.to_string(),
            p.prefix_tokens.to_string(),
            format!("{:.0}", p.peak_frag as f64 / 1024.0),
        ]);

        // the tentpole claims, checked on every run
        assert_eq!(d.completed, 16);
        assert_eq!(p.completed, 16);
        assert!(
            p.peak_inflight > DENSE_SLOTS,
            "{label}: paged peak {} must beat the dense slot count {DENSE_SLOTS}",
            p.peak_inflight
        );
        assert!(p.preemptions >= 1, "{label}: workload must exercise preemption");
        assert!(p.prefix_tokens > 0, "{label}: shared prefixes must be reused");
        eprintln!(
            "[table8_paged] {label}: paged {}x in-flight at the dense budget \
             ({} preemptions, {} prefix tokens reused, {} ticks vs {})",
            p.peak_inflight, p.preemptions, p.prefix_tokens, p.ticks, d.ticks
        );
    }
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());
    println!(
        "\npaged arm: same kv_bytes budget, {PAGED_SLOTS} scheduler slots over a page pool \
         (dense reserves {DENSE_SLOTS}x s_max up front). Oversubscription is reconciled by \
         youngest-first preemption + re-prefill; common prompt prefixes are served from \
         shared refcounted pages."
    );
    Ok(())
}
