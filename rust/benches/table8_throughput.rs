//! Bench: regenerate Table 8 — decode throughput (tokens/s) across KV
//! precision settings × context lengths, KV8 as baseline, including the
//! paper's "+X%" column. Run: `cargo bench --bench table8_throughput`
//! (env: KVTUNER_BATCH, KVTUNER_LENS, KVTUNER_STEPS to widen the grid).

use std::sync::Arc;

use kvtuner::runtime::Runtime;
use kvtuner::util::bench::Table;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table8: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let rt = Arc::new(Runtime::load(&dir)?);
    let cfg = rt.manifest.config.clone();
    let batch = env_usize("KVTUNER_BATCH", *rt.manifest.decode_batches().last().unwrap_or(&1));
    let s_max = env_usize("KVTUNER_SMAX", 256);
    let steps = env_usize("KVTUNER_STEPS", 30);
    let lens: Vec<usize> = std::env::var("KVTUNER_LENS")
        .unwrap_or_else(|_| "64,128,192".into())
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    // uniform KIVI settings (the paper's Table 8 grid) + a tuned-style map
    let mut settings = kvtuner::cli_settings_grid(cfg.n_layers)?;
    settings.push(("KVTuner-style mix".into(), kvtuner::tuned_style_map(cfg.n_layers)));

    let mut t = Table::with_headers(
        &format!("Table 8 — decode throughput, batch={batch}, s_max={s_max}, steps={steps}"),
        {
            let mut h = vec!["setting".to_string(), "bits".into(), "KV MiB".into()];
            h.extend(lens.iter().map(|l| format!("len={l} tok/s")));
            h.push("HBM-proj tok/s".into());
            h.push("vs KV8 (proj)".into());
            h
        },
    );
    let mut baseline: Vec<f64> = Vec::new();
    for (i, (label, specs)) in settings.iter().enumerate() {
        let mut tps_list = Vec::new();
        let mut bits = 0.0;
        let mut mib = 0.0;
        let mut proj = 0.0;
        const HBM_BW: f64 = 1.5e12; // A100-class HBM bandwidth
        for &il in &lens {
            let r = kvtuner::measure_throughput(&rt, &cfg.name, specs.clone(), batch, s_max, il, steps)?;
            bits = r.equiv_bits;
            mib = r.kv_mib;
            proj = r.projected_tps(batch, HBM_BW);
            tps_list.push(r.toks_per_sec);
        }
        if i == 0 {
            baseline = vec![proj];
        }
        let mut row = vec![label.clone(), format!("{bits:.2}"), format!("{mib:.2}")];
        row.extend(tps_list.iter().map(|t| format!("{t:.0}")));
        row.push(format!("{:.2e}", proj));
        row.push(format!("{:+.1}%", (proj / baseline[0] - 1.0) * 100.0));
        t.row(row);
        eprintln!("[table8] {label} done");
    }
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());
    println!(
        "\nmeasured CPU tok/s is compute-dominated post-optimization (fixed dispatch +\n\
         unpack work); the HBM-projected column — tokens/s when each step reads the live\n\
         KV cache once at A100-class bandwidth, the paper's memory-bound decode regime —\n\
         reproduces Table 8's ordering: lower equivalent bits -> proportionally higher\n\
         throughput, with the tuned mix between its min/max pairs."
    );
    Ok(())
}
