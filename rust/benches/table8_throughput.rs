//! Bench: decode throughput.
//!
//! Two arms:
//!
//! * **Native continuous-batching curve** (always runs, zero artifacts):
//!   decode tokens/s over the batched native engine at 1/2/4 active slots,
//!   against the same four requests stepped one-at-a-time through the
//!   sequential oracle (`decode_step_sequential`) — the "no continuous
//!   batching" baseline. The curve must be monotone nondecreasing in batch
//!   size, every batched stream must be bit-identical to the sequential
//!   one, and on hosts with ≥4 hardware threads batch-4 must beat the
//!   sequential ×4 arm by ≥1.5×.
//! * **Table 8 reproduction** (`xla` feature + artifacts): tokens/s across
//!   KV precision settings × context lengths, KV8 as baseline, including
//!   the paper's "+X%" column.
//!
//! Run: `cargo bench --bench table8_throughput`
//! (env: KVTUNER_BATCH, KVTUNER_LENS, KVTUNER_STEPS widen the xla grid;
//! KVTUNER_NATIVE_STEPS the native one).

use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::engine::NativeEngine;
use kvtuner::kvcache::PagedOptions;
use kvtuner::model::Weights;
use kvtuner::util::bench::Table;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

const NATIVE_S_MAX: usize = 128;
const NATIVE_PROMPT: usize = 64;
const NATIVE_BATCHES: [usize; 3] = [1, 2, 4];
/// Best-of per arm, so one scheduling hiccup on a shared runner cannot
/// invert the curve.
const REPS: usize = 3;

fn best_of(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
    (0..reps).map(|_| f()).fold(f64::MIN, f64::max)
}

/// Weight streaming dominates each decode step, so folding slots into one
/// `[nb, d]`-row pass per layer is the measurable win.
fn sim_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim-batch".into(),
        n_layers: 6,
        d_model: 128,
        n_heads: 8,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 512,
        vocab: 8192,
        rope_theta: 10000.0,
        group: 32,
        residual: 32,
        rms_eps: 1e-5,
    }
}

/// The continuous-batching decode curve: aggregate tokens/s at 1/2/4 active
/// slots through the batched path, vs 4 slots through the sequential
/// per-slot oracle.
fn native_batch_curve() -> anyhow::Result<()> {
    let cfg = sim_cfg();
    let w = Weights::synthetic(&cfg, 13);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.min(4);
    let steps = env_usize("KVTUNER_NATIVE_STEPS", 32);
    let max_b = *NATIVE_BATCHES.last().unwrap();

    let mk = || {
        NativeEngine::new(
            &cfg,
            w.clone(),
            specs.clone(),
            max_b,
            NATIVE_S_MAX,
            32,
            threads,
            Some(PagedOptions::default()),
        )
        .unwrap()
    };
    let prompt_for = |slot: usize| -> Vec<i32> {
        (0..NATIVE_PROMPT).map(|j| ((j * 31 + 17 * slot + 7) % cfg.vocab) as i32).collect()
    };

    // sequential oracle arm: the same four requests, each stepped on its own
    let (seq_tps, seq_streams) = {
        let mut streams_out: Vec<Vec<i32>> = Vec::new();
        let tps = best_of(REPS, || {
            let mut e = mk();
            e.set_sequential_decode(true);
            let mut tokens = vec![0i32; max_b];
            for (b, t) in tokens.iter_mut().enumerate() {
                *t = e.prefill(b, &prompt_for(b)).unwrap();
            }
            let active = vec![true; max_b];
            let mut streams = vec![Vec::with_capacity(steps); max_b];
            let t0 = Instant::now();
            for _ in 0..steps {
                let next = e.decode_step(&tokens, &active).unwrap();
                for b in 0..max_b {
                    streams[b].push(next[b]);
                    tokens[b] = next[b];
                }
            }
            let tps = (max_b * steps) as f64 / t0.elapsed().as_secs_f64();
            streams_out = streams;
            tps
        });
        (tps, streams_out)
    };

    let measure_batched = |nb: usize, seq_streams: &[Vec<i32>]| -> f64 {
        best_of(REPS, || {
            let mut e = mk();
            let mut tokens = vec![0i32; max_b];
            let mut active = vec![false; max_b];
            for (b, a) in active.iter_mut().enumerate().take(nb) {
                tokens[b] = e.prefill(b, &prompt_for(b)).unwrap();
                *a = true;
            }
            let mut streams = vec![Vec::with_capacity(steps); nb];
            let t0 = Instant::now();
            for _ in 0..steps {
                let next = e.decode_step(&tokens, &active).unwrap();
                for (b, s) in streams.iter_mut().enumerate() {
                    s.push(next[b]);
                    tokens[b] = next[b];
                }
            }
            let tps = (nb * steps) as f64 / t0.elapsed().as_secs_f64();
            for (b, s) in streams.iter().enumerate() {
                assert_eq!(
                    s, &seq_streams[b],
                    "batch {nb} slot {b}: batched decode diverged from the sequential oracle"
                );
            }
            tps
        })
    };
    let mut tps: Vec<f64> =
        NATIVE_BATCHES.iter().map(|&nb| measure_batched(nb, &seq_streams)).collect();

    // folding more slots into each layer pass must never lose aggregate
    // throughput; one re-measure before declaring failure
    for i in 1..tps.len() {
        if tps[i] < tps[i - 1] {
            tps[i] = tps[i].max(measure_batched(NATIVE_BATCHES[i], &seq_streams));
        }
        assert!(
            tps[i] >= tps[i - 1],
            "batched decode curve not monotone: {:.1} tok/s at batch {} < {:.1} at batch {}",
            tps[i],
            NATIVE_BATCHES[i],
            tps[i - 1],
            NATIVE_BATCHES[i - 1]
        );
    }
    let mut batched_vs_seq = tps[tps.len() - 1] / seq_tps;
    if hw >= 4 {
        // one re-measure of the batched arm before declaring failure: a
        // shared-runner stall can depress a whole best-of round
        if batched_vs_seq < 1.5 {
            let last = NATIVE_BATCHES.len() - 1;
            tps[last] = tps[last].max(measure_batched(max_b, &seq_streams));
            batched_vs_seq = tps[last] / seq_tps;
        }
        assert!(
            batched_vs_seq >= 1.5,
            "continuous batching must deliver ≥1.5× the sequential ×{max_b} arm on a \
             ≥4-thread host (got {batched_vs_seq:.2}×)"
        );
    } else {
        eprintln!(
            "[table8] host has {hw} threads (<4): reporting the batch-4 vs sequential \
             ratio ({batched_vs_seq:.2}×) without the 1.5× floor"
        );
    }

    let mut t = Table::with_headers(
        &format!(
            "table8_native — continuous-batching decode curve ({} layers, d={}, vocab={}, \
             prompt={NATIVE_PROMPT}, {steps} steps, threads={threads}, host threads={hw})",
            cfg.n_layers, cfg.d_model, cfg.vocab
        ),
        vec!["batch".into(), "decode tok/s".into(), "vs batch 1".into()],
    );
    for (i, &nb) in NATIVE_BATCHES.iter().enumerate() {
        t.row(vec![format!("{nb}"), format!("{:.1}", tps[i]), format!("{:.2}x", tps[i] / tps[0])]);
    }
    t.row(vec![
        format!("seq x{max_b}"),
        format!("{seq_tps:.1}"),
        format!("{:.2}x", seq_tps / tps[0]),
    ]);
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());
    println!(
        "\nbatch-{max_b} batched decode vs sequential x{max_b}: {batched_vs_seq:.2}x \
         (bit-identical streams)"
    );
    Ok(())
}

/// Table 8 proper over the PJRT runtime (needs `make artifacts`).
#[cfg(feature = "xla")]
fn xla_table8() -> anyhow::Result<()> {
    use std::sync::Arc;

    use kvtuner::runtime::Runtime;

    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP table8 xla arm: artifacts missing (run `make artifacts`)");
        return Ok(());
    }
    let rt = Arc::new(Runtime::load(&dir)?);
    let cfg = rt.manifest.config.clone();
    let batch = env_usize("KVTUNER_BATCH", *rt.manifest.decode_batches().last().unwrap_or(&1));
    let s_max = env_usize("KVTUNER_SMAX", 256);
    let steps = env_usize("KVTUNER_STEPS", 30);
    let lens: Vec<usize> = std::env::var("KVTUNER_LENS")
        .unwrap_or_else(|_| "64,128,192".into())
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    // uniform KIVI settings (the paper's Table 8 grid) + a tuned-style map
    let mut settings = kvtuner::cli_settings_grid(cfg.n_layers)?;
    settings.push(("KVTuner-style mix".into(), kvtuner::tuned_style_map(cfg.n_layers)));

    let mut t = Table::with_headers(
        &format!("Table 8 — decode throughput, batch={batch}, s_max={s_max}, steps={steps}"),
        {
            let mut h = vec!["setting".to_string(), "bits".into(), "KV MiB".into()];
            h.extend(lens.iter().map(|l| format!("len={l} tok/s")));
            h.push("HBM-proj tok/s".into());
            h.push("vs KV8 (proj)".into());
            h
        },
    );
    let mut baseline: Vec<f64> = Vec::new();
    for (i, (label, specs)) in settings.iter().enumerate() {
        let mut tps_list = Vec::new();
        let mut bits = 0.0;
        let mut mib = 0.0;
        let mut proj = 0.0;
        const HBM_BW: f64 = 1.5e12; // A100-class HBM bandwidth
        for &il in &lens {
            let r = kvtuner::measure_throughput(
                &rt,
                &cfg.name,
                specs.clone(),
                batch,
                s_max,
                il,
                steps,
            )?;
            bits = r.equiv_bits;
            mib = r.kv_mib;
            proj = r.projected_tps(batch, HBM_BW);
            tps_list.push(r.toks_per_sec);
        }
        if i == 0 {
            baseline = vec![proj];
        }
        let mut row = vec![label.clone(), format!("{bits:.2}"), format!("{mib:.2}")];
        row.extend(tps_list.iter().map(|t| format!("{t:.0}")));
        row.push(format!("{:.2e}", proj));
        row.push(format!("{:+.1}%", (proj / baseline[0] - 1.0) * 100.0));
        t.row(row);
        eprintln!("[table8] {label} done");
    }
    t.print();
    println!("BENCH_JSON {}", t.to_json().to_string_compact());
    println!(
        "\nmeasured CPU tok/s is compute-dominated post-optimization (fixed dispatch +\n\
         unpack work); the HBM-projected column — tokens/s when each step reads the live\n\
         KV cache once at A100-class bandwidth, the paper's memory-bound decode regime —\n\
         reproduces Table 8's ordering: lower equivalent bits -> proportionally higher\n\
         throughput, with the tuned mix between its min/max pairs."
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    native_batch_curve()?;
    #[cfg(feature = "xla")]
    xla_table8()?;
    #[cfg(not(feature = "xla"))]
    eprintln!("SKIP table8 xla arm: built without the xla feature");
    Ok(())
}
