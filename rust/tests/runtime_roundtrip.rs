//! Round-trip tests over the real artifacts: python/JAX/Pallas AOT-lowered
//! HLO text, loaded and executed through the PJRT CPU client, diffed against
//! the Rust-native quantization substrate and the reference engine.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use kvtuner::config::{LayerSpec, Mode, PrecisionPair};
use kvtuner::kvcache::CacheBackend;
use kvtuner::model::{RefEngine, Weights};
use kvtuner::quant::{quantize_per_channel, quantize_per_token};
use kvtuner::runtime::Runtime;
use kvtuner::tensor::Tensor;
use kvtuner::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("loading runtime"))
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::seed(seed);
    (0..n).map(|_| r.normal() as f32).collect()
}

#[test]
fn quant_artifact_matches_rust_native() {
    let Some(rt) = runtime() else { return };
    let cfg = &rt.manifest.config;
    let (h, dh, g) = (cfg.n_kv_heads, cfg.head_dim, cfg.group);
    let x = randv(h * g * dh, 7);
    let xt = Tensor::f32(&[1, h, g, dh], x.clone());

    // per-token artifact vs rust
    for bits in [2u8, 4, 8] {
        let name = format!("quant_token_{bits}_b1_c{g}");
        let outs = rt.execute(&name, &[xt.clone()]).expect("exec quant_token");
        assert_eq!(outs.len(), 3);
        for hh in 0..h {
            let off = hh * g * dh;
            let q = quantize_per_token(&x[off..off + g * dh], g, dh, bits).unwrap();
            let dhp = q.codes.len() / g;
            let art_codes = outs[0].as_u8().unwrap();
            assert_eq!(
                &art_codes[hh * g * dhp..(hh + 1) * g * dhp],
                &q.codes[..],
                "codes mismatch bits={bits} head={hh}"
            );
            let art_scale = outs[1].as_f32().unwrap();
            for t in 0..g {
                assert!(
                    (art_scale[hh * g + t] - q.scale[t]).abs() < 1e-6,
                    "scale mismatch bits={bits}"
                );
            }
        }
    }

    // per-channel artifact vs rust
    for bits in [2u8, 4, 8] {
        let name = format!("quant_channel_{bits}_b1_c{g}");
        let outs = rt.execute(&name, &[xt.clone()]).expect("exec quant_channel");
        for hh in 0..h {
            let off = hh * g * dh;
            let q = quantize_per_channel(&x[off..off + g * dh], g, dh, bits).unwrap();
            let dhp = q.codes.len() / g;
            let art_codes = outs[0].as_u8().unwrap();
            assert_eq!(
                &art_codes[hh * g * dhp..(hh + 1) * g * dhp],
                &q.codes[..],
                "codes mismatch bits={bits} head={hh}"
            );
            let art_scale = outs[2].as_f32().unwrap(); // zero = lo
            for d in 0..dh {
                assert!((art_scale[hh * dh + d] - q.zero[d]).abs() < 1e-6);
            }
        }
    }
}

#[test]
fn embed_and_lmhead_artifacts() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.config.clone();
    let w = Weights::load(&rt.manifest, &cfg.name).unwrap();
    let ids = Tensor::i32(&[1, 1], vec![5]);
    let outs = rt
        .execute("embed_b1_t1", &[ids, w.embed().unwrap().clone()])
        .expect("embed exec");
    let emb_row = w.embed().unwrap().as_f32().unwrap();
    let d = cfg.d_model;
    let got = outs[0].as_f32().unwrap();
    assert_eq!(got.len(), d);
    for i in 0..d {
        assert!((got[i] - emb_row[5 * d + i]).abs() < 1e-6);
    }

    let x = Tensor::f32(&[1, d], randv(d, 3));
    let outs = rt
        .execute(
            "lmhead_b1",
            &[x, w.ln_f().unwrap().clone(), w.embed().unwrap().clone()],
        )
        .expect("lmhead exec");
    let logits = outs[0].as_f32().unwrap();
    assert_eq!(logits.len(), cfg.vocab);
    let argmax = outs[1].as_i32().unwrap()[0];
    let best = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(argmax as usize, best);
}

/// The decisive parity test: the PJRT engine (fp cache) and the pure-Rust
/// reference engine run the same model; logits must agree closely when fed
/// the same token stream.
#[test]
fn pjrt_engine_matches_ref_engine_fp() {
    let Some(rt) = runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let cfg = rt.manifest.config.clone();
    let model = cfg.name.clone();
    let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);

    let mut eng = kvtuner::engine::Engine::new(rt.clone(), &model, specs.clone(), 1, 256, 32)
        .expect("engine");
    let w = Weights::load(&rt.manifest, &model).unwrap();
    let mut re = RefEngine::new(&cfg, &w, specs, 256).unwrap();

    // drive both with the same fixed token stream; compare logits each step
    let stream: Vec<i32> = (0..24).map(|i| (i * 37 % cfg.vocab as i32).abs()).collect();
    let mut max_rel = 0f32;
    for (i, &t) in stream.iter().enumerate() {
        let ref_next = re.step(t).unwrap();
        let eng_next = eng.decode_step(&[t], &[true]).unwrap()[0];
        let logits = &eng.last_logits[0];
        // reconstruct ref logits margin check via argmax equality
        if i > 0 {
            let _ = ref_next;
            let _ = eng_next;
        }
        // compare argmax agreement (exact logits live in different engines)
        assert_eq!(eng_next, ref_next, "argmax diverged at step {i}");
        let norm = logits.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm.is_finite() && norm > 0.0);
        max_rel = max_rel.max(0.0);
    }
}

/// Layer-step artifact vs reference engine at the single-layer level, fp mode.
#[test]
fn kivi_engine_residual_semantics() {
    let Some(rt) = runtime() else { return };
    let rt = std::sync::Arc::new(rt);
    let cfg = rt.manifest.config.clone();
    let model = cfg.name.clone();
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers);
    let mut eng = kvtuner::engine::Engine::new(rt.clone(), &model, specs.clone(), 1, 256, 32)
        .expect("engine");

    // run enough steps to force a group commit (group=32)
    let mut t = 1i32;
    for _ in 0..(cfg.group + 4) {
        t = eng.decode_step(&[t], &[true]).unwrap()[0];
    }
    assert_eq!(eng.cache.cache_len(0, 0), cfg.group as i32, "one group committed");
    assert_eq!(eng.cache.res_len(0, 0), 4, "remainder in residual");

    // K8V8 kivi should track the ref engine's kivi arm closely
    let w = Weights::load(&rt.manifest, &model).unwrap();
    let mut re = RefEngine::new(&cfg, &w, specs, 256).unwrap();
    let prompt: Vec<i32> = (1..20).map(|i| (i * 13) % cfg.vocab as i32).collect();
    let ref_out = re.generate(&prompt, 16).unwrap();
    eng.cache.reset_slot(0);
    let eng_out = eng.generate(0, &prompt, 16).unwrap();
    let agree = ref_out.iter().zip(&eng_out).filter(|(a, b)| a == b).count();
    assert!(
        agree >= 12,
        "kivi K8V8 agreement too low: {agree}/16 ({ref_out:?} vs {eng_out:?})"
    );
}
