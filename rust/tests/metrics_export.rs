//! Memory-hierarchy observability, end to end (pure host, no artifacts):
//! drive a real `Scheduler` over a native paged engine with counter tracks
//! armed and a `/metrics` endpoint up, scrape the Prometheus exposition
//! while the run lives, and assert the exposition is well-formed and
//! carries the hierarchy tracks (pool occupancy, per-layer KV bytes, swap
//! bandwidth) alongside the snapshot aggregates. Then check the Chrome
//! trace export interleaves well-formed, time-ordered `"ph":"C"` counter
//! events with the lifecycle spans.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::coordinator::{AccuracyClass, Metrics, Request, Scheduler, SchedulerOptions};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kvcache::{PagedOptions, SwapPolicy};
use kvtuner::obs::{
    chrome_trace_json, render_tracks, Counters, Exposition, MetricsServer, TraceSink, Tracer,
};
use kvtuner::util::json::Json;

// Same pressure geometry as tests/obs.rs: a 4-page pool under two requests
// that peak at 3 pages each forces a swap-out, so the swap-bandwidth rate
// tracks see real bytes.
const PROMPT_LEN: usize = 7;
const MAX_NEW: usize = 18;
const TOTAL_BLOCKS: usize = 4;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "metrics-export-test".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 64,
        vocab: 128,
        rope_theta: 10000.0,
        group: 8,
        residual: 8,
        rms_eps: 1e-5,
    }
}

/// Strict line-by-line check of the Prometheus text exposition: HELP/TYPE
/// comments, then `name{labels} value` samples whose family has a TYPE
/// header. Returns the sample count.
fn check_exposition(body: &str) -> usize {
    let mut samples = 0;
    let mut typed: BTreeMap<String, String> = BTreeMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if line.starts_with("# HELP ") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE without name").to_string();
            let kind = it.next().expect("TYPE without kind").to_string();
            assert!(
                ["gauge", "counter", "summary"].contains(&kind.as_str()),
                "unexpected TYPE {kind} in {line}"
            );
            typed.insert(name, kind);
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment: {line}");
        let (series, value) = line.rsplit_once(' ').expect("sample line without value");
        let name = series.split('{').next().unwrap();
        assert!(
            typed.keys().any(|t| name == t.as_str() || name.starts_with(&format!("{t}_"))),
            "sample {name} has no TYPE header"
        );
        if !matches!(value, "NaN" | "+Inf" | "-Inf") {
            value.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line}"));
        }
        samples += 1;
    }
    samples
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut st = std::net::TcpStream::connect(addr).unwrap();
    write!(st, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut out = String::new();
    st.read_to_string(&mut out).unwrap();
    out
}

#[test]
fn live_scrape_and_chrome_counters_during_synthetic_serve_run() {
    let c = cfg();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), c.n_layers);
    let w = kvtuner::model::Weights::synthetic(&c, 5);
    let engine = NativeEngine::new(
        &c,
        w,
        specs,
        2,
        64,
        8,
        1,
        Some(PagedOptions {
            total_blocks: Some(TOTAL_BLOCKS),
            swap_mib: Some(4.0),
            swap_policy: SwapPolicy::Always,
            ..PagedOptions::default()
        }),
    )
    .unwrap();

    // the serve wiring in miniature: tracer + counters share an epoch, the
    // engine publishes per-layer tracks, the scheduler the hierarchy tracks
    let tracer = Arc::new(Tracer::with_default_capacity());
    let counters = Arc::new(Counters::with_epoch(tracer.epoch()));
    let mut engine: Box<dyn EngineCore> = Box::new(engine);
    engine.set_counters(&counters);
    let metrics = Arc::new(Metrics::default());
    let mut sched = Scheduler::new(
        engine,
        "metrics-worker",
        SchedulerOptions {
            swap_policy: SwapPolicy::Always,
            trace: Some(TraceSink { tracer: tracer.clone(), worker: 0 }),
            counters: Some(counters.clone()),
            ..SchedulerOptions::default()
        },
        metrics.clone(),
    );

    // /metrics endpoint over the live registries, port picked by the OS
    let server = {
        let metrics = metrics.clone();
        let counters = counters.clone();
        MetricsServer::start("127.0.0.1:0", move || {
            let mut expo = Exposition::new();
            metrics.snapshot().render_prometheus(&mut expo, "metrics-worker");
            render_tracks(&mut expo, "metrics-worker", &counters.snapshot());
            expo.render()
        })
        .unwrap()
    };
    let addr = server.addr();

    let (tx, rx) = mpsc::channel::<Request>();
    let mut responses = Vec::new();
    for id in 0..2u64 {
        let (rtx, rrx) = mpsc::channel();
        let prompt: Vec<i32> =
            (0..PROMPT_LEN).map(|j| ((j * 7 + 13 * id as usize) % c.vocab) as i32).collect();
        tx.send(Request {
            id,
            prompt,
            max_new_tokens: MAX_NEW,
            class: AccuracyClass::Balanced,
            arrival: Instant::now(),
            deadline: None,
            respond: rtx,
        })
        .unwrap();
        responses.push(rrx);
    }
    drop(tx);
    let worker = std::thread::spawn(move || {
        sched.run(&rx, Arc::new(AtomicBool::new(true)), Arc::new(AtomicUsize::new(0))).unwrap();
    });

    // scrape while the run lives (and after — the registries outlive the
    // scheduler, exactly like the serve command's shutdown path); retry
    // until the hierarchy tracks have published
    let deadline = Instant::now() + std::time::Duration::from_secs(30);
    let body = loop {
        let resp = http_get(addr, "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).unwrap().to_string();
        if body.contains("kvtuner_pool_blocks_live")
            && body.contains("kvtuner_layer_kv_live")
            && body.contains("kvtuner_swap_out_bytes_total")
        {
            break body;
        }
        assert!(Instant::now() < deadline, "hierarchy tracks never appeared:\n{body}");
        std::thread::sleep(std::time::Duration::from_millis(10));
    };
    worker.join().unwrap();
    for rrx in responses {
        let r = rrx.recv().expect("scheduler dropped a response channel");
        assert!(r.error.is_none(), "request {} degraded: {:?}", r.id, r.error);
    }

    // the captured exposition is well-formed and complete
    let n = check_exposition(&body);
    assert!(n > 20, "suspiciously small exposition ({n} samples):\n{body}");
    assert!(body.contains("kvtuner_schema_version 2"), "{body}");
    for family in [
        "# TYPE kvtuner_pool_blocks_live gauge",
        "# TYPE kvtuner_pool_bytes_live gauge",
        "# TYPE kvtuner_layer_kv_live gauge",
        "# TYPE kvtuner_swap_out_bytes_total counter",
        "# TYPE kvtuner_swap_out_bytes_ewma_per_sec gauge",
        "# TYPE kvtuner_requests_completed_total counter",
        "# TYPE kvtuner_ttft_seconds summary",
    ] {
        assert!(body.contains(family), "missing {family:?} in:\n{body}");
    }
    assert!(
        body.contains("kvtuner_layer_kv_live{engine=\"metrics-worker\",layer=\"00\","),
        "per-layer track must carry engine + layer labels:\n{body}"
    );
    assert!(http_get(addr, "/nope").starts_with("HTTP/1.1 404"));

    // a final scrape reflects the drained run: swap bytes moved, requests
    // completed (Always-policy eviction under a 4-page pool must swap)
    let resp = http_get(addr, "/metrics");
    let final_body = resp.split("\r\n\r\n").nth(1).unwrap();
    check_exposition(final_body);
    let sample_of = |name: &str| -> f64 {
        final_body
            .lines()
            .find(|l| l.starts_with(name) && !l.starts_with('#'))
            .unwrap_or_else(|| panic!("no sample for {name}:\n{final_body}"))
            .rsplit_once(' ')
            .unwrap()
            .1
            .parse()
            .unwrap()
    };
    assert_eq!(sample_of("kvtuner_requests_completed_total") as u64, 2);
    assert!(sample_of("kvtuner_swap_out_bytes_total") > 0.0, "pressure must have swapped");
    server.stop();

    // Chrome export: counter events ride alongside the lifecycle spans,
    // well-formed and time-ordered per track
    let doc = chrome_trace_json(&tracer, &[(0, counters.snapshot())]);
    let re = Json::parse(&doc.to_string_pretty()).unwrap();
    assert_eq!(re.get("schema_version").unwrap().as_usize().unwrap(), 2);
    assert_eq!(re.get("droppedEvents").unwrap().as_usize().unwrap(), 0);
    let evs = re.get("traceEvents").unwrap().as_arr().unwrap();
    let spans = evs
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
        .count();
    assert!(spans > 0, "no lifecycle spans in the merged export");
    let mut last_ts: BTreeMap<String, f64> = BTreeMap::new();
    let mut counter_events = 0;
    for e in evs {
        if e.get("ph").unwrap().as_str().unwrap() != "C" {
            continue;
        }
        counter_events += 1;
        assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "kvtuner_counters");
        assert_eq!(e.get("pid").unwrap().as_usize().unwrap(), 0);
        let name = e.get("name").unwrap().as_str().unwrap().to_string();
        let args = e.get("args").unwrap().as_obj().unwrap();
        assert_eq!(args.len(), 1, "one series value per counter event");
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let key = format!("{name}/{}", args.keys().next().unwrap());
        if let Some(prev) = last_ts.get(&key) {
            assert!(ts >= *prev, "counter events out of order on {key}");
        }
        last_ts.insert(key, ts);
    }
    assert!(counter_events > 0, "no counter events in the merged export");
    let names: Vec<&String> = last_ts.keys().collect();
    assert!(
        last_ts.keys().any(|k| k.starts_with("pool_blocks_live/"))
            && last_ts.keys().any(|k| k.starts_with("layer_kv_live/"))
            && last_ts.keys().any(|k| k.starts_with("swap_out_bytes_per_sec/")),
        "hierarchy tracks missing from the chrome export: {names:?}"
    );
}
