//! Edge cases and failure injection: cache overflow, bucket mismatches,
//! corrupt artifacts, mixed per-layer modes, and slot isolation.

use std::sync::Arc;

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::engine::Engine;
use kvtuner::kvcache::{CacheBackend, KvCache};
use kvtuner::model::Weights;
use kvtuner::runtime::Runtime;
use kvtuner::tensor::Tensor;

fn manifest() -> Option<Manifest> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest"))
}

fn mk_cfg(m: &Manifest) -> kvtuner::config::ModelConfig {
    m.config.clone()
}

#[test]
fn cache_overflow_is_an_error_not_corruption() {
    let Some(m) = manifest() else { return };
    let cfg = mk_cfg(&m);
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), cfg.n_layers);
    let mut kc = KvCache::new(&cfg, &specs, 1, 64).unwrap();
    // fill to capacity
    let h = cfg.n_kv_heads;
    let outs = vec![
        Tensor::zeros_u8(&[1, h, 1, 16]),
        Tensor::zeros_f32(&[1, h, 1]),
        Tensor::zeros_f32(&[1, h, 1]),
        Tensor::zeros_u8(&[1, h, 1, 16]),
        Tensor::zeros_f32(&[1, h, 1]),
        Tensor::zeros_f32(&[1, h, 1]),
    ];
    for _ in 0..64 {
        kc.append_token_outputs(0, 0, &outs, &[1]).unwrap();
    }
    let err = kc.append_token_outputs(0, 0, &outs, &[1]);
    assert!(err.is_err(), "overflow must error");
    assert_eq!(kc.layers[0].cache_len[0], 64, "len unchanged after failed append");
}

#[test]
fn kivi_commit_requires_full_group() {
    let Some(m) = manifest() else { return };
    let cfg = mk_cfg(&m);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let kc = KvCache::new(&cfg, &specs, 1, 64).unwrap();
    assert!(kc.residual_chunk(0, 0).is_err(), "empty residual cannot be committed");
}

#[test]
fn engine_rejects_missing_buckets() {
    let Some(_m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = rt.manifest.config.clone();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), cfg.n_layers);
    // batch=64 was never emitted
    let err = Engine::new(rt.clone(), &cfg.name, specs.clone(), 64, 256, 32);
    assert!(err.is_err());
    // s_max=1024 was never emitted
    let err = Engine::new(rt, &cfg.name, specs, 1, 1024, 32);
    assert!(err.is_err());
}

#[test]
fn engine_rejects_unknown_model_and_wrong_spec_count() {
    let Some(_m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = rt.manifest.config.clone();
    let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
    assert!(Engine::new(rt.clone(), "no-such-model", specs, 1, 256, 32).is_err());
    let too_few = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers - 1);
    assert!(Engine::new(rt, &cfg.name, too_few, 1, 256, 32).is_err());
}

#[test]
fn corrupt_manifest_fails_loud() {
    let dir = std::env::temp_dir().join("kvtuner_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"config": {}}"#).unwrap();
    assert!(Manifest::load(&dir).is_err(), "missing fields must error");
}

#[test]
fn truncated_weights_fail_loud() {
    let Some(m) = manifest() else { return };
    // copy manifest dir entry but truncate the weights file
    let dir = std::env::temp_dir().join("kvtuner_truncated_weights");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(m.dir.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let entry = m.model(&m.config.name).unwrap();
    let src = std::fs::read(m.dir.join(&entry.weights_file)).unwrap();
    std::fs::write(dir.join(&entry.weights_file), &src[..src.len() / 2]).unwrap();
    let m2 = Manifest::load(&dir).unwrap();
    assert!(Weights::load(&m2, &m2.config.name).is_err());
}

#[test]
fn mixed_mode_layer_map_generates() {
    // fp + token + kivi in ONE engine — the fully heterogeneous case the
    // layer-wise design promises.
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = m.config.clone();
    let modes = [Mode::Fp, Mode::Token, Mode::Kivi];
    let specs: Vec<LayerSpec> = (0..cfg.n_layers)
        .map(|l| {
            let mode = modes[l % 3];
            LayerSpec {
                mode,
                pair: match mode {
                    Mode::Fp => PrecisionPair::FP,
                    Mode::Token => PrecisionPair::new(8, 4),
                    Mode::Kivi => PrecisionPair::new(4, 2),
                },
            }
        })
        .collect();
    let mut eng = Engine::new(rt, &cfg.name, specs, 1, 256, 32).unwrap();
    let prompt: Vec<i32> = (0..40).map(|i| (i * 3 % cfg.vocab) as i32).collect();
    let out = eng.generate(0, &prompt, 40).unwrap(); // crosses a kivi commit
    assert_eq!(out.len(), 40);
    // kivi layers committed at least one group during the run
    let kivi_layer = (0..cfg.n_layers).find(|l| eng.specs[*l].mode == Mode::Kivi).unwrap();
    assert!(eng.cache.cache_len(kivi_layer, 0) >= cfg.group as i32);
}

#[test]
fn slot_reset_isolates_sequences() {
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = m.config.clone();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), cfg.n_layers);
    let mut eng = Engine::new(rt, &cfg.name, specs, 1, 256, 32).unwrap();
    let p1: Vec<i32> = (0..16).map(|i| (i % cfg.vocab) as i32).collect();
    let a = eng.generate(0, &p1, 8).unwrap();
    // run a different sequence, then the first again: must match exactly
    let p2: Vec<i32> = (0..24).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();
    let _ = eng.generate(0, &p2, 8).unwrap();
    let a2 = eng.generate(0, &p1, 8).unwrap();
    assert_eq!(a, a2, "stale cache state leaked across reset");
}

#[test]
fn tensor_literal_roundtrip_all_dtypes() {
    let t = Tensor::f32(&[2, 3], vec![1.5, -2.0, 0.0, 3.25, -0.5, 9.0]);
    let lit = t.to_literal().unwrap();
    assert_eq!(Tensor::from_literal(&lit).unwrap(), t);
    let t = Tensor::u8(&[4], vec![0, 127, 200, 255]);
    let lit = t.to_literal().unwrap();
    assert_eq!(Tensor::from_literal(&lit).unwrap(), t);
    let t = Tensor::i32(&[2, 2], vec![-5, 0, 7, i32::MAX]);
    let lit = t.to_literal().unwrap();
    assert_eq!(Tensor::from_literal(&lit).unwrap(), t);
}

#[test]
fn slot_inputs_slice_matches_full_buffer() {
    let Some(m) = manifest() else { return };
    let cfg = mk_cfg(&m);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let mut kc = KvCache::new(&cfg, &specs, 2, 64).unwrap();
    // mark slot 1's residual with a distinctive value
    let h = cfg.n_kv_heads;
    let dh = cfg.head_dim;
    let k_new = Tensor::f32(&[1, h, 1, dh], vec![42.0; h * dh]);
    kc.append_kivi_residual(0, 1, &k_new, &k_new, &[1]).unwrap();
    let slot0 = kc.layers[0].slot_inputs(0);
    let slot1 = kc.layers[0].slot_inputs(1);
    // k_res is the 7th tensor (codes, kscale, kzero, vcodes, vscale, vzero, kres, vres)
    let r0 = slot0[6].as_f32().unwrap();
    let r1 = slot1[6].as_f32().unwrap();
    assert!(r0.iter().all(|&v| v == 0.0));
    assert_eq!(r1[0], 42.0);
}
