//! Host-tier swap correctness, pure-host (no artifacts): bit-equality of a
//! swapped-out-and-back slot vs. never-evicted state on the dense arm, the
//! paged arm, and the paged arm with prefix-shared pages; refcount
//! correctness when a swapped sequence's prefix pages are concurrently
//! resurrected by another request; the recycled-link fallback; and host
//! arena budget/accounting.

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::kvcache::{
    CacheBackend, HostArenaFull, KvCache, PagedKvCache, PagedOptions, SwapLost, SwapPage,
    SwapPayload, SwapPolicy,
};
use kvtuner::tensor::Tensor;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "test".into(),
        n_layers: 3,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 128,
        vocab: 64,
        rope_theta: 10000.0,
        group: 8, // page size
        residual: 8,
        rms_eps: 1e-5,
    }
}

fn mixed_specs() -> Vec<LayerSpec> {
    vec![
        LayerSpec { mode: Mode::Fp, pair: PrecisionPair::FP },
        LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 4) },
        LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(4, 2) },
    ]
}

fn token_specs(n: usize) -> Vec<LayerSpec> {
    LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), n)
}

/// Deterministic pseudo-random fill so round-trip comparisons are
/// meaningful (page scrambling cannot cancel out).
fn fill(n: usize, seed: u64) -> Vec<f32> {
    let mut x = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    (0..n)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x % 1000) as f32 / 250.0 - 2.0
        })
        .collect()
}

fn fill_u8(n: usize, seed: u64) -> Vec<u8> {
    fill(n, seed).iter().map(|v| (v.abs() * 40.0) as u8).collect()
}

/// Write distinctive content into slot 0 of every layer of the mixed-specs
/// cache: 5 fp rows, 10 token rows (crossing the 8-token page boundary),
/// one committed kivi group plus one leftover residual row. Ends with
/// `advance_pos(0, 10)` so the position round-trips too.
fn drive_slot0(cb: &mut dyn CacheBackend, c: &ModelConfig) {
    let (h, dh, g) = (c.n_kv_heads, c.head_dim, c.group);
    let t = 5;
    let k = Tensor::f32(&[1, h, t, dh], fill(h * t * dh, 1));
    let v = Tensor::f32(&[1, h, t, dh], fill(h * t * dh, 2));
    cb.append_fp(0, 0, &k, &v, &[t]).unwrap();

    let (kp, vp) = (16, 8); // dh=16 at K8V4
    for round in 0..2u64 {
        let outs = vec![
            Tensor::u8(&[1, h, t, kp], fill_u8(h * t * kp, 30 + round)),
            Tensor::f32(&[1, h, t], fill(h * t, 40 + round)),
            Tensor::f32(&[1, h, t], fill(h * t, 50 + round)),
            Tensor::u8(&[1, h, t, vp], fill_u8(h * t * vp, 60 + round)),
            Tensor::f32(&[1, h, t], fill(h * t, 70 + round)),
            Tensor::f32(&[1, h, t], fill(h * t, 80 + round)),
        ];
        cb.append_token_outputs(1, 0, &outs, &[t]).unwrap();
    }

    for i in 0..g {
        let kr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 100 + i as u64));
        let vr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 200 + i as u64));
        let need = cb.append_kivi_residual(2, 0, &kr, &vr, &[1]).unwrap();
        assert_eq!(need[0], i + 1 == g);
    }
    let (kp2, vp2) = (8, 4); // dh=16 at K4V2
    let k_outs = vec![
        Tensor::u8(&[1, h, g, kp2], fill_u8(h * g * kp2, 9)),
        Tensor::f32(&[1, h, dh], fill(h * dh, 10)),
        Tensor::f32(&[1, h, dh], fill(h * dh, 11)),
    ];
    let v_outs = vec![
        Tensor::u8(&[1, h, g, vp2], fill_u8(h * g * vp2, 12)),
        Tensor::f32(&[1, h, g], fill(h * g, 13)),
        Tensor::f32(&[1, h, g], fill(h * g, 14)),
    ];
    cb.commit_kivi_chunk(2, 0, &k_outs, &v_outs).unwrap();
    // leftover residual row, so res_len > 0 must survive the round trip
    let kr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 300));
    cb.append_kivi_residual(2, 0, &kr, &kr, &[1]).unwrap();

    cb.advance_pos(0, 10);
}

#[test]
fn dense_swap_roundtrip_is_bit_exact_across_slots() {
    let c = cfg();
    let specs = mixed_specs();
    let mut kc = KvCache::new(&c, &specs, 2, 32).unwrap();
    assert!(CacheBackend::swap_enabled(&kc));
    drive_slot0(&mut kc, &c);

    let snap: Vec<Vec<Tensor>> = (0..specs.len()).map(|l| kc.layers[l].slot_inputs(0)).collect();
    let lens: Vec<(i32, i32)> = (0..specs.len())
        .map(|l| (CacheBackend::cache_len(&kc, l, 0), CacheBackend::res_len(&kc, l, 0)))
        .collect();

    let h = CacheBackend::swap_out(&mut kc, 0).unwrap();
    assert_eq!(h.pos, 10);
    assert!(matches!(&h.payload, SwapPayload::Dense(_)));
    assert_eq!(CacheBackend::pos(&kc, 0), 0, "slot released");
    assert_eq!(CacheBackend::cache_len(&kc, 1, 0), 0);
    let st = CacheBackend::mem_stats(&kc);
    assert_eq!(st.host_bytes_used, h.host_bytes, "host tier pins the blob");
    assert!(st.host_bytes_used > 0);

    // restore into the *other* slot: the handle is slot-agnostic
    assert!(CacheBackend::can_swap_in(&kc, &h));
    CacheBackend::swap_in(&mut kc, 1, &h).unwrap();
    let host_bytes = h.host_bytes;
    CacheBackend::release_swap(&mut kc, h);
    assert_eq!(CacheBackend::mem_stats(&kc).host_bytes_used, 0);

    assert_eq!(CacheBackend::pos(&kc, 1), 10);
    for l in 0..specs.len() {
        assert_eq!(
            (CacheBackend::cache_len(&kc, l, 1), CacheBackend::res_len(&kc, l, 1)),
            lens[l],
            "layer {l} lengths"
        );
        assert_eq!(kc.layers[l].slot_inputs(1), snap[l], "layer {l} bytes diverged");
    }
    let stats = CacheBackend::swap_stats(&kc);
    assert_eq!((stats.swap_outs, stats.swap_ins), (1, 1));
    assert_eq!(stats.bytes_out, host_bytes as u64);
    assert_eq!(stats.bytes_out, stats.bytes_in);
}

#[test]
fn paged_swap_roundtrip_is_bit_exact_across_slots() {
    let c = cfg();
    let specs = mixed_specs();
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        2,
        32,
        &PagedOptions { swap_mib: Some(1.0), swap_policy: SwapPolicy::Auto, ..PagedOptions::default() },
    )
    .unwrap();
    assert!(CacheBackend::swap_enabled(&kc));
    let total = kc.total_blocks();
    drive_slot0(&mut kc, &c);
    assert_eq!(kc.block_table(0).len(), 2, "10 token rows = 2 pages of 8");
    assert!(CacheBackend::swap_out_bytes(&kc, 0) > 0);

    let snap: Vec<Vec<Tensor>> = (0..specs.len()).map(|l| kc.gather_slot(l, 0).unwrap()).collect();
    let lens: Vec<(i32, i32)> = (0..specs.len())
        .map(|l| (CacheBackend::cache_len(&kc, l, 0), CacheBackend::res_len(&kc, l, 0)))
        .collect();

    let h = CacheBackend::swap_out(&mut kc, 0).unwrap();
    assert_eq!(kc.free_blocks(), total, "device pages all released");
    assert!(kc.block_table(0).is_empty());
    match &h.payload {
        SwapPayload::Paged { pages, residual } => {
            assert_eq!(pages.len(), 2);
            assert!(pages.iter().all(|p| matches!(p, SwapPage::Host(_))), "nothing registered -> all copied");
            assert!(!residual.is_empty(), "kivi residual ring rides along");
        }
        _ => panic!("paged arm must emit a paged payload"),
    }
    let st = CacheBackend::mem_stats(&kc);
    assert_eq!(st.host_bytes_used, h.host_bytes);
    assert!(st.host_bytes_total >= st.host_bytes_used);

    assert!(CacheBackend::can_swap_in(&kc, &h));
    CacheBackend::swap_in(&mut kc, 1, &h).unwrap();
    CacheBackend::release_swap(&mut kc, h);
    assert_eq!(CacheBackend::mem_stats(&kc).host_bytes_used, 0);

    assert_eq!(CacheBackend::pos(&kc, 1), 10);
    for l in 0..specs.len() {
        assert_eq!(
            (CacheBackend::cache_len(&kc, l, 1), CacheBackend::res_len(&kc, l, 1)),
            lens[l],
            "layer {l} lengths"
        );
        assert_eq!(kc.gather_slot(l, 1).unwrap(), snap[l], "layer {l} bytes diverged");
    }
    let stats = CacheBackend::swap_stats(&kc);
    assert_eq!((stats.pages_copied_out, stats.pages_copied_in), (2, 2));
    assert_eq!(stats.pages_relinked, 0);
    assert_eq!(stats.bytes_out, stats.bytes_in);
}

/// Build a 2-layer token cache, prefill slot 0 with 20 tokens of real
/// content, publish its prompt pages, and prefix-share them into slot 1
/// (16 reused + 4 private tail tokens). Returns the prompt.
fn share_into_slot1(kc: &mut PagedKvCache, c: &ModelConfig) -> Vec<i32> {
    let h = c.n_kv_heads;
    let prompt: Vec<i32> = (0..20).map(|i| (i * 3 % 64) as i32).collect();
    assert_eq!(CacheBackend::prefill_reuse(kc, 0, &prompt), 0, "cold index");
    let t = 5;
    for l in 0..2usize {
        for a in 0..4u64 {
            let seed = l as u64 * 10 + a * 50;
            let outs = vec![
                Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, seed + 40)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 41)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 42)),
                Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, seed + 43)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 44)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 45)),
            ];
            CacheBackend::append_token_outputs(kc, l, 0, &outs, &[t]).unwrap();
        }
    }
    CacheBackend::register_prefix(kc, 0, &prompt);
    CacheBackend::advance_pos(kc, 0, 20);

    assert_eq!(CacheBackend::prefill_reuse(kc, 1, &prompt), 16);
    let t = 4; // private tail: positions 16..20
    for l in 0..2usize {
        let outs = vec![
            Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, 900 + l as u64)),
            Tensor::f32(&[1, h, t], fill(h * t, 910 + l as u64)),
            Tensor::f32(&[1, h, t], fill(h * t, 920 + l as u64)),
            Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, 930 + l as u64)),
            Tensor::f32(&[1, h, t], fill(h * t, 940 + l as u64)),
            Tensor::f32(&[1, h, t], fill(h * t, 950 + l as u64)),
        ];
        CacheBackend::append_token_outputs(kc, l, 1, &outs, &[t]).unwrap();
    }
    CacheBackend::advance_pos(kc, 1, 4);
    prompt
}

#[test]
fn swap_relinks_prefix_pages_shared_with_a_concurrent_request() {
    let c = cfg();
    let specs = token_specs(2);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        3,
        32,
        &PagedOptions {
            total_blocks: Some(12),
            swap_mib: Some(1.0),
            swap_policy: SwapPolicy::Auto,
            ..PagedOptions::default()
        },
    )
    .unwrap();
    let prompt = share_into_slot1(&mut kc, &c);
    let shared: Vec<u32> = kc.block_table(1)[..2].to_vec();
    for &id in &shared {
        assert_eq!(kc.ref_count(id), 2);
    }
    let snap: Vec<Vec<Tensor>> = (0..2).map(|l| kc.gather_slot(l, 1).unwrap()).collect();

    let h = CacheBackend::swap_out(&mut kc, 1).unwrap();
    match &h.payload {
        SwapPayload::Paged { pages, .. } => {
            assert!(matches!(pages[0], SwapPage::Linked { .. }));
            assert!(matches!(pages[1], SwapPage::Linked { .. }));
            assert!(matches!(pages[2], SwapPage::Host(_)), "private tail page is copied");
        }
        _ => panic!("expected paged payload"),
    }
    for &id in &shared {
        assert_eq!(kc.ref_count(id), 1, "swap-out drops the victim's reference");
    }
    assert_eq!(CacheBackend::swap_stats(&kc).pages_copied_out, 1);

    // while slot 1 is away: its publisher finishes, then a third request
    // resurrects the same prefix pages — the swapped handle must re-link
    // against whatever reference state it finds
    CacheBackend::reset_slot(&mut kc, 0);
    assert_eq!(CacheBackend::prefill_reuse(&mut kc, 2, &prompt), 16);
    for &id in &shared {
        assert_eq!(kc.ref_count(id), 1, "resurrected by slot 2");
    }

    assert!(CacheBackend::can_swap_in(&kc, &h));
    CacheBackend::swap_in(&mut kc, 1, &h).unwrap();
    CacheBackend::release_swap(&mut kc, h);
    for &id in &shared {
        assert_eq!(kc.ref_count(id), 2, "slot 1 re-linked alongside slot 2");
    }
    for l in 0..2 {
        assert_eq!(kc.gather_slot(l, 1).unwrap(), snap[l], "layer {l} bytes diverged");
    }
    let stats = CacheBackend::swap_stats(&kc);
    assert_eq!(stats.pages_relinked, 2);
    assert_eq!(stats.pages_copied_in, 1);

    // refcounts unwind cleanly
    CacheBackend::reset_slot(&mut kc, 1);
    for &id in &shared {
        assert_eq!(kc.ref_count(id), 1);
    }
    CacheBackend::reset_slot(&mut kc, 2);
    assert_eq!(kc.free_blocks(), kc.total_blocks());
}

#[test]
fn swap_resurrects_prefix_pages_freed_while_away() {
    let c = cfg();
    let specs = token_specs(2);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        3,
        32,
        &PagedOptions {
            total_blocks: Some(12),
            swap_mib: Some(1.0),
            swap_policy: SwapPolicy::Auto,
            ..PagedOptions::default()
        },
    )
    .unwrap();
    share_into_slot1(&mut kc, &c);
    let snap: Vec<Vec<Tensor>> = (0..2).map(|l| kc.gather_slot(l, 1).unwrap()).collect();

    let h = CacheBackend::swap_out(&mut kc, 1).unwrap();
    CacheBackend::reset_slot(&mut kc, 0);
    assert_eq!(kc.free_blocks(), kc.total_blocks(), "everything on the free list");

    // linked pages are refcount-0 but still indexed: swap-in resurrects
    // them instead of copying
    assert!(CacheBackend::can_swap_in(&kc, &h));
    CacheBackend::swap_in(&mut kc, 1, &h).unwrap();
    CacheBackend::release_swap(&mut kc, h);
    assert_eq!(kc.free_blocks(), kc.total_blocks() - 3);
    for l in 0..2 {
        assert_eq!(kc.gather_slot(l, 1).unwrap(), snap[l], "layer {l} bytes diverged");
    }
    assert_eq!(CacheBackend::swap_stats(&kc).pages_relinked, 2);
}

#[test]
fn swap_in_reports_lost_when_linked_pages_were_recycled() {
    let c = cfg();
    let specs = token_specs(2);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        3,
        32,
        &PagedOptions {
            total_blocks: Some(6),
            swap_mib: Some(1.0),
            swap_policy: SwapPolicy::Auto,
            ..PagedOptions::default()
        },
    )
    .unwrap();
    share_into_slot1(&mut kc, &c);
    let h = CacheBackend::swap_out(&mut kc, 1).unwrap();
    CacheBackend::reset_slot(&mut kc, 0);

    // churn the pool until the indexed prefix pages are recycled for new
    // content — the swapped sequence's linked pages are gone for good
    CacheBackend::synthetic_fill(&mut kc, 2, 32).unwrap();
    assert!(CacheBackend::swap_stats(&kc).swap_in_lost == 0);
    CacheBackend::reset_slot(&mut kc, 2); // free pages again so capacity passes

    assert!(CacheBackend::can_swap_in(&kc, &h), "capacity is there; content is not");
    let free_before = kc.free_blocks();
    let err = CacheBackend::swap_in(&mut kc, 1, &h).unwrap_err();
    assert!(err.downcast_ref::<SwapLost>().is_some(), "{err:#}");
    // validate-before-mutate: the failed swap-in touched nothing
    assert_eq!(kc.free_blocks(), free_before);
    assert!(kc.block_table(1).is_empty());
    assert_eq!(CacheBackend::cache_len(&kc, 0, 1), 0);
    assert_eq!(CacheBackend::swap_stats(&kc).swap_in_lost, 1);

    // the caller's fallback: release the handle, then recompute-prefill
    CacheBackend::release_swap(&mut kc, h);
    assert_eq!(CacheBackend::mem_stats(&kc).host_bytes_used, 0);
}

#[test]
fn swap_out_rejected_when_host_arena_is_full_leaves_slot_intact() {
    let c = cfg();
    let specs = mixed_specs();
    // size the arena to exactly one page slot
    let probe = PagedKvCache::new(&c, &specs, 2, 32, &PagedOptions::default()).unwrap();
    let one_slot_mib = probe.block_bytes() as f64 * 1.5 / (1024.0 * 1024.0);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        2,
        32,
        &PagedOptions { swap_mib: Some(one_slot_mib), swap_policy: SwapPolicy::Always, ..PagedOptions::default() },
    )
    .unwrap();
    assert_eq!(kc.host_swap_slots(), Some((1, 1)));

    drive_slot0(&mut kc, &c); // 2 private pages > 1 host slot
    let snap: Vec<Vec<Tensor>> = (0..specs.len()).map(|l| kc.gather_slot(l, 0).unwrap()).collect();
    let err = CacheBackend::swap_out(&mut kc, 0).unwrap_err();
    assert!(err.downcast_ref::<HostArenaFull>().is_some(), "{err:#}");
    // the victim is untouched: the scheduler falls back to recompute
    assert_eq!(CacheBackend::pos(&kc, 0), 10);
    assert_eq!(kc.block_table(0).len(), 2);
    for l in 0..specs.len() {
        assert_eq!(kc.gather_slot(l, 0).unwrap(), snap[l]);
    }
    let stats = CacheBackend::swap_stats(&kc);
    assert_eq!(stats.swap_out_rejected, 1);
    assert_eq!(stats.swap_outs, 0);
}
