//! Property tests over the coordinator/tuner invariants (an in-tree
//! mini-proptest: seeded random cases — the offline crate set has no
//! proptest crate, so cases are enumerated with the in-tree PRNG).

use kvtuner::config::{LayerSpec, Mode, PrecisionPair, PAIRS};
use kvtuner::quant::{pack_row, packed_width, quantize_per_channel, quantize_per_token, unpack_row};
use kvtuner::tuner::cluster::{cluster_layers, dbscan, expand_assignment};
use kvtuner::tuner::pareto::{candidate_signature, pareto_front, Candidate};
use kvtuner::util::rng::Rng;

/// Run `f` over `n` seeded cases; panics carry the failing seed.
fn for_all(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::seed(seed * 7919 + 13);
        f(&mut rng);
    }
}

// ---------------------------------------------------------------------------
// packing
// ---------------------------------------------------------------------------

#[test]
fn prop_pack_unpack_roundtrip() {
    for_all(200, |rng| {
        let bits = *rng.choose(&[2u8, 4, 8]);
        let dh = *rng.choose(&[16usize, 32, 64, 128]);
        let codes: Vec<u8> = (0..dh).map(|_| (rng.below(1 << bits as usize)) as u8).collect();
        let mut packed = vec![0u8; packed_width(dh, bits).unwrap()];
        pack_row(&codes, bits, &mut packed);
        let mut back = vec![0u8; dh];
        unpack_row(&packed, bits, &mut back);
        assert_eq!(codes, back, "bits={bits} dh={dh}");
    });
}

#[test]
fn prop_packed_density() {
    for_all(50, |rng| {
        let bits = *rng.choose(&[2u8, 4, 8]);
        let dh = 8 * rng.range(1, 9);
        assert_eq!(packed_width(dh, bits).unwrap(), dh * bits as usize / 8);
    });
}

// ---------------------------------------------------------------------------
// quantization
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_error_within_half_scale() {
    for_all(60, |rng| {
        let (t, dh) = (rng.range(1, 40), *rng.choose(&[16usize, 32]));
        let bits = *rng.choose(&[2u8, 4, 8]);
        let x: Vec<f32> = (0..t * dh).map(|_| rng.normal() as f32 * 4.0).collect();
        let per_channel = rng.chance(0.5);
        let q = if per_channel {
            quantize_per_channel(&x, t, dh, bits).unwrap()
        } else {
            quantize_per_token(&x, t, dh, bits).unwrap()
        };
        let y = q.dequantize();
        for ti in 0..t {
            for d in 0..dh {
                let s = if per_channel { q.scale[d] } else { q.scale[ti] };
                let e = (x[ti * dh + d] - y[ti * dh + d]).abs();
                assert!(e <= s * 0.5 + 1e-5, "e={e} s={s} bits={bits} pc={per_channel}");
            }
        }
    });
}

#[test]
fn prop_quant_idempotent() {
    // dequantized grid points survive a second quantize→dequantize unchanged
    for_all(40, |rng| {
        let (t, dh) = (8usize, 16usize);
        let bits = *rng.choose(&[2u8, 4, 8]);
        let x: Vec<f32> = (0..t * dh).map(|_| rng.normal() as f32).collect();
        let y = quantize_per_token(&x, t, dh, bits).unwrap().dequantize();
        let z = quantize_per_token(&y, t, dh, bits).unwrap().dequantize();
        for (a, b) in y.iter().zip(&z) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    });
}

#[test]
fn prop_constant_input_exact() {
    for bits in [2u8, 4, 8] {
        let x = vec![3.25f32; 4 * 16];
        let y = quantize_per_token(&x, 4, 16, bits).unwrap().dequantize();
        for v in y {
            assert!((v - 3.25).abs() < 1e-5);
        }
    }
}

// ---------------------------------------------------------------------------
// pareto / clustering
// ---------------------------------------------------------------------------

#[test]
fn prop_pareto_front_is_nondominated_and_complete() {
    for_all(100, |rng| {
        let n = rng.range(1, 20);
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64() * 8.0, rng.f64())).collect();
        let keep = pareto_front(&pts);
        assert!(!keep.is_empty());
        for &i in &keep {
            for &j in &keep {
                if i != j {
                    let dom = pts[j].0 <= pts[i].0
                        && pts[j].1 <= pts[i].1
                        && (pts[j].0 < pts[i].0 || pts[j].1 < pts[i].1);
                    assert!(!dom, "kept point {i} dominated by kept {j}");
                }
            }
        }
        for i in 0..n {
            if !keep.contains(&i) {
                let covered = keep.iter().any(|&j| {
                    pts[j].0 <= pts[i].0
                        && pts[j].1 <= pts[i].1
                        && (pts[j].0 < pts[i].0 || pts[j].1 < pts[i].1)
                });
                assert!(covered, "dropped point {i} not dominated");
            }
        }
    });
}

#[test]
fn prop_dbscan_labels_total_and_consistent() {
    for_all(60, |rng| {
        let n = rng.range(2, 24);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let labels = dbscan(&pts, 0.15, 2);
        assert_eq!(labels.len(), n);
        // identical points always share a cluster
        let mut pts2 = pts.clone();
        pts2.push(pts[0].clone());
        let labels2 = dbscan(&pts2, 0.15, 2);
        assert_eq!(labels2[0], labels2[n]);
    });
}

#[test]
fn prop_cluster_expand_roundtrip() {
    for_all(60, |rng| {
        let n_layers = rng.range(2, 12);
        let pruned: Vec<Vec<Candidate>> = (0..n_layers)
            .map(|_| {
                let n_c = rng.range(1, 4);
                (0..n_c)
                    .map(|i| {
                        let pair = PAIRS[(i * 4) % PAIRS.len()];
                        Candidate { pair, bits: pair.equivalent_bits(), e_o: rng.f64() * 0.2 }
                    })
                    .collect()
            })
            .collect();
        let groups = cluster_layers(&pruned, 0.05, 2);
        // groups partition the layers and respect signatures
        let mut seen = vec![false; n_layers];
        for g in &groups {
            for &l in &g.layers {
                assert!(!seen[l], "layer {l} in two groups");
                seen[l] = true;
                assert_eq!(
                    candidate_signature(&pruned[l]),
                    candidate_signature(&g.candidates),
                    "layer {l} grouped across signatures"
                );
            }
        }
        assert!(seen.iter().all(|&s| s), "not all layers grouped");
        let picks: Vec<usize> = groups.iter().map(|g| rng.below(g.candidates.len())).collect();
        let assignment = expand_assignment(&groups, &picks, n_layers);
        assert_eq!(assignment.len(), n_layers);
    });
}

// ---------------------------------------------------------------------------
// scheduler preemption cost model
// ---------------------------------------------------------------------------

/// The integer cost model `choose_preempt_action` optimizes over, restated
/// independently: swap = copy out + copy back; recompute = T/chunk chunked
/// layer sweeps each re-reading the O(T)-token cache (multiply before
/// divide, as the scheduler does, so the tie-breaking is bit-identical).
fn preempt_costs(swap_out_bytes: usize, t: usize, ptb: usize, chunk: usize) -> (u64, u64) {
    let swap = 2 * swap_out_bytes as u64;
    let recompute = (t as u64) * (t as u64) * ptb.max(1) as u64 / chunk.max(1) as u64;
    (swap, recompute)
}

#[test]
fn prop_preempt_action_minimizes_modeled_cost() {
    use kvtuner::coordinator::{choose_preempt_action, PreemptAction};
    use kvtuner::kvcache::SwapPolicy;
    for_all(300, |rng| {
        let ptb = *rng.choose(&[64usize, 256, 1024, 4096]);
        let chunk = *rng.choose(&[8usize, 16, 32, 128]);
        let t = rng.range(0, 4096);
        // bytes roam independently of t: prefix-linked pages can make the
        // swap payload much smaller than the resident context
        let bytes = rng.below(t * ptb + 1);
        let action = choose_preempt_action(SwapPolicy::Auto, true, bytes, t, ptb, chunk);
        let (swap, recompute) = preempt_costs(bytes, t, ptb, chunk);
        let (chosen, alternative) = match action {
            PreemptAction::SwapOut => (swap, recompute),
            PreemptAction::Recompute => (recompute, swap),
        };
        assert!(
            chosen <= alternative,
            "chose {action:?} (cost {chosen}) over {alternative}: \
             bytes={bytes} t={t} ptb={ptb} chunk={chunk}"
        );
        // policy overrides dominate the cost model; no-arena forces recompute
        assert_eq!(
            choose_preempt_action(SwapPolicy::Off, true, bytes, t, ptb, chunk),
            PreemptAction::Recompute
        );
        assert_eq!(
            choose_preempt_action(SwapPolicy::Always, true, bytes, t, ptb, chunk),
            PreemptAction::SwapOut
        );
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, false, bytes, t, ptb, chunk),
            PreemptAction::Recompute
        );
    });
}

#[test]
fn prop_preempt_crossover_at_twice_prefill_chunk() {
    use kvtuner::coordinator::{choose_preempt_action, PreemptAction};
    use kvtuner::kvcache::SwapPolicy;
    // with the full context swapped (bytes = t * ptb), the two costs meet at
    // exactly T = 2 * chunk: 2*t*ptb == t*t*ptb/chunk. Ties break toward
    // recompute (strict `<` for swap), so the boundary token lands there.
    for_all(100, |rng| {
        let ptb = *rng.choose(&[64usize, 256, 1024]);
        let chunk = *rng.choose(&[8usize, 16, 32, 64]);
        let at = 2 * chunk;
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, at * ptb, at, ptb, chunk),
            PreemptAction::Recompute,
            "t = 2*chunk is a tie: ptb={ptb} chunk={chunk}"
        );
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, (at + 1) * ptb, at + 1, ptb, chunk),
            PreemptAction::SwapOut,
            "one past the tie must swap: ptb={ptb} chunk={chunk}"
        );
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, (at - 1) * ptb, at - 1, ptb, chunk),
            PreemptAction::Recompute,
            "below the tie must recompute: ptb={ptb} chunk={chunk}"
        );
        // and the ordering is monotone: longer contexts never flip back
        let longer = at + 1 + rng.below(512);
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, longer * ptb, longer, ptb, chunk),
            PreemptAction::SwapOut,
            "t={longer} past the crossover must swap: ptb={ptb} chunk={chunk}"
        );
    });
}

// ---------------------------------------------------------------------------
// config / precision pairs
// ---------------------------------------------------------------------------

#[test]
fn prop_pair_label_parse_roundtrip() {
    for pair in PAIRS {
        assert_eq!(PrecisionPair::parse(&pair.label()).unwrap(), pair);
    }
}

#[test]
fn prop_equivalent_bits_bounds() {
    for_all(50, |rng| {
        let n = rng.range(1, 16);
        let specs: Vec<LayerSpec> = (0..n)
            .map(|_| LayerSpec { mode: Mode::Token, pair: *rng.choose(&PAIRS) })
            .collect();
        let b = LayerSpec::equivalent_bits(&specs);
        assert!((2.0..=8.0).contains(&b), "{b}");
    });
}

// ---------------------------------------------------------------------------
// JSON substrate
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_numbers_strings() {
    use kvtuner::util::json::{arr, num, obj, s, Json};
    for_all(60, |rng| {
        let v = obj(vec![
            ("a", num((rng.f64() * 1e6).round())),
            ("b", num(rng.f64())),
            ("c", s(format!("x{}y\"z\\n{}", rng.below(100), rng.below(100)))),
            ("d", arr((0..rng.below(5)).map(|i| num(i as f64)))),
        ]);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "{text}");
    });
}
