//! Differential lockdown for the batched decode path (pure host, no
//! artifacts). Two layers of defense:
//!
//! * Engine-level: `NativeEngine::decode_step` (batched kernels folding all
//!   active slots into one `[nb, d]`-row pass per layer, attention fanned
//!   out over `nb x heads` via `attend_many`) against
//!   `decode_step_sequential` (the pre-batching per-slot loop, kept verbatim
//!   as the oracle) — bit-identical logits and tokens for ragged lengths,
//!   inactive slots, mid-residual-ring kivi state, batch-of-1, and every
//!   (mode, precision pair) combination.
//! * Scheduler-level: a seeded randomized churn harness drives two real
//!   `Scheduler`s — chunked-prefill + batched decode vs whole-prompt
//!   prefill + sequential decode — over tight page pools that force
//!   preempt/swap/resume, and asserts every request's token stream and
//!   final-step logits are bit-identical across arms. Failures print the
//!   reproducing seed.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use kvtuner::coordinator::{AccuracyClass, Metrics, Request, Scheduler, SchedulerOptions};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kvcache::{PagedOptions, SwapPolicy};
use kvtuner::model::Weights;
use kvtuner::util::rng::Rng;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "batched-decode-test".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 64,
        vocab: 128,
        rope_theta: 10000.0,
        group: 8,
        residual: 8,
        rms_eps: 1e-5,
    }
}

fn assert_logits_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: logits length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: logits diverge at vocab {i}: {x} vs {y}");
    }
}

// ---------------------------------------------------------------------------
// Engine-level parity: batched decode_step vs the sequential oracle
// ---------------------------------------------------------------------------

/// Build an oracle (sequential, 1 thread) and a batched engine over the same
/// synthetic weights, prefill the given `(slot, prompt_len)` pairs on both,
/// then run `steps` lockstep decode steps asserting bit-identical tokens and
/// logits for every active slot.
fn run_decode_parity(
    specs: &[LayerSpec],
    batch: usize,
    threads: usize,
    prompts: &[(usize, usize)],
    steps: usize,
    label: &str,
) {
    let c = cfg();
    let w = Weights::synthetic(&c, 9);
    let paged = Some(PagedOptions { total_blocks: Some(64), ..PagedOptions::default() });
    let mut oracle =
        NativeEngine::new(&c, w.clone(), specs.to_vec(), batch, 64, 8, 1, paged.clone()).unwrap();
    oracle.set_sequential_decode(true);
    let mut batched =
        NativeEngine::new(&c, w, specs.to_vec(), batch, 64, 8, threads, paged).unwrap();

    let mut tokens = vec![0i32; batch];
    let mut active = vec![false; batch];
    for &(slot, len) in prompts {
        let prompt: Vec<i32> =
            (0..len).map(|j| ((j * 7 + 11 * slot + 3) % c.vocab) as i32).collect();
        let a = oracle.prefill(slot, &prompt).unwrap();
        let b = batched.prefill(slot, &prompt).unwrap();
        assert_eq!(a, b, "{label}: slot {slot} prefill token");
        assert_logits_bits_eq(
            EngineCore::logits(&oracle, slot),
            EngineCore::logits(&batched, slot),
            &format!("{label}: slot {slot} prefill"),
        );
        tokens[slot] = a;
        active[slot] = true;
    }

    for step in 0..steps {
        let a = oracle.decode_step(&tokens, &active).unwrap();
        let b = batched.decode_step(&tokens, &active).unwrap();
        for &(slot, _) in prompts {
            assert_eq!(
                a[slot], b[slot],
                "{label}: step {step} slot {slot} token diverged (threads={threads})"
            );
            assert_logits_bits_eq(
                EngineCore::logits(&oracle, slot),
                EngineCore::logits(&batched, slot),
                &format!("{label}: step {step} slot {slot} (threads={threads})"),
            );
            tokens[slot] = a[slot];
        }
    }
}

/// Ragged sequence lengths across all four slots, mixed per-layer specs
/// (token-mode K8V2 under kivi K2V8): every slot walks a different number of
/// pages and the batched attention fan-out sees per-view ragged `seq_len`s.
#[test]
fn batched_decode_matches_sequential_ragged_lengths() {
    let specs = vec![
        LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 2) },
        LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(2, 8) },
    ];
    run_decode_parity(&specs, 4, 2, &[(0, 11), (1, 4), (2, 1), (3, 7)], 10, "ragged");
}

/// Only slots 0 and 2 are live: the batched gather must skip idle slots
/// entirely (no cache writes, no stale logits) and still match the oracle.
#[test]
fn batched_decode_matches_sequential_with_inactive_slots() {
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), cfg().n_layers);
    run_decode_parity(&specs, 4, 8, &[(0, 9), (2, 13)], 6, "inactive-slots");
}

/// Kivi slots parked mid-residual-ring (prompt lengths 11 and 13 leave 3 and
/// 5 fp rows in the ring after block prefill); 12 steps cross the
/// group-commit boundary where the ring flushes into a quantized page.
#[test]
fn batched_decode_matches_sequential_mid_residual_ring() {
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), cfg().n_layers);
    run_decode_parity(&specs, 2, 8, &[(0, 11), (1, 13)], 12, "mid-residual-ring");
}

/// Batch of one: the `attend_many` single-view fast path and the one-row
/// matmul forms must still agree with the oracle.
#[test]
fn batched_decode_matches_sequential_batch_of_one() {
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), cfg().n_layers);
    run_decode_parity(&specs, 1, 2, &[(0, 10)], 8, "batch-of-1");
}

/// Every quantization mode x precision pair (plus the fp reference arm)
/// through 9 lockstep steps that cross a group boundary.
#[test]
fn batched_decode_matches_sequential_all_modes_and_pairs() {
    let c = cfg();
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in PAIRS {
            let specs = LayerSpec::uniform(mode, pair, c.n_layers);
            let label = format!("{}-{}", mode.as_str(), pair.label());
            run_decode_parity(&specs, 2, 2, &[(0, 9), (1, 12)], 9, &label);
        }
    }
    let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, c.n_layers);
    run_decode_parity(&specs, 2, 2, &[(0, 9), (1, 12)], 9, "fp");
}

// ---------------------------------------------------------------------------
// Scheduler-level randomized differential churn
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct ChurnReq {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    arrival: usize,
}

struct ChurnPlan {
    reqs: Vec<ChurnReq>,
    specs: Vec<LayerSpec>,
    batch: usize,
    threads: usize,
    total_blocks: usize,
    swap_mib: Option<f64>,
    swap_policy: SwapPolicy,
}

/// Seeded workload: random arrivals, prompt/output lengths spanning KIVI
/// group boundaries, random per-layer (mode, pair), and a page pool sized
/// just above the largest single request — big enough that every request can
/// always finish alone (no livelock), tight enough that concurrent requests
/// must preempt, swap, and resume.
fn churn_plan(seed: u64, c: &ModelConfig) -> ChurnPlan {
    let mut rng = Rng::seed(seed.wrapping_mul(0x9E37_79B9).wrapping_add(17));
    let n = rng.range(3, 7);
    let mut reqs = Vec::new();
    let mut floor_blocks = 0usize;
    for id in 0..n {
        let plen = rng.range(3, 21);
        let max_new = rng.range(1, 13);
        let arrival = rng.below(16);
        let prompt = (0..plen).map(|_| rng.below(c.vocab) as i32).collect();
        // peak pages for this request alone, plus kivi-commit + admission
        // headroom: the pool floor that guarantees forward progress
        floor_blocks = floor_blocks.max((plen + max_new + c.group) / c.group + 1);
        reqs.push(ChurnReq { id: id as u64, prompt, max_new, arrival });
    }
    let specs = (0..c.n_layers)
        .map(|_| LayerSpec {
            mode: *rng.choose(&[Mode::Token, Mode::Kivi]),
            pair: *rng.choose(&PAIRS),
        })
        .collect();
    let batch = rng.range(2, 5);
    let threads = [1, 2, 8][seed as usize % 3];
    let total_blocks = floor_blocks + rng.below(3);
    let (swap_mib, swap_policy) = if rng.chance(0.5) {
        (Some(4.0), *rng.choose(&[SwapPolicy::Always, SwapPolicy::Auto]))
    } else {
        (None, SwapPolicy::Off)
    };
    ChurnPlan { reqs, specs, batch, threads, total_blocks, swap_mib, swap_policy }
}

/// Run one scheduler arm over the plan's request stream, submitting each
/// request at its arrival tick and driving `tick()` until drained. Returns
/// per-request (token stream, final-logit bits), id-ordered.
fn run_churn_arm(
    p: &ChurnPlan,
    c: &ModelConfig,
    oracle: bool,
    seed: u64,
) -> Vec<(Vec<i32>, Vec<u32>)> {
    let arm = if oracle { "oracle" } else { "batched" };
    let w = Weights::synthetic(c, 11);
    let threads = if oracle { 1 } else { p.threads };
    let mut engine = NativeEngine::new(
        c,
        w,
        p.specs.clone(),
        p.batch,
        64,
        8,
        threads,
        Some(PagedOptions {
            total_blocks: Some(p.total_blocks),
            swap_mib: p.swap_mib,
            swap_policy: p.swap_policy,
            ..PagedOptions::default()
        }),
    )
    .unwrap();
    if oracle {
        engine.set_sequential_decode(true);
    }
    let mut sched = Scheduler::new(
        Box::new(engine),
        "churn",
        SchedulerOptions {
            swap_policy: p.swap_policy,
            chunked_prefill: !oracle,
            capture_logits: true,
            ..SchedulerOptions::default()
        },
        Arc::new(Metrics::default()),
    );

    let mut rxs = Vec::new();
    let mut pending: Vec<(usize, Request)> = p
        .reqs
        .iter()
        .map(|r| {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            let req = Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                class: AccuracyClass::Balanced,
                arrival: Instant::now(),
                deadline: None,
                respond: tx,
            };
            (r.arrival, req)
        })
        .collect();

    let mut tick = 0usize;
    loop {
        let mut i = 0;
        while i < pending.len() {
            if pending[i].0 <= tick {
                let (_, req) = pending.remove(i);
                assert!(sched.submit(req), "seed {seed} [{arm}]: queue rejected a request");
            } else {
                i += 1;
            }
        }
        sched.tick().unwrap_or_else(|e| panic!("seed {seed} [{arm}]: tick {tick} failed: {e:#}"));
        if pending.is_empty() && sched.is_idle() {
            break;
        }
        tick += 1;
        assert!(tick < 20_000, "seed {seed} [{arm}]: scheduler failed to drain in 20k ticks");
    }

    rxs.into_iter()
        .enumerate()
        .map(|(id, rx)| {
            let r = rx
                .try_recv()
                .unwrap_or_else(|_| panic!("seed {seed} [{arm}]: request {id} got no response"));
            assert!(
                r.error.is_none(),
                "seed {seed} [{arm}]: request {id} degraded: {:?} (blocks={}, batch={})",
                r.error,
                p.total_blocks,
                p.batch
            );
            let bits = r
                .final_logits
                .unwrap_or_else(|| panic!("seed {seed} [{arm}]: request {id} missing final logits"))
                .iter()
                .map(|x| x.to_bits())
                .collect();
            (r.tokens, bits)
        })
        .collect()
}

/// The harness proper: for each seed, replay the identical request stream
/// through the chunked-prefill + batched-decode scheduler and through the
/// whole-prompt + sequential-oracle scheduler, under page pools tight enough
/// to force preempt/swap/resume churn, and demand bit-identical token
/// streams and final logits per request. On failure, rerun with the printed
/// seed to reproduce.
#[test]
fn churn_batched_scheduler_is_bit_identical_to_sequential_oracle() {
    let c = cfg();
    for case in 0..12u64 {
        let seed = 0xC0FFEE + case;
        let p = churn_plan(seed, &c);
        let oracle = run_churn_arm(&p, &c, true, seed);
        let batched = run_churn_arm(&p, &c, false, seed);
        assert_eq!(oracle.len(), batched.len());
        for (id, (o, b)) in oracle.iter().zip(&batched).enumerate() {
            assert_eq!(
                o.0, b.0,
                "seed {seed}: request {id} token stream diverged \
                 (threads={}, batch={}, blocks={}, swap={:?})",
                p.threads, p.batch, p.total_blocks, p.swap_policy
            );
            assert_eq!(
                o.1, b.1,
                "seed {seed}: request {id} final logits diverged \
                 (threads={}, batch={}, blocks={})",
                p.threads, p.batch, p.total_blocks
            );
        }
    }
}
