//! Cross-backend parity for the native kernel subsystem. Runs with zero
//! artifacts and without the `xla` feature (hosted CI exercises exactly
//! this file with `--no-default-features`, across a thread matrix via
//! `KVTUNER_THREADS`):
//!
//! * property-style sweep over every shipped precision pair × storage mode:
//!   native-engine logits (paged arm, block-table-direct attention) match
//!   the pure-Rust reference engine at tight tolerance — including a kivi
//!   residual-ring page-boundary prompt length;
//! * thread-count invariance: logits are *bit-identical* across pool sizes
//!   {1, 2, 8} for all nine precision pairs × token/kivi modes — the
//!   determinism-by-output-partitioning contract;
//! * block prefill vs token-by-token prefill is bit-exact (same pairs ×
//!   modes, including the kivi residual-ring page-boundary prompt and an
//!   exact multiple-of-group prompt);
//! * native dense arm vs native paged arm is bit-for-bit identical;
//! * prefix-page reuse on the native paged arm is bit-exact;
//! * dequant-on-read through `KvView` is bit-exact against dequantizing
//!   `gather_layer`'s dense staged output, and `staged_bytes` reports
//!   exactly what that gather materializes (the `gather_bytes` metric);
//! * the native path's staging counter is structurally zero.

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kernel;
use kvtuner::kvcache::{CacheBackend, KvView, PageAddr, PagedKvCache, PagedOptions};
use kvtuner::model::{RefEngine, Weights};
use kvtuner::quant::packed_width;
use kvtuner::tensor::Tensor;
use kvtuner::util::rng::Rng;

const S_MAX: usize = 64;
/// Crosses a page boundary (group = 8) and leaves a 5-token residual tail.
const PROMPT_LEN: usize = 13;
const MAX_NEW: usize = 12;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "native-test".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2, // GQA factor 2 exercised
        head_dim: 8,
        d_ff: 64,
        vocab: 48,
        rope_theta: 10000.0,
        group: 8,
        residual: 8,
        rms_eps: 1e-5,
    }
}

fn prompt(cfg: &ModelConfig, seed: usize) -> Vec<i32> {
    (0..PROMPT_LEN).map(|j| ((j * 7 + seed * 11 + 1) % cfg.vocab) as i32).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn native_paged_matches_ref_engine_across_all_pairs() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 7);
    let p = prompt(&cfg, 0);
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in PAIRS {
            let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
            let mut reff = RefEngine::new(&cfg, &w, specs.clone(), S_MAX).unwrap();
            let ref_out = reff.generate(&p, MAX_NEW).unwrap();
            let mut nat = NativeEngine::new(
                &cfg,
                w.clone(),
                specs,
                1,
                S_MAX,
                16,
                kernel::default_threads(),
                Some(PagedOptions::default()),
            )
            .unwrap();
            let nat_out = nat.generate(0, &p, MAX_NEW).unwrap();
            assert_eq!(
                ref_out,
                nat_out,
                "token stream diverged: {mode:?} {}",
                pair.label()
            );
            let d = max_abs_diff(&reff.last_logits, nat.logits(0));
            assert!(d <= 1e-3, "logits diverged by {d}: {mode:?} {}", pair.label());
        }
    }
    // the fp reference arm, for completeness
    let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
    let mut reff = RefEngine::new(&cfg, &w, specs.clone(), S_MAX).unwrap();
    let ref_out = reff.generate(&p, MAX_NEW).unwrap();
    let mut nat = NativeEngine::new(
        &cfg,
        w.clone(),
        specs,
        1,
        S_MAX,
        16,
        kernel::default_threads(),
        Some(PagedOptions::default()),
    )
    .unwrap();
    let nat_out = nat.generate(0, &p, MAX_NEW).unwrap();
    assert_eq!(ref_out, nat_out);
    assert!(max_abs_diff(&reff.last_logits, nat.logits(0)) <= 1e-3);
}

#[test]
fn native_dense_and_paged_are_bit_identical() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 21);
    let p = prompt(&cfg, 3);
    for (mode, pair) in [
        (Mode::Token, PrecisionPair::new(4, 4)),
        (Mode::Kivi, PrecisionPair::new(8, 4)),
        (Mode::Kivi, PrecisionPair::new(4, 2)),
    ] {
        let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
        let mut dense =
            NativeEngine::new(&cfg, w.clone(), specs.clone(), 1, S_MAX, 16, 2, None).unwrap();
        let dense_out = dense.generate(0, &p, MAX_NEW).unwrap();
        let mut paged = NativeEngine::new(
            &cfg,
            w.clone(),
            specs,
            1,
            S_MAX,
            16,
            2,
            Some(PagedOptions::default()),
        )
        .unwrap();
        let paged_out = paged.generate(0, &p, MAX_NEW).unwrap();
        assert_eq!(dense_out, paged_out, "{mode:?} {}", pair.label());
        // same codes, same scales, same fold -> identical floats
        let d = max_abs_diff(dense.logits(0), paged.logits(0));
        assert!(d <= 1e-6, "dense/paged drifted by {d}: {mode:?} {}", pair.label());
    }
}

#[test]
fn prefix_reuse_on_native_paged_arm_is_bit_exact() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 5);
    let p = prompt(&cfg, 9);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let mut nat =
        NativeEngine::new(&cfg, w, specs, 2, S_MAX, 16, 2, Some(PagedOptions::default())).unwrap();
    let first = nat.prefill(0, &p).unwrap();
    let logits0 = nat.logits(0).to_vec();
    nat.cache.register_prefix(0, &p);
    // slot 1: same prompt, served partly from the shared page chain
    let reused = nat.cache.prefill_reuse(1, &p);
    assert!(reused > 0, "one full page must be reusable");
    assert!(reused < p.len(), "at least one suffix token is always prefilled");
    let first2 = nat.prefill(1, &p[reused..]).unwrap();
    assert_eq!(first, first2, "prefix-served prefill changed the next token");
    assert!(max_abs_diff(&logits0, nat.logits(1)) <= 1e-6);
}

/// Fill one slot of a paged cache through the real scatter paths with
/// natively quantized content (same routine the native engine runs).
fn fill_paged(
    cache: &mut PagedKvCache,
    cfg: &ModelConfig,
    specs: &[LayerSpec],
    slot: usize,
    n_tokens: usize,
    seed: u64,
) {
    let (h, dh, g) = (cfg.n_kv_heads, cfg.head_dim, cfg.group);
    let mut r = Rng::seed(seed);
    for _t in 0..n_tokens {
        for (l, sp) in specs.iter().enumerate() {
            let k: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
            let v: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
            match sp.mode {
                Mode::Token => {
                    let outs = kernel::token_step_outputs(&k, &v, h, dh, sp.pair).unwrap();
                    cache.append_token_outputs(l, slot, &outs, &[1]).unwrap();
                }
                Mode::Kivi => {
                    let kt = Tensor::f32(&[1, h, 1, dh], k);
                    let vt = Tensor::f32(&[1, h, 1, dh], v);
                    let commit = cache.append_kivi_residual(l, slot, &kt, &vt, &[1]).unwrap();
                    if commit[0] {
                        let (kc, vc) = cache.residual_chunk(l, slot).unwrap();
                        let (ko, vo) =
                            kernel::kivi_commit_outputs(&kc, &vc, h, g, dh, sp.pair).unwrap();
                        cache.commit_kivi_chunk(l, slot, &ko, &vo).unwrap();
                    }
                }
                Mode::Fp => {
                    let kt = Tensor::f32(&[1, h, 1, dh], k);
                    let vt = Tensor::f32(&[1, h, 1, dh], v);
                    cache.append_fp(l, slot, &kt, &vt, &[1]).unwrap();
                }
            }
        }
        cache.advance_pos(slot, 1);
    }
}

/// Build a `KvView` over `gather_slot`'s staged dense tensors — the layouts
/// the XLA arm feeds its artifacts — so the exact same dequant walk can run
/// on both representations.
fn view_over_gathered<'a>(
    cfg: &ModelConfig,
    spec: LayerSpec,
    tensors: &'a [Tensor],
    cache_len: usize,
    res_len: usize,
    s_max: usize,
) -> KvView<'a> {
    let (h, dh, g) = (cfg.n_kv_heads, cfg.head_dim, cfg.group);
    let empty_f: &[f32] = &[];
    match spec.mode {
        Mode::Fp => KvView {
            spec,
            h,
            dh,
            kp: 0,
            vp: 0,
            page: g,
            cache_len,
            res_len,
            addr: PageAddr::Dense { slot: 0, s_max },
            k_codes: &[],
            k_scale: empty_f,
            k_zero: empty_f,
            v_codes: &[],
            v_scale: empty_f,
            v_zero: empty_f,
            k_fp: tensors[0].as_f32().unwrap(),
            v_fp: tensors[1].as_f32().unwrap(),
            k_res: empty_f,
            v_res: empty_f,
            res_cap: cfg.residual,
        },
        Mode::Token | Mode::Kivi => KvView {
            spec,
            h,
            dh,
            kp: packed_width(dh, spec.pair.k_bits).unwrap(),
            vp: packed_width(dh, spec.pair.v_bits).unwrap(),
            page: g,
            cache_len,
            res_len,
            addr: PageAddr::Dense { slot: 0, s_max },
            k_codes: tensors[0].as_u8().unwrap(),
            k_scale: tensors[1].as_f32().unwrap(),
            k_zero: tensors[2].as_f32().unwrap(),
            v_codes: tensors[3].as_u8().unwrap(),
            v_scale: tensors[4].as_f32().unwrap(),
            v_zero: tensors[5].as_f32().unwrap(),
            k_fp: empty_f,
            v_fp: empty_f,
            k_res: if spec.mode == Mode::Kivi {
                tensors[6].as_f32().unwrap()
            } else {
                empty_f
            },
            v_res: if spec.mode == Mode::Kivi {
                tensors[7].as_f32().unwrap()
            } else {
                empty_f
            },
            res_cap: cfg.residual,
        },
    }
}

#[test]
fn view_dequant_is_bit_exact_against_gather_output() {
    let cfg = tiny_cfg();
    let specs = vec![
        LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 4) },
        LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(4, 2) },
    ];
    let mut cache =
        PagedKvCache::new(&cfg, &specs, 1, S_MAX, &PagedOptions::default()).unwrap();
    fill_paged(&mut cache, &cfg, &specs, 0, PROMPT_LEN, 31);

    for (l, sp) in specs.iter().enumerate() {
        let view = cache.kv_view(l, 0).unwrap();
        let cache_len = view.cache_len;
        let res_len = view.res_len;
        let tensors = cache.gather_slot(l, 0).unwrap();
        // the satellite metric must report exactly what the gather staged
        let staged: usize = tensors.iter().map(|t| t.size_bytes()).sum();
        assert_eq!(
            cache.staged_bytes(l, 1),
            staged,
            "staged_bytes accounting out of sync with gather_layer (layer {l})"
        );
        let gview = view_over_gathered(&cfg, *sp, &tensors, cache_len, res_len, S_MAX);
        let dh = cfg.head_dim;
        for hh in 0..cfg.n_kv_heads {
            let mut from_pages_k = vec![0f32; cache_len * dh];
            let mut from_gather_k = vec![0f32; cache_len * dh];
            view.dequant_k_into(hh, &mut from_pages_k);
            gview.dequant_k_into(hh, &mut from_gather_k);
            assert_eq!(from_pages_k, from_gather_k, "K bits diverged (layer {l} head {hh})");
            let mut from_pages_v = vec![0f32; cache_len * dh];
            let mut from_gather_v = vec![0f32; cache_len * dh];
            view.dequant_v_into(hh, &mut from_pages_v);
            gview.dequant_v_into(hh, &mut from_gather_v);
            assert_eq!(from_pages_v, from_gather_v, "V bits diverged (layer {l} head {hh})");
        }
    }
}

#[test]
fn native_backend_never_stages() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 13);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let mut nat =
        NativeEngine::new(&cfg, w, specs, 1, S_MAX, 16, 2, Some(PagedOptions::default())).unwrap();
    let p = prompt(&cfg, 1);
    nat.generate(0, &p, MAX_NEW).unwrap();
    assert_eq!(
        EngineCore::gather_bytes(&nat),
        0,
        "the block-direct path must move zero staging bytes"
    );
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Determinism-by-output-partitioning: generation (block prefill + decode)
/// must produce bit-identical logits for every pool size, across all nine
/// precision pairs × token/kivi modes (plus fp).
#[test]
fn logits_bit_identical_across_pool_sizes() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 17);
    let p = prompt(&cfg, 4);
    let mut cases: Vec<(Mode, PrecisionPair)> = Vec::new();
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in PAIRS {
            cases.push((mode, pair));
        }
    }
    cases.push((Mode::Fp, PrecisionPair::FP));
    for (mode, pair) in cases {
        let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
        let run = |threads: usize| -> (Vec<i32>, Vec<u32>) {
            let mut nat = NativeEngine::new(
                &cfg,
                w.clone(),
                specs.clone(),
                1,
                S_MAX,
                16,
                threads,
                Some(PagedOptions::default()),
            )
            .unwrap();
            let out = nat.generate(0, &p, MAX_NEW).unwrap();
            (out, bits(nat.logits(0)))
        };
        let (tok1, log1) = run(1);
        for threads in [2, 8] {
            let (tok_n, log_n) = run(threads);
            assert_eq!(tok1, tok_n, "token stream: {mode:?} {} x{threads}", pair.label());
            assert_eq!(log1, log_n, "logit bits: {mode:?} {} x{threads}", pair.label());
        }
    }
}

/// Group-blocked prefill must be bit-exact against the token-by-token
/// oracle — first token, logits, and the decode steps that follow (whose
/// attention reads the cache both paths wrote). Covers the kivi
/// residual-ring page-boundary prompt (13 = 8 + 5-token fp tail) and an
/// exact multiple-of-group prompt (16 = two full pages).
#[test]
fn block_prefill_matches_tokenwise_bit_exact() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 29);
    let mut cases: Vec<(Mode, PrecisionPair)> = Vec::new();
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in PAIRS {
            cases.push((mode, pair));
        }
    }
    cases.push((Mode::Fp, PrecisionPair::FP));
    for (mode, pair) in cases {
        for plen in [PROMPT_LEN, 2 * cfg.group] {
            let p: Vec<i32> = (0..plen).map(|j| ((j * 5 + 2) % cfg.vocab) as i32).collect();
            let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
            let build = || {
                NativeEngine::new(
                    &cfg,
                    w.clone(),
                    specs.clone(),
                    1,
                    S_MAX,
                    16,
                    2,
                    Some(PagedOptions::default()),
                )
                .unwrap()
            };
            let mut tokenwise = build();
            let mut blocked = build();
            let first_t = tokenwise.prefill_tokenwise(0, &p).unwrap();
            let first_b = blocked.prefill(0, &p).unwrap();
            assert_eq!(first_t, first_b, "first token: {mode:?} {} len={plen}", pair.label());
            assert_eq!(
                bits(tokenwise.logits(0)),
                bits(blocked.logits(0)),
                "prefill logit bits: {mode:?} {} len={plen}",
                pair.label()
            );
            // decode over the caches each path wrote: identical pages ->
            // identical attention -> identical streams, bit for bit
            let (mut tok_t, mut tok_b) = (first_t, first_b);
            for step in 0..6 {
                let next_t = tokenwise.decode_step(&[tok_t], &[true]).unwrap()[0];
                let next_b = blocked.decode_step(&[tok_b], &[true]).unwrap()[0];
                assert_eq!(
                    bits(tokenwise.logits(0)),
                    bits(blocked.logits(0)),
                    "decode step {step} logit bits: {mode:?} {} len={plen}",
                    pair.label()
                );
                assert_eq!(next_t, next_b, "decode step {step}");
                tok_t = next_t;
                tok_b = next_b;
            }
        }
    }
}

#[test]
fn zero_threads_is_rejected() {
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 3);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), cfg.n_layers);
    assert!(NativeEngine::new(&cfg, w, specs, 1, S_MAX, 16, 0, None).is_err());
}
