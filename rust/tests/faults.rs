//! Chaos extension of the differential-churn harness (pure host, no
//! artifacts): seeded fault plans drive the scheduler's injection points —
//! swap-out/swap-in I/O, page allocation, engine step, worker death — and
//! the suite asserts the failure-domain contract:
//!
//! * every request terminates (typed failure or completion; nothing hangs),
//! * completed token streams + final logits are bit-identical to a
//!   fault-free oracle (injection displaces engine calls, never corrupts
//!   state),
//! * the page pool and host swap arena leak nothing after drain,
//! * a worker killed mid-serve is isolated by the router: its orphans are
//!   redispatched to a surviving sibling and complete there, and
//!   `shutdown()` still returns every engine's report.
//!
//! Every failing case prints its reproducing seed.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use kvtuner::coordinator::{
    AccuracyClass, FailureKind, Metrics, Request, Router, Scheduler, SchedulerOptions,
    Snapshot, WorkerSpec,
};
use kvtuner::engine::{BackendKind, EngineCore, NativeEngine};
use kvtuner::faults::{FaultInjector, FaultPlan, FaultRates};
use kvtuner::kvcache::{CacheBackend, PagedOptions, SwapPolicy};
use kvtuner::model::Weights;
use kvtuner::obs::{EventKind, Tracer};
use kvtuner::util::rng::Rng;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "faults-test".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 64,
        vocab: 128,
        rope_theta: 10000.0,
        group: 8,
        residual: 8,
        rms_eps: 1e-5,
    }
}

#[derive(Clone)]
struct ChaosReq {
    id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    arrival: usize,
    /// Submitted with an already-expired deadline: must come back as a
    /// typed `DeadlineExceeded` in every arm, and is excluded from the
    /// stream comparison.
    expired: bool,
}

struct ChaosPlan {
    reqs: Vec<ChaosReq>,
    specs: Vec<LayerSpec>,
    batch: usize,
    threads: usize,
    total_blocks: usize,
    swap_mib: Option<f64>,
    swap_policy: SwapPolicy,
}

/// Seeded workload, shaped like the churn harness's: page pool just above
/// the largest single request (forward progress guaranteed, concurrency
/// forces preemption), swap tier on for even seeds so the swap injection
/// points get traffic, and every third request carrying an expired deadline.
fn chaos_plan(seed: u64, c: &ModelConfig) -> ChaosPlan {
    let mut rng = Rng::seed(seed.wrapping_mul(0x9E37_79B9).wrapping_add(29));
    let n = rng.range(4, 8);
    let mut reqs = Vec::new();
    let mut floor_blocks = 0usize;
    for id in 0..n {
        let plen = rng.range(3, 21);
        let max_new = rng.range(1, 13);
        let arrival = rng.below(12);
        let prompt = (0..plen).map(|_| rng.below(c.vocab) as i32).collect();
        floor_blocks = floor_blocks.max((plen + max_new + c.group) / c.group + 1);
        reqs.push(ChaosReq {
            id: id as u64,
            prompt,
            max_new,
            arrival,
            expired: id % 3 == 2,
        });
    }
    let specs = (0..c.n_layers)
        .map(|_| LayerSpec {
            mode: *rng.choose(&[Mode::Token, Mode::Kivi]),
            pair: *rng.choose(&PAIRS),
        })
        .collect();
    let batch = rng.range(2, 5);
    let threads = [1, 2, 8][seed as usize % 3];
    let total_blocks = floor_blocks + rng.below(3);
    let (swap_mib, swap_policy) = if seed % 2 == 0 {
        (Some(4.0), SwapPolicy::Always)
    } else {
        (None, SwapPolicy::Off)
    };
    ChaosPlan { reqs, specs, batch, threads, total_blocks, swap_mib, swap_policy }
}

/// Drive one scheduler arm over the plan, tick-driven, until drained.
/// `rates: Some` arms the injector; `None` is the fault-free arm. Returns
/// per-request responses (id-ordered) plus the arm's metrics snapshot.
fn run_chaos_arm(
    p: &ChaosPlan,
    c: &ModelConfig,
    oracle: bool,
    rates: Option<FaultRates>,
    seed: u64,
) -> (Vec<kvtuner::coordinator::Response>, Snapshot) {
    let arm = if oracle { "oracle" } else { "chaos" };
    let w = Weights::synthetic(c, 11);
    let threads = if oracle { 1 } else { p.threads };
    let mut engine = NativeEngine::new(
        c,
        w,
        p.specs.clone(),
        p.batch,
        64,
        8,
        threads,
        Some(PagedOptions {
            total_blocks: Some(p.total_blocks),
            swap_mib: p.swap_mib,
            swap_policy: p.swap_policy,
            ..PagedOptions::default()
        }),
    )
    .unwrap();
    if oracle {
        engine.set_sequential_decode(true);
    }
    let metrics = Arc::new(Metrics::default());
    let mut sched = Scheduler::new(
        Box::new(engine),
        arm,
        SchedulerOptions {
            swap_policy: p.swap_policy,
            chunked_prefill: !oracle,
            capture_logits: true,
            faults: rates.map(|r| FaultInjector::new(&FaultPlan { seed, rates: r }, 0)),
            ..SchedulerOptions::default()
        },
        metrics.clone(),
    );

    let mut rxs = Vec::new();
    let mut pending: Vec<(usize, Request)> = p
        .reqs
        .iter()
        .map(|r| {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            let req = Request {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new,
                class: AccuracyClass::Balanced,
                arrival: Instant::now(),
                // an already-expired deadline: the scheduler must abandon it
                // typed at its first enforcement boundary
                deadline: r.expired.then(Instant::now),
                respond: tx,
            };
            (r.arrival, req)
        })
        .collect();

    let mut tick = 0usize;
    loop {
        let mut i = 0;
        while i < pending.len() {
            if pending[i].0 <= tick {
                let (_, req) = pending.remove(i);
                assert!(sched.submit(req), "seed {seed} [{arm}]: queue rejected a request");
            } else {
                i += 1;
            }
        }
        sched.tick().unwrap_or_else(|e| panic!("seed {seed} [{arm}]: tick {tick} failed: {e:#}"));
        if pending.is_empty() && sched.is_idle() {
            break;
        }
        tick += 1;
        // the termination contract: injected faults may stretch the run but
        // must never livelock it
        assert!(tick < 20_000, "seed {seed} [{arm}]: scheduler failed to drain in 20k ticks");
    }

    // leak check: after a full drain nothing may pin device pages or host
    // swap-arena bytes, no matter which fault paths fired
    let ms = sched.engine.cache().mem_stats();
    assert_eq!(ms.blocks_live, 0, "seed {seed} [{arm}]: leaked {} live blocks", ms.blocks_live);
    assert_eq!(
        ms.host_bytes_used, 0,
        "seed {seed} [{arm}]: leaked {} host swap bytes",
        ms.host_bytes_used
    );

    let responses = rxs
        .into_iter()
        .enumerate()
        .map(|(id, rx)| {
            rx.try_recv()
                .unwrap_or_else(|_| panic!("seed {seed} [{arm}]: request {id} got no response"))
        })
        .collect();
    (responses, metrics.snapshot())
}

/// Tentpole capstone: across >= 8 seeded mixed-rate plans, every request
/// terminates, expired-deadline requests fail typed in both arms, completed
/// streams and final logits are bit-identical to the fault-free oracle, and
/// nothing leaks.
#[test]
fn chaos_completed_streams_match_fault_free_oracle() {
    let c = cfg();
    let mut total_injected = 0u64;
    for case in 0..8u64 {
        let seed = 0xFA017 + case;
        let p = chaos_plan(seed, &c);
        let (oracle, _) = run_chaos_arm(&p, &c, true, None, seed);
        let plan = FaultPlan::from_seed(seed);
        let (chaos, snap) = run_chaos_arm(&p, &c, false, Some(plan.rates.clone()), seed);
        total_injected += snap.faults_injected;
        assert_eq!(oracle.len(), chaos.len());
        for (r, (o, ch)) in p.reqs.iter().zip(oracle.iter().zip(&chaos)) {
            if r.expired {
                for (arm, resp) in [("oracle", o), ("chaos", ch)] {
                    let f = resp.error.as_ref().unwrap_or_else(|| {
                        panic!("seed {seed} [{arm}]: expired request {} completed", r.id)
                    });
                    assert_eq!(
                        f.kind,
                        FailureKind::DeadlineExceeded,
                        "seed {seed} [{arm}]: request {} failed with the wrong kind",
                        r.id
                    );
                }
                continue;
            }
            assert!(
                o.error.is_none(),
                "seed {seed} [oracle]: request {} degraded: {:?}",
                r.id,
                o.error
            );
            assert!(
                ch.error.is_none(),
                "seed {seed} [chaos]: request {} degraded: {:?} \
                 (faults={}, retries={})",
                r.id,
                ch.error,
                snap.faults_injected,
                snap.retries
            );
            assert_eq!(
                o.tokens, ch.tokens,
                "seed {seed}: request {} token stream diverged under injected faults \
                 (threads={}, batch={}, blocks={}, swap={:?})",
                r.id, p.threads, p.batch, p.total_blocks, p.swap_policy
            );
            let ob: Vec<u32> =
                o.final_logits.as_ref().unwrap().iter().map(|x| x.to_bits()).collect();
            let cb: Vec<u32> =
                ch.final_logits.as_ref().unwrap().iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                ob, cb,
                "seed {seed}: request {} final logits diverged under injected faults",
                r.id
            );
        }
        // deadline failures are tallied per kind in the arm's metrics
        let expired = p.reqs.iter().filter(|r| r.expired).count() as u64;
        assert_eq!(snap.failed(FailureKind::DeadlineExceeded), expired, "seed {seed}");
    }
    assert!(
        total_injected > 0,
        "8 mixed-rate plans injected nothing — the injection points are dead"
    );
}

/// A fixed workload that forces preemption: 3 requests arriving together,
/// each needing ~7 of 8 pool blocks at peak, so two concurrent generations
/// cannot both stay resident — with `SwapPolicy::Always` and a host arena,
/// every eviction is a swap-out.
fn preempt_heavy_plan(c: &ModelConfig) -> ChaosPlan {
    let reqs = (0..3u64)
        .map(|id| ChaosReq {
            id,
            prompt: (0..16).map(|j| ((j * 7 + 13 * id as usize) % c.vocab) as i32).collect(),
            max_new: 24,
            arrival: 0,
            expired: false,
        })
        .collect();
    ChaosPlan {
        reqs,
        specs: LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), c.n_layers),
        batch: 2,
        threads: 1,
        // floor for one request: (16 + 24 + 8) / 8 + 1 = 7 blocks; 8 total
        // guarantees solo progress, forbids two resident peaks
        total_blocks: 8,
        swap_mib: Some(4.0),
        swap_policy: SwapPolicy::Always,
    }
}

/// Satellite: the SwapLost -> release + re-prefill fallback, driven through
/// the scheduler by injection. `swap_in_lost: 1.0` turns every swapped
/// resume into a loss; the request must recover via recompute and its
/// stream must still match the fault-free oracle bit-for-bit.
#[test]
fn injected_swap_loss_falls_back_to_reprefill_and_streams_match() {
    let c = cfg();
    let p = preempt_heavy_plan(&c);
    let seed = 0xDEAD01;
    let (oracle, osnap) = run_chaos_arm(&p, &c, true, None, seed);
    assert!(
        osnap.swap_outs > 0,
        "plan failed to force swap-outs (preemptions={}) — retune the pool",
        osnap.preemptions
    );
    let rates = FaultRates { swap_in_lost: 1.0, ..FaultRates::default() };
    let (chaos, snap) = run_chaos_arm(&p, &c, false, Some(rates), seed);
    assert!(snap.swap_outs > 0, "chaos arm produced no swap-outs");
    assert!(
        snap.swap_fallbacks > 0,
        "every swapped resume was injected Lost yet no fallback was recorded"
    );
    assert!(snap.faults_injected > 0);
    for (o, ch) in oracle.iter().zip(&chaos) {
        assert!(ch.error.is_none(), "request {} degraded: {:?}", ch.id, ch.error);
        assert_eq!(o.tokens, ch.tokens, "request {} diverged after SwapLost fallback", ch.id);
    }
}

/// Satellite: the HostArenaFull-shaped swap-out refusal -> recompute
/// fallback. `swap_out_fail: 1.0` refuses every swap-out before the copy;
/// victims must evict by recompute (stall recorded) and still finish with
/// oracle-identical streams.
#[test]
fn injected_swap_out_failure_falls_back_to_recompute_and_streams_match() {
    let c = cfg();
    let p = preempt_heavy_plan(&c);
    let seed = 0xDEAD02;
    let (oracle, _) = run_chaos_arm(&p, &c, true, None, seed);
    let rates = FaultRates { swap_out_fail: 1.0, ..FaultRates::default() };
    let (chaos, snap) = run_chaos_arm(&p, &c, false, Some(rates), seed);
    assert!(
        snap.swap_stalls > 0,
        "every swap-out was injected to fail yet no stall was recorded \
         (preemptions={})",
        snap.preemptions
    );
    assert_eq!(snap.swap_outs, 0, "a refused swap-out still copied bytes");
    for (o, ch) in oracle.iter().zip(&chaos) {
        assert!(ch.error.is_none(), "request {} degraded: {:?}", ch.id, ch.error);
        assert_eq!(o.tokens, ch.tokens, "request {} diverged after swap-out refusal", ch.id);
    }
}

fn synthetic_worker(name: &str, class: AccuracyClass, c: &ModelConfig) -> WorkerSpec {
    WorkerSpec {
        name: name.into(),
        model: c.name.clone(),
        specs: LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), c.n_layers),
        class,
        batch: 2,
        s_max: 512,
        prefill_chunk: 16,
        backend: BackendKind::Native,
        threads: 1,
        synthetic: Some(c.clone()),
        ..WorkerSpec::default()
    }
}

/// Tentpole, router level: an injected worker death mid-serve is confined to
/// its thread. Requests are pinned to the doomed worker by accuracy class;
/// its orphans are redispatched to the (different-class) survivor, every
/// request completes, the trace carries WorkerDeath + Redispatch events, and
/// shutdown() still reports both engines.
#[test]
fn worker_death_redispatches_orphans_to_survivor() {
    let c = cfg();
    let tracer = Arc::new(Tracer::with_default_capacity());
    let mut doomed = synthetic_worker("doomed", AccuracyClass::High, &c);
    // deterministic death at tick 40: far fewer ticks than the ~1500 the
    // workload needs, so orphans are guaranteed to exist at death
    doomed.faults = Some(FaultPlan::parse(r#"{"death_tick": 40}"#).unwrap());
    doomed.trace = Some(tracer.clone());
    let mut survivor = synthetic_worker("survivor", AccuracyClass::Balanced, &c);
    survivor.trace = Some(tracer.clone());

    let router = Router::start(std::env::temp_dir(), vec![doomed, survivor]).unwrap();
    // class High pins every request to the doomed worker while it lives
    let subs: Vec<_> = (0..6u64)
        .map(|i| {
            let prompt: Vec<i32> = (0..8).map(|j| ((j * 3 + i as usize) % c.vocab) as i32).collect();
            router.submit(prompt, 250, AccuracyClass::High).unwrap()
        })
        .collect();
    for (i, sub) in subs.into_iter().enumerate() {
        let r = sub.wait_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "request {i} failed after redispatch: {:?}", r.error);
        assert_eq!(r.tokens.len(), 250, "request {i} truncated");
        assert_eq!(r.engine, "survivor", "request {i} answered by the dead worker");
    }
    assert!(router.drain(Duration::from_secs(30)), "fleet failed to drain");

    let evs = tracer.events();
    let death: Vec<_> =
        evs.iter().filter(|e| e.kind == EventKind::WorkerDeath).collect();
    assert_eq!(death.len(), 1, "exactly one worker death expected");
    assert_eq!(death[0].worker, 0, "the doomed worker is pid 0");
    let orphans = death[0].arg;
    assert!(orphans >= 1, "death at tick 40 must orphan in-flight requests");
    let redispatched =
        evs.iter().filter(|e| e.kind == EventKind::Redispatch).count() as u64;
    assert_eq!(redispatched, orphans, "every orphan must be redispatched");

    let reports = router.shutdown().unwrap();
    assert_eq!(reports.len(), 2, "shutdown must report dead workers too");
    let done: u64 = reports.iter().map(|r| r.snapshot.requests_completed).sum();
    assert_eq!(done, 6);
}

/// Satellite regression: routing over a fully-dead fleet is a typed
/// `Unroutable` error, not a panic (the old `min_by_key(...).unwrap()` +
/// unchecked `send`).
#[test]
fn routing_to_a_dead_fleet_is_a_typed_error_not_a_panic() {
    let c = cfg();
    let mut solo = synthetic_worker("solo", AccuracyClass::Balanced, &c);
    solo.faults = Some(FaultPlan::parse(r#"{"death_tick": 1}"#).unwrap());
    let router = Router::start(std::env::temp_dir(), vec![solo]).unwrap();
    // the worker dies on its first tick; wait for the liveness flag to drop
    let t0 = Instant::now();
    while router.workers[0].alive.load(std::sync::atomic::Ordering::Relaxed) {
        assert!(t0.elapsed() < Duration::from_secs(30), "worker never died");
        std::thread::sleep(Duration::from_millis(5));
    }
    let err = match router.submit(vec![1, 2, 3], 4, AccuracyClass::Balanced) {
        Err(e) => e,
        Ok(sub) => {
            // raced the death window: the request slipped into the channel
            // before the thread exited — it must still resolve typed, not
            // hang (redispatch finds no sibling)
            let r = sub.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(r.error.unwrap().kind, FailureKind::WorkerDied);
            router.shutdown().unwrap();
            return;
        }
    };
    let f = err.downcast_ref::<kvtuner::coordinator::Failure>().expect("typed routing error");
    assert_eq!(f.kind, FailureKind::Unroutable);
    router.shutdown().unwrap();
}

/// An unarmed plan (all rates zero) must parse as a no-op so the serve CLI
/// can skip building an injector entirely; and an armed injector must be
/// droppable into SchedulerOptions without further plumbing.
#[test]
fn noop_plans_are_detected_and_armed_plans_thread_through_options() {
    assert!(FaultPlan::parse("{}").unwrap().is_noop());
    assert!(!FaultPlan::from_seed(3).is_noop());
    let opts = SchedulerOptions {
        faults: Some(FaultInjector::new(&FaultPlan::from_seed(3), 7)),
        ..SchedulerOptions::default()
    };
    assert!(opts.faults.is_some());
}
