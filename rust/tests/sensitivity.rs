//! Online sensitivity probe, end to end: (1) parity — a fully-sampled
//! online probe over an error-free (uniform-Fp) engine reproduces the
//! offline profiler's per-layer `ErrorMetrics` grid bit-for-bit, because
//! both paths feed the very same tensors through `quant::error`; (2) drift
//! — calibrate the envelope on one prompt family, serve another, and the
//! envelope-exceeded alert must surface as a typed trace event, a metrics
//! counter, and a line in the Chrome export.

use std::sync::atomic::{AtomicBool, AtomicUsize};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use kvtuner::coordinator::{AccuracyClass, Metrics, Request, Scheduler, SchedulerOptions};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kvcache::PagedOptions;
use kvtuner::model::Weights;
use kvtuner::obs::{EventKind, ProbeConfig, TraceSink, Tracer};
use kvtuner::tuner::{calib, profiler};
use kvtuner::util::rng::Rng;

/// The parity contract: with uniform-Fp layer specs the native engine's
/// forward pass is bit-identical to the reference capture the offline
/// profiler uses, so a probe that samples every group and evaluates the
/// same (mode, pair) grid must land on the exact same floats. One prompt
/// of exactly `cfg.group` tokens keeps both sides at a single sample per
/// layer — the offline weighted merge and the online sum/count mean are
/// both exact, so `==` on f64 is the right assertion, not a tolerance.
#[test]
fn online_probe_matches_offline_profiler_bit_for_bit() {
    let c = ModelConfig::synthetic("sens-parity");
    let w = Weights::synthetic(&c, 7);
    let prompt: Vec<i32> = (0..c.group).map(|j| ((j * 13 + 5) % c.vocab) as i32).collect();
    let modes = [Mode::Token, Mode::Kivi];

    let prof = profiler::profile(&c, &w, &[prompt.clone()], &modes).unwrap();

    // Fp specs: the served cache introduces no error, so every layer's
    // input matches the offline FP capture bitwise. The `modes` override
    // makes the probe evaluate the full grid even though no layer is
    // actually quantized.
    let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, c.n_layers);
    let paged = Some(PagedOptions::default());
    let mut e = NativeEngine::new(&c, w, specs, 1, 64, c.group, 1, paged).unwrap();
    e.set_probe(ProbeConfig { every: 1, modes: modes.to_vec(), ..ProbeConfig::default() });
    e.prefill(0, &prompt).unwrap();

    let snap = EngineCore::sensitivity(&e).expect("armed probe must expose a snapshot");
    for l in 0..c.n_layers {
        for mode in modes {
            for pair in PAIRS {
                let cell = format!("L{l} {} {}", mode.as_str(), pair.label());
                let online = snap.metrics(l, mode, pair).expect("full grid sampled");
                let offline = prof.errors[l][&(mode, pair)];
                assert_eq!(online.e_k, offline.e_k, "{cell}: e_k");
                assert_eq!(online.e_v, offline.e_v, "{cell}: e_v");
                assert_eq!(online.e_a, offline.e_a, "{cell}: e_a");
                assert_eq!(online.e_a_max, offline.e_a_max, "{cell}: e_a_max");
                assert_eq!(online.e_o, offline.e_o, "{cell}: e_o");
            }
        }
    }
    assert_eq!(
        snap.samples(),
        (c.n_layers * modes.len() * PAIRS.len()) as u64,
        "one 32-token prompt = exactly one sample per grid cell"
    );
}

/// Calibrate the envelope on the `Periodic` prompt family, then serve the
/// `Random` family through a real scheduler: the out-of-distribution
/// tensors must trip the envelope check, and the alert must be visible in
/// all three places the issue names — the typed trace event, the metrics
/// counter, and the Chrome export.
#[test]
fn drift_alert_fires_on_out_of_distribution_workload() {
    let c = ModelConfig::synthetic("sens-drift");
    let w = Weights::synthetic(&c, 9);
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(2, 2), c.n_layers);
    let mut rng = Rng::seed(11);

    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|_| calib::gen_prompt(calib::PromptFamily::Periodic, c.vocab, 64, &mut rng))
        .collect();
    let prof = profiler::profile(&c, &w, &prompts, &[Mode::Kivi]).unwrap();
    let env = prof.envelope_for(&specs);

    let paged = Some(PagedOptions::default());
    let mut engine = NativeEngine::new(&c, w, specs, 2, 128, c.group, 1, paged).unwrap();
    // Headroom 0.25 makes the test deterministic rather than lenient: for a
    // fixed bit width the *relative* quantization error varies only a small
    // factor (~2×) across input distributions, so a served family distinct
    // from the calibration family always lands above a quarter of the
    // calibrated per-layer peak — while a matched family at the shipped
    // default of 1.5× would never alert.
    engine.set_probe(ProbeConfig {
        every: 1,
        headroom: 0.25,
        envelope: Some(env),
        modes: Vec::new(),
    });

    let tracer = Arc::new(Tracer::with_default_capacity());
    let metrics = Arc::new(Metrics::default());
    let mut sched = Scheduler::new(
        Box::new(engine),
        "sens-worker",
        SchedulerOptions {
            trace: Some(TraceSink { tracer: tracer.clone(), worker: 0 }),
            ..SchedulerOptions::default()
        },
        metrics.clone(),
    );

    let (tx, rx) = mpsc::channel::<Request>();
    let mut responses = Vec::new();
    for id in 0..2u64 {
        let (rtx, rrx) = mpsc::channel();
        let prompt = calib::gen_prompt(calib::PromptFamily::Random, c.vocab, 64, &mut rng);
        tx.send(Request {
            id,
            prompt,
            max_new_tokens: 4,
            class: AccuracyClass::Balanced,
            arrival: Instant::now(),
            deadline: None,
            respond: rtx,
        })
        .unwrap();
        responses.push(rrx);
    }
    drop(tx);
    sched
        .run(&rx, Arc::new(AtomicBool::new(true)), Arc::new(AtomicUsize::new(0)))
        .unwrap();
    for (id, rrx) in responses.into_iter().enumerate() {
        let r = rrx.recv().expect("scheduler dropped a response channel");
        assert!(r.error.is_none(), "request {id} degraded: {:?}", r.error);
    }

    let snap = metrics.snapshot();
    assert!(snap.drift_alerts > 0, "out-of-family workload must leave the envelope");
    let evs = tracer.events();
    let drift: Vec<_> = evs.iter().filter(|e| e.kind == EventKind::Drift).collect();
    assert!(!drift.is_empty(), "drift must surface as a typed trace event");
    // the instant carries the cumulative count, and re-emits only on growth
    let last = drift.last().unwrap();
    assert_eq!(last.arg, snap.drift_alerts, "trace arg is the cumulative alert count");
    assert!(
        drift.windows(2).all(|w| w[0].arg < w[1].arg),
        "each drift instant must report strictly more alerts than the last"
    );
    assert!(
        tracer.to_chrome_json().to_string_pretty().contains("drift"),
        "the Chrome export must make the drift alert visible"
    );
}
