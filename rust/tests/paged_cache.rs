//! Pure-host tests for the paged KV cache subsystem: dense-vs-paged gather
//! equivalence, free-list recycling, prefix sharing (refcounts, resurrection,
//! copy-on-write), admission/preemption arithmetic, and memory accounting.
//! These need no artifacts — they exercise the cache layer directly.

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::kvcache::{CacheBackend, KvCache, OutOfPages, PagedKvCache, PagedOptions};
use kvtuner::tensor::Tensor;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "test".into(),
        n_layers: 3,
        d_model: 64,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 128,
        vocab: 64,
        rope_theta: 10000.0,
        group: 8, // page size
        residual: 8,
        rms_eps: 1e-5,
    }
}

fn mixed_specs() -> Vec<LayerSpec> {
    vec![
        LayerSpec { mode: Mode::Fp, pair: PrecisionPair::FP },
        LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 4) },
        LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(4, 2) },
    ]
}

fn token_specs(n: usize) -> Vec<LayerSpec> {
    LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), n)
}

/// Deterministic pseudo-random fill so dense and paged see identical writes.
fn fill(n: usize, seed: u64) -> Vec<f32> {
    let mut x = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    (0..n)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x % 1000) as f32 / 250.0 - 2.0
        })
        .collect()
}

fn fill_u8(n: usize, seed: u64) -> Vec<u8> {
    fill(n, seed).iter().map(|v| (v.abs() * 40.0) as u8).collect()
}

/// Apply the same appends to both arms: fp rows, token rows, kivi residual
/// rows + one fabricated group commit.
fn drive_both(dense: &mut KvCache, paged: &mut PagedKvCache, c: &ModelConfig) {
    let (h, dh, g) = (c.n_kv_heads, c.head_dim, c.group);
    let both = |d: &mut KvCache, p: &mut PagedKvCache, f: &mut dyn FnMut(&mut dyn CacheBackend)| {
        f(d);
        f(p);
    };

    // layer 0 (fp): 5 tokens on slot 0, 3 on slot 1, batched exec of 2
    let t = 5;
    let k = Tensor::f32(&[2, h, t, dh], fill(2 * h * t * dh, 1));
    let v = Tensor::f32(&[2, h, t, dh], fill(2 * h * t * dh, 2));
    both(dense, paged, &mut |cb| cb.append_fp(0, 0, &k, &v, &[5, 3]).unwrap());

    // layer 1 (token, K8V4): kp=16, vp=8 for dh=16
    let (kp, vp) = (16, 8);
    let outs = vec![
        Tensor::u8(&[2, h, t, kp], fill_u8(2 * h * t * kp, 3)),
        Tensor::f32(&[2, h, t], fill(2 * h * t, 4)),
        Tensor::f32(&[2, h, t], fill(2 * h * t, 5)),
        Tensor::u8(&[2, h, t, vp], fill_u8(2 * h * t * vp, 6)),
        Tensor::f32(&[2, h, t], fill(2 * h * t, 7)),
        Tensor::f32(&[2, h, t], fill(2 * h * t, 8)),
    ];
    both(dense, paged, &mut |cb| cb.append_token_outputs(1, 0, &outs, &[5, 3]).unwrap());
    // second append crosses the 8-token page boundary on slot 0
    both(dense, paged, &mut |cb| cb.append_token_outputs(1, 0, &outs, &[5, 0]).unwrap());

    // layer 2 (kivi, K4V2): fill the residual to a full group and commit
    for i in 0..g {
        let kr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 100 + i as u64));
        let vr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 200 + i as u64));
        both(dense, paged, &mut |cb| {
            let need = cb.append_kivi_residual(2, 0, &kr, &vr, &[1]).unwrap();
            assert_eq!(need[0], i + 1 == g);
        });
    }
    let (kp2, vp2) = (8, 4); // dh=16 at 4/2 bits
    let k_outs = vec![
        Tensor::u8(&[1, h, g, kp2], fill_u8(h * g * kp2, 9)),
        Tensor::f32(&[1, h, dh], fill(h * dh, 10)),
        Tensor::f32(&[1, h, dh], fill(h * dh, 11)),
    ];
    let v_outs = vec![
        Tensor::u8(&[1, h, g, vp2], fill_u8(h * g * vp2, 12)),
        Tensor::f32(&[1, h, g], fill(h * g, 13)),
        Tensor::f32(&[1, h, g], fill(h * g, 14)),
    ];
    both(dense, paged, &mut |cb| cb.commit_kivi_chunk(2, 0, &k_outs, &v_outs).unwrap());
    // leave a partial residual behind on slot 0
    let kr = Tensor::f32(&[1, h, 1, dh], fill(h * dh, 300));
    both(dense, paged, &mut |cb| {
        cb.append_kivi_residual(2, 0, &kr, &kr, &[1]).unwrap();
    });
}

#[test]
fn dense_and_paged_gathers_are_bit_identical() {
    let c = cfg();
    let specs = mixed_specs();
    let mut dense = KvCache::new(&c, &specs, 2, 32).unwrap();
    let mut paged = PagedKvCache::new(&c, &specs, 2, 32, &PagedOptions::default()).unwrap();
    drive_both(&mut dense, &mut paged, &c);

    for l in 0..specs.len() {
        assert_eq!(dense.layers[l].cache_len, vec![
            CacheBackend::cache_len(&paged, l, 0),
            CacheBackend::cache_len(&paged, l, 1)
        ]);
        // full-batch gather vs the dense buffers (fresh caches: the dense
        // arm's unwritten tail still holds its init values, which the paged
        // gather reproduces)
        let d: Vec<Tensor> = dense.layers[l].artifact_inputs().into_iter().cloned().collect();
        let p = paged.gather_batch(l).unwrap();
        assert_eq!(d.len(), p.len(), "layer {l} tensor count");
        for (i, (a, b)) in d.iter().zip(&p).enumerate() {
            assert_eq!(a, b, "layer {l} tensor {i} diverged");
        }
        // single-slot gather vs the dense slot slice
        for slot in 0..2 {
            let ds = dense.layers[l].slot_inputs(slot);
            let ps = paged.gather_slot(l, slot).unwrap();
            for (i, (a, b)) in ds.iter().zip(&ps).enumerate() {
                assert_eq!(a, b, "layer {l} slot {slot} tensor {i} diverged");
            }
        }
    }
}

#[test]
fn pages_recycle_through_the_free_list() {
    let c = cfg();
    let specs = token_specs(3);
    let mut kc = PagedKvCache::new(&c, &specs, 2, 32, &PagedOptions::default()).unwrap();
    let total = kc.total_blocks();
    assert_eq!(total, 2 * 32 / 8, "dense-equivalent default pool");

    // 20 tokens = 3 pages (one partial)
    CacheBackend::synthetic_fill(&mut kc, 0, 20).unwrap();
    assert_eq!(kc.block_table(0).len(), 3);
    assert_eq!(kc.free_blocks(), total - 3);
    let st = kc.mem_stats();
    assert_eq!(st.blocks_live, 3);
    // 4 unfilled rows in the partial tail page, across 3 token layers
    assert!(st.frag_bytes > 0, "partial page must report fragmentation");
    assert_eq!(st.bytes_total, CacheBackend::kv_bytes(&kc));

    let first_table: Vec<u32> = kc.block_table(0).to_vec();
    CacheBackend::reset_slot(&mut kc, 0);
    assert_eq!(kc.free_blocks(), total, "completion returns pages to the pool");
    assert_eq!(kc.mem_stats().frag_bytes, 0);

    // refill both slots: 6 of the 8 blocks get used, which wraps the FIFO
    // free list around to the recycled ids
    CacheBackend::synthetic_fill(&mut kc, 1, 20).unwrap();
    CacheBackend::synthetic_fill(&mut kc, 0, 20).unwrap();
    let reused = kc
        .block_table(0)
        .iter()
        .chain(kc.block_table(1))
        .filter(|id| first_table.contains(id))
        .count();
    assert!(reused >= 1, "free list should recycle freed ids");
}

#[test]
fn prefix_sharing_refcounts_resurrection_and_cow() {
    let c = cfg();
    let specs = token_specs(2);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        3,
        32,
        &PagedOptions { total_blocks: Some(12), ..PagedOptions::default() },
    )
    .unwrap();
    let prompt: Vec<i32> = (0..20).map(|i| (i * 3 % 64) as i32).collect();
    let h = c.n_kv_heads;

    // slot 0 "prefills" the prompt — real scatter writes, so shared pages
    // carry distinctive content — and publishes its full pages (2 of 8 tok;
    // the partial 4-token tail page is never shared)
    assert_eq!(CacheBackend::prefill_reuse(&mut kc, 0, &prompt), 0, "cold index");
    let t = 5;
    for l in 0..2usize {
        for a in 0..4u64 {
            let seed = l as u64 * 10 + a * 50;
            let outs = vec![
                Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, seed + 40)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 41)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 42)),
                Tensor::u8(&[1, h, t, 8], fill_u8(h * t * 8, seed + 43)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 44)),
                Tensor::f32(&[1, h, t], fill(h * t, seed + 45)),
            ];
            CacheBackend::append_token_outputs(&mut kc, l, 0, &outs, &[t]).unwrap();
        }
    }
    CacheBackend::register_prefix(&mut kc, 0, &prompt);

    // slot 1 with the same prompt reuses the 2 full pages
    let reused = CacheBackend::prefill_reuse(&mut kc, 1, &prompt);
    assert_eq!(reused, 16);
    assert_eq!(kc.prefix_hits, 1);
    assert_eq!(CacheBackend::pos(&kc, 1), 16);
    assert_eq!(kc.block_table(1)[..2], kc.block_table(0)[..2]);
    for &id in &kc.block_table(1)[..2] {
        assert_eq!(kc.ref_count(id), 2, "shared pages are refcounted");
    }
    CacheBackend::synthetic_fill(&mut kc, 1, prompt.len()).unwrap();
    assert_ne!(
        kc.block_table(1)[2],
        kc.block_table(0)[2],
        "suffix pages are private"
    );

    // a different prompt only matches the common prefix chain
    let mut other = prompt.clone();
    other[9] = 63; // diverge inside page 1
    let reused = CacheBackend::prefill_reuse(&mut kc, 2, &other);
    assert_eq!(reused, 8, "only page 0 matches after divergence");
    CacheBackend::reset_slot(&mut kc, 2);

    // copy-on-write: making slot 1's shared page writable copies it
    let before = kc.gather_slot(0, 1).unwrap();
    let shared = kc.block_table(1)[0];
    let new_id = kc.ensure_writable(1, 0).unwrap();
    assert_ne!(new_id, shared);
    assert_eq!(kc.cow_copies, 1);
    assert_eq!(kc.ref_count(shared), 1, "source page back to one owner");
    assert_eq!(kc.block_table(0)[0], shared, "owner's table untouched");
    let after = kc.gather_slot(0, 1).unwrap();
    assert_eq!(before, after, "CoW must preserve content");

    // free slot 0: its remaining shared page drops to refcount 1 (slot 1)
    let page1 = kc.block_table(0)[1];
    CacheBackend::reset_slot(&mut kc, 0);
    assert_eq!(kc.ref_count(page1), 1);

    // free slot 1 too: pages go to the free list but stay in the index —
    // a new identical prompt resurrects them without recompute
    CacheBackend::reset_slot(&mut kc, 1);
    let free_before = kc.free_blocks();
    let reused = CacheBackend::prefill_reuse(&mut kc, 0, &prompt);
    assert!(reused >= 8, "cached pages must resurrect, got {reused}");
    assert!(kc.free_blocks() < free_before);
}

#[test]
fn admission_and_decode_shortfall_track_the_pool() {
    let c = cfg();
    let specs = token_specs(2);
    let mut kc = PagedKvCache::new(
        &c,
        &specs,
        2,
        32,
        &PagedOptions { total_blocks: Some(3), ..PagedOptions::default() },
    )
    .unwrap();
    // 3 free blocks: a 9-token prompt needs 2 pages + 1 headroom = 3 -> ok
    assert!(CacheBackend::can_admit(&kc, 9, 16));
    // a 17-token prompt needs 3 pages + 1 headroom -> refused
    assert!(!CacheBackend::can_admit(&kc, 17, 16));

    // fill a slot to an exact page boundary: the next decode token needs a
    // fresh page per the shortfall accounting
    CacheBackend::synthetic_fill(&mut kc, 0, 16).unwrap();
    assert_eq!(kc.free_blocks(), 1);
    assert_eq!(CacheBackend::decode_block_shortfall(&kc, &[0]), 0, "one page left");
    CacheBackend::synthetic_fill(&mut kc, 1, 8).unwrap();
    assert_eq!(kc.free_blocks(), 0);
    // both slots sit on page boundaries, zero pages free -> shortfall 2
    assert_eq!(CacheBackend::decode_block_shortfall(&kc, &[0, 1]), 2);

    // an actual append past the boundary errors with the typed marker
    let (h, kp, vp) = (c.n_kv_heads, 8, 8);
    let outs = vec![
        Tensor::u8(&[1, h, 1, kp], vec![1; h * kp]),
        Tensor::f32(&[1, h, 1], vec![0.5; h]),
        Tensor::f32(&[1, h, 1], vec![0.1; h]),
        Tensor::u8(&[1, h, 1, vp], vec![2; h * vp]),
        Tensor::f32(&[1, h, 1], vec![0.5; h]),
        Tensor::f32(&[1, h, 1], vec![0.1; h]),
    ];
    let err = CacheBackend::append_token_outputs(&mut kc, 0, 0, &outs, &[1]).unwrap_err();
    assert!(err.downcast_ref::<OutOfPages>().is_some(), "{err:#}");

    // freeing the other slot unblocks the append
    CacheBackend::reset_slot(&mut kc, 1);
    CacheBackend::append_token_outputs(&mut kc, 0, 0, &outs, &[1]).unwrap();
    assert_eq!(CacheBackend::cache_len(&kc, 0, 0), 17);
}

#[test]
fn paged_rejects_misaligned_kivi_s_max() {
    let c = cfg(); // group 8
    let specs = vec![LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(4, 2) }; 3];
    assert!(PagedKvCache::new(&c, &specs, 1, 30, &PagedOptions::default()).is_err());
    assert!(PagedKvCache::new(&c, &specs, 1, 32, &PagedOptions::default()).is_ok());
}

#[test]
fn budget_caps_the_pool() {
    let c = cfg();
    let specs = mixed_specs();
    let full = PagedKvCache::new(&c, &specs, 4, 32, &PagedOptions::default()).unwrap();
    // halve the byte budget: the pool must shrink accordingly
    let budget_mib = CacheBackend::kv_bytes(&full) as f64 / (1024.0 * 1024.0) / 2.0;
    let half = PagedKvCache::new(
        &c,
        &specs,
        4,
        32,
        &PagedOptions { budget_mib: Some(budget_mib), ..PagedOptions::default() },
    )
    .unwrap();
    assert!(half.total_blocks() < full.total_blocks());
    assert!(half.total_blocks() >= full.total_blocks() / 4);
    assert!(PagedKvCache::new(
        &c,
        &specs,
        4,
        32,
        &PagedOptions { budget_mib: Some(0.000001), ..PagedOptions::default() }
    )
    .is_err());
}
