//! Serving-path observability, end to end at the scheduler level (pure
//! host, no artifacts): drive a real `Scheduler` over a native paged engine
//! sized so page pressure forces preemption, and assert the lifecycle
//! trace tells the true story — admit → prefill → decode → preempt(swap) →
//! swap-out → swap-in → resume → complete, in order, for every request —
//! plus that the Chrome export of that real trace is well-formed and the
//! latency histograms saw the traffic.

use std::sync::atomic::{AtomicBool, AtomicUsize};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use kvtuner::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use kvtuner::coordinator::{AccuracyClass, Metrics, Request, Scheduler, SchedulerOptions};
use kvtuner::engine::{EngineCore, NativeEngine};
use kvtuner::kvcache::{PagedOptions, SwapPolicy};
use kvtuner::obs::{EventKind, TraceEvent, TraceSink, Tracer};
use kvtuner::util::json::Json;

// Sized so the lifecycle is deterministic: a 7-token prompt is below one
// full page, so `register_prefix` publishes nothing and every victim page
// is host-copied at swap-out — the swap-in can never hit the recycled-link
// fallback, it just waits for free pages. Each request peaks at
// 7 + (MAX_NEW - 1) = 24 tokens = 3 pages; the 4-page pool runs one request
// comfortably (3 + 1 admission headroom) but not two (6 pages at peak), so
// exactly when both cross the 16->17 token page boundary the scheduler must
// swap one out, finish the other, then swap the victim back in.
const PROMPT_LEN: usize = 7;
const MAX_NEW: usize = 18;
const TOTAL_BLOCKS: usize = 4;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "obs-test".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        n_kv_heads: 2,
        head_dim: 16,
        d_ff: 64,
        vocab: 128,
        rope_theta: 10000.0,
        group: 8, // page size: small so pressure builds fast
        residual: 8,
        rms_eps: 1e-5,
    }
}

/// Event kinds for one request, in emission order.
fn kinds_for(evs: &[TraceEvent], req: u64) -> Vec<EventKind> {
    evs.iter().filter(|e| e.req == req).map(|e| e.kind).collect()
}

fn index_of(kinds: &[EventKind], k: EventKind) -> Option<usize> {
    kinds.iter().position(|&x| x == k)
}

/// Two requests against a pool that holds only one: the scheduler must
/// preempt, and with `SwapPolicy::Always` + a host arena the eviction is a
/// swap-out whose state later swaps back in bit-exact. The trace ring is
/// the witness for the whole lifecycle.
#[test]
fn scheduler_trace_records_preempt_swap_resume_lifecycle() {
    let c = cfg();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), c.n_layers);
    let w = kvtuner::model::Weights::synthetic(&c, 5);
    let engine = NativeEngine::new(
        &c,
        w,
        specs,
        2, // batch: both requests in flight so they contend
        64,
        8,
        1,
        Some(PagedOptions {
            total_blocks: Some(TOTAL_BLOCKS),
            swap_mib: Some(4.0),
            swap_policy: SwapPolicy::Always,
            ..PagedOptions::default()
        }),
    )
    .unwrap();

    let tracer = Arc::new(Tracer::with_default_capacity());
    let metrics = Arc::new(Metrics::default());
    let mut sched = Scheduler::new(
        Box::new(engine),
        "obs-worker",
        SchedulerOptions {
            swap_policy: SwapPolicy::Always,
            trace: Some(TraceSink { tracer: tracer.clone(), worker: 0 }),
            ..SchedulerOptions::default()
        },
        metrics.clone(),
    );

    // pre-load both requests, then run with shutdown already set: the loop
    // drains everything (including preempted work) and returns
    let (tx, rx) = mpsc::channel::<Request>();
    let mut responses = Vec::new();
    for id in 0..2u64 {
        let (rtx, rrx) = mpsc::channel();
        // distinct prompts so the contention is pure page pressure
        let prompt: Vec<i32> =
            (0..PROMPT_LEN).map(|j| ((j * 7 + 13 * id as usize) % c.vocab) as i32).collect();
        tx.send(Request {
            id,
            prompt,
            max_new_tokens: MAX_NEW,
            class: AccuracyClass::Balanced,
            arrival: Instant::now(),
            deadline: None,
            respond: rtx,
        })
        .unwrap();
        responses.push(rrx);
    }
    drop(tx);
    sched
        .run(&rx, Arc::new(AtomicBool::new(true)), Arc::new(AtomicUsize::new(0)))
        .unwrap();

    // both requests complete fully despite the pool holding only one
    for (id, rrx) in responses.into_iter().enumerate() {
        let r = rrx.recv().expect("scheduler dropped a response channel");
        assert_eq!(r.id, id as u64);
        assert!(r.error.is_none(), "request {id} degraded: {:?}", r.error);
        assert_eq!(r.tokens.len(), MAX_NEW, "request {id} was truncated");
    }

    let evs = tracer.events();
    assert_eq!(tracer.dropped(), 0, "this workload must fit the default ring");

    // every request's story starts with admit and ends with complete
    for id in 0..2u64 {
        let kinds = kinds_for(&evs, id);
        assert_eq!(kinds.first(), Some(&EventKind::Admit), "req {id}: {kinds:?}");
        assert_eq!(kinds.last(), Some(&EventKind::Complete), "req {id}: {kinds:?}");
        assert_eq!(
            kinds.iter().filter(|&&k| k == EventKind::Complete).count(),
            1,
            "req {id} completed more than once: {kinds:?}"
        );
        let prefill = index_of(&kinds, EventKind::PrefillChunk);
        let decode = index_of(&kinds, EventKind::DecodeStep);
        assert!(prefill.is_some() && decode.is_some(), "req {id}: {kinds:?}");
        assert!(prefill < decode, "req {id}: prefill must precede decode: {kinds:?}");
    }

    // page pressure forced a swap-out eviction, and the victim's events
    // appear in causal order: swap-out / preempt marker → swap-in → resume
    // → complete (the scheduler emits SwapOut just before Preempt)
    let victim = (0..2u64)
        .find(|&id| kinds_for(&evs, id).contains(&EventKind::Preempt { swap: true }))
        .expect("a 4-page pool under two 3-page requests must preempt by swap");
    let kinds = kinds_for(&evs, victim);
    let preempt = index_of(&kinds, EventKind::Preempt { swap: true }).unwrap();
    let swap_out = index_of(&kinds, EventKind::SwapOut).expect("swap eviction emits SwapOut");
    let swap_in = index_of(&kinds, EventKind::SwapIn)
        .expect("host-copied pages cannot be lost: the victim must swap back in");
    let resume = index_of(&kinds, EventKind::Resume).expect("victim must resume");
    let complete = index_of(&kinds, EventKind::Complete).unwrap();
    assert!(swap_out < swap_in, "req {victim}: {kinds:?}");
    assert!(preempt < swap_in, "req {victim}: {kinds:?}");
    assert!(swap_in < resume, "req {victim}: {kinds:?}");
    assert!(resume < complete, "req {victim}: {kinds:?}");
    // a swapped resume restores state bit-exact: no re-prefilled tokens
    let resume_ev = evs
        .iter()
        .filter(|e| e.req == victim)
        .find(|e| e.kind == EventKind::Resume)
        .unwrap();
    assert_eq!(resume_ev.arg, 0, "swapped resume must not re-prefill");
    // the swap round trip moved the same bytes out and back
    let bytes_of = |k: EventKind| {
        evs.iter().filter(|e| e.req == victim).find(|e| e.kind == k).unwrap().arg
    };
    assert!(bytes_of(EventKind::SwapOut) > 0);
    assert_eq!(bytes_of(EventKind::SwapOut), bytes_of(EventKind::SwapIn));

    // decode steps are spans (they carry duration); admits are instants
    assert!(
        evs.iter().any(|e| e.kind == EventKind::DecodeStep && e.dur_nanos > 0),
        "decode steps must be spans with wall time"
    );
    assert!(
        evs.iter().filter(|e| e.kind == EventKind::Admit).all(|e| e.dur_nanos == 0),
        "admits are instant events"
    );

    // the Chrome export of this real trace round-trips through the parser
    // and keeps the slot-per-track shape
    let j = tracer.to_chrome_json();
    let re = Json::parse(&j.to_string_pretty()).unwrap();
    let trace_events = re.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(trace_events.len(), evs.len());
    let decode_spans = trace_events
        .iter()
        .filter(|e| {
            e.get("name").unwrap().as_str().unwrap() == "decode_step"
                && e.get("ph").unwrap().as_str().unwrap() == "X"
        })
        .count();
    assert!(decode_spans > 0, "chrome export must contain decode-step spans");
    for e in trace_events {
        assert_eq!(e.get("pid").unwrap().as_usize().unwrap(), 0, "single worker = pid 0");
        assert!(e.get("tid").unwrap().as_usize().unwrap() < 2, "tid is the slot index");
    }

    // the bounded histograms saw the traffic the trace describes
    let s = metrics.snapshot();
    assert_eq!(s.requests_completed, 2);
    assert_eq!(
        s.tokens_generated as usize,
        2 * MAX_NEW - 2,
        "decode tokens (prefill's first token excluded)"
    );
    assert!(s.preemptions >= 1, "shortfall must have preempted");
    assert!(s.swap_outs >= 1 && s.swap_ins >= 1);
    assert_eq!(s.swap_fallbacks, 0, "host-copied pages never fall back to recompute");
    assert_eq!(s.swap_bytes_out, s.swap_bytes_in);
    assert!(s.ttft_p50 > 0.0 && s.ttft_p99 >= s.ttft_p50);
    assert!(s.total_p50 > 0.0 && s.total_p99 >= s.total_p50);
    assert!(s.tpot_p50 > 0.0, "18-token requests must record TPOT");
    assert!(s.step_p50 > 0.0, "decode steps must record wall time");
}

/// Regression for the profiler's per-layer live-KV peak: the highest
/// occupancy can exist only *between* engine steps. Two 24-token prompts
/// (3 full pages each of a 7-page pool) are resident together after
/// prefill — which never samples — and the very first decode tick must
/// evict one before the batched step runs, so the step path's own
/// sampling never sees the 48-token moment. Only the scheduler's
/// swap-site `sample_kv_live` calls (just before eviction, and again
/// after swap-in) can record it.
#[test]
fn kv_live_peak_includes_the_pre_eviction_moment() {
    let c = cfg();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), c.n_layers);
    let w = kvtuner::model::Weights::synthetic(&c, 6);
    let paged = PagedOptions {
        total_blocks: Some(7),
        swap_mib: Some(4.0),
        swap_policy: SwapPolicy::Always,
        ..PagedOptions::default()
    };
    let mk = || {
        NativeEngine::new(&c, w.clone(), specs.clone(), 3, 64, 8, 1, Some(paged.clone())).unwrap()
    };
    // distinct prompts, so no page is shared and eviction must free real
    // bytes rather than collapse onto a common prefix
    let pa: Vec<i32> = (0..24).map(|j| ((j * 5 + 1) % c.vocab) as i32).collect();
    let pb: Vec<i32> = (0..24).map(|j| ((j * 11 + 3) % c.vocab) as i32).collect();
    let pc: Vec<i32> = (0..9).map(|j| ((j * 3 + 2) % c.vocab) as i32).collect();

    // reference: per-layer live bytes with both prompts resident at once —
    // exactly the state the scheduled run reaches right before eviction
    let mut reference = mk();
    reference.prefill(0, &pa).unwrap();
    reference.prefill(1, &pb).unwrap();
    let expected = reference.cache().layer_kv_live();
    assert!(expected.iter().all(|&b| b > 0), "reference must hold bytes at every layer");

    let mut engine = mk();
    engine.set_profiling(true);
    let metrics = Arc::new(Metrics::default());
    let mut sched = Scheduler::new(
        Box::new(engine),
        "obs-worker",
        SchedulerOptions { swap_policy: SwapPolicy::Always, ..SchedulerOptions::default() },
        metrics.clone(),
    );
    let (tx, rx) = mpsc::channel::<Request>();
    let mut responses = Vec::new();
    let reqs = vec![(pa, 2usize), (pb, 2), (pc, 1)];
    for (id, (prompt, max_new)) in reqs.into_iter().enumerate() {
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            id: id as u64,
            prompt,
            max_new_tokens: max_new,
            class: AccuracyClass::Balanced,
            arrival: Instant::now(),
            deadline: None,
            respond: rtx,
        })
        .unwrap();
        responses.push(rrx);
    }
    drop(tx);
    sched
        .run(&rx, Arc::new(AtomicBool::new(true)), Arc::new(AtomicUsize::new(0)))
        .unwrap();
    for rrx in responses {
        let r = rrx.recv().expect("scheduler dropped a response channel");
        assert!(r.error.is_none(), "request {} degraded: {:?}", r.id, r.error);
    }
    assert!(metrics.snapshot().preemptions >= 1, "growth past the 7-page pool must preempt");

    let prof = sched.engine.profile().expect("profiling was on");
    for (l, want) in expected.iter().enumerate() {
        assert!(
            prof.layers[l].kv_live_peak >= *want as u64,
            "layer {l}: live-KV peak {} missed the both-resident moment ({want} bytes) — \
             the scheduler's swap-site sampling regressed",
            prof.layers[l].kv_live_peak
        );
    }
}
