//! Integration tests across the tuner pipeline, evaluators, engine batching,
//! and the serving coordinator. Requires `make artifacts`.

use std::sync::Arc;

use kvtuner::config::{LayerSpec, Manifest, Mode, PrecisionPair};
use kvtuner::coordinator::{AccuracyClass, Router, WorkerSpec};
use kvtuner::engine::{BackendKind, Engine};
use kvtuner::kvcache::{CacheBackend, PagedOptions, SwapPolicy};
use kvtuner::model::Weights;
use kvtuner::runtime::Runtime;
use kvtuner::tuner::{self, calib, Algorithm, MooOptions, TuneOptions};

fn manifest() -> Option<Manifest> {
    let dir = kvtuner::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest"))
}

#[test]
fn fp_reference_is_exactly_self_consistent() {
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let w = Weights::load(&m, &cfg.name).unwrap();
    let prompts = calib::calib_set(cfg.vocab, 4, 32, 7);
    let r = tuner::build_reference(&cfg, &w, &prompts, 16).unwrap();
    let fp_specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
    let acc = tuner::fidelity_accuracy(&cfg, &w, &r, &fp_specs).unwrap();
    assert_eq!(acc, 1.0);
    // KV8 must be (near-)lossless — the paper's baseline claim
    let kv8 = LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), cfg.n_layers);
    let acc8 = tuner::fidelity_accuracy(&cfg, &w, &r, &kv8).unwrap();
    assert!(acc8 > 0.95, "KV8 fidelity {acc8}");
}

#[test]
fn perplexity_orders_with_precision() {
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let w = Weights::load(&m, &cfg.name).unwrap();
    let prompts = calib::calib_set(cfg.vocab, 4, 24, 11);
    let r = tuner::build_reference(&cfg, &w, &prompts, 16).unwrap();
    let ppl = |mode, k, v| {
        let specs = LayerSpec::uniform(mode, PrecisionPair::new(k, v), cfg.n_layers);
        tuner::pseudo_perplexity(&cfg, &w, &r, &specs).unwrap()
    };
    let fp = {
        let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
        tuner::pseudo_perplexity(&cfg, &w, &r, &specs).unwrap()
    };
    let p8 = ppl(Mode::Token, 8, 8);
    let p2 = ppl(Mode::Token, 2, 2);
    assert!(fp <= p8 * 1.05, "fp {fp} vs kv8 {p8}");
    assert!(p2 > p8 * 1.1, "kv2 {p2} should be clearly worse than kv8 {p8}");
}

#[test]
fn kivi_beats_token_at_4bit_keys_on_outlier_model() {
    // The KIVI-vs-per-token gap (paper Sec. 4.2): channel outliers in keys
    // make per-channel key quantization much more accurate.
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let w = Weights::load(&m, "tiny-robust").unwrap();
    let prompts = calib::calib_set(cfg.vocab, 6, 40, 13);
    let r = tuner::build_reference(&cfg, &w, &prompts, 24).unwrap();
    let acc = |mode| {
        let specs = LayerSpec::uniform(mode, PrecisionPair::new(4, 4), cfg.n_layers);
        tuner::fidelity_accuracy(&cfg, &w, &r, &specs).unwrap()
    };
    let kivi = acc(Mode::Kivi);
    let token = acc(Mode::Token);
    assert!(kivi >= token - 0.02, "kivi {kivi} vs token {token}");
    assert!(kivi > 0.8, "kivi KV4 should be near-lossless on robust model, got {kivi}");
}

#[test]
fn tuner_pipeline_end_to_end_invariants() {
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let w = Weights::load(&m, &cfg.name).unwrap();
    let opts = TuneOptions {
        mode: Mode::Kivi,
        n_prompts: 4,
        prompt_len: 32,
        horizon: 16,
        moo: MooOptions { evaluations: 24, population: 8, ..Default::default() },
        algorithm: Algorithm::Nsga2,
        ..Default::default()
    };
    let r = tuner::run_pipeline(&cfg, &w, &opts).unwrap();
    // pruning keeps at least the extremes per layer
    for cands in &r.pruned {
        assert!(!cands.is_empty());
        assert!(cands.iter().any(|c| c.bits >= 8.0));
        assert!(cands.iter().any(|c| c.bits <= 2.0));
        // candidates sorted high-precision first and non-dominated
        for win in cands.windows(2) {
            assert!(win[0].bits >= win[1].bits);
        }
    }
    // groups partition the layers
    let covered: usize = r.groups.iter().map(|g| g.layers.len()).sum();
    assert_eq!(covered, cfg.n_layers);
    // front is non-dominated and non-empty
    assert!(!r.front.is_empty());
    for a in &r.front {
        for b in &r.front {
            let dom = b.bits <= a.bits && b.accuracy >= a.accuracy
                && (b.bits < a.bits || b.accuracy > a.accuracy);
            assert!(!dom, "front point dominated");
        }
    }
    // selected configs respect their ceilings
    for c in &r.configs {
        assert!(c.equivalent_bits <= 6.0 + 1e-9);
        assert_eq!(c.specs.len(), cfg.n_layers);
    }
}

#[test]
fn moead_and_nsga2_both_reach_high_accuracy_corner() {
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let w = Weights::load(&m, &cfg.name).unwrap();
    for algo in [Algorithm::Nsga2, Algorithm::Moead] {
        let opts = TuneOptions {
            mode: Mode::Kivi,
            n_prompts: 3,
            prompt_len: 24,
            horizon: 12,
            moo: MooOptions { evaluations: 16, population: 6, ..Default::default() },
            algorithm: algo,
            ..Default::default()
        };
        let r = tuner::run_pipeline(&cfg, &w, &opts).unwrap();
        let best = r.front.iter().map(|p| p.accuracy).fold(0.0, f64::max);
        assert!(best > 0.8, "{algo:?} best accuracy {best}");
    }
}

#[test]
fn tuned_config_json_roundtrip() {
    let Some(m) = manifest() else { return };
    let cfg = m.config.clone();
    let specs: Vec<LayerSpec> = (0..cfg.n_layers)
        .map(|l| LayerSpec {
            mode: Mode::Kivi,
            pair: if l % 2 == 0 { PrecisionPair::new(8, 4) } else { PrecisionPair::new(4, 2) },
        })
        .collect();
    let c = tuner::TunedConfig {
        model: cfg.name.clone(),
        mode: Mode::Kivi,
        specs: specs.clone(),
        equivalent_bits: LayerSpec::equivalent_bits(&specs),
        accuracy: 0.93,
        label: "KVTuner-C4.50".into(),
        envelope: Some(kvtuner::obs::Envelope {
            layers: (0..cfg.n_layers)
                .map(|l| kvtuner::obs::EnvelopeBound {
                    e_k: 0.01 * (l + 1) as f64,
                    e_v: 0.02,
                    e_a: 0.003,
                    e_o: 0.004,
                })
                .collect(),
        }),
    };
    let path = std::env::temp_dir().join("kvtuner_test_cfg.json");
    c.save(&path).unwrap();
    let back = tuner::TunedConfig::load(&path).unwrap();
    assert_eq!(back.specs, specs);
    assert_eq!(back.label, c.label);
    assert!((back.equivalent_bits - c.equivalent_bits).abs() < 1e-9);
    // the calibration envelope rides through the JSON round trip, and its
    // absence (configs saved before it existed) parses as None
    assert_eq!(back.envelope, c.envelope);
    let mut legacy = c.clone();
    legacy.envelope = None;
    legacy.save(&path).unwrap();
    assert_eq!(tuner::TunedConfig::load(&path).unwrap().envelope, None);
}

#[test]
fn engine_batch_decode_matches_single_slot() {
    // batch=2 decode with one active slot must produce the same tokens as
    // B=1-style generation of that sequence alone (slot isolation).
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = rt.manifest.config.clone();
    let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), cfg.n_layers);

    let prompt: Vec<i32> = (0..20).map(|i| (i * 7) % cfg.vocab as i32).collect();
    let mut eng = Engine::new(rt.clone(), &cfg.name, specs.clone(), 2, 256, 32).unwrap();
    // run the same prompt in both slots, decode both active
    let a = eng.generate(0, &prompt, 12).unwrap();
    eng.cache.reset_slot(0);
    eng.cache.reset_slot(1);
    let mut next0 = eng.prefill(0, &prompt).unwrap();
    let mut next1 = eng.prefill(1, &prompt).unwrap();
    assert_eq!(next0, next1, "same prompt, same first token");
    let mut both = vec![vec![next0], vec![next1]];
    for _ in 0..11 {
        let out = eng.decode_step(&[next0, next1], &[true, true]).unwrap();
        next0 = out[0];
        next1 = out[1];
        both[0].push(next0);
        both[1].push(next1);
    }
    assert_eq!(both[0], both[1], "slots drifted");
    assert_eq!(both[0], a, "batched decode differs from single-slot generate");
}

#[test]
fn router_serves_mixed_classes_end_to_end() {
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let batch = *m.decode_batches().last().unwrap();
    let workers = vec![
        WorkerSpec {
            name: "high".into(),
            model: cfg.name.clone(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers),
            class: AccuracyClass::High,
            batch,
            s_max: 256,
            prefill_chunk: 32,
            paged: None,
            backend: BackendKind::Xla,
            threads: 1,
            ..WorkerSpec::default()
        },
        WorkerSpec {
            name: "efficient".into(),
            model: cfg.name.clone(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers),
            class: AccuracyClass::Efficient,
            batch,
            s_max: 256,
            prefill_chunk: 32,
            paged: None,
            backend: BackendKind::Xla,
            threads: 1,
            ..WorkerSpec::default()
        },
    ];
    let router = Router::start(dir, workers).expect("router start");
    let mut subs = Vec::new();
    for i in 0..6u64 {
        let class = if i % 2 == 0 { AccuracyClass::High } else { AccuracyClass::Efficient };
        let prompt: Vec<i32> = (0..16).map(|j| ((j as u64 * 5 + i) % cfg.vocab as u64) as i32).collect();
        subs.push((class, router.submit(prompt, 8, class).unwrap()));
    }
    for (class, sub) in subs {
        let r = sub.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 8);
        let expect = match class {
            AccuracyClass::High => "high",
            _ => "efficient",
        };
        assert_eq!(r.engine, expect, "routed to wrong engine");
        assert!(r.ttft <= r.total);
    }
    let reports = router.shutdown().unwrap();
    let total: u64 = reports.iter().map(|r| r.snapshot.requests_completed).sum();
    assert_eq!(total, 6);
    for r in &reports {
        assert!(r.snapshot.tokens_per_sec_decode > 0.0);
    }
}

#[test]
fn scheduler_handles_more_requests_than_slots() {
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let workers = vec![WorkerSpec {
        name: "solo".into(),
        model: cfg.name.clone(),
        specs: LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), cfg.n_layers),
        class: AccuracyClass::Balanced,
        batch: 2,
        s_max: 256,
        prefill_chunk: 32,
        paged: None,
        backend: BackendKind::Xla,
        threads: 1,
        ..WorkerSpec::default()
    }];
    let router = Router::start(dir, workers).unwrap();
    // 7 requests through 2 slots: forces queueing + slot reuse
    let subs: Vec<_> = (0..7u64)
        .map(|i| {
            let prompt: Vec<i32> = (0..10).map(|j| ((j * 3 + i as usize) % cfg.vocab) as i32).collect();
            router.submit(prompt, 6, AccuracyClass::Balanced).unwrap()
        })
        .collect();
    for sub in subs {
        let r = sub.wait_timeout(std::time::Duration::from_secs(180)).unwrap();
        assert!(r.error.is_none());
        assert_eq!(r.tokens.len(), 6);
    }
    router.shutdown().unwrap();
}

#[test]
fn prompt_longer_than_slot_is_clamped_not_fatal() {
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let workers = vec![WorkerSpec {
        name: "clamp".into(),
        model: cfg.name.clone(),
        specs: LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
        class: AccuracyClass::Balanced,
        batch: 1,
        s_max: 256,
        prefill_chunk: 32,
        paged: None,
        backend: BackendKind::Xla,
        threads: 1,
        ..WorkerSpec::default()
    }];
    let router = Router::start(dir, workers).unwrap();
    let prompt: Vec<i32> = (0..400).map(|j| (j % cfg.vocab) as i32).collect(); // > s_max
    let sub = router.submit(prompt, 8, AccuracyClass::Balanced).unwrap();
    let r = sub.wait_timeout(std::time::Duration::from_secs(120)).unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.tokens.len(), 8);
    router.shutdown().unwrap();
}

#[test]
fn paged_engine_matches_dense_end_to_end() {
    // the paged arm must be bit-exact with the dense reference: same
    // executables, same quantization path, pages gathered into the same
    // layout — identical tokens AND identical final logits.
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = m.config.clone();
    let modes = [Mode::Fp, Mode::Token, Mode::Kivi];
    let specs: Vec<LayerSpec> = (0..cfg.n_layers)
        .map(|l| {
            let mode = modes[l % 3];
            LayerSpec {
                mode,
                pair: match mode {
                    Mode::Fp => PrecisionPair::FP,
                    Mode::Token => PrecisionPair::new(8, 4),
                    Mode::Kivi => PrecisionPair::new(4, 2),
                },
            }
        })
        .collect();
    let prompt: Vec<i32> = (0..48).map(|i| (i * 5 % cfg.vocab) as i32).collect();

    let mut dense = Engine::new(rt.clone(), &cfg.name, specs.clone(), 1, 256, 32).unwrap();
    let a = dense.generate(0, &prompt, 24).unwrap();
    let dense_logits = dense.last_logits[0].clone();

    let mut paged = Engine::new_paged(
        rt,
        &cfg.name,
        specs,
        1,
        256,
        32,
        PagedOptions::default(),
    )
    .unwrap();
    let b = paged.generate(0, &prompt, 24).unwrap();
    assert_eq!(a, b, "paged tokens diverged from dense");
    assert_eq!(dense_logits, paged.last_logits[0], "paged logits diverged from dense");
    assert!(paged.cache.is_paged());
}

#[test]
fn paged_router_oversubscribes_slots_beyond_pool() {
    // batch=2 slots but a page pool sized for roughly one full sequence:
    // the scheduler must queue/preempt/resume instead of failing, and every
    // request must still complete with its full token budget.
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let workers = vec![WorkerSpec {
        name: "paged".into(),
        model: cfg.name.clone(),
        specs: LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), cfg.n_layers),
        class: AccuracyClass::Balanced,
        batch: 2,
        s_max: 256,
        prefill_chunk: 32,
        // ~1.5 sequences of prompt 40 + 24 new tokens (64 tokens = 2 pages
        // of 32) -> 3 blocks; admission headroom forces contention
        paged: Some(PagedOptions { total_blocks: Some(3), ..PagedOptions::default() }),
        backend: BackendKind::Xla,
        threads: 1,
        ..WorkerSpec::default()
    }];
    let router = Router::start(dir, workers).unwrap();
    let subs: Vec<_> = (0..5u64)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..40).map(|j| ((j * 3 + i as usize) % cfg.vocab) as i32).collect();
            router.submit(prompt, 24, AccuracyClass::Balanced).unwrap()
        })
        .collect();
    for sub in subs {
        let r = sub.wait_timeout(std::time::Duration::from_secs(300)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 24);
    }
    let reports = router.shutdown().unwrap();
    assert_eq!(reports[0].snapshot.requests_completed, 5);
}

#[test]
fn paged_router_reuses_shared_prompt_prefixes() {
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let workers = vec![WorkerSpec {
        name: "paged".into(),
        model: cfg.name.clone(),
        specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers),
        class: AccuracyClass::Balanced,
        batch: 2,
        s_max: 256,
        prefill_chunk: 32,
        paged: Some(PagedOptions::default()),
        backend: BackendKind::Xla,
        threads: 1,
        ..WorkerSpec::default()
    }];
    let router = Router::start(dir, workers).unwrap();
    // identical 64-token system prompt + distinct 8-token tails
    let system: Vec<i32> = (0..64).map(|j| (j * 7 % cfg.vocab) as i32).collect();
    let subs: Vec<_> = (0..4u64)
        .map(|i| {
            let mut prompt = system.clone();
            prompt.extend((0..8).map(|j| ((j + i as usize) % cfg.vocab) as i32));
            router.submit(prompt, 8, AccuracyClass::Balanced).unwrap()
        })
        .collect();
    for sub in subs {
        let r = sub.wait_timeout(std::time::Duration::from_secs(300)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 8);
    }
    let reports = router.shutdown().unwrap();
    let s = &reports[0].snapshot;
    assert!(s.prefix_hits >= 1, "no prefix reuse recorded: {s}");
    assert!(s.prefix_tokens_reused >= 64, "reused too little: {s}");
}

#[test]
fn swapped_engine_resume_is_bit_exact() {
    // prefill + half the decode, swap the sequence out of the paged pool,
    // swap it back into the *other* slot, finish decoding: the token stream
    // and final logits must be bit-identical to an uninterrupted run.
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let rt = Arc::new(Runtime::load(dir).unwrap());
    let cfg = rt.manifest.config.clone();
    // kivi layers so the fp residual ring rides through the swap too
    let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers);
    let popts = PagedOptions { swap_mib: Some(8.0), ..PagedOptions::default() };
    let prompt: Vec<i32> = (0..40).map(|i| (i * 3) % cfg.vocab as i32).collect();

    let mut eng =
        Engine::new_paged(rt.clone(), &cfg.name, specs.clone(), 2, 256, 32, popts).unwrap();
    assert!(eng.cache.swap_enabled());
    let reference = eng.generate(0, &prompt, 12).unwrap();
    let ref_logits = eng.last_logits[0].clone();
    eng.cache.reset_slot(0);

    let mut next = eng.prefill(0, &prompt).unwrap();
    let mut got = vec![next];
    for _ in 0..6 {
        next = eng.decode_step(&[next, 0], &[true, false]).unwrap()[0];
        got.push(next);
    }
    let h = eng.cache.swap_out(0).unwrap();
    assert!(h.host_bytes > 0, "private pages must move to the host tier");
    assert!(eng.cache.can_swap_in(&h));
    eng.cache.swap_in(1, &h).unwrap();
    eng.cache.release_swap(h);
    for _ in 0..5 {
        next = eng.decode_step(&[0, next], &[false, true]).unwrap()[1];
        got.push(next);
    }
    assert_eq!(got, reference, "swap round trip changed the decode");
    assert_eq!(eng.last_logits[1].len(), ref_logits.len());
    for (i, (a, b)) in eng.last_logits[1].iter().zip(&ref_logits).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logit {i} diverged after swap");
    }
    let st = eng.cache.swap_stats();
    assert_eq!((st.swap_outs, st.swap_ins), (1, 1));
}

#[test]
fn swap_enabled_router_drains_oversubscribed_pool() {
    // a pool too small for two growing sequences, with an always-swap
    // policy: the scheduler must preempt by swap-out and resume the victim
    // bit-exact (full token budget, no error), with swap counters moving.
    let Some(m) = manifest() else { return };
    let dir = kvtuner::default_artifact_dir();
    let cfg = m.config.clone();
    let page = cfg.group;
    let prompt_len = page.saturating_sub(8).max(4);
    let max_new = page + page / 2; // each sequence outgrows 2 pages
    let workers = vec![WorkerSpec {
        name: "paged-swap".into(),
        model: cfg.name.clone(),
        specs: LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), cfg.n_layers),
        class: AccuracyClass::Balanced,
        batch: 2,
        s_max: 256,
        prefill_chunk: 32,
        paged: Some(PagedOptions {
            total_blocks: Some(4),
            swap_mib: Some(8.0),
            swap_policy: SwapPolicy::Always,
            ..PagedOptions::default()
        }),
        backend: BackendKind::Xla,
        threads: 1,
        ..WorkerSpec::default()
    }];
    let router = Router::start(dir, workers).unwrap();
    let subs: Vec<_> = (0..3u64)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|j| ((j * 3 + i as usize) % cfg.vocab) as i32).collect();
            router.submit(prompt, max_new, AccuracyClass::Balanced).unwrap()
        })
        .collect();
    for sub in subs {
        let r = sub.wait_timeout(std::time::Duration::from_secs(300)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), max_new);
    }
    let reports = router.shutdown().unwrap();
    let s = &reports[0].snapshot;
    assert_eq!(s.requests_completed, 3);
    assert!(s.preemptions >= 1, "pool must be oversubscribed: {s}");
    assert!(s.swap_outs >= 1, "always-policy must swap victims out: {s}");
    assert!(
        s.swap_ins + s.swap_fallbacks >= 1,
        "swapped victims must resume one way or the other: {s}"
    );
    assert_eq!(s.swap_stalls, 0, "8 MiB arena must not overflow: {s}");
}
