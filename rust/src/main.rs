//! KVTuner CLI — subcommands are wired in `cli_main.rs` as the crate grows.

fn main() -> anyhow::Result<()> {
    kvtuner::cli_main()
}
