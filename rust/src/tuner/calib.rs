//! Calibration prompt generator (paper Sec. 5.3 "Calibration Dataset
//! Design"): deterministic synthetic prompt families chosen to exercise
//! different attention regimes — periodic/copy structure for induction-like
//! retrieval, random streams for diffuse attention, and walk sequences for
//! local recency — so error accumulation differentiates precision pairs the
//! way GSM8K CoT prompts do in the paper.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptFamily {
    /// i.i.d. uniform tokens.
    Random,
    /// A random motif repeated (copy / induction-head structure).
    Periodic,
    /// Bounded random walk through the vocab (local structure).
    Walk,
}

pub const FAMILIES: [PromptFamily; 3] =
    [PromptFamily::Random, PromptFamily::Periodic, PromptFamily::Walk];

pub fn gen_prompt(family: PromptFamily, vocab: usize, len: usize, rng: &mut Rng) -> Vec<i32> {
    match family {
        PromptFamily::Random => (0..len).map(|_| rng.below(vocab) as i32).collect(),
        PromptFamily::Periodic => {
            let period = rng.range(4, 12.min(len.max(5)));
            let motif: Vec<i32> = (0..period).map(|_| rng.below(vocab) as i32).collect();
            (0..len).map(|i| motif[i % period]).collect()
        }
        PromptFamily::Walk => {
            let mut t = rng.below(vocab) as i64;
            (0..len)
                .map(|_| {
                    let step = rng.range(0, 7) as i64 - 3;
                    t = (t + step).rem_euclid(vocab as i64);
                    t as i32
                })
                .collect()
        }
    }
}

/// A calibration set cycling through the families, fully deterministic.
pub fn calib_set(vocab: usize, n_prompts: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::seed(seed);
    (0..n_prompts)
        .map(|i| gen_prompt(FAMILIES[i % FAMILIES.len()], vocab, len, &mut rng))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let a = calib_set(256, 9, 48, 42);
        let b = calib_set(256, 9, 48, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 9);
        for p in &a {
            assert_eq!(p.len(), 48);
            assert!(p.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn periodic_actually_repeats() {
        let mut rng = Rng::seed(1);
        let p = gen_prompt(PromptFamily::Periodic, 100, 40, &mut rng);
        // find a period <= 12 that explains the sequence
        let ok = (4..=12).any(|per| (per..p.len()).all(|i| p[i] == p[i - per]));
        assert!(ok, "{p:?}");
    }

    #[test]
    fn families_differ() {
        let s = calib_set(256, 3, 64, 7);
        assert_ne!(s[0], s[1]);
        assert_ne!(s[1], s[2]);
    }
}
