//! Intra-layer KV precision-pair pruning (paper Sec. 5.3): per layer, keep
//! only the Pareto frontier of (equivalent bits, relative attention output
//! error e_o) over the 9 candidate pairs. This is the first stage of the
//! two-level search-space reduction (S^L -> S_p^L).

use crate::config::{Mode, PrecisionPair, PAIRS};
use crate::quant::ErrorMetrics;

use super::profiler::Profile;

/// A candidate point for one layer.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub pair: PrecisionPair,
    pub bits: f64,
    pub e_o: f64,
}

/// Generic 2-D Pareto filter: keep points not dominated in
/// (minimize a, minimize b). Stable order: by bits descending (high
/// precision first), matching the paper's table presentation.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    let mut keep = Vec::new();
    'outer: for (i, &(a, b)) in points.iter().enumerate() {
        for (j, &(a2, b2)) in points.iter().enumerate() {
            if j != i && a2 <= a && b2 <= b && (a2 < a || b2 < b) {
                continue 'outer;
            }
        }
        keep.push(i);
    }
    keep
}

/// Prune one layer's candidate pairs under `mode`.
pub fn prune_layer(profile: &Profile, layer: usize, mode: Mode) -> Vec<Candidate> {
    let cands: Vec<Candidate> = PAIRS
        .iter()
        .map(|&pair| {
            let e = profile.errors[layer]
                .get(&(mode, pair))
                .copied()
                .unwrap_or(ErrorMetrics::default());
            Candidate { pair, bits: pair.equivalent_bits(), e_o: e.e_o }
        })
        .collect();
    let pts: Vec<(f64, f64)> = cands.iter().map(|c| (c.bits, c.e_o)).collect();
    let mut keep: Vec<Candidate> = pareto_front(&pts).into_iter().map(|i| cands[i]).collect();
    keep.sort_by(|a, b| b.bits.partial_cmp(&a.bits).unwrap());
    keep
}

/// Prune every layer; returns per-layer candidate sets.
pub fn prune_all(profile: &Profile, mode: Mode) -> Vec<Vec<Candidate>> {
    (0..profile.n_layers).map(|l| prune_layer(profile, l, mode)).collect()
}

/// The label set of a layer's pruned candidates (used to group layers with
/// identical preference structure, paper Table 4 / first clustering step).
pub fn candidate_signature(cands: &[Candidate]) -> String {
    cands.iter().map(|c| c.pair.label()).collect::<Vec<_>>().join(",")
}

/// log10 search-space sizes before/after pruning (paper's 9^L -> prod |S_p^l|).
pub fn search_space_log10(cands: &[Vec<Candidate>]) -> (f64, f64) {
    let full = cands.len() as f64 * (PAIRS.len() as f64).log10();
    let pruned = cands.iter().map(|c| (c.len() as f64).log10()).sum();
    (full, pruned)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_front_basics() {
        // points: (bits, err); (4,0.1) dominates (4,0.2) and (5,0.15)
        let pts = vec![(4.0, 0.1), (4.0, 0.2), (5.0, 0.15), (3.0, 0.5), (2.0, 0.9)];
        let keep = pareto_front(&pts);
        assert!(keep.contains(&0));
        assert!(!keep.contains(&1));
        assert!(!keep.contains(&2));
        assert!(keep.contains(&3));
        assert!(keep.contains(&4));
    }

    #[test]
    fn front_always_contains_extremes() {
        let pts = vec![(8.0, 0.01), (5.0, 0.2), (2.0, 0.95), (6.0, 0.02), (3.0, 0.4)];
        let keep = pareto_front(&pts);
        // cheapest point and most accurate point always survive
        assert!(keep.contains(&0));
        assert!(keep.contains(&2));
    }

    #[test]
    fn duplicates_both_kept() {
        let pts = vec![(4.0, 0.1), (4.0, 0.1)];
        assert_eq!(pareto_front(&pts).len(), 2);
    }
}
