//! Multi-objective search over per-group precision picks (paper Sec. 5.1):
//!
//!   min_P ( f_m(P), -f_acc(P) )   s.t.  f_m(P) <= M
//!
//! where P indexes each layer group's pruned candidate list, f_m is mean
//! equivalent KV bits, and f_acc is the black-box accuracy evaluator
//! (generation fidelity vs the fp reference). Two engines are provided —
//! NSGA-II (default) and MOEA/D (the paper's choice) — both from scratch;
//! the ablation bench compares them and the no-pruning variant.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::rng::Rng;

use super::cluster::LayerGroup;

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub picks: Vec<usize>,
    pub bits: f64,
    pub accuracy: f64,
}

/// Search options.
#[derive(Debug, Clone)]
pub struct MooOptions {
    pub evaluations: usize,
    pub population: usize,
    pub seed: u64,
    /// Soft equivalent-bits ceilings; the paper searches at 4- and 6-bit.
    pub bit_constraints: Vec<f64>,
    pub mutation_rate: f64,
}

impl Default for MooOptions {
    fn default() -> Self {
        MooOptions {
            evaluations: 200,
            population: 20,
            seed: 17,
            bit_constraints: vec![4.0, 6.0],
            mutation_rate: 0.2,
        }
    }
}

fn genome_bits(groups: &[LayerGroup], picks: &[usize]) -> f64 {
    let mut total = 0.0;
    let mut n = 0usize;
    for (g, &p) in groups.iter().zip(picks) {
        total += g.candidates[p].bits * g.layers.len() as f64;
        n += g.layers.len();
    }
    total / n as f64
}

/// Cache of evaluated genomes (accuracy evals are expensive).
pub struct EvalCache<'a> {
    pub groups: &'a [LayerGroup],
    eval_fn: Box<dyn Fn(&[usize]) -> Result<f64> + Sync + 'a>,
    cache: BTreeMap<Vec<usize>, f64>,
    pub evals: usize,
    /// Total eval() calls including cache hits — the search loops' progress
    /// guard (a genome space smaller than the eval budget must still halt).
    pub lookups: usize,
    pub history: Vec<EvalPoint>,
}

impl<'a> EvalCache<'a> {
    pub fn new(
        groups: &'a [LayerGroup],
        eval_fn: impl Fn(&[usize]) -> Result<f64> + Sync + 'a,
    ) -> Self {
        EvalCache {
            groups,
            eval_fn: Box::new(eval_fn),
            cache: BTreeMap::new(),
            evals: 0,
            lookups: 0,
            history: Vec::new(),
        }
    }

    /// log2 of the genome space size (saturating).
    pub fn space_log2(&self) -> f64 {
        self.groups.iter().map(|g| (g.candidates.len() as f64).log2()).sum()
    }

    /// True while the search budget allows more work: fresh evals remain AND
    /// the lookup guard (10x budget) hasn't tripped (the whole space may be
    /// smaller than the budget).
    pub fn budget_left(&self, evaluations: usize) -> bool {
        self.evals < evaluations && self.lookups < evaluations.saturating_mul(10)
    }

    pub fn eval(&mut self, picks: &[usize]) -> Result<EvalPoint> {
        self.lookups += 1;
        let bits = genome_bits(self.groups, picks);
        if let Some(&acc) = self.cache.get(picks) {
            return Ok(EvalPoint { picks: picks.to_vec(), bits, accuracy: acc });
        }
        let acc = (self.eval_fn)(picks)?;
        self.cache.insert(picks.to_vec(), acc);
        self.evals += 1;
        let pt = EvalPoint { picks: picks.to_vec(), bits, accuracy: acc };
        self.history.push(pt.clone());
        Ok(pt)
    }
}

/// Pareto front over (minimize bits, maximize accuracy).
pub fn pareto_front_points(points: &[EvalPoint]) -> Vec<EvalPoint> {
    let mut front: Vec<EvalPoint> = Vec::new();
    'outer: for p in points {
        for q in points {
            if (q.bits <= p.bits && q.accuracy >= p.accuracy)
                && (q.bits < p.bits || q.accuracy > p.accuracy)
            {
                continue 'outer;
            }
        }
        if !front.iter().any(|f| f.picks == p.picks) {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.bits.partial_cmp(&b.bits).unwrap());
    front
}

// ---------------------------------------------------------------------------
// NSGA-II
// ---------------------------------------------------------------------------

fn dominates(a: &EvalPoint, b: &EvalPoint) -> bool {
    (a.bits <= b.bits && a.accuracy >= b.accuracy) && (a.bits < b.bits || a.accuracy > b.accuracy)
}

/// Fast non-dominated sort; returns front index per point (0 = best).
fn nondominated_rank(pts: &[EvalPoint]) -> Vec<usize> {
    let n = pts.len();
    let mut rank = vec![0usize; n];
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&pts[i], &pts[j]) {
                dominates_list[i].push(j);
            } else if i != j && dominates(&pts[j], &pts[i]) {
                dominated_by[i] += 1;
            }
        }
    }
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut r = 0;
    let mut remaining = dominated_by;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            rank[i] = r;
            for &j in &dominates_list[i] {
                remaining[j] -= 1;
                if remaining[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        r += 1;
    }
    rank
}

/// Crowding distance within one front (bigger = more isolated = preferred).
fn crowding(pts: &[EvalPoint], idxs: &[usize]) -> BTreeMap<usize, f64> {
    let mut out: BTreeMap<usize, f64> = idxs.iter().map(|&i| (i, 0.0)).collect();
    for dim in 0..2 {
        let mut order = idxs.to_vec();
        let key = |i: usize| if dim == 0 { pts[i].bits } else { pts[i].accuracy };
        order.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        let lo = key(order[0]);
        let hi = key(*order.last().unwrap());
        let span = (hi - lo).max(1e-12);
        *out.get_mut(&order[0]).unwrap() = f64::INFINITY;
        *out.get_mut(order.last().unwrap()).unwrap() = f64::INFINITY;
        for w in order.windows(3) {
            *out.get_mut(&w[1]).unwrap() += (key(w[2]) - key(w[0])) / span;
        }
    }
    out
}

pub fn nsga2(cache: &mut EvalCache, opts: &MooOptions) -> Result<Vec<EvalPoint>> {
    let groups = cache.groups;
    let n_groups = groups.len();
    let mut rng = Rng::seed(opts.seed);
    let rand_genome = |rng: &mut Rng| -> Vec<usize> {
        (0..n_groups).map(|g| rng.below(groups[g].candidates.len())).collect()
    };

    // seed the population with every uniform-PAIR config (each group picks
    // the candidate matching that pair, or the nearest by bits) — these are
    // exactly the paper's uniform baselines, so the searched front can only
    // dominate them — plus randoms
    let mut pop: Vec<EvalPoint> = Vec::new();
    for pair in crate::config::PAIRS {
        let genome: Vec<usize> = groups
            .iter()
            .map(|g| {
                g.candidates
                    .iter()
                    .position(|c| c.pair == pair)
                    .unwrap_or_else(|| {
                        // nearest candidate by equivalent bits
                        let target = pair.equivalent_bits();
                        g.candidates
                            .iter()
                            .enumerate()
                            .min_by(|a, b| {
                                (a.1.bits - target)
                                    .abs()
                                    .partial_cmp(&(b.1.bits - target).abs())
                                    .unwrap()
                            })
                            .map(|(i, _)| i)
                            .unwrap_or(0)
                    })
            })
            .collect();
        pop.push(cache.eval(&genome)?);
        if !cache.budget_left(opts.evaluations) {
            break;
        }
    }
    while pop.len() < opts.population && cache.budget_left(opts.evaluations) {
        let g = rand_genome(&mut rng);
        pop.push(cache.eval(&g)?);
    }
    if pop.is_empty() {
        pop.push(cache.eval(&vec![0; n_groups])?);
    }

    while cache.budget_left(opts.evaluations) {
        // tournament selection by (rank, crowding)
        let ranks = nondominated_rank(&pop);
        let all_idx: Vec<usize> = (0..pop.len()).collect();
        let crowd = crowding(&pop, &all_idx);
        let select = |rng: &mut Rng| -> usize {
            let a = rng.below(pop.len());
            let b = rng.below(pop.len());
            if ranks[a] < ranks[b] || (ranks[a] == ranks[b] && crowd[&a] > crowd[&b]) {
                a
            } else {
                b
            }
        };
        // offspring
        let mut children = Vec::new();
        while children.len() < opts.population && cache.budget_left(opts.evaluations) {
            let (pa, pb) = (select(&mut rng), select(&mut rng));
            let mut child: Vec<usize> = (0..n_groups)
                .map(|g| if rng.chance(0.5) { pop[pa].picks[g] } else { pop[pb].picks[g] })
                .collect();
            for g in 0..n_groups {
                if rng.chance(opts.mutation_rate) {
                    // local move preferred: step one candidate up/down
                    let len = groups[g].candidates.len();
                    let cur = child[g];
                    child[g] = if rng.chance(0.5) && len > 1 {
                        (cur + if rng.chance(0.5) { 1 } else { len - 1 }) % len
                    } else {
                        rng.below(len)
                    };
                }
            }
            children.push(cache.eval(&child)?);
        }
        // environmental selection: combine, rank, truncate
        pop.extend(children);
        let ranks = nondominated_rank(&pop);
        let all_idx: Vec<usize> = (0..pop.len()).collect();
        let crowd = crowding(&pop, &all_idx);
        let mut order: Vec<usize> = all_idx;
        order.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then(crowd[&b].partial_cmp(&crowd[&a]).unwrap())
        });
        order.truncate(opts.population);
        pop = order.into_iter().map(|i| pop[i].clone()).collect();
    }
    Ok(pareto_front_points(&cache.history))
}

// ---------------------------------------------------------------------------
// MOEA/D (Tchebycheff decomposition; the paper's algorithm)
// ---------------------------------------------------------------------------

pub fn moead(cache: &mut EvalCache, opts: &MooOptions) -> Result<Vec<EvalPoint>> {
    let groups = cache.groups;
    let n_groups = groups.len();
    let n = opts.population.max(4);
    let mut rng = Rng::seed(opts.seed ^ 0x5eed);

    // weight vectors over the 2 objectives (normalized bits / accuracy)
    let weights: Vec<(f64, f64)> = (0..n)
        .map(|i| {
            let w = i as f64 / (n - 1) as f64;
            (w, 1.0 - w)
        })
        .collect();
    // neighborhoods: adjacent weight indices
    let t_size = 4.min(n);
    let neighbors: Vec<Vec<usize>> = (0..n)
        .map(|i| {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by_key(|&j| (j as i64 - i as i64).abs());
            idx.truncate(t_size);
            idx
        })
        .collect();

    let mut pop: Vec<EvalPoint> = Vec::new();
    for _ in 0..n {
        let g: Vec<usize> =
            (0..n_groups).map(|gi| rng.below(groups[gi].candidates.len())).collect();
        pop.push(cache.eval(&g)?);
    }
    // ideal point
    let mut z = (
        pop.iter().map(|p| p.bits).fold(f64::INFINITY, f64::min),
        pop.iter().map(|p| p.accuracy).fold(f64::NEG_INFINITY, f64::max),
    );
    let bits_span = 8.0 - 2.0;
    let tcheby = |p: &EvalPoint, w: (f64, f64), z: (f64, f64)| -> f64 {
        let d1 = (p.bits - z.0).abs() / bits_span;
        let d2 = (z.1 - p.accuracy).abs();
        (w.0 * d1).max(w.1 * d2)
    };

    while cache.budget_left(opts.evaluations) {
        for i in 0..n {
            if !cache.budget_left(opts.evaluations) {
                break;
            }
            // recombine within the neighborhood
            let pa = neighbors[i][rng.below(t_size)];
            let pb = neighbors[i][rng.below(t_size)];
            let mut child: Vec<usize> = (0..n_groups)
                .map(|g| if rng.chance(0.5) { pop[pa].picks[g] } else { pop[pb].picks[g] })
                .collect();
            for g in 0..n_groups {
                if rng.chance(opts.mutation_rate) {
                    child[g] = rng.below(groups[g].candidates.len());
                }
            }
            let c = cache.eval(&child)?;
            z.0 = z.0.min(c.bits);
            z.1 = z.1.max(c.accuracy);
            for &j in &neighbors[i] {
                if tcheby(&c, weights[j], z) < tcheby(&pop[j], weights[j], z) {
                    pop[j] = c.clone();
                }
            }
        }
    }
    Ok(pareto_front_points(&cache.history))
}

/// Pick, from a front, the best-accuracy config whose bits fit a ceiling —
/// the paper's "KVTuner-C<bits>" selections.
pub fn select_under_constraint(front: &[EvalPoint], max_bits: f64) -> Option<EvalPoint> {
    front
        .iter()
        .filter(|p| p.bits <= max_bits + 1e-9)
        .max_by(|a, b| {
            a.accuracy
                .partial_cmp(&b.accuracy)
                .unwrap()
                .then(b.bits.partial_cmp(&a.bits).unwrap())
        })
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrecisionPair;
    use crate::tuner::pareto::Candidate;

    fn groups2() -> Vec<LayerGroup> {
        let c = |k: u8, v: u8, e: f64| Candidate {
            pair: PrecisionPair::new(k, v),
            bits: (k as f64 + v as f64) / 2.0,
            e_o: e,
        };
        vec![
            LayerGroup {
                layers: vec![0, 1],
                candidates: vec![c(8, 8, 0.01), c(4, 4, 0.1), c(2, 2, 0.8)],
            },
            LayerGroup {
                layers: vec![2],
                candidates: vec![c(8, 8, 0.02), c(4, 2, 0.2), c(2, 2, 0.9)],
            },
        ]
    }

    /// Synthetic accuracy: layers weighted, quadratic penalty on error.
    fn acc_fn(groups: &[LayerGroup]) -> impl Fn(&[usize]) -> Result<f64> + Sync + '_ {
        move |picks: &[usize]| {
            let mut acc = 1.0;
            for (g, &p) in groups.iter().zip(picks) {
                acc -= g.candidates[p].e_o * g.layers.len() as f64 * 0.3;
            }
            Ok(acc.max(0.0))
        }
    }

    #[test]
    fn nsga2_finds_corners() {
        let groups = groups2();
        let f = acc_fn(&groups);
        let mut cache = EvalCache::new(&groups, f);
        let opts = MooOptions { evaluations: 60, population: 8, ..Default::default() };
        let front = nsga2(&mut cache, &opts).unwrap();
        assert!(!front.is_empty());
        // front must contain the all-high (8.0 bits) and all-low (2.0 bits) corners
        assert!(front.iter().any(|p| p.bits <= 2.01));
        assert!(front.iter().any(|p| p.accuracy > 0.97));
        // front sorted and non-dominated
        for w in front.windows(2) {
            assert!(w[0].bits <= w[1].bits);
            assert!(w[0].accuracy <= w[1].accuracy + 1e-12);
        }
    }

    #[test]
    fn moead_reaches_similar_front() {
        let groups = groups2();
        let f = acc_fn(&groups);
        let mut cache = EvalCache::new(&groups, f);
        let opts = MooOptions { evaluations: 60, population: 8, ..Default::default() };
        let front = moead(&mut cache, &opts).unwrap();
        assert!(front.iter().any(|p| p.bits <= 2.01));
        assert!(front.iter().any(|p| p.accuracy > 0.9));
    }

    #[test]
    fn constraint_selection() {
        let groups = groups2();
        let f = acc_fn(&groups);
        let mut cache = EvalCache::new(&groups, f);
        let opts = MooOptions { evaluations: 50, population: 8, ..Default::default() };
        let front = nsga2(&mut cache, &opts).unwrap();
        let c4 = select_under_constraint(&front, 4.0).unwrap();
        assert!(c4.bits <= 4.0 + 1e-9);
        let c8 = select_under_constraint(&front, 8.0).unwrap();
        assert!(c8.accuracy >= c4.accuracy - 1e-12);
    }

    #[test]
    fn eval_cache_dedups() {
        let groups = groups2();
        let f = acc_fn(&groups);
        let mut cache = EvalCache::new(&groups, f);
        cache.eval(&[0, 0]).unwrap();
        cache.eval(&[0, 0]).unwrap();
        assert_eq!(cache.evals, 1);
    }
}
