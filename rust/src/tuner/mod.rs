//! KVTuner: the paper's offline calibration pipeline (Fig. 1).
//!
//!   profile  → per-layer error metrics over calibration prompts
//!   prune    → intra-layer Pareto pruning of precision pairs
//!   cluster  → inter-layer DBSCAN grouping by sensitivity
//!   search   → multi-objective optimization (NSGA-II / MOEA/D) over the
//!              reduced space, objectives (equivalent bits, accuracy)
//!   emit     → a TunedConfig the serving engine loads with zero online cost

pub mod calib;
pub mod cluster;
pub mod eval;
pub mod moo;
pub mod pareto;
pub mod profiler;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use crate::model::Weights;
use crate::util::json::{arr, num, obj, s, Json};

pub use cluster::{cluster_layers, expand_assignment, LayerGroup};
pub use eval::{build_reference, fidelity_accuracy, pseudo_perplexity, Reference};
pub use moo::{moead, nsga2, select_under_constraint, EvalCache, EvalPoint, MooOptions};
pub use pareto::{prune_all, Candidate};
pub use profiler::{profile, Profile};

/// A searched layer-wise configuration (the artifact KVTuner ships).
#[derive(Debug, Clone)]
pub struct TunedConfig {
    pub model: String,
    pub mode: Mode,
    pub specs: Vec<LayerSpec>,
    pub equivalent_bits: f64,
    pub accuracy: f64,
    pub label: String,
    /// Per-layer calibration error bounds (peak over the calibration
    /// prompts at each layer's served pair) — the online drift detector's
    /// reference. `None` on configs saved before the envelope existed.
    pub envelope: Option<crate::obs::Envelope>,
}

impl TunedConfig {
    pub fn from_point(
        model: &str,
        mode: Mode,
        groups: &[LayerGroup],
        point: &EvalPoint,
        n_layers: usize,
    ) -> TunedConfig {
        let cands = expand_assignment(groups, &point.picks, n_layers);
        let specs: Vec<LayerSpec> =
            cands.iter().map(|c| LayerSpec { mode, pair: c.pair }).collect();
        TunedConfig {
            model: model.to_string(),
            mode,
            specs,
            equivalent_bits: point.bits,
            accuracy: point.accuracy,
            label: format!("KVTuner-C{:.2}", point.bits),
            envelope: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", s(self.model.clone())),
            ("mode", s(self.mode.as_str())),
            ("equivalent_bits", num(self.equivalent_bits)),
            ("accuracy", num(self.accuracy)),
            ("label", s(self.label.clone())),
            (
                "layers",
                arr(self.specs.iter().map(|sp| s(sp.pair.label()))),
            ),
        ];
        if let Some(env) = &self.envelope {
            pairs.push(("envelope", env.to_json()));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<TunedConfig> {
        let mode = Mode::parse(j.get("mode")?.as_str()?)?;
        let specs = j
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|p| Ok(LayerSpec { mode, pair: PrecisionPair::parse(p.as_str()?)? }))
            .collect::<Result<Vec<_>>>()?;
        let envelope = match j.opt("envelope") {
            Some(e) => Some(crate::obs::Envelope::from_json(e)?),
            None => None,
        };
        Ok(TunedConfig {
            model: j.get("model")?.as_str()?.to_string(),
            mode,
            specs,
            equivalent_bits: j.get("equivalent_bits")?.as_f64()?,
            accuracy: j.get("accuracy")?.as_f64()?,
            label: j.get("label")?.as_str()?.to_string(),
            envelope,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<TunedConfig> {
        TunedConfig::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct TuneOptions {
    pub mode: Mode,
    pub n_prompts: usize,
    pub prompt_len: usize,
    pub horizon: usize,
    pub seed: u64,
    pub moo: MooOptions,
    pub algorithm: Algorithm,
    /// Ablation: skip the two-stage pruning and search the full S^L space.
    pub no_prune: bool,
    pub dbscan_eps: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Nsga2,
    Moead,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            mode: Mode::Token,
            n_prompts: 9,
            prompt_len: 48,
            horizon: 32,
            seed: 1234,
            moo: MooOptions::default(),
            algorithm: Algorithm::Nsga2,
            no_prune: false,
            dbscan_eps: 0.05,
        }
    }
}

/// Full pipeline output.
pub struct TuneResult {
    pub profile: Profile,
    pub pruned: Vec<Vec<Candidate>>,
    pub groups: Vec<LayerGroup>,
    pub front: Vec<EvalPoint>,
    pub history: Vec<EvalPoint>,
    pub configs: Vec<TunedConfig>,
    pub evals: usize,
}

/// Run the complete KVTuner pipeline for one model + quant mode.
pub fn run_pipeline(
    cfg: &ModelConfig,
    weights: &Weights,
    opts: &TuneOptions,
) -> Result<TuneResult> {
    // 1. calibration set + fp reference generations
    let prompts = calib::calib_set(cfg.vocab, opts.n_prompts, opts.prompt_len, opts.seed);
    let reference = build_reference(cfg, weights, &prompts, opts.horizon)?;

    // 2. profile (offline, no accumulation)
    let prof = profile(cfg, weights, &prompts, &[opts.mode])?;

    // 3. intra-layer pruning (or the full space for the ablation)
    let pruned: Vec<Vec<Candidate>> = if opts.no_prune {
        (0..cfg.n_layers)
            .map(|l| {
                crate::config::PAIRS
                    .iter()
                    .map(|&pair| {
                        let e = prof.errors[l].get(&(opts.mode, pair)).copied().unwrap_or_default();
                        Candidate { pair, bits: pair.equivalent_bits(), e_o: e.e_o }
                    })
                    .collect()
            })
            .collect()
    } else {
        prune_all(&prof, opts.mode)
    };

    // 4. inter-layer clustering (ablation: every layer its own group)
    let groups = if opts.no_prune {
        pruned
            .iter()
            .enumerate()
            .map(|(l, c)| LayerGroup { layers: vec![l], candidates: c.clone() })
            .collect()
    } else {
        cluster_layers(&pruned, opts.dbscan_eps, 2)
    };

    // 5. MOO search with the fidelity evaluator
    let n_layers = cfg.n_layers;
    let mode = opts.mode;
    let eval_fn = |picks: &[usize]| -> Result<f64> {
        let cands = expand_assignment(&groups, picks, n_layers);
        let specs: Vec<LayerSpec> =
            cands.iter().map(|c| LayerSpec { mode, pair: c.pair }).collect();
        fidelity_accuracy(cfg, weights, &reference, &specs)
    };
    let (front, history, evals) = {
        let mut cache = EvalCache::new(&groups, eval_fn);
        let front = match opts.algorithm {
            Algorithm::Nsga2 => nsga2(&mut cache, &opts.moo)?,
            Algorithm::Moead => moead(&mut cache, &opts.moo)?,
        };
        (front, cache.history, cache.evals)
    };

    // 6. constraint picks (paper's KVTuner-C<bits> configs)
    let mut configs = Vec::new();
    for &ceil in &opts.moo.bit_constraints {
        if let Some(p) = select_under_constraint(&front, ceil) {
            let mut tc =
                TunedConfig::from_point(&weights.model_name, mode, &groups, &p, n_layers);
            tc.envelope = Some(prof.envelope_for(&tc.specs));
            configs.push(tc);
        }
    }
    configs.dedup_by(|a, b| a.equivalent_bits == b.equivalent_bits);

    Ok(TuneResult { profile: prof, pruned, groups, front, history, configs, evals })
}
