//! Offline sensitivity profiler (paper Sec. 4 / App. B, F): runs calibration
//! prompts through the fp reference engine with Q/K/V capture, then
//! simulates quantize→dequantize per (mode, precision pair) per layer and
//! aggregates the error metrics — no error accumulation, exactly the
//! paper's "simulated offline quantization" setting.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use crate::model::{RefEngine, Weights};
use crate::obs::{Envelope, EnvelopeBound};
use crate::quant::error::{layer_errors, ErrorMetrics, LayerCapture};
use crate::util::json::{arr, num, obj, s, Json};

/// errors[layer][(mode, pair)] -> metrics averaged over prompts;
/// peaks[layer][(mode, pair)] -> component-wise maxima over prompts (the
/// calibration envelope the online drift detector compares against).
#[derive(Debug, Clone)]
pub struct Profile {
    pub n_layers: usize,
    pub errors: Vec<BTreeMap<(Mode, PrecisionPair), ErrorMetrics>>,
    pub peaks: Vec<BTreeMap<(Mode, PrecisionPair), ErrorMetrics>>,
    pub n_prompts: usize,
}

/// Capture per-layer Q/K/V for each prompt with the fp engine.
pub fn capture_prompts(
    cfg: &ModelConfig,
    weights: &Weights,
    prompts: &[Vec<i32>],
) -> Result<Vec<Vec<LayerCapture>>> {
    let mut all = Vec::with_capacity(prompts.len());
    for p in prompts {
        let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers);
        let mut eng = RefEngine::new(cfg, weights, specs, p.len() + 1)?;
        eng.enable_capture();
        for &t in p {
            eng.step(t)?;
        }
        all.push(eng.take_capture().unwrap());
    }
    Ok(all)
}

/// Profile all (mode, pair) combinations over captured prompts, in parallel
/// across prompts.
pub fn profile(
    cfg: &ModelConfig,
    weights: &Weights,
    prompts: &[Vec<i32>],
    modes: &[Mode],
) -> Result<Profile> {
    let captures = capture_prompts(cfg, weights, prompts)?;
    let group = cfg.group;
    let n_layers = cfg.n_layers;
    let w = 1.0 / captures.len() as f64;

    // prompt-parallel: each thread computes the full (layer, mode, pair) grid
    // for one prompt's captures
    let per_prompt: Vec<Vec<BTreeMap<(Mode, PrecisionPair), ErrorMetrics>>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = captures
                .iter()
                .map(|caps| {
                    let modes = modes.to_vec();
                    scope.spawn(move || -> Result<_> {
                        let mut per_layer = Vec::with_capacity(n_layers);
                        for cap in caps {
                            let mut m = BTreeMap::new();
                            for &mode in &modes {
                                for pair in PAIRS {
                                    let spec = LayerSpec { mode, pair };
                                    m.insert((mode, pair), layer_errors(cap, spec, group)?);
                                }
                            }
                            per_layer.push(m);
                        }
                        Ok(per_layer)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<Vec<_>>>()
        })?;

    let mut errors = vec![BTreeMap::<(Mode, PrecisionPair), ErrorMetrics>::new(); n_layers];
    let mut peaks = vec![BTreeMap::<(Mode, PrecisionPair), ErrorMetrics>::new(); n_layers];
    for prompt_tables in &per_prompt {
        for (l, table) in prompt_tables.iter().enumerate() {
            for (k, v) in table {
                errors[l].entry(*k).or_default().merge(v, w);
                let p = peaks[l].entry(*k).or_default();
                p.e_k = p.e_k.max(v.e_k);
                p.e_v = p.e_v.max(v.e_v);
                p.e_a = p.e_a.max(v.e_a);
                p.e_a_max = p.e_a_max.max(v.e_a_max);
                p.e_o = p.e_o.max(v.e_o);
            }
        }
    }
    Ok(Profile { n_layers, errors, peaks, n_prompts: prompts.len() })
}

impl Profile {
    /// The calibration envelope for a served spec vector: each layer's
    /// peak-over-prompts errors at its *own* (mode, pair). Fp layers (and
    /// pairs outside the profiled grid) get zero bounds — the online probe
    /// never drift-checks an Fp layer, so zeros are inert there.
    pub fn envelope_for(&self, specs: &[LayerSpec]) -> Envelope {
        let layers = specs
            .iter()
            .enumerate()
            .map(|(l, sp)| {
                let peak = self
                    .peaks
                    .get(l)
                    .and_then(|m| m.get(&(sp.mode, sp.pair)))
                    .copied()
                    .unwrap_or_default();
                EnvelopeBound { e_k: peak.e_k, e_v: peak.e_v, e_a: peak.e_a, e_o: peak.e_o }
            })
            .collect();
        Envelope { layers }
    }

    /// Model-average metrics for one (mode, pair) — Table 9's rows.
    pub fn model_avg(&self, mode: Mode, pair: PrecisionPair) -> ErrorMetrics {
        let mut out = ErrorMetrics::default();
        let w = 1.0 / self.n_layers as f64;
        for l in &self.errors {
            if let Some(m) = l.get(&(mode, pair)) {
                out.merge(m, w);
            }
        }
        out
    }

    /// Per-layer e_o series for one (mode, pair) — Fig. 3/13's series.
    pub fn layer_series(&self, mode: Mode, pair: PrecisionPair) -> Vec<f64> {
        self.errors
            .iter()
            .map(|m| m.get(&(mode, pair)).map(|e| e.e_o).unwrap_or(0.0))
            .collect()
    }

    pub fn layer_series_ea(&self, mode: Mode, pair: PrecisionPair) -> Vec<f64> {
        self.errors
            .iter()
            .map(|m| m.get(&(mode, pair)).map(|e| e.e_a).unwrap_or(0.0))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .errors
            .iter()
            .enumerate()
            .map(|(l, m)| {
                let entries: Vec<Json> = m
                    .iter()
                    .map(|((mode, pair), e)| {
                        obj(vec![
                            ("mode", s(mode.as_str())),
                            ("pair", s(pair.label())),
                            ("e_k", num(e.e_k)),
                            ("e_v", num(e.e_v)),
                            ("e_a", num(e.e_a)),
                            ("e_o", num(e.e_o)),
                        ])
                    })
                    .collect();
                obj(vec![("layer", num(l as f64)), ("errors", arr(entries))])
            })
            .collect();
        obj(vec![
            ("n_prompts", num(self.n_prompts as f64)),
            ("layers", arr(layers)),
        ])
    }
}
