//! Inter-layer clustering (paper Sec. 5.3 / App. D.1.2): after intra-layer
//! pruning, layers with the same pruned candidate set are grouped, then
//! DBSCAN (eps = 0.05, min_samples = 2) clusters them by quantization
//! sensitivity — the vector of relative attention output errors over the
//! pruned pairs. Search space shrinks from S_p^L to S_p^G.

use std::collections::BTreeMap;

use super::pareto::{candidate_signature, Candidate};

/// DBSCAN over points with Euclidean distance. Returns cluster id per point;
/// noise points get unique singleton ids (they still need a precision pick).
pub fn dbscan(points: &[Vec<f64>], eps: f64, min_samples: usize) -> Vec<usize> {
    let n = points.len();
    let dist = |a: &Vec<f64>, b: &Vec<f64>| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    };
    let neighbors: Vec<Vec<usize>> = (0..n)
        .map(|i| (0..n).filter(|&j| dist(&points[i], &points[j]) <= eps).collect())
        .collect();
    const UNVISITED: usize = usize::MAX;
    let mut label = vec![UNVISITED; n];
    let mut cluster = 0usize;
    for i in 0..n {
        if label[i] != UNVISITED {
            continue;
        }
        if neighbors[i].len() < min_samples {
            continue; // provisionally noise; may be claimed as border point
        }
        // expand a new cluster from core point i
        label[i] = cluster;
        let mut stack: Vec<usize> = neighbors[i].clone();
        while let Some(j) = stack.pop() {
            if label[j] == UNVISITED {
                label[j] = cluster;
                if neighbors[j].len() >= min_samples {
                    stack.extend(neighbors[j].iter().copied());
                }
            }
        }
        cluster += 1;
    }
    // noise -> singleton clusters
    for l in label.iter_mut() {
        if *l == UNVISITED {
            *l = cluster;
            cluster += 1;
        }
    }
    label
}

/// A group of layers sharing a candidate set and sensitivity cluster.
#[derive(Debug, Clone)]
pub struct LayerGroup {
    pub layers: Vec<usize>,
    pub candidates: Vec<Candidate>,
}

/// Two-stage grouping: partition by identical pruned candidate signature,
/// then DBSCAN within each partition on the e_o sensitivity vectors.
pub fn cluster_layers(
    pruned: &[Vec<Candidate>],
    eps: f64,
    min_samples: usize,
) -> Vec<LayerGroup> {
    let mut by_sig: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (l, cands) in pruned.iter().enumerate() {
        by_sig.entry(candidate_signature(cands)).or_default().push(l);
    }
    let mut groups = Vec::new();
    for (_sig, layers) in by_sig {
        // sensitivity feature: e_o per pruned candidate (same signature =>
        // comparable vectors)
        let feats: Vec<Vec<f64>> = layers
            .iter()
            .map(|&l| pruned[l].iter().map(|c| c.e_o).collect())
            .collect();
        let labels = dbscan(&feats, eps, min_samples);
        let mut by_cluster: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (idx, &l) in layers.iter().enumerate() {
            by_cluster.entry(labels[idx]).or_default().push(l);
        }
        for (_c, ls) in by_cluster {
            let candidates = pruned[ls[0]].clone();
            groups.push(LayerGroup { layers: ls, candidates });
        }
    }
    // stable order by first layer id
    groups.sort_by_key(|g| g.layers[0]);
    groups
}

/// Map a per-group pick back to per-layer assignments.
pub fn expand_assignment(groups: &[LayerGroup], picks: &[usize], n_layers: usize) -> Vec<Candidate> {
    assert_eq!(groups.len(), picks.len());
    let mut out = vec![None; n_layers];
    for (g, &p) in groups.iter().zip(picks) {
        for &l in &g.layers {
            out[l] = Some(g.candidates[p]);
        }
    }
    out.into_iter().map(|o| o.expect("every layer grouped")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrecisionPair;

    #[test]
    fn dbscan_two_blobs_and_noise() {
        let mut pts: Vec<Vec<f64>> = Vec::new();
        for i in 0..4 {
            pts.push(vec![0.0 + i as f64 * 0.01]);
        }
        for i in 0..4 {
            pts.push(vec![1.0 + i as f64 * 0.01]);
        }
        pts.push(vec![5.0]); // noise
        let labels = dbscan(&pts, 0.05, 2);
        assert_eq!(labels[0], labels[3]);
        assert_eq!(labels[4], labels[7]);
        assert_ne!(labels[0], labels[4]);
        assert_ne!(labels[8], labels[0]);
        assert_ne!(labels[8], labels[4]);
    }

    #[test]
    fn grouping_respects_signature() {
        let c = |k, v, e| Candidate {
            pair: PrecisionPair::new(k, v),
            bits: (k + v) as f64 / 2.0,
            e_o: e,
        };
        // layers 0/1 share a signature and are close; layer 2 differs
        let pruned = vec![
            vec![c(8, 8, 0.01), c(4, 4, 0.1)],
            vec![c(8, 8, 0.012), c(4, 4, 0.11)],
            vec![c(8, 8, 0.01), c(4, 2, 0.3)],
        ];
        let groups = cluster_layers(&pruned, 0.05, 2);
        assert_eq!(groups.len(), 2);
        let g0 = groups.iter().find(|g| g.layers.contains(&0)).unwrap();
        assert!(g0.layers.contains(&1));
    }

    #[test]
    fn expand_assignment_covers_all() {
        let c = |k: u8, e| Candidate { pair: PrecisionPair::new(k, k), bits: k as f64, e_o: e };
        let groups = vec![
            LayerGroup { layers: vec![0, 2], candidates: vec![c(8, 0.1), c(4, 0.2)] },
            LayerGroup { layers: vec![1], candidates: vec![c(2, 0.5)] },
        ];
        let got = expand_assignment(&groups, &[1, 0], 3);
        assert_eq!(got[0].pair, PrecisionPair::new(4, 4));
        assert_eq!(got[1].pair, PrecisionPair::new(2, 2));
        assert_eq!(got[2].pair, PrecisionPair::new(4, 4));
    }
}
