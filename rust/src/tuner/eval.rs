//! Accuracy evaluators (the substituted `A_LLM` of the paper, DESIGN.md §2):
//!
//! * `fidelity_accuracy` — token agreement between the quantized engine's
//!   greedy generation and the fp reference generation on fixed prompts.
//!   This is the paper's Δaccuracy definition with A = fidelity-vs-BF16.
//! * `pseudo_perplexity` — exp(mean NLL) of the fp reference continuation
//!   under the quantized engine (teacher-forced) — the Table 2 metric.
//!
//! All evaluation runs on the pure-Rust reference engine (identical
//! quantization semantics to the PJRT path — parity-tested), prompt-parallel.

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use crate::model::{RefEngine, Weights};

/// fp reference generations for a prompt set (computed once, reused across
/// hundreds of MOO evaluations).
pub struct Reference {
    pub prompts: Vec<Vec<i32>>,
    pub generations: Vec<Vec<i32>>,
    pub horizon: usize,
}

pub fn build_reference(
    cfg: &ModelConfig,
    weights: &Weights,
    prompts: &[Vec<i32>],
    horizon: usize,
) -> Result<Reference> {
    let gens = run_generations(
        cfg,
        weights,
        prompts,
        &LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
        horizon,
    )?;
    Ok(Reference { prompts: prompts.to_vec(), generations: gens, horizon })
}

/// Greedy generations under `specs`, parallel over prompts.
pub fn run_generations(
    cfg: &ModelConfig,
    weights: &Weights,
    prompts: &[Vec<i32>],
    specs: &[LayerSpec],
    horizon: usize,
) -> Result<Vec<Vec<i32>>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| {
                let specs = specs.to_vec();
                scope.spawn(move || -> Result<Vec<i32>> {
                    let cap = p.len() + horizon + 1;
                    let mut eng = RefEngine::new(cfg, weights, specs, cap)?;
                    eng.generate(p, horizon)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Mean per-token agreement with the reference generations in [0, 1].
pub fn fidelity_accuracy(
    cfg: &ModelConfig,
    weights: &Weights,
    reference: &Reference,
    specs: &[LayerSpec],
) -> Result<f64> {
    let gens = run_generations(cfg, weights, &reference.prompts, specs, reference.horizon)?;
    let mut agree = 0usize;
    let mut total = 0usize;
    for (g, r) in gens.iter().zip(&reference.generations) {
        for (a, b) in g.iter().zip(r) {
            agree += (a == b) as usize;
            total += 1;
        }
    }
    Ok(agree as f64 / total.max(1) as f64)
}

/// exp(mean NLL) of the reference continuation under `specs`, teacher-forced.
pub fn pseudo_perplexity(
    cfg: &ModelConfig,
    weights: &Weights,
    reference: &Reference,
    specs: &[LayerSpec],
) -> Result<f64> {
    let nlls: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = reference
            .prompts
            .iter()
            .zip(&reference.generations)
            .map(|(p, gen)| {
                let specs = specs.to_vec();
                scope.spawn(move || -> Result<Vec<f64>> {
                    let cap = p.len() + gen.len() + 1;
                    let mut eng = RefEngine::new(cfg, weights, specs, cap)?;
                    let mut nlls = Vec::with_capacity(gen.len());
                    // prefill the prompt
                    let mut _next = 0;
                    for &t in p {
                        _next = eng.step(t)?;
                    }
                    // teacher-force the reference continuation
                    let mut prev = *p.last().unwrap();
                    let _ = prev;
                    for (i, &target) in gen.iter().enumerate() {
                        // logits for position after the tokens fed so far
                        let logits = &eng.last_logits;
                        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let lse: f32 =
                            logits.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
                        nlls.push((lse - logits[target as usize]) as f64);
                        if i + 1 < gen.len() {
                            eng.step(target)?;
                        }
                        prev = target;
                        let _ = prev;
                    }
                    Ok(nlls)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Result<Vec<_>>>()
    })?;
    let flat: Vec<f64> = nlls.into_iter().flatten().collect();
    let mean = flat.iter().sum::<f64>() / flat.len().max(1) as f64;
    Ok(mean.exp())
}

#[cfg(test)]
mod tests {
    // Evaluators need real weights; covered by rust/tests/integration.rs.
}
