//! Deterministic, seeded fault injection for the serving path.
//!
//! Production failure domains — the swap tier's I/O, the page pool's
//! allocator, the engine step, the worker thread itself — are modeled as
//! *injection points* the scheduler consults before touching real state.
//! Every decision comes from one seeded PRNG, so a failing chaos run
//! reproduces from its seed exactly like the churn harness's workloads, and
//! every injected failure happens *before* the engine call it displaces:
//! no cache or model state is mutated on an injected path, which is what
//! keeps completed token streams bit-identical to a fault-free run.
//!
//! Zero-cost when disabled: the scheduler holds an `Option<FaultInjector>`
//! and every injection point is one `is-Some` branch on `None`.
//!
//! A [`FaultPlan`] comes from a single CLI string (`--fault-plan`): a bare
//! integer seeds a small mixed-rate plan (each rate drawn from 1–5%), while
//! a JSON object (inline or a path to a file) pins every rate explicitly:
//!
//! ```json
//! {"seed": 7, "swap_out_fail": 0.05, "swap_in_transient": 0.1,
//!  "swap_in_lost": 0.02, "alloc_fail": 0.03, "step_transient": 0.02,
//!  "step_panic": 0.0, "death_tick": null, "max_delay_ticks": 4}
//! ```

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Per-injection-point probabilities (rolled independently at each visit)
/// plus the deterministic worker-death tick.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRates {
    /// Swap-out refused before the copy starts (the victim falls back to
    /// recompute, as on a real `HostArenaFull`).
    pub swap_out_fail: f64,
    /// Swap-in transiently unavailable: the resume is delayed and retried
    /// with backoff before the permanent-loss fallback fires.
    pub swap_in_transient: f64,
    /// Swap-in permanently lost (as on a real `SwapLost`): the handle is
    /// released and the request re-prefills.
    pub swap_in_lost: f64,
    /// Spurious `OutOfPages` on a prefill chunk: the slot retries the chunk
    /// on a later tick (bounded; see the scheduler's retry cap).
    pub alloc_fail: f64,
    /// Transient engine-step error: the batched decode tick is skipped and
    /// retried next tick (no state mutated).
    pub step_transient: f64,
    /// Injected panic at a tick boundary — the worker thread dies and the
    /// router's isolation/redispatch path takes over.
    pub step_panic: f64,
    /// Deterministic worker death: panic exactly at this scheduler tick.
    pub death_tick: Option<u64>,
    /// Upper bound on the per-retry delay (in scheduler ticks) a transient
    /// swap-in fault imposes.
    pub max_delay_ticks: u64,
}

impl Default for FaultRates {
    fn default() -> FaultRates {
        FaultRates {
            swap_out_fail: 0.0,
            swap_in_transient: 0.0,
            swap_in_lost: 0.0,
            alloc_fail: 0.0,
            step_transient: 0.0,
            step_panic: 0.0,
            death_tick: None,
            max_delay_ticks: 4,
        }
    }
}

/// A reproducible fault schedule: one seed plus the rates above. Thread it
/// through `WorkerSpec`/`SchedulerOptions`; each worker salts the seed with
/// its index so a fleet under one plan still exercises distinct schedules.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rates: FaultRates,
}

impl FaultPlan {
    /// A mixed low-rate plan derived deterministically from one seed: every
    /// transient/permanent rate lands in [1%, 5%], panics and worker death
    /// stay off (those are opted into explicitly via JSON).
    pub fn from_seed(seed: u64) -> FaultPlan {
        let mut rng = Rng::seed(seed.wrapping_mul(0xA24B_AED4_963E_E407).wrapping_add(5));
        let mut rate = || 0.01 + 0.04 * rng.f64();
        FaultPlan {
            seed,
            rates: FaultRates {
                swap_out_fail: rate(),
                swap_in_transient: rate(),
                swap_in_lost: rate(),
                alloc_fail: rate(),
                step_transient: rate(),
                ..FaultRates::default()
            },
        }
    }

    /// Parse a `--fault-plan` argument: a bare integer (`from_seed`), an
    /// inline JSON object, or a path to a JSON file.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let s = s.trim();
        if let Ok(seed) = s.parse::<u64>() {
            return Ok(FaultPlan::from_seed(seed));
        }
        let body = if s.starts_with('{') {
            s.to_string()
        } else {
            std::fs::read_to_string(s)
                .with_context(|| format!("--fault-plan: reading plan file {s:?}"))?
        };
        let j = Json::parse(&body).context("--fault-plan: parsing plan JSON")?;
        let f = |key: &str| -> Result<f64> {
            match j.opt(key) {
                Some(v) => {
                    let r = v.as_f64()?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&r),
                        "--fault-plan: {key} must be a probability in [0,1], got {r}"
                    );
                    Ok(r)
                }
                None => Ok(0.0),
            }
        };
        let d = FaultRates::default();
        Ok(FaultPlan {
            seed: j.opt("seed").map(|v| v.as_usize()).transpose()?.unwrap_or(0) as u64,
            rates: FaultRates {
                swap_out_fail: f("swap_out_fail")?,
                swap_in_transient: f("swap_in_transient")?,
                swap_in_lost: f("swap_in_lost")?,
                alloc_fail: f("alloc_fail")?,
                step_transient: f("step_transient")?,
                step_panic: f("step_panic")?,
                death_tick: match j.opt("death_tick") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.as_usize()? as u64),
                },
                max_delay_ticks: match j.opt("max_delay_ticks") {
                    Some(v) => (v.as_usize()? as u64).max(1),
                    None => d.max_delay_ticks,
                },
            },
        })
    }

    /// True when every injection point is inert — the scheduler drops the
    /// injector entirely and pays nothing.
    pub fn is_noop(&self) -> bool {
        let r = &self.rates;
        r.swap_out_fail == 0.0
            && r.swap_in_transient == 0.0
            && r.swap_in_lost == 0.0
            && r.alloc_fail == 0.0
            && r.step_transient == 0.0
            && r.step_panic == 0.0
            && r.death_tick.is_none()
    }
}

/// Outcome of a swap-in injection roll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwapInFault {
    /// Retry after this many ticks (bounded backoff in the scheduler).
    Transient { delay_ticks: u64 },
    /// Permanent: release the handle and re-prefill.
    Lost,
}

/// Outcome of an engine-step injection roll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepFault {
    /// Skip this decode tick and retry next tick.
    Transient,
    /// Kill the worker thread (caught by the router's isolation layer).
    Panic,
}

/// Names for the injection points, used by trace events and tallies.
pub const FAULT_POINTS: [&str; 6] =
    ["swap_out", "swap_in_transient", "swap_in_lost", "alloc", "step_transient", "step_panic"];

/// Indices into [`FAULT_POINTS`] — the `arg` payload of
/// `EventKind::Fault` trace events.
pub mod point {
    pub const SWAP_OUT: u64 = 0;
    pub const SWAP_IN_TRANSIENT: u64 = 1;
    pub const SWAP_IN_LOST: u64 = 2;
    pub const ALLOC: u64 = 3;
    pub const STEP_TRANSIENT: u64 = 4;
    pub const STEP_PANIC: u64 = 5;
}

/// The live injector one scheduler owns: the plan's rates driven by a
/// salted PRNG, plus per-point injected counts for reporting.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rates: FaultRates,
    rng: Rng,
    injected: [u64; FAULT_POINTS.len()],
}

impl FaultInjector {
    /// `salt` distinguishes workers sharing one plan (use the worker index).
    pub fn new(plan: &FaultPlan, salt: u64) -> FaultInjector {
        FaultInjector {
            rates: plan.rates.clone(),
            rng: Rng::seed(plan.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            injected: [0; FAULT_POINTS.len()],
        }
    }

    fn hit(&mut self, point: usize, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let hit = self.rng.chance(p);
        if hit {
            self.injected[point] += 1;
        }
        hit
    }

    /// Roll the swap-out injection point (before the device-to-host copy).
    pub fn swap_out_fails(&mut self) -> bool {
        let p = self.rates.swap_out_fail;
        self.hit(0, p)
    }

    /// Roll the swap-in injection point (before the host-to-device copy).
    pub fn swap_in_fault(&mut self) -> Option<SwapInFault> {
        // permanent loss is rolled first so a plan with both rates set
        // exercises both outcomes
        let lost = self.rates.swap_in_lost;
        if self.hit(2, lost) {
            return Some(SwapInFault::Lost);
        }
        let transient = self.rates.swap_in_transient;
        if self.hit(1, transient) {
            let delay = 1 + self.rng.below(self.rates.max_delay_ticks.max(1) as usize) as u64;
            return Some(SwapInFault::Transient { delay_ticks: delay });
        }
        None
    }

    /// Roll the page-allocation injection point (before a prefill chunk).
    pub fn alloc_fails(&mut self) -> bool {
        let p = self.rates.alloc_fail;
        self.hit(3, p)
    }

    /// Roll the engine-step injection point at tick `tick_no` (before the
    /// batched decode call). Worker death at `death_tick` wins over the
    /// probabilistic rolls.
    pub fn step_fault(&mut self, tick_no: u64) -> Option<StepFault> {
        if self.rates.death_tick == Some(tick_no) {
            self.injected[5] += 1;
            return Some(StepFault::Panic);
        }
        let panic_p = self.rates.step_panic;
        if self.hit(5, panic_p) {
            return Some(StepFault::Panic);
        }
        let transient = self.rates.step_transient;
        if self.hit(4, transient) {
            return Some(StepFault::Transient);
        }
        None
    }

    /// Total injected faults across every point.
    pub fn total_injected(&self) -> u64 {
        self.injected.iter().sum()
    }

    /// Injected count per point, aligned with [`FAULT_POINTS`].
    pub fn injected(&self) -> &[u64; FAULT_POINTS.len()] {
        &self.injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_seed_derives_small_mixed_rates() {
        let p = FaultPlan::parse("42").unwrap();
        assert_eq!(p.seed, 42);
        for r in [
            p.rates.swap_out_fail,
            p.rates.swap_in_transient,
            p.rates.swap_in_lost,
            p.rates.alloc_fail,
            p.rates.step_transient,
        ] {
            assert!((0.01..=0.05).contains(&r), "derived rate {r} outside 1-5%");
        }
        assert_eq!(p.rates.step_panic, 0.0, "panics are opt-in only");
        assert_eq!(p.rates.death_tick, None);
        // same seed, same plan — the reproducibility contract
        assert_eq!(FaultPlan::parse("42").unwrap(), p);
        assert_ne!(FaultPlan::from_seed(43).rates, p.rates);
    }

    #[test]
    fn json_plan_pins_rates_and_rejects_bad_probabilities() {
        let p = FaultPlan::parse(
            r#"{"seed": 9, "swap_in_lost": 1.0, "death_tick": 17, "max_delay_ticks": 2}"#,
        )
        .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rates.swap_in_lost, 1.0);
        assert_eq!(p.rates.swap_out_fail, 0.0, "unset rates default to 0");
        assert_eq!(p.rates.death_tick, Some(17));
        assert_eq!(p.rates.max_delay_ticks, 2);
        assert!(FaultPlan::parse(r#"{"alloc_fail": 1.5}"#).is_err());
        assert!(FaultPlan::parse("not json or a number").is_err());
    }

    #[test]
    fn injector_is_reproducible_and_counts_injections() {
        let plan = FaultPlan::parse(r#"{"seed": 5, "alloc_fail": 0.5, "step_transient": 0.5}"#)
            .unwrap();
        let roll = |salt: u64| {
            let mut inj = FaultInjector::new(&plan, salt);
            let seq: Vec<bool> = (0..64).map(|_| inj.alloc_fails()).collect();
            (seq, inj.total_injected())
        };
        let (a, na) = roll(0);
        let (b, nb) = roll(0);
        assert_eq!(a, b, "same plan + salt must replay identically");
        assert_eq!(na, nb);
        assert!(na > 0, "a 50% rate over 64 rolls must inject");
        let (c, _) = roll(1);
        assert_ne!(a, c, "different salts must draw different schedules");
    }

    #[test]
    fn death_tick_fires_exactly_once_at_its_tick() {
        let plan = FaultPlan::parse(r#"{"death_tick": 3}"#).unwrap();
        assert!(!plan.is_noop());
        let mut inj = FaultInjector::new(&plan, 0);
        for t in 0..3 {
            assert_eq!(inj.step_fault(t), None);
        }
        assert_eq!(inj.step_fault(3), Some(StepFault::Panic));
        assert_eq!(inj.step_fault(4), None);
    }

    #[test]
    fn unarmed_plan_is_noop() {
        assert!(FaultPlan::parse("{}").unwrap().is_noop());
        assert!(!FaultPlan::from_seed(1).is_noop());
    }
}
