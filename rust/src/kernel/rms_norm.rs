//! RMSNorm, matching `model.py::rms_norm` and the reference engine: mean of
//! squares (not variance), epsilon inside the sqrt.

/// out[i] = x[i] * g[i] / sqrt(mean(x^2) + eps)
pub fn rms_norm(x: &[f32], g: &[f32], eps: f32, out: &mut [f32]) {
    let d = x.len();
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(out.len(), d);
    let ms = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * g[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_gain_normalizes_rms_to_one() {
        let x = vec![3.0, -3.0, 3.0, -3.0];
        let g = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        rms_norm(&x, &g, 0.0, &mut out);
        let rms = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-6, "rms {rms}");
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gain_scales_channels() {
        let x = vec![1.0, 1.0];
        let g = vec![2.0, 0.5];
        let mut out = vec![0.0; 2];
        rms_norm(&x, &g, 0.0, &mut out);
        assert!((out[0] / out[1] - 4.0).abs() < 1e-6);
    }
}
