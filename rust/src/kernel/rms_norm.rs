//! RMSNorm, matching `model.py::rms_norm` and the reference engine: mean of
//! squares (not variance), epsilon inside the sqrt.

use super::pool::{partition, SharedMut, ThreadPool};

/// out[i] = x[i] * g[i] / sqrt(mean(x^2) + eps)
pub fn rms_norm(x: &[f32], g: &[f32], eps: f32, out: &mut [f32]) {
    let d = x.len();
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(out.len(), d);
    let ms = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * g[i];
    }
}

/// Row-blocked RMSNorm over `[rows, d]`, partitioned across the pool. Each
/// row is the scalar `rms_norm`, so outputs are bit-identical at any width.
pub fn rms_norm_rows(
    pool: &ThreadPool,
    x: &[f32],
    g: &[f32],
    eps: f32,
    rows: usize,
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(out.len(), rows * d);
    let ranges = partition(rows, pool.threads());
    let shared = SharedMut::new(out);
    pool.run(ranges.len(), &|ci: usize| {
        for t in ranges[ci].clone() {
            let o = unsafe { shared.slice(t * d, d) };
            rms_norm(&x[t * d..(t + 1) * d], g, eps, o);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_match_scalar_bitwise() {
        let (rows, d) = (5, 12);
        let x: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.31).sin()).collect();
        let g: Vec<f32> = (0..d).map(|i| 1.0 + (i as f32) * 0.01).collect();
        for threads in [1, 3] {
            let pool = ThreadPool::new(threads);
            let mut blocked = vec![0f32; rows * d];
            rms_norm_rows(&pool, &x, &g, 1e-5, rows, d, &mut blocked);
            for t in 0..rows {
                let mut row = vec![0f32; d];
                rms_norm(&x[t * d..(t + 1) * d], &g, 1e-5, &mut row);
                let a: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> =
                    blocked[t * d..(t + 1) * d].iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "row {t} threads={threads}");
            }
        }
    }

    #[test]
    fn unit_gain_normalizes_rms_to_one() {
        let x = vec![3.0, -3.0, 3.0, -3.0];
        let g = vec![1.0; 4];
        let mut out = vec![0.0; 4];
        rms_norm(&x, &g, 0.0, &mut out);
        let rms = (out.iter().map(|v| v * v).sum::<f32>() / 4.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-6, "rms {rms}");
        assert!((out[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gain_scales_channels() {
        let x = vec![1.0, 1.0];
        let g = vec![2.0, 0.5];
        let mut out = vec![0.0; 2];
        rms_norm(&x, &g, 0.0, &mut out);
        assert!((out[0] / out[1] - 4.0).abs() < 1e-6);
    }
}
