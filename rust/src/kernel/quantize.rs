//! Native quantize-at-commit: packages freshly computed K/V rows into the
//! exact tensor shapes the cache append paths expect from the PJRT quant
//! executables, using the same `quant::asym` round-to-nearest the reference
//! engine fake-quants with — so natively written pages and artifact-written
//! pages are interchangeable.

use anyhow::Result;

use crate::config::PrecisionPair;
use crate::quant::{packed_width, quantize_per_channel, quantize_per_token};
use crate::tensor::Tensor;

/// Quantize one decode token's K/V (`[h * dh]` each, post-RoPE keys) into
/// the 6-tensor `append_token_outputs` layout:
/// (k_codes [1,h,1,kp], k_scale [1,h,1], k_zero, v_codes [1,h,1,vp],
/// v_scale, v_zero) — one per-token (scale, zero) per head.
pub fn token_step_outputs(
    k: &[f32],
    v: &[f32],
    h: usize,
    dh: usize,
    pair: PrecisionPair,
) -> Result<Vec<Tensor>> {
    debug_assert_eq!(k.len(), h * dh);
    debug_assert_eq!(v.len(), h * dh);
    let kp = packed_width(dh, pair.k_bits)?;
    let vp = packed_width(dh, pair.v_bits)?;
    let mut kc = vec![0u8; h * kp];
    let mut ks = vec![0f32; h];
    let mut kz = vec![0f32; h];
    let mut vc = vec![0u8; h * vp];
    let mut vs = vec![0f32; h];
    let mut vz = vec![0f32; h];
    for hh in 0..h {
        let kq = quantize_per_token(&k[hh * dh..(hh + 1) * dh], 1, dh, pair.k_bits)?;
        kc[hh * kp..(hh + 1) * kp].copy_from_slice(&kq.codes);
        ks[hh] = kq.scale[0];
        kz[hh] = kq.zero[0];
        let vq = quantize_per_token(&v[hh * dh..(hh + 1) * dh], 1, dh, pair.v_bits)?;
        vc[hh * vp..(hh + 1) * vp].copy_from_slice(&vq.codes);
        vs[hh] = vq.scale[0];
        vz[hh] = vq.zero[0];
    }
    Ok(vec![
        Tensor::u8(&[1, h, 1, kp], kc),
        Tensor::f32(&[1, h, 1], ks),
        Tensor::f32(&[1, h, 1], kz),
        Tensor::u8(&[1, h, 1, vp], vc),
        Tensor::f32(&[1, h, 1], vs),
        Tensor::f32(&[1, h, 1], vz),
    ])
}

/// Quantize a whole block of `g` prefill tokens' K/V (head-major
/// `[h, g, dh]`, post-RoPE keys) into one `append_token_outputs` call:
/// (k_codes [1,h,g,kp], k_scale [1,h,g], k_zero, v_codes [1,h,g,vp],
/// v_scale, v_zero). Per-token quantization is row-independent, so each
/// row's codes and scales are bit-identical to `token_step_outputs` on that
/// row — the block prefill path writes exactly the cache the token-by-token
/// path would.
pub fn token_block_outputs(
    k: &[f32],
    v: &[f32],
    h: usize,
    g: usize,
    dh: usize,
    pair: PrecisionPair,
) -> Result<Vec<Tensor>> {
    debug_assert_eq!(k.len(), h * g * dh);
    debug_assert_eq!(v.len(), h * g * dh);
    let kp = packed_width(dh, pair.k_bits)?;
    let vp = packed_width(dh, pair.v_bits)?;
    let mut kc = vec![0u8; h * g * kp];
    let mut ks = vec![0f32; h * g];
    let mut kz = vec![0f32; h * g];
    let mut vc = vec![0u8; h * g * vp];
    let mut vs = vec![0f32; h * g];
    let mut vz = vec![0f32; h * g];
    for hh in 0..h {
        let kq = quantize_per_token(&k[hh * g * dh..(hh + 1) * g * dh], g, dh, pair.k_bits)?;
        kc[hh * g * kp..(hh + 1) * g * kp].copy_from_slice(&kq.codes);
        ks[hh * g..(hh + 1) * g].copy_from_slice(&kq.scale);
        kz[hh * g..(hh + 1) * g].copy_from_slice(&kq.zero);
        let vq = quantize_per_token(&v[hh * g * dh..(hh + 1) * g * dh], g, dh, pair.v_bits)?;
        vc[hh * g * vp..(hh + 1) * g * vp].copy_from_slice(&vq.codes);
        vs[hh * g..(hh + 1) * g].copy_from_slice(&vq.scale);
        vz[hh * g..(hh + 1) * g].copy_from_slice(&vq.zero);
    }
    Ok(vec![
        Tensor::u8(&[1, h, g, kp], kc),
        Tensor::f32(&[1, h, g], ks),
        Tensor::f32(&[1, h, g], kz),
        Tensor::u8(&[1, h, g, vp], vc),
        Tensor::f32(&[1, h, g], vs),
        Tensor::f32(&[1, h, g], vz),
    ])
}

/// Quantize a full kivi residual group (`residual_chunk` output, `[1,h,g,dh]`
/// each) into `commit_kivi_chunk`'s expected tensors:
/// keys per-channel over the group — (codes [1,h,g,kp], scale [1,h,dh],
/// zero [1,h,dh]) — and values per-token — (codes [1,h,g,vp], scale [1,h,g],
/// zero [1,h,g]).
pub fn kivi_commit_outputs(
    kchunk: &Tensor,
    vchunk: &Tensor,
    h: usize,
    g: usize,
    dh: usize,
    pair: PrecisionPair,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let kf = kchunk.as_f32()?;
    let vf = vchunk.as_f32()?;
    debug_assert_eq!(kf.len(), h * g * dh);
    let kp = packed_width(dh, pair.k_bits)?;
    let vp = packed_width(dh, pair.v_bits)?;
    let mut kc = vec![0u8; h * g * kp];
    let mut ks = vec![0f32; h * dh];
    let mut kz = vec![0f32; h * dh];
    let mut vc = vec![0u8; h * g * vp];
    let mut vs = vec![0f32; h * g];
    let mut vz = vec![0f32; h * g];
    for hh in 0..h {
        let kq = quantize_per_channel(&kf[hh * g * dh..(hh + 1) * g * dh], g, dh, pair.k_bits)?;
        kc[hh * g * kp..(hh + 1) * g * kp].copy_from_slice(&kq.codes);
        ks[hh * dh..(hh + 1) * dh].copy_from_slice(&kq.scale);
        kz[hh * dh..(hh + 1) * dh].copy_from_slice(&kq.zero);
        let vq = quantize_per_token(&vf[hh * g * dh..(hh + 1) * g * dh], g, dh, pair.v_bits)?;
        vc[hh * g * vp..(hh + 1) * g * vp].copy_from_slice(&vq.codes);
        vs[hh * g..(hh + 1) * g].copy_from_slice(&vq.scale);
        vz[hh * g..(hh + 1) * g].copy_from_slice(&vq.zero);
    }
    Ok((
        vec![
            Tensor::u8(&[1, h, g, kp], kc),
            Tensor::f32(&[1, h, dh], ks),
            Tensor::f32(&[1, h, dh], kz),
        ],
        vec![
            Tensor::u8(&[1, h, g, vp], vc),
            Tensor::f32(&[1, h, g], vs),
            Tensor::f32(&[1, h, g], vz),
        ],
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::unpack_row;
    use crate::util::rng::Rng;

    #[test]
    fn token_outputs_roundtrip_matches_fake_quant() {
        let (h, dh) = (2, 16);
        let mut r = Rng::seed(9);
        let k: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
        let v: Vec<f32> = (0..h * dh).map(|_| r.normal() as f32).collect();
        let outs = token_step_outputs(&k, &v, h, dh, PrecisionPair::new(4, 8)).unwrap();
        assert_eq!(outs[0].shape, vec![1, h, 1, 8]);
        // dequantizing the codes reproduces the fake-quant values
        let mut row = vec![0u8; dh];
        for hh in 0..h {
            let kp = outs[0].shape[3];
            unpack_row(&outs[0].as_u8().unwrap()[hh * kp..(hh + 1) * kp], 4, &mut row);
            let q = quantize_per_token(&k[hh * dh..(hh + 1) * dh], 1, dh, 4).unwrap();
            let want = q.dequantize();
            let s = outs[1].as_f32().unwrap()[hh];
            let z = outs[2].as_f32().unwrap()[hh];
            for d in 0..dh {
                assert_eq!(row[d] as f32 * s + z, want[d]);
            }
        }
    }

    #[test]
    fn block_outputs_match_per_token_outputs_bitwise() {
        let (h, g, dh) = (2, 4, 16);
        let mut r = Rng::seed(23);
        // head-major block [h, g, dh], the layout the block prefill commits
        let k: Vec<f32> = (0..h * g * dh).map(|_| r.normal() as f32).collect();
        let v: Vec<f32> = (0..h * g * dh).map(|_| r.normal() as f32).collect();
        let pair = PrecisionPair::new(4, 2);
        let blk = token_block_outputs(&k, &v, h, g, dh, pair).unwrap();
        let (kp, vp) = (blk[0].shape[3], blk[3].shape[3]);
        for t in 0..g {
            let mut kt = vec![0f32; h * dh];
            let mut vt = vec![0f32; h * dh];
            for hh in 0..h {
                kt[hh * dh..(hh + 1) * dh]
                    .copy_from_slice(&k[(hh * g + t) * dh..(hh * g + t + 1) * dh]);
                vt[hh * dh..(hh + 1) * dh]
                    .copy_from_slice(&v[(hh * g + t) * dh..(hh * g + t + 1) * dh]);
            }
            let one = token_step_outputs(&kt, &vt, h, dh, pair).unwrap();
            for hh in 0..h {
                assert_eq!(
                    &blk[0].as_u8().unwrap()[(hh * g + t) * kp..(hh * g + t + 1) * kp],
                    &one[0].as_u8().unwrap()[hh * kp..(hh + 1) * kp],
                    "k codes (t={t} h={hh})"
                );
                assert_eq!(
                    &blk[3].as_u8().unwrap()[(hh * g + t) * vp..(hh * g + t + 1) * vp],
                    &one[3].as_u8().unwrap()[hh * vp..(hh + 1) * vp],
                    "v codes (t={t} h={hh})"
                );
                for (bi, oi) in [(1, 1), (2, 2), (4, 4), (5, 5)] {
                    assert_eq!(
                        blk[bi].as_f32().unwrap()[hh * g + t].to_bits(),
                        one[oi].as_f32().unwrap()[hh].to_bits(),
                        "scale/zero tensor {bi} (t={t} h={hh})"
                    );
                }
            }
        }
    }

    #[test]
    fn kivi_outputs_have_page_aligned_channel_scales() {
        let (h, g, dh) = (2, 8, 16);
        let mut r = Rng::seed(11);
        let k = Tensor::f32(&[1, h, g, dh], (0..h * g * dh).map(|_| r.normal() as f32).collect());
        let v = Tensor::f32(&[1, h, g, dh], (0..h * g * dh).map(|_| r.normal() as f32).collect());
        let (ko, vo) = kivi_commit_outputs(&k, &v, h, g, dh, PrecisionPair::new(4, 2)).unwrap();
        assert_eq!(ko[1].shape, vec![1, h, dh], "one scale vector per page");
        assert_eq!(vo[1].shape, vec![1, h, g], "per-token value scales");
        assert_eq!(ko[0].shape, vec![1, h, g, 8]);
        assert_eq!(vo[0].shape, vec![1, h, g, 4]);
    }
}
