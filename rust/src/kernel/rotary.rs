//! Split-half rotary position embedding, matching `model.py::apply_rope`
//! and the reference engine: channel i pairs with i + dh/2, frequency
//! theta^(-i / (dh/2)).

/// Rotate one head's `[head_dim]` vector in place for absolute position `pos`.
pub fn apply_rope(x: &mut [f32], pos: usize, head_dim: usize, theta: f64) {
    debug_assert_eq!(x.len(), head_dim);
    let half = head_dim / 2;
    for i in 0..half {
        let freq = (theta as f32).powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (s, c) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * c - b * s;
        x[i + half] = a * s + b * c;
    }
}

/// Rotate `n_heads` packed `[n_heads * head_dim]` vectors in place.
pub fn apply_rope_heads(x: &mut [f32], n_heads: usize, head_dim: usize, pos: usize, theta: f64) {
    debug_assert_eq!(x.len(), n_heads * head_dim);
    for h in 0..n_heads {
        apply_rope(&mut x[h * head_dim..(h + 1) * head_dim], pos, head_dim, theta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_zero_is_identity() {
        let mut x: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let orig = x.clone();
        apply_rope(&mut x, 0, 8, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rotation_preserves_pair_norms() {
        let mut x: Vec<f32> = (0..8).map(|i| (i as f32 * 0.9).sin()).collect();
        let orig = x.clone();
        apply_rope(&mut x, 17, 8, 10000.0);
        for i in 0..4 {
            let before = orig[i] * orig[i] + orig[i + 4] * orig[i + 4];
            let after = x[i] * x[i] + x[i + 4] * x[i + 4];
            assert!((before - after).abs() < 1e-5, "pair {i}: {before} vs {after}");
        }
    }
}
