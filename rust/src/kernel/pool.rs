//! In-tree scoped thread pool for the native kernels (rayon is not in the
//! offline crate set).
//!
//! The pool exists to parallelize kernels **deterministically**: callers
//! partition work over *outputs* (column ranges of a gemm, query heads of an
//! attention step, row blocks of a prefill), so every output element keeps
//! its exact scalar accumulation order and results are bit-identical for any
//! thread count. The pool itself guarantees only that each task index in
//! `0..n` runs exactly once; which thread runs it is irrelevant by
//! construction.
//!
//! Dispatch is latency-tuned for kernel-sized jobs (tens of microseconds):
//! workers spin briefly on an epoch counter before falling back to a
//! condvar, so back-to-back kernel launches inside one decode step do not
//! pay a futex round trip each. A pool with `threads == 1` spawns no worker
//! threads and runs every job inline — `--threads 1` is exactly the scalar
//! engine.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Spin iterations before a waiter parks on the condvar. Large enough to
/// bridge the gap between consecutive kernel launches in a decode step,
/// small enough that an idle pool sleeps within a few microseconds.
const SPIN_ITERS: usize = 1 << 14;

/// Default worker count: `KVTUNER_THREADS` when set to a positive integer
/// (the CI thread matrix uses this), else the machine's available
/// parallelism. An unusable value is reported on stderr rather than
/// silently ignored — mirroring `--threads`' validation stance (0 is not
/// "auto").
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("KVTUNER_THREADS") {
        match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            _ => eprintln!(
                "ignoring invalid KVTUNER_THREADS={v:?} (expected an integer >= 1); \
                 falling back to available parallelism"
            ),
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `n` items into at most `parts` contiguous, near-equal ranges.
/// Deterministic in `n` and `parts`; never returns an empty range.
pub fn partition(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let (base, rem) = (n / parts, n % parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Shared mutable view of a slice for tasks that write provably disjoint
/// ranges (the output-partitioning contract). Each range must be handed to
/// exactly one concurrent task.
pub struct SharedMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    pub fn new(s: &'a mut [T]) -> SharedMut<'a, T> {
        SharedMut { ptr: s.as_mut_ptr(), len: s.len(), _marker: std::marker::PhantomData }
    }

    /// # Safety
    /// Concurrent callers must request disjoint `[start, start + len)`
    /// ranges; the pool's one-task-per-index guarantee plus a disjoint
    /// partition of the output makes that hold structurally.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[derive(Clone)]
struct Job {
    /// Lifetime-erased task closure; `run` does not return until every
    /// worker has left the job, which is what makes the erasure sound.
    f: &'static (dyn Fn(usize) + Sync),
    next: Arc<AtomicUsize>,
    total: usize,
}

struct Shared {
    /// Bumped once per published job; each worker runs each epoch once.
    epoch: AtomicU64,
    /// Workers still inside the current epoch's job.
    active: AtomicUsize,
    /// A task closure panicked; re-raised on the submitting thread.
    panicked: AtomicBool,
    shutdown: AtomicBool,
    job: Mutex<Option<Job>>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes `run` submissions: the pool's epoch/active protocol
    /// handles one job at a time, and `run` takes `&self` (the pool is
    /// shared with every kernel call), so concurrent submitters from safe
    /// code must queue here rather than clobber each other's job state.
    submit: Mutex<()>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool with `threads` total execution lanes (the submitting
    /// thread participates, so `threads - 1` workers are spawned; `1` spawns
    /// none and runs everything inline).
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads >= 1, "thread pool needs at least one thread");
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            job: Mutex::new(None),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 1..threads {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("kvtuner-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawning pool worker");
            handles.push(h);
        }
        ThreadPool { shared, handles, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..total`, each exactly once, distributed
    /// over the pool (the calling thread participates). Returns after every
    /// task has finished. Concurrent `run` calls from different threads
    /// serialize on an internal lock; `f` must not call back into `run` on
    /// the same pool (that would deadlock on the submission lock).
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if self.handles.is_empty() || total == 1 {
            for i in 0..total {
                f(i);
            }
            return;
        }
        let _submission = self.shared.submit.lock().unwrap();
        let next = Arc::new(AtomicUsize::new(0));
        // Sound because `drain` below does not return (even on unwind)
        // until every worker has decremented `active` — no worker touches
        // `f` after that.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut job = self.shared.job.lock().unwrap();
            debug_assert!(self.shared.active.load(Ordering::Acquire) == 0);
            *job = Some(Job { f: f_static, next: next.clone(), total });
            self.shared.panicked.store(false, Ordering::Relaxed);
            self.shared.active.store(self.handles.len(), Ordering::Release);
            self.shared.epoch.fetch_add(1, Ordering::Release);
            self.shared.work_cv.notify_all();
        }
        // waits for the workers even if f(i) panics on this thread
        let drain = DrainGuard(&self.shared);
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= total {
                break;
            }
            f(i);
        }
        drop(drain);
        if self.shared.panicked.load(Ordering::Acquire) {
            panic!("a thread-pool task panicked");
        }
    }
}

/// Blocks until `active == 0` when dropped (spin first, then condvar).
struct DrainGuard<'a>(&'a Shared);

impl Drop for DrainGuard<'_> {
    fn drop(&mut self) {
        let sh = self.0;
        for _ in 0..SPIN_ITERS {
            if sh.active.load(Ordering::Acquire) == 0 {
                return;
            }
            std::hint::spin_loop();
        }
        let mut guard = sh.job.lock().unwrap();
        while sh.active.load(Ordering::Acquire) != 0 {
            guard = sh.done_cv.wait(guard).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = self.shared.job.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Wait until the epoch moves past `seen` (spin, then sleep). `None` on
/// shutdown.
fn wait_for_epoch(sh: &Shared, seen: u64) -> Option<u64> {
    for _ in 0..SPIN_ITERS {
        if sh.shutdown.load(Ordering::Acquire) {
            return None;
        }
        let e = sh.epoch.load(Ordering::Acquire);
        if e != seen {
            return Some(e);
        }
        std::hint::spin_loop();
    }
    let mut guard = sh.job.lock().unwrap();
    loop {
        if sh.shutdown.load(Ordering::Acquire) {
            return None;
        }
        let e = sh.epoch.load(Ordering::Acquire);
        if e != seen {
            return Some(e);
        }
        guard = sh.work_cv.wait(guard).unwrap();
    }
}

fn worker_loop(sh: &Shared) {
    let mut seen = 0u64;
    loop {
        let Some(e) = wait_for_epoch(sh, seen) else { return };
        seen = e;
        let job = sh.job.lock().unwrap().clone().expect("epoch bumped without a job");
        let ok = catch_unwind(AssertUnwindSafe(|| loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.total {
                break;
            }
            (job.f)(i);
        }));
        if ok.is_err() {
            sh.panicked.store(true, Ordering::Release);
        }
        if sh.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = sh.job.lock().unwrap();
            sh.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_index_runs_exactly_once() {
        for threads in [1, 2, 5] {
            let pool = ThreadPool::new(threads);
            let n = 197;
            let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            pool.run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} (threads={threads})");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = ThreadPool::new(3);
        let sum = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(17, &|i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (16 * 17 / 2));
    }

    #[test]
    fn disjoint_output_partitioning_writes_everything() {
        let pool = ThreadPool::new(4);
        let n = 103;
        let mut out = vec![0u64; n];
        let ranges = partition(n, pool.threads());
        let shared = SharedMut::new(&mut out);
        pool.run(ranges.len(), &|ci| {
            let r = ranges[ci].clone();
            let chunk = unsafe { shared.slice(r.start, r.len()) };
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (r.start + k) as u64 + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 + 1);
        }
    }

    #[test]
    fn partition_is_contiguous_and_complete() {
        for (n, parts) in [(0, 4), (1, 4), (7, 3), (8, 3), (64, 64), (5, 9)] {
            let rs = partition(n, parts);
            assert!(rs.len() <= parts.max(1));
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next, "n={n} parts={parts}");
                assert!(r.end > r.start, "no empty ranges");
                next = r.end;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        // a !Sync-unfriendly check: inline execution sees updates in order
        let cell = AtomicUsize::new(0);
        pool.run(4, &|i| {
            assert_eq!(cell.load(Ordering::Relaxed), i);
            cell.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(cell.load(Ordering::Relaxed), 4);
    }
}
