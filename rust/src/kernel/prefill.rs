//! Block-prefill attention: causal attention for a whole KIVI-group-sized
//! block of fresh prompt tokens in one kernel, instead of one `attend_one`
//! per token.
//!
//! Row `t` of the block attends the chronological first `base + t + 1`
//! tokens of the view — committed pages first, then the fp residual ring —
//! which is exactly what the token-by-token path sees at that token's step:
//!
//! * token / fp layers: all block tokens are already committed when the
//!   kernel runs (their quantization is row-independent), and row `t`'s
//!   causal prefix stops inside the committed region;
//! * kivi layers: the engine appends the whole block to the fp residual
//!   ring first and commits the group *after* this kernel, so rows
//!   `0..g-1` see the old committed pages plus an in-block fp causal tail
//!   of rows `0..=t` — bit-for-bit what the scalar path's interleaved
//!   append/attend produced. (The group-filling row itself attends
//!   post-commit via `attend_one_mt`, because the scalar path commits the
//!   group before that token attends.)
//!
//! Scores run through `causal_softmax_rows` — mask and normalization fused,
//! masked columns never enter the max/denominator — and the per-column K·Q
//! and P·V folds are the shared `paged_attention` head bodies, so the block
//! path is bit-identical to the scalar path by construction. Work is
//! partitioned over query heads (disjoint `[Dh]` output stripes), keeping
//! the thread-count-invariance contract.

use anyhow::Result;

use crate::kvcache::KvView;

use super::paged_attention::{head_pv, head_scores, with_scratch};
use super::pool::{SharedMut, ThreadPool};
use super::softmax::causal_softmax_rows;

/// Causal attention for `rows` fresh query tokens over a slot's view.
///
/// `q_rows` / `out` are `[rows, hq * dh]` row-major; `base` is the number of
/// tokens that existed before the block (row `t` sees the first
/// `base + t + 1` view tokens). Requires `base + rows <= view.seq_len()`.
pub fn attend_block(
    pool: &ThreadPool,
    q_rows: &[f32],
    rows: usize,
    hq: usize,
    view: &KvView<'_>,
    base: usize,
    out: &mut [f32],
) -> Result<()> {
    if rows == 0 {
        return Ok(());
    }
    let (h, dh) = (view.h, view.dh);
    debug_assert_eq!(q_rows.len(), rows * hq * dh);
    debug_assert_eq!(out.len(), rows * hq * dh);
    anyhow::ensure!(hq % h == 0, "query heads must be a multiple of kv heads");
    let cols = base + rows;
    anyhow::ensure!(cols <= view.seq_len(), "block overruns the kv view");
    let gqa = hq / h;
    let scale = 1.0 / (dh as f32).sqrt();
    let stride = hq * dh;
    let shared = SharedMut::new(out);
    pool.run(hq, &|hh: usize| {
        // [rows, cols] score matrix + code row from the shared per-thread
        // attention scratch (one pair per pool thread, decode and prefill)
        with_scratch(rows * cols, dh, |scores, codes| {
            let kv = hh / gqa;
            // K·Q for every visible (row, column): committed pages first,
            // then the fp residual tail — the decode kernel's fold exactly
            for t in 0..rows {
                let visible = base + t + 1;
                let n_comm = visible.min(view.cache_len);
                let n_res = visible - n_comm;
                let qh = &q_rows[t * stride + hh * dh..t * stride + (hh + 1) * dh];
                head_scores(
                    view,
                    qh,
                    kv,
                    n_comm,
                    n_res,
                    scale,
                    codes,
                    &mut scores[t * cols..t * cols + visible],
                );
            }
            causal_softmax_rows(scores, rows, cols, base);
            for t in 0..rows {
                let visible = base + t + 1;
                let n_comm = visible.min(view.cache_len);
                let n_res = visible - n_comm;
                let o = unsafe { shared.slice(t * stride + hh * dh, dh) };
                head_pv(
                    view,
                    kv,
                    n_comm,
                    n_res,
                    &scores[t * cols..t * cols + visible],
                    codes,
                    o,
                );
            }
        });
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::attend_one;
    use crate::kernel::paged_attention::test_fp_view as fp_view;

    /// Row `t` of the block must equal a scalar `attend_one` over a view
    /// truncated to `base + t + 1` tokens — bitwise, at any pool width.
    #[test]
    fn block_rows_match_per_token_attention_bitwise() {
        let (h, hq, dh, s_max, page) = (2usize, 4usize, 8usize, 16usize, 4usize);
        let (base, rows) = (3usize, 5usize);
        let total = base + rows;
        let mut k_fp = vec![0f32; h * s_max * dh];
        let mut v_fp = vec![0f32; h * s_max * dh];
        for hh in 0..h {
            for j in 0..total {
                for d in 0..dh {
                    let o = (hh * s_max + j) * dh + d;
                    k_fp[o] = ((o * 13 % 31) as f32 - 15.0) * 0.07;
                    v_fp[o] = ((o * 11 % 29) as f32 - 14.0) * 0.05;
                }
            }
        }
        let q_rows: Vec<f32> = (0..rows * hq * dh).map(|i| (i as f32 * 0.23).sin()).collect();
        for threads in [1, 2, 8] {
            let pool = ThreadPool::new(threads);
            let full = fp_view(&k_fp, &v_fp, h, dh, s_max, page, total);
            let mut block_out = vec![0f32; rows * hq * dh];
            attend_block(&pool, &q_rows, rows, hq, &full, base, &mut block_out).unwrap();
            for t in 0..rows {
                let causal = fp_view(&k_fp, &v_fp, h, dh, s_max, page, base + t + 1);
                let mut row_out = vec![0f32; hq * dh];
                attend_one(&q_rows[t * hq * dh..(t + 1) * hq * dh], hq, &causal, &mut row_out)
                    .unwrap();
                let a: Vec<u32> = row_out.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u32> = block_out[t * hq * dh..(t + 1) * hq * dh]
                    .iter()
                    .map(|x| x.to_bits())
                    .collect();
                assert_eq!(a, b, "row {t} threads={threads}");
            }
        }
    }
}
