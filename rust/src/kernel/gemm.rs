//! Dense matrix kernels. `matvec_acc` is the decode hot path (one token
//! against `[d_in, d_out]` row-major weights) and keeps the reference
//! engine's zero-skip so the two paths produce bit-identical accumulations;
//! `matmul` is the prefill-shaped variant (row blocks of tokens).

/// y[j] += sum_i x[i] * w[i, j]  (w: [d_in, d_out] row-major).
///
/// Skipping exact zeros matches `ref_engine::matvec_acc` float-op for
/// float-op — important because parity tests compare logits at tight
/// tolerance, and a different accumulation order would drift.
pub fn matvec_acc(x: &[f32], w: &[f32], d_in: usize, d_out: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(y.len(), d_out);
    for i in 0..d_in {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xi * row[j];
        }
    }
}

/// out[t, j] = sum_i a[t, i] * w[i, j]  (a: [rows, d_in], w: [d_in, d_out]).
///
/// Accumulates row-of-w at a time (same inner order as `matvec_acc` per
/// output row), so a one-row `matmul` equals a `matvec_acc` over zeroed
/// output exactly.
pub fn matmul(a: &[f32], w: &[f32], rows: usize, d_in: usize, d_out: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), rows * d_out);
    out.fill(0.0);
    for t in 0..rows {
        let row_in = &a[t * d_in..(t + 1) * d_in];
        matvec_acc(row_in, w, d_in, d_out, &mut out[t * d_out..(t + 1) * d_out]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_known_values() {
        // w = [[1, 2], [3, 4], [5, 6]] (3 in, 2 out), x = [1, 0, 2]
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![1.0, 0.0, 2.0];
        let mut y = vec![0.0; 2];
        matvec_acc(&x, &w, 3, 2, &mut y);
        assert_eq!(y, vec![11.0, 14.0]);
    }

    #[test]
    fn matmul_matches_per_row_matvec() {
        let (rows, d_in, d_out) = (3, 4, 5);
        let a: Vec<f32> = (0..rows * d_in).map(|i| (i as f32 * 0.3).sin()).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut out = vec![0.0; rows * d_out];
        matmul(&a, &w, rows, d_in, d_out, &mut out);
        for t in 0..rows {
            let mut y = vec![0.0; d_out];
            matvec_acc(&a[t * d_in..(t + 1) * d_in], &w, d_in, d_out, &mut y);
            assert_eq!(&out[t * d_out..(t + 1) * d_out], &y[..]);
        }
    }
}
