//! Dense matrix kernels. `matvec_acc` is the decode hot path (one token
//! against `[d_in, d_out]` row-major weights) and keeps the reference
//! engine's zero-skip so the two paths produce bit-identical accumulations;
//! `matmul` is the row-block variant (one weight pass for a whole block of
//! tokens — prefill groups *and* batched decode, where each row is one
//! active slot's hidden state); `matvec_rows` is the lm-head shape
//! (row-major `[rows, d]` matrix times a vector, one dot per output row)
//! and `matvec_rows_many` its batched-decode form (the same weight rows
//! against several slot vectors, one weight pass for the whole batch).
//!
//! Every `_mt` variant partitions over *outputs* — column ranges for
//! `matvec_acc`/`matmul`, row ranges for `matvec_rows`/`matvec_rows_many` —
//! so each output element keeps the exact scalar accumulation order and
//! results are bit-identical for any thread count (the determinism contract
//! pinned by `tests/native_backend.rs`).

use super::pool::{partition, SharedMut, ThreadPool};

/// y[j] += sum_i x[i] * w[i, j]  (w: [d_in, d_out] row-major).
///
/// Skipping exact zeros matches `ref_engine::matvec_acc` float-op for
/// float-op — important because parity tests compare logits at tight
/// tolerance, and a different accumulation order would drift.
pub fn matvec_acc(x: &[f32], w: &[f32], d_in: usize, d_out: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(y.len(), d_out);
    matvec_acc_cols(x, w, d_out, 0, d_out, y);
}

/// The column-range body of `matvec_acc`: accumulate columns `[j0, j1)` into
/// `y` (length `j1 - j0`). Per output column the i-loop is identical to the
/// full-width kernel, which is what makes column splits bit-exact.
fn matvec_acc_cols(x: &[f32], w: &[f32], d_out: usize, j0: usize, j1: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), j1 - j0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out + j0..i * d_out + j1];
        for (yj, &wj) in y.iter_mut().zip(row) {
            *yj += xi * wj;
        }
    }
}

/// Threaded `matvec_acc`: columns are split into one contiguous range per
/// pool thread; every `y[j]` still accumulates in ascending-`i` order with
/// the same zero-skip, so the result is bit-identical to the scalar kernel.
pub fn matvec_acc_mt(
    pool: &ThreadPool,
    x: &[f32],
    w: &[f32],
    d_in: usize,
    d_out: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(x.len(), d_in);
    debug_assert_eq!(y.len(), d_out);
    if pool.threads() == 1 || d_out < 2 {
        return matvec_acc(x, w, d_in, d_out, y);
    }
    let ranges = partition(d_out, pool.threads());
    let out = SharedMut::new(y);
    pool.run(ranges.len(), &|ci: usize| {
        let r = ranges[ci].clone();
        let yc = unsafe { out.slice(r.start, r.len()) };
        matvec_acc_cols(x, w, d_out, r.start, r.end, yc);
    });
}

/// out[t, j] = sum_i a[t, i] * w[i, j]  (a: [rows, d_in], w: [d_in, d_out]).
///
/// The i-loop is outermost so each weight row is read once for the whole
/// row block (the point of block prefill: ~rows× fewer weight passes than
/// per-token `matvec_acc`). Per output element the accumulation is still
/// ascending-`i` with the same zero-skip, so a one-row `matmul` equals a
/// `matvec_acc` over zeroed output exactly.
pub fn matmul(a: &[f32], w: &[f32], rows: usize, d_in: usize, d_out: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), rows * d_out);
    out.fill(0.0);
    let shared = SharedMut::new(out);
    matmul_cols(a, w, rows, d_in, d_out, 0, d_out, &shared);
}

/// The column-range body of `matmul`: accumulate columns `[j0, j1)` of every
/// row into `out` (a `[rows, d_out]` buffer behind `SharedMut` — sequential
/// callers pass the full range, pool tasks pass disjoint ranges). One body
/// for both paths is what keeps the scalar/threaded bit-identity structural
/// rather than copy-paste-maintained.
#[allow(clippy::too_many_arguments)]
fn matmul_cols(
    a: &[f32],
    w: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    j0: usize,
    j1: usize,
    out: &SharedMut<'_, f32>,
) {
    for i in 0..d_in {
        let wrow = &w[i * d_out + j0..i * d_out + j1];
        for t in 0..rows {
            let ai = a[t * d_in + i];
            if ai == 0.0 {
                continue;
            }
            let o = unsafe { out.slice(t * d_out + j0, j1 - j0) };
            for (oj, &wj) in o.iter_mut().zip(wrow) {
                *oj += ai * wj;
            }
        }
    }
}

/// Threaded `matmul`: column-range split (each task streams its column
/// stripe of `w` once across all rows). Bit-identical to `matmul` for any
/// thread count.
pub fn matmul_mt(
    pool: &ThreadPool,
    a: &[f32],
    w: &[f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(out.len(), rows * d_out);
    if pool.threads() == 1 || d_out < 2 {
        return matmul(a, w, rows, d_in, d_out, out);
    }
    out.fill(0.0);
    let ranges = partition(d_out, pool.threads());
    let shared = SharedMut::new(out);
    pool.run(ranges.len(), &|ci: usize| {
        let r = ranges[ci].clone();
        matmul_cols(a, w, rows, d_in, d_out, r.start, r.end, &shared);
    });
}

/// y[r] = dot(m[r, :], x) for row-major `m: [rows, d]` — the tied-embedding
/// lm-head shape (no zero-skip: matches the engine's original hand-rolled
/// dot exactly).
pub fn matvec_rows(m: &[f32], x: &[f32], rows: usize, d: usize, y: &mut [f32]) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(x.len(), d);
    debug_assert_eq!(y.len(), rows);
    for t in 0..rows {
        let row = &m[t * d..(t + 1) * d];
        let mut dot = 0f32;
        for i in 0..d {
            dot += x[i] * row[i];
        }
        y[t] = dot;
    }
}

/// Threaded `matvec_rows`: row-range split; each output is one whole dot, so
/// any split is trivially bit-exact.
pub fn matvec_rows_mt(
    pool: &ThreadPool,
    m: &[f32],
    x: &[f32],
    rows: usize,
    d: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(y.len(), rows);
    if pool.threads() == 1 || rows < 2 {
        return matvec_rows(m, x, rows, d, y);
    }
    let ranges = partition(rows, pool.threads());
    let shared = SharedMut::new(y);
    pool.run(ranges.len(), &|ci: usize| {
        let r = ranges[ci].clone();
        let yc = unsafe { shared.slice(r.start, r.len()) };
        matvec_rows(&m[r.start * d..r.end * d], x, r.len(), d, yc);
    });
}

/// The row-range body of `matvec_rows_many`: for weight rows `[r0, r1)`
/// compute `ys[b][r] = dot(m[r, :], xs[b, :])` for every batch vector. The
/// row loop is outermost so each weight row is read once for the whole
/// batch (the batched-lm-head win); per `(b, r)` the dot is the exact
/// `matvec_rows` loop, which is what makes the batched head bit-identical
/// to per-slot `matvec_rows_mt`.
fn matvec_rows_many_range(
    m: &[f32],
    xs: &[f32],
    nb: usize,
    d: usize,
    r0: usize,
    r1: usize,
    ys: &[SharedMut<'_, f32>],
) {
    for r in r0..r1 {
        let row = &m[r * d..(r + 1) * d];
        for (b, y) in ys.iter().enumerate().take(nb) {
            let x = &xs[b * d..(b + 1) * d];
            let mut dot = 0f32;
            for i in 0..d {
                dot += x[i] * row[i];
            }
            unsafe { y.slice(r, 1)[0] = dot };
        }
    }
}

/// Batched `matvec_rows`: `ys[b][r] = dot(m[r, :], xs[b, :])` for batch
/// vectors `xs: [nb, d]` against row-major `m: [rows, d]` — the lm head
/// over all active decode slots in one weight pass. Each output row is one
/// whole dot in `matvec_rows` order, so a one-vector call equals
/// `matvec_rows` bitwise.
pub fn matvec_rows_many(
    m: &[f32],
    xs: &[f32],
    nb: usize,
    rows: usize,
    d: usize,
    ys: &mut [&mut [f32]],
) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(xs.len(), nb * d);
    debug_assert_eq!(ys.len(), nb);
    debug_assert!(ys.iter().all(|y| y.len() == rows));
    let shared: Vec<SharedMut<'_, f32>> = ys.iter_mut().map(|y| SharedMut::new(y)).collect();
    matvec_rows_many_range(m, xs, nb, d, 0, rows, &shared);
}

/// Threaded `matvec_rows_many`: row-range split, each task streaming its
/// weight-row stripe once across every batch vector. Bit-identical to the
/// scalar form (and to per-slot `matvec_rows_mt`) for any thread count.
pub fn matvec_rows_many_mt(
    pool: &ThreadPool,
    m: &[f32],
    xs: &[f32],
    nb: usize,
    rows: usize,
    d: usize,
    ys: &mut [&mut [f32]],
) {
    debug_assert_eq!(m.len(), rows * d);
    debug_assert_eq!(xs.len(), nb * d);
    debug_assert_eq!(ys.len(), nb);
    if pool.threads() == 1 || rows < 2 {
        return matvec_rows_many(m, xs, nb, rows, d, ys);
    }
    let ranges = partition(rows, pool.threads());
    let shared: Vec<SharedMut<'_, f32>> = ys.iter_mut().map(|y| SharedMut::new(y)).collect();
    pool.run(ranges.len(), &|ci: usize| {
        let r = ranges[ci].clone();
        matvec_rows_many_range(m, xs, nb, d, r.start, r.end, &shared);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn matvec_known_values() {
        // w = [[1, 2], [3, 4], [5, 6]] (3 in, 2 out), x = [1, 0, 2]
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let x = vec![1.0, 0.0, 2.0];
        let mut y = vec![0.0; 2];
        matvec_acc(&x, &w, 3, 2, &mut y);
        assert_eq!(y, vec![11.0, 14.0]);
    }

    #[test]
    fn matmul_matches_per_row_matvec() {
        let (rows, d_in, d_out) = (3, 4, 5);
        let a: Vec<f32> = (0..rows * d_in).map(|i| (i as f32 * 0.3).sin()).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut out = vec![0.0; rows * d_out];
        matmul(&a, &w, rows, d_in, d_out, &mut out);
        for t in 0..rows {
            let mut y = vec![0.0; d_out];
            matvec_acc(&a[t * d_in..(t + 1) * d_in], &w, d_in, d_out, &mut y);
            assert_eq!(&out[t * d_out..(t + 1) * d_out], &y[..]);
        }
    }

    #[test]
    fn threaded_kernels_are_bit_identical_to_scalar() {
        let (rows, d_in, d_out) = (7, 19, 33);
        let a: Vec<f32> = (0..rows * d_in)
            .map(|i| if i % 11 == 0 { 0.0 } else { (i as f32 * 0.13).sin() })
            .collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|i| (i as f32 * 0.29).cos()).collect();
        for threads in [2, 3, 8] {
            let pool = ThreadPool::new(threads);
            // matvec: column split
            let mut y0 = vec![0.1f32; d_out];
            let mut y1 = y0.clone();
            matvec_acc(&a[..d_in], &w, d_in, d_out, &mut y0);
            matvec_acc_mt(&pool, &a[..d_in], &w, d_in, d_out, &mut y1);
            assert_eq!(bits(&y0), bits(&y1), "matvec threads={threads}");
            // matmul: column split over a row block
            let mut o0 = vec![0f32; rows * d_out];
            let mut o1 = o0.clone();
            matmul(&a, &w, rows, d_in, d_out, &mut o0);
            matmul_mt(&pool, &a, &w, rows, d_in, d_out, &mut o1);
            assert_eq!(bits(&o0), bits(&o1), "matmul threads={threads}");
            // matvec_rows: row split (m: [rows, d_in], x: [d_in])
            let mut r0 = vec![0f32; rows];
            let mut r1 = r0.clone();
            matvec_rows(&a, &w[..d_in], rows, d_in, &mut r0);
            matvec_rows_mt(&pool, &a, &w[..d_in], rows, d_in, &mut r1);
            assert_eq!(bits(&r0), bits(&r1), "matvec_rows threads={threads}");
        }
    }

    #[test]
    fn batched_rows_kernel_matches_per_slot_matvec_rows() {
        // the batched lm head must be bit-identical to per-slot matvec_rows
        // at any thread count, including the one-vector case
        let (rows, d) = (37, 12);
        let m: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.21).sin()).collect();
        for nb in [1usize, 2, 5] {
            let xs: Vec<f32> =
                (0..nb * d).map(|i| (i as f32 * 0.43).cos() * ((i % 3) as f32)).collect();
            let mut want = vec![vec![0f32; rows]; nb];
            for b in 0..nb {
                matvec_rows(&m, &xs[b * d..(b + 1) * d], rows, d, &mut want[b]);
            }
            let mut got = vec![vec![0f32; rows]; nb];
            {
                let mut ys: Vec<&mut [f32]> = got.iter_mut().map(|y| y.as_mut_slice()).collect();
                matvec_rows_many(&m, &xs, nb, rows, d, &mut ys);
            }
            for b in 0..nb {
                assert_eq!(bits(&want[b]), bits(&got[b]), "scalar nb={nb} b={b}");
            }
            for threads in [2, 3, 8] {
                let pool = ThreadPool::new(threads);
                let mut got = vec![vec![0f32; rows]; nb];
                {
                    let mut ys: Vec<&mut [f32]> =
                        got.iter_mut().map(|y| y.as_mut_slice()).collect();
                    matvec_rows_many_mt(&pool, &m, &xs, nb, rows, d, &mut ys);
                }
                for b in 0..nb {
                    assert_eq!(
                        bits(&want[b]),
                        bits(&got[b]),
                        "threads={threads} nb={nb} b={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn matvec_rows_matches_hand_dot() {
        let (rows, d) = (4, 6);
        let m: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.5).sin()).collect();
        let x: Vec<f32> = (0..d).map(|i| (i as f32 * 0.9).cos()).collect();
        let mut y = vec![0f32; rows];
        matvec_rows(&m, &x, rows, d, &mut y);
        for t in 0..rows {
            let mut dot = 0f32;
            for i in 0..d {
                dot += x[i] * m[t * d + i];
            }
            assert_eq!(y[t].to_bits(), dot.to_bits());
        }
    }
}
