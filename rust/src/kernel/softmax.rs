//! Softmax kernels. `softmax` is the decode form (one query row, every
//! cached token visible); `causal_softmax_rows` is the prefill form — mask
//! and normalization fused in one pass over each row, no materialized mask.

/// In-place numerically-stable softmax over one score row.
///
/// Matches the reference engine's order exactly: subtract the running max,
/// exponentiate, then divide by the accumulated denominator — so attention
/// probabilities agree bitwise with `ref_engine`'s `exp(x - max) / denom`.
pub fn softmax(scores: &mut [f32]) {
    let mut maxs = f32::NEG_INFINITY;
    for &s in scores.iter() {
        maxs = maxs.max(s);
    }
    let mut denom = 0f32;
    for s in scores.iter_mut() {
        *s = (*s - maxs).exp();
        denom += *s;
    }
    for s in scores.iter_mut() {
        *s /= denom;
    }
}

/// Fused causal softmax over `[rows, cols]` scores where query row `t` may
/// attend to key columns `0..=offset + t` (offset = tokens already cached
/// before this block). Masked positions come out exactly 0.0 and never enter
/// the max/denominator.
pub fn causal_softmax_rows(scores: &mut [f32], rows: usize, cols: usize, offset: usize) {
    debug_assert_eq!(scores.len(), rows * cols);
    for t in 0..rows {
        let visible = (offset + t + 1).min(cols);
        let row = &mut scores[t * cols..(t + 1) * cols];
        softmax(&mut row[..visible]);
        for s in row[visible..].iter_mut() {
            *s = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one_and_orders() {
        let mut s = vec![1.0, 3.0, 2.0];
        softmax(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[1] > s[2] && s[2] > s[0]);
    }

    #[test]
    fn causal_rows_mask_the_future() {
        // 2 query rows over 4 columns, 1 token already cached
        let mut s = vec![0.5; 8];
        causal_softmax_rows(&mut s, 2, 4, 1);
        // row 0 sees cols 0..=1, row 1 sees cols 0..=2
        assert_eq!(&s[2..4], &[0.0, 0.0]);
        assert_eq!(s[7], 0.0);
        assert!((s[0] + s[1] - 1.0).abs() < 1e-6);
        assert!((s[4] + s[5] + s[6] - 1.0).abs() < 1e-6);
    }
}
