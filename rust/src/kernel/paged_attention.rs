//! Block-table-direct, dequant-on-read attention: the kernel the native
//! backend runs instead of the XLA arm's gather-to-dense staging copy.
//!
//! For one query token it walks the slot's `KvView` page by page —
//! physically scattered pages on the paged arm, one contiguous region on
//! the dense arm — and folds dequantization straight into the K·Q and P·V
//! accumulation loops: `dot += q[d] * (code * scale + zero)`. No dense
//! staging buffer exists on this path; the only scratch is one score row
//! and one unpacked-code row. KIVI's asymmetric layout is what makes the
//! fold cheap: per-channel key (scale, zero) vectors are page-aligned (one
//! `[Dh]` pair per page, hoisted out of the row loop), and per-token value
//! scales are scalar per row.
//!
//! Token order is chronological: committed pages first, then the kivi fp
//! residual ring — exactly the sequence the reference engine attends over,
//! so probabilities match it bitwise given identical stored codes.

use anyhow::Result;

use crate::config::Mode;
use crate::kvcache::KvView;
use crate::quant::unpack_row;

use super::softmax::softmax;

/// Attention for one query token over everything the view holds (committed
/// + residual). `q` is `[hq * dh]` post-RoPE; `out` receives `[hq * dh]`.
/// GQA: query head `hh` reads KV head `hh / (hq / view.h)`.
pub fn attend_one(q: &[f32], hq: usize, view: &KvView<'_>, out: &mut [f32]) -> Result<()> {
    let (h, dh, p) = (view.h, view.dh, view.page);
    debug_assert_eq!(q.len(), hq * dh);
    debug_assert_eq!(out.len(), hq * dh);
    anyhow::ensure!(hq % h == 0, "query heads must be a multiple of kv heads");
    let gqa = hq / h;
    let s_len = view.seq_len();
    anyhow::ensure!(s_len > 0, "attention over an empty cache");
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0f32; s_len];
    let mut codes = vec![0u8; dh];
    for hh in 0..hq {
        let kv = hh / gqa;
        let qh = &q[hh * dh..(hh + 1) * dh];

        // K·Q over committed pages, dequant folded into the dot
        match view.spec.mode {
            Mode::Fp => {
                for j in 0..view.cache_len {
                    let kj = view.k_fp_row(j / p, kv, j % p);
                    let mut dot = 0f32;
                    for d in 0..dh {
                        dot += qh[d] * kj[d];
                    }
                    scores[j] = dot * scale;
                }
            }
            Mode::Token => {
                for j in 0..view.cache_len {
                    let (pi, row) = (j / p, j % p);
                    unpack_row(view.k_code_row(pi, kv, row), view.spec.pair.k_bits, &mut codes);
                    let (ks, kz) = view.k_tok_scale(pi, kv, row);
                    let mut dot = 0f32;
                    for d in 0..dh {
                        dot += qh[d] * (codes[d] as f32 * ks + kz);
                    }
                    scores[j] = dot * scale;
                }
            }
            Mode::Kivi => {
                // per-channel key scales are page-aligned: hoist the [Dh]
                // scale/zero vectors once per page, outside the row loop
                for pi in 0..view.n_pages() {
                    let rows = view.page_rows(pi);
                    let (ks, kz) = view.k_page_scale(pi, kv);
                    for row in 0..rows {
                        unpack_row(view.k_code_row(pi, kv, row), view.spec.pair.k_bits, &mut codes);
                        let mut dot = 0f32;
                        for d in 0..dh {
                            dot += qh[d] * (codes[d] as f32 * ks[d] + kz[d]);
                        }
                        scores[pi * p + row] = dot * scale;
                    }
                }
            }
        }
        // kivi fp residual tokens (chronologically after every committed one)
        for i in 0..view.res_len {
            let kj = view.res_k_row(kv, i);
            let mut dot = 0f32;
            for d in 0..dh {
                dot += qh[d] * kj[d];
            }
            scores[view.cache_len + i] = dot * scale;
        }

        softmax(&mut scores);

        // P·V, dequant folded the same way
        let o = &mut out[hh * dh..(hh + 1) * dh];
        o.fill(0.0);
        match view.spec.mode {
            Mode::Fp => {
                for j in 0..view.cache_len {
                    let pj = scores[j];
                    let vj = view.v_fp_row(j / p, kv, j % p);
                    for d in 0..dh {
                        o[d] += pj * vj[d];
                    }
                }
            }
            Mode::Token | Mode::Kivi => {
                for j in 0..view.cache_len {
                    let (pi, row) = (j / p, j % p);
                    let pj = scores[j];
                    unpack_row(view.v_code_row(pi, kv, row), view.spec.pair.v_bits, &mut codes);
                    let (vs, vz) = view.v_tok_scale(pi, kv, row);
                    for d in 0..dh {
                        o[d] += pj * (codes[d] as f32 * vs + vz);
                    }
                }
            }
        }
        for i in 0..view.res_len {
            let pj = scores[view.cache_len + i];
            let vj = view.res_v_row(kv, i);
            for d in 0..dh {
                o[d] += pj * vj[d];
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LayerSpec, Mode, PrecisionPair};
    use crate::kvcache::{KvView, PageAddr};

    /// Fp-mode dense view over hand-built buffers: with identical V rows the
    /// attention output must be exactly V regardless of the scores.
    #[test]
    fn uniform_values_pass_through() {
        let (h, dh, s_max, page) = (1usize, 4usize, 8usize, 4usize);
        let len = 5usize;
        let mut k_fp = vec![0f32; h * s_max * dh];
        let mut v_fp = vec![0f32; h * s_max * dh];
        for j in 0..len {
            for d in 0..dh {
                k_fp[j * dh + d] = (j as f32 + 1.0) * 0.1 * (d as f32 - 1.5);
                v_fp[j * dh + d] = 3.0 + d as f32; // identical across tokens
            }
        }
        let view = KvView {
            spec: LayerSpec { mode: Mode::Fp, pair: PrecisionPair::FP },
            h,
            dh,
            kp: 0,
            vp: 0,
            page,
            cache_len: len,
            res_len: 0,
            addr: PageAddr::Dense { slot: 0, s_max },
            k_codes: &[],
            k_scale: &[],
            k_zero: &[],
            v_codes: &[],
            v_scale: &[],
            v_zero: &[],
            k_fp: &k_fp,
            v_fp: &v_fp,
            k_res: &[],
            v_res: &[],
            res_cap: 0,
        };
        let q = vec![0.3f32; dh];
        let mut out = vec![0f32; dh];
        attend_one(&q, 1, &view, &mut out).unwrap();
        for d in 0..dh {
            assert!((out[d] - (3.0 + d as f32)).abs() < 1e-5, "d={d}: {}", out[d]);
        }
    }
}
