//! Block-table-direct, dequant-on-read attention: the kernel the native
//! backend runs instead of the XLA arm's gather-to-dense staging copy.
//!
//! For one query token it walks the slot's `KvView` page by page —
//! physically scattered pages on the paged arm, one contiguous region on
//! the dense arm — and folds dequantization straight into the K·Q and P·V
//! accumulation loops: `dot += q[d] * (code * scale + zero)`. No dense
//! staging buffer exists on this path; the only scratch is one score row
//! and one unpacked-code row (thread-local, so decode steps allocate
//! nothing once each pool thread has warmed up). KIVI's asymmetric layout
//! is what makes the fold cheap: per-channel key (scale, zero) vectors are
//! page-aligned (one `[Dh]` pair per page, hoisted out of the row loop),
//! and per-token value scales are scalar per row.
//!
//! Token order is chronological: committed pages first, then the kivi fp
//! residual ring — exactly the sequence the reference engine attends over,
//! so probabilities match it bitwise given identical stored codes.
//!
//! `attend_one_mt` partitions over *query heads* (each head's output is one
//! disjoint `[Dh]` stripe and each head's math is fully independent), so
//! results are bit-identical for any thread count; the per-head body is
//! shared with `attend_one` and with the block-prefill kernel
//! (`kernel::prefill`), which is what makes the parity provable rather than
//! coincidental.

use std::cell::RefCell;

use anyhow::Result;

use crate::config::Mode;
use crate::kvcache::KvView;
use crate::quant::unpack_row;

use super::pool::{SharedMut, ThreadPool};
use super::softmax::softmax;

thread_local! {
    /// Per-thread attention scratch: (score buffer, unpacked-code row).
    /// Shared with the block-prefill kernel via `with_scratch`, so each pool
    /// thread carries exactly one scratch pair.
    static SCRATCH: RefCell<(Vec<f32>, Vec<u8>)> = const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Run `f` with this thread's attention scratch, grown to at least
/// (`scores_len`, `codes_len`). Used by the decode and block-prefill
/// kernels alike.
pub(crate) fn with_scratch<R>(
    scores_len: usize,
    codes_len: usize,
    f: impl FnOnce(&mut [f32], &mut [u8]) -> R,
) -> R {
    SCRATCH.with(|c| {
        let mut borrow = c.borrow_mut();
        let (scores, codes) = &mut *borrow;
        if scores.len() < scores_len {
            scores.resize(scores_len, 0.0);
        }
        if codes.len() < codes_len {
            codes.resize(codes_len, 0);
        }
        f(&mut scores[..scores_len], &mut codes[..codes_len])
    })
}

/// K·Q scores for one query head over the chronological first `n_comm`
/// committed tokens and first `n_res` residual tokens of the view, scaled
/// by `scale`. Writes `scores[0..n_comm + n_res]`; the per-column fold and
/// iteration order are exactly the decode kernel's, so any caller slicing a
/// causal prefix gets bit-identical prefixes of the same score row.
pub(crate) fn head_scores(
    view: &KvView<'_>,
    qh: &[f32],
    kv: usize,
    n_comm: usize,
    n_res: usize,
    scale: f32,
    codes: &mut [u8],
    scores: &mut [f32],
) {
    let (dh, p) = (view.dh, view.page);
    debug_assert_eq!(qh.len(), dh);
    debug_assert!(scores.len() >= n_comm + n_res);
    match view.spec.mode {
        Mode::Fp => {
            for (j, s) in scores.iter_mut().enumerate().take(n_comm) {
                let kj = view.k_fp_row(j / p, kv, j % p);
                let mut dot = 0f32;
                for d in 0..dh {
                    dot += qh[d] * kj[d];
                }
                *s = dot * scale;
            }
        }
        Mode::Token => {
            for (j, s) in scores.iter_mut().enumerate().take(n_comm) {
                let (pi, row) = (j / p, j % p);
                unpack_row(view.k_code_row(pi, kv, row), view.spec.pair.k_bits, codes);
                let (ks, kz) = view.k_tok_scale(pi, kv, row);
                let mut dot = 0f32;
                for d in 0..dh {
                    dot += qh[d] * (codes[d] as f32 * ks + kz);
                }
                *s = dot * scale;
            }
        }
        Mode::Kivi => {
            // per-channel key scales are page-aligned: hoist the [Dh]
            // scale/zero vectors once per page, outside the row loop
            let np = (n_comm + p - 1) / p;
            for pi in 0..np {
                let rows = (n_comm - pi * p).min(p);
                let (ks, kz) = view.k_page_scale(pi, kv);
                for row in 0..rows {
                    unpack_row(view.k_code_row(pi, kv, row), view.spec.pair.k_bits, codes);
                    let mut dot = 0f32;
                    for d in 0..dh {
                        dot += qh[d] * (codes[d] as f32 * ks[d] + kz[d]);
                    }
                    scores[pi * p + row] = dot * scale;
                }
            }
        }
    }
    // kivi fp residual tokens (chronologically after every committed one)
    for i in 0..n_res {
        let kj = view.res_k_row(kv, i);
        let mut dot = 0f32;
        for d in 0..dh {
            dot += qh[d] * kj[d];
        }
        scores[n_comm + i] = dot * scale;
    }
}

/// P·V for one query head over the same chronological token range, dequant
/// folded the same way. `o` (length `[Dh]`) is zeroed then accumulated in
/// column order — committed first, then residual — matching the decode
/// kernel exactly.
pub(crate) fn head_pv(
    view: &KvView<'_>,
    kv: usize,
    n_comm: usize,
    n_res: usize,
    scores: &[f32],
    codes: &mut [u8],
    o: &mut [f32],
) {
    let (dh, p) = (view.dh, view.page);
    debug_assert!(scores.len() >= n_comm + n_res);
    o.fill(0.0);
    match view.spec.mode {
        Mode::Fp => {
            for (j, &pj) in scores.iter().enumerate().take(n_comm) {
                let vj = view.v_fp_row(j / p, kv, j % p);
                for d in 0..dh {
                    o[d] += pj * vj[d];
                }
            }
        }
        Mode::Token | Mode::Kivi => {
            for (j, &pj) in scores.iter().enumerate().take(n_comm) {
                let (pi, row) = (j / p, j % p);
                unpack_row(view.v_code_row(pi, kv, row), view.spec.pair.v_bits, codes);
                let (vs, vz) = view.v_tok_scale(pi, kv, row);
                for d in 0..dh {
                    o[d] += pj * (codes[d] as f32 * vs + vz);
                }
            }
        }
    }
    for i in 0..n_res {
        let pj = scores[n_comm + i];
        let vj = view.res_v_row(kv, i);
        for d in 0..dh {
            o[d] += pj * vj[d];
        }
    }
}

/// Full scores → softmax → P·V for one query head (`n = n_comm + n_res`
/// visible tokens). The single shared body behind the scalar, threaded and
/// block-prefill entry points.
pub(crate) fn attend_head(
    view: &KvView<'_>,
    q: &[f32],
    hh: usize,
    gqa: usize,
    n_comm: usize,
    n_res: usize,
    scale: f32,
    codes: &mut [u8],
    scores: &mut [f32],
    o: &mut [f32],
) {
    let dh = view.dh;
    let kv = hh / gqa;
    let qh = &q[hh * dh..(hh + 1) * dh];
    let n = n_comm + n_res;
    head_scores(view, qh, kv, n_comm, n_res, scale, codes, &mut scores[..n]);
    softmax(&mut scores[..n]);
    head_pv(view, kv, n_comm, n_res, &scores[..n], codes, o);
}

/// Attention for one query token over everything the view holds (committed
/// + residual). `q` is `[hq * dh]` post-RoPE; `out` receives `[hq * dh]`.
/// GQA: query head `hh` reads KV head `hh / (hq / view.h)`.
pub fn attend_one(q: &[f32], hq: usize, view: &KvView<'_>, out: &mut [f32]) -> Result<()> {
    let (h, dh) = (view.h, view.dh);
    debug_assert_eq!(q.len(), hq * dh);
    debug_assert_eq!(out.len(), hq * dh);
    anyhow::ensure!(hq % h == 0, "query heads must be a multiple of kv heads");
    let gqa = hq / h;
    let s_len = view.seq_len();
    anyhow::ensure!(s_len > 0, "attention over an empty cache");
    let scale = 1.0 / (dh as f32).sqrt();
    // same thread-local scratch as the threaded path, so the scalar engine
    // (`--threads 1`) also allocates nothing per decode step after warmup
    with_scratch(s_len, dh, |scores, codes| {
        for hh in 0..hq {
            attend_head(
                view,
                q,
                hh,
                gqa,
                view.cache_len,
                view.res_len,
                scale,
                codes,
                scores,
                &mut out[hh * dh..(hh + 1) * dh],
            );
        }
    });
    Ok(())
}

/// Threaded `attend_one`: query heads are split across the pool (each head
/// writes its own disjoint `[Dh]` output stripe and runs the exact per-head
/// body of the scalar kernel), so the result is bit-identical for any
/// thread count.
pub fn attend_one_mt(
    pool: &ThreadPool,
    q: &[f32],
    hq: usize,
    view: &KvView<'_>,
    out: &mut [f32],
) -> Result<()> {
    if pool.threads() == 1 || hq == 1 {
        return attend_one(q, hq, view, out);
    }
    let (h, dh) = (view.h, view.dh);
    debug_assert_eq!(q.len(), hq * dh);
    debug_assert_eq!(out.len(), hq * dh);
    anyhow::ensure!(hq % h == 0, "query heads must be a multiple of kv heads");
    let gqa = hq / h;
    let s_len = view.seq_len();
    anyhow::ensure!(s_len > 0, "attention over an empty cache");
    let scale = 1.0 / (dh as f32).sqrt();
    let shared = SharedMut::new(out);
    pool.run(hq, &|hh: usize| {
        with_scratch(s_len, dh, |scores, codes| {
            let o = unsafe { shared.slice(hh * dh, dh) };
            attend_head(
                view,
                q,
                hh,
                gqa,
                view.cache_len,
                view.res_len,
                scale,
                codes,
                scores,
                o,
            );
        });
    });
    Ok(())
}

/// Multi-query attention for batched decode: one query token per slot,
/// `views[b]` is slot `b`'s cache view, `qs`/`outs` are `[nb, hq * dh]`
/// row-major. All `nb x hq` (slot, head) tasks go through one pool
/// dispatch, walking every slot's block table in a single pass; each task
/// runs the shared `attend_head` body on its own disjoint `[Dh]` output
/// stripe, so the result is op-for-op identical to `nb` separate
/// `attend_one_mt` calls at any thread count. A batch of one takes the
/// single-slot fast path (the identical task set, one indirection less).
pub fn attend_many(
    pool: &ThreadPool,
    qs: &[f32],
    hq: usize,
    views: &[KvView<'_>],
    outs: &mut [f32],
) -> Result<()> {
    let nb = views.len();
    if nb == 0 {
        return Ok(());
    }
    if nb == 1 {
        return attend_one_mt(pool, qs, hq, &views[0], outs);
    }
    let dh = views[0].dh;
    let stride = hq * dh;
    debug_assert_eq!(qs.len(), nb * stride);
    debug_assert_eq!(outs.len(), nb * stride);
    for v in views {
        anyhow::ensure!(v.dh == dh, "mismatched head_dim across batch views");
        anyhow::ensure!(hq % v.h == 0, "query heads must be a multiple of kv heads");
        anyhow::ensure!(v.seq_len() > 0, "attention over an empty cache");
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let shared = SharedMut::new(outs);
    pool.run(nb * hq, &|idx: usize| {
        let (b, hh) = (idx / hq, idx % hq);
        let view = &views[b];
        let gqa = hq / view.h;
        with_scratch(view.seq_len(), dh, |scores, codes| {
            let o = unsafe { shared.slice(b * stride + hh * dh, dh) };
            attend_head(
                view,
                &qs[b * stride..(b + 1) * stride],
                hh,
                gqa,
                view.cache_len,
                view.res_len,
                scale,
                codes,
                scores,
                o,
            );
        });
    });
    Ok(())
}

/// Hand-built fp-mode dense view over raw buffers — the shared fixture for
/// the attention kernels' bitwise-parity tests (here and in
/// `kernel::prefill`).
#[cfg(test)]
pub(crate) fn test_fp_view<'a>(
    k_fp: &'a [f32],
    v_fp: &'a [f32],
    h: usize,
    dh: usize,
    s_max: usize,
    page: usize,
    len: usize,
) -> KvView<'a> {
    use crate::config::{LayerSpec, PrecisionPair};
    use crate::kvcache::PageAddr;
    KvView {
        spec: LayerSpec { mode: Mode::Fp, pair: PrecisionPair::FP },
        h,
        dh,
        kp: 0,
        vp: 0,
        page,
        cache_len: len,
        res_len: 0,
        addr: PageAddr::Dense { slot: 0, s_max },
        k_codes: &[],
        k_scale: &[],
        k_zero: &[],
        v_codes: &[],
        v_scale: &[],
        v_zero: &[],
        k_fp,
        v_fp,
        k_res: &[],
        v_res: &[],
        res_cap: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::test_fp_view as fp_view;

    /// Fp-mode dense view over hand-built buffers: with identical V rows the
    /// attention output must be exactly V regardless of the scores.
    #[test]
    fn uniform_values_pass_through() {
        let (h, dh, s_max, page) = (1usize, 4usize, 8usize, 4usize);
        let len = 5usize;
        let mut k_fp = vec![0f32; h * s_max * dh];
        let mut v_fp = vec![0f32; h * s_max * dh];
        for j in 0..len {
            for d in 0..dh {
                k_fp[j * dh + d] = (j as f32 + 1.0) * 0.1 * (d as f32 - 1.5);
                v_fp[j * dh + d] = 3.0 + d as f32; // identical across tokens
            }
        }
        let view = fp_view(&k_fp, &v_fp, h, dh, s_max, page, len);
        let q = vec![0.3f32; dh];
        let mut out = vec![0f32; dh];
        attend_one(&q, 1, &view, &mut out).unwrap();
        for d in 0..dh {
            assert!((out[d] - (3.0 + d as f32)).abs() < 1e-5, "d={d}: {}", out[d]);
        }
    }

    /// `attend_many` over a ragged batch (every slot at a different
    /// position) must be bit-identical to per-slot `attend_one_mt` at every
    /// pool width — the batched-decode determinism contract at the kernel
    /// level.
    #[test]
    fn attend_many_matches_per_slot_attend_one() {
        let (h, hq, dh, s_max, page) = (2usize, 4usize, 8usize, 16usize, 4usize);
        let stride = hq * dh;
        // ragged: mixed positions, including a mid-page one and a lone token
        let lens = [11usize, 4, 1, 7];
        let nb = lens.len();
        let mut bufs: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for (b, &len) in lens.iter().enumerate() {
            let mut k_fp = vec![0f32; h * s_max * dh];
            let mut v_fp = vec![0f32; h * s_max * dh];
            for hh in 0..h {
                for j in 0..len {
                    for d in 0..dh {
                        let o = (hh * s_max + j) * dh + d;
                        k_fp[o] = (((o * 7 + b * 13) % 23) as f32 - 11.0) * 0.09;
                        v_fp[o] = (((o * 5 + b * 3) % 19) as f32 - 9.0) * 0.11;
                    }
                }
            }
            bufs.push((k_fp, v_fp));
        }
        let views: Vec<KvView<'_>> = bufs
            .iter()
            .zip(&lens)
            .map(|((k, v), &len)| fp_view(k, v, h, dh, s_max, page, len))
            .collect();
        let qs: Vec<f32> = (0..nb * stride).map(|i| (i as f32 * 0.37).sin()).collect();
        // per-slot oracle (threaded — itself pinned to the scalar kernel)
        let pool1 = ThreadPool::new(2);
        let mut want = vec![0f32; nb * stride];
        for b in 0..nb {
            attend_one_mt(
                &pool1,
                &qs[b * stride..(b + 1) * stride],
                hq,
                &views[b],
                &mut want[b * stride..(b + 1) * stride],
            )
            .unwrap();
        }
        for threads in [1, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut got = vec![0f32; nb * stride];
            attend_many(&pool, &qs, hq, &views, &mut got).unwrap();
            let a: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            let g: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, g, "threads={threads}");
        }
        // batch-of-1 fast path: identical to attend_one_mt by construction,
        // asserted anyway
        for threads in [1, 8] {
            let pool = ThreadPool::new(threads);
            let mut got = vec![0f32; stride];
            attend_many(&pool, &qs[..stride], hq, &views[..1], &mut got).unwrap();
            let a: Vec<u32> = want[..stride].iter().map(|x| x.to_bits()).collect();
            let g: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, g, "batch-of-1 threads={threads}");
        }
    }

    /// Per-query-head splits must be bit-identical to the scalar kernel for
    /// any pool width (GQA factor 2 exercised).
    #[test]
    fn threaded_attention_is_bit_identical() {
        let (h, hq, dh, s_max, page) = (2usize, 4usize, 8usize, 16usize, 4usize);
        let len = 11usize;
        let mut k_fp = vec![0f32; h * s_max * dh];
        let mut v_fp = vec![0f32; h * s_max * dh];
        for hh in 0..h {
            for j in 0..len {
                for d in 0..dh {
                    let o = (hh * s_max + j) * dh + d;
                    k_fp[o] = ((o * 7 % 23) as f32 - 11.0) * 0.09;
                    v_fp[o] = ((o * 5 % 19) as f32 - 9.0) * 0.11;
                }
            }
        }
        let view = fp_view(&k_fp, &v_fp, h, dh, s_max, page, len);
        let q: Vec<f32> = (0..hq * dh).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut scalar = vec![0f32; hq * dh];
        attend_one(&q, hq, &view, &mut scalar).unwrap();
        for threads in [2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut mt = vec![0f32; hq * dh];
            attend_one_mt(&pool, &q, hq, &view, &mut mt).unwrap();
            let a: Vec<u32> = scalar.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = mt.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "threads={threads}");
        }
    }
}
