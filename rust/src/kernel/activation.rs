//! MLP activations. The model family here uses tanh-approximate GELU
//! (matching `jax.nn.gelu(approximate=True)` and the reference engine);
//! `swiglu` ships alongside it for SwiGLU-gated checkpoints (the InfiniLM
//! lineage), so the kernel set covers both MLP shapes.

/// Tanh-approximate GELU, bit-matching `ref_engine::gelu_tanh`.
pub fn gelu_tanh(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub fn gelu_tanh_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = gelu_tanh(*x);
    }
}

/// SwiGLU gate: out[i] = silu(gate[i]) * up[i].
pub fn swiglu(gate: &[f32], up: &[f32], out: &mut [f32]) {
    debug_assert_eq!(gate.len(), up.len());
    debug_assert_eq!(gate.len(), out.len());
    for i in 0..gate.len() {
        let g = gate[i];
        let silu = g / (1.0 + (-g).exp());
        out[i] = silu * up[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_fixed_points() {
        assert_eq!(gelu_tanh(0.0), 0.0);
        // gelu(x) -> x for large positive x, -> 0 for large negative x
        assert!((gelu_tanh(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_tanh(-10.0).abs() < 1e-4);
        // odd-ish symmetry: gelu(x) + gelu(-x) == x
        let x = 1.3f32;
        assert!((gelu_tanh(x) + gelu_tanh(-x) - x).abs() < 1e-6);
    }

    #[test]
    fn swiglu_known_values() {
        let mut out = vec![0.0; 2];
        swiglu(&[0.0, 2.0], &[5.0, 3.0], &mut out);
        assert_eq!(out[0], 0.0); // silu(0) = 0
        let silu2 = 2.0 / (1.0 + (-2.0f32).exp());
        assert!((out[1] - silu2 * 3.0).abs() < 1e-6);
    }
}
