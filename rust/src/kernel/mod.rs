//! Native CPU compute kernels: the artifact-free backend of the engine.
//!
//! These are the InfiniLM-shaped primitives (gemm, rms_norm, rotary, fused
//! softmax, activations) plus the subsystem's reason to exist: a paged
//! attention kernel that walks `PagedKvCache` block tables directly and
//! dequantizes each page on the fly from its layer's precision pair
//! (`paged_attention`). Nothing here stages pages into a dense buffer — the
//! KIVI layout makes that possible, because per-channel key scales are
//! page-aligned by construction, so `(code * scale + zero)` folds straight
//! into the K·Q and P·V accumulation loops.
//!
//! Execution is parallel but **deterministic**: `pool` provides a small
//! in-tree scoped thread pool (rayon is not in the offline crate set), and
//! every threaded kernel partitions over *outputs* — column ranges for
//! `matvec_acc_mt`/`matmul_mt`, row ranges for `matvec_rows_mt`, query
//! heads for `attend_one_mt`/`attend_block` — so each output element keeps
//! its exact scalar accumulation order and results are bit-identical for
//! any thread count. `prefill::attend_block` is the block-prefill causal
//! kernel (one fused pass per KIVI group instead of one attention call per
//! token).
//!
//! Numerics deliberately mirror `model::ref_engine` operation for operation
//! (same zero-skip matvec, same split-half RoPE, same softmax order), so the
//! native engine is comparable to the reference engine at tight tolerance —
//! that parity is what `tests/native_backend.rs` pins down.
//!
//! Observability: the kernels themselves carry no instrumentation — the
//! native engine brackets them from the outside with `crate::obs::Profiler`
//! phases (`qkv` around the projections + RoPE, `quant_commit` around the
//! quantize/commit kernels, `attend` around `attend_one_mt`/`attend_block`
//! + the output projection, `mlp` around the FFN). That keeps the hot loops
//! free of clock reads and preserves the bit-exactness guarantees above
//! whether profiling is on or off.

pub mod activation;
pub mod gemm;
pub mod paged_attention;
pub mod pool;
pub mod prefill;
pub mod quantize;
pub mod rms_norm;
pub mod rotary;
pub mod softmax;

pub use activation::{gelu_tanh, gelu_tanh_inplace, swiglu};
pub use gemm::{
    matmul, matmul_mt, matvec_acc, matvec_acc_mt, matvec_rows, matvec_rows_many,
    matvec_rows_many_mt, matvec_rows_mt,
};
pub use paged_attention::{attend_many, attend_one, attend_one_mt};
pub use pool::{default_threads, ThreadPool};
pub use prefill::attend_block;
pub use quantize::{kivi_commit_outputs, token_block_outputs, token_step_outputs};
pub use rms_norm::{rms_norm, rms_norm_rows};
pub use rotary::{apply_rope, apply_rope_heads};
pub use softmax::{causal_softmax_rows, softmax};
