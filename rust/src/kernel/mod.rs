//! Native CPU compute kernels: the artifact-free backend of the engine.
//!
//! These are the InfiniLM-shaped primitives (gemm, rms_norm, rotary, fused
//! softmax, activations) plus the subsystem's reason to exist: a paged
//! attention kernel that walks `PagedKvCache` block tables directly and
//! dequantizes each page on the fly from its layer's precision pair
//! (`paged_attention`). Nothing here stages pages into a dense buffer — the
//! KIVI layout makes that possible, because per-channel key scales are
//! page-aligned by construction, so `(code * scale + zero)` folds straight
//! into the K·Q and P·V accumulation loops.
//!
//! Numerics deliberately mirror `model::ref_engine` operation for operation
//! (same zero-skip matvec, same split-half RoPE, same softmax order), so the
//! native engine is comparable to the reference engine at tight tolerance —
//! that parity is what `tests/native_backend.rs` pins down.

pub mod activation;
pub mod gemm;
pub mod paged_attention;
pub mod quantize;
pub mod rms_norm;
pub mod rotary;
pub mod softmax;

pub use activation::{gelu_tanh, gelu_tanh_inplace, swiglu};
pub use gemm::{matmul, matvec_acc};
pub use paged_attention::attend_one;
pub use quantize::{kivi_commit_outputs, token_step_outputs};
pub use rms_norm::rms_norm;
pub use rotary::{apply_rope, apply_rope_heads};
pub use softmax::{causal_softmax_rows, softmax};
