//! Rust-native quantization substrate: bit-packing, asymmetric quant, and
//! offline error metrics. This mirrors the L1 Pallas kernels and powers the
//! KVTuner offline pipeline (which must not depend on the PJRT hot path).

pub mod asym;
pub mod error;
pub mod packing;

pub use asym::{fake_quant, quantize_per_channel, quantize_per_token, QuantChunk};
pub use error::{attention_probs, fake_quant_cache, layer_errors, ErrorMetrics, LayerCapture};
pub use packing::{pack_row, packed_width, unpack_row};
