//! Offline KV-quantization error metrics (paper Sec. 3.2): given captured
//! full-precision Q/K/V for one layer, simulate quantize→dequantize (no
//! error accumulation) and measure
//!   e_k / e_v — relative KV cache errors,
//!   e_a       — absolute attention score error,
//!   e_o       — relative attention output error.
//! These drive Table 9, Table 3, Fig. 3/7/13–19, and the tuner's intra-layer
//! Pareto pruning.

use anyhow::Result;

use super::asym::fake_quant;
use crate::config::{LayerSpec, Mode};

/// Captured fp tensors for one layer over one prompt:
/// q: [S, Hq, Dh] (every position's query), k/v: [Hkv, S, Dh].
#[derive(Debug, Clone)]
pub struct LayerCapture {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub s: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct ErrorMetrics {
    pub e_k: f64,
    pub e_v: f64,
    pub e_a: f64,
    pub e_a_max: f64,
    pub e_o: f64,
}

impl ErrorMetrics {
    pub fn merge(&mut self, other: &ErrorMetrics, w: f64) {
        self.e_k += other.e_k * w;
        self.e_v += other.e_v * w;
        self.e_a += other.e_a * w;
        self.e_a_max = self.e_a_max.max(other.e_a_max);
        self.e_o += other.e_o * w;
    }
}

/// Fake-quantize a [Hkv, S, Dh] cache tensor under `spec`, group size `g`.
/// KIVI keys are per-channel in token groups; everything else per-token.
/// Only whole groups are quantized in kivi mode (the tail would live in the
/// fp residual online, so the offline sim leaves it fp too).
pub fn fake_quant_cache(
    x: &mut [f32],
    is_key: bool,
    spec: LayerSpec,
    n_kv_heads: usize,
    s: usize,
    head_dim: usize,
    group: usize,
) -> Result<()> {
    let bits = if is_key { spec.pair.k_bits } else { spec.pair.v_bits };
    if spec.mode == Mode::Fp || bits >= 16 {
        return Ok(());
    }
    let per_channel = is_key && spec.mode == Mode::Kivi;
    for h in 0..n_kv_heads {
        let base = h * s * head_dim;
        if per_channel {
            let whole = (s / group) * group;
            for g0 in (0..whole).step_by(group) {
                let lo = base + g0 * head_dim;
                let hi = lo + group * head_dim;
                fake_quant(&mut x[lo..hi], group, head_dim, bits, true)?;
            }
        } else {
            // per-token: each token its own group; one call covers all rows
            fake_quant(&mut x[base..base + s * head_dim], s, head_dim, bits, false)?;
        }
    }
    Ok(())
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in a.iter().zip(b) {
        num += (x - y).abs() as f64;
        den += x.abs() as f64;
    }
    num / den.max(1e-12)
}

/// K/V-only error split for callers that hold no query capture (the XLA
/// serving arm cannot see Q inside its compiled executables): simulate
/// quantize→dequantize on the fp shadow and return `(e_k, e_v)` — the same
/// relative-error definition `layer_errors` uses, without the attention
/// terms. k/v: [Hkv, S, Dh].
pub fn kv_errors(
    k: &[f32],
    v: &[f32],
    spec: LayerSpec,
    n_kv_heads: usize,
    s: usize,
    head_dim: usize,
    group: usize,
) -> Result<(f64, f64)> {
    let mut k_hat = k.to_vec();
    let mut v_hat = v.to_vec();
    fake_quant_cache(&mut k_hat, true, spec, n_kv_heads, s, head_dim, group)?;
    fake_quant_cache(&mut v_hat, false, spec, n_kv_heads, s, head_dim, group)?;
    Ok((rel_err(k, &k_hat), rel_err(v, &v_hat)))
}

/// Causal attention over a single head's K/V; returns (scores, out) so the
/// caller can diff against the quantized run.
/// q: [S, Hq, Dh]; the head's kv index is h / (Hq/Hkv).
fn causal_attention(
    cap: &LayerCapture,
    k: &[f32],
    v: &[f32],
    probs_out: &mut [f32], // [Hq, S, S] lower-triangular filled
    out: &mut [f32],       // [Hq, S, Dh]
) {
    let (s, hq, hkv, dh) = (cap.s, cap.n_heads, cap.n_kv_heads, cap.head_dim);
    let gqa = hq / hkv;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0f32; s];
    for h in 0..hq {
        let kv = h / gqa;
        for i in 0..s {
            let q = &cap.q[(i * hq + h) * dh..(i * hq + h + 1) * dh];
            let mut maxs = f32::NEG_INFINITY;
            for j in 0..=i {
                let kj = &k[(kv * s + j) * dh..(kv * s + j + 1) * dh];
                let mut dot = 0f32;
                for d in 0..dh {
                    dot += q[d] * kj[d];
                }
                scores[j] = dot * scale;
                maxs = maxs.max(scores[j]);
            }
            let mut denom = 0f32;
            for j in 0..=i {
                scores[j] = (scores[j] - maxs).exp();
                denom += scores[j];
            }
            let o = &mut out[(h * s + i) * dh..(h * s + i + 1) * dh];
            o.fill(0.0);
            for j in 0..=i {
                let p = scores[j] / denom;
                probs_out[(h * s + i) * s + j] = p;
                let vj = &v[(kv * s + j) * dh..(kv * s + j + 1) * dh];
                for d in 0..dh {
                    o[d] += p * vj[d];
                }
            }
        }
    }
}

/// Full offline error simulation for one layer capture under one spec.
pub fn layer_errors(cap: &LayerCapture, spec: LayerSpec, group: usize) -> Result<ErrorMetrics> {
    let (s, hq, hkv, dh) = (cap.s, cap.n_heads, cap.n_kv_heads, cap.head_dim);
    let mut k_hat = cap.k.clone();
    let mut v_hat = cap.v.clone();
    fake_quant_cache(&mut k_hat, true, spec, hkv, s, dh, group)?;
    fake_quant_cache(&mut v_hat, false, spec, hkv, s, dh, group)?;

    let mut probs = vec![0f32; hq * s * s];
    let mut probs_hat = vec![0f32; hq * s * s];
    let mut out = vec![0f32; hq * s * dh];
    let mut out_hat = vec![0f32; hq * s * dh];
    causal_attention(cap, &cap.k, &cap.v, &mut probs, &mut out);
    causal_attention(cap, &k_hat, &v_hat, &mut probs_hat, &mut out_hat);

    let mut e_a = 0f64;
    let mut e_a_max = 0f64;
    let mut n_scores = 0usize;
    for h in 0..hq {
        for i in 0..s {
            for j in 0..=i {
                let d = (probs[(h * s + i) * s + j] - probs_hat[(h * s + i) * s + j]).abs() as f64;
                e_a += d;
                e_a_max = e_a_max.max(d);
                n_scores += 1;
            }
        }
    }
    Ok(ErrorMetrics {
        e_k: rel_err(&cap.k, &k_hat),
        e_v: rel_err(&cap.v, &v_hat),
        e_a: e_a / n_scores as f64,
        e_a_max,
        e_o: rel_err(&out, &out_hat),
    })
}

/// Per-(query, head) attention rows for pattern analysis (Fig. 2/4/11/12):
/// returns probs [Hq, S, S] under the given spec.
pub fn attention_probs(cap: &LayerCapture, spec: LayerSpec, group: usize) -> Result<Vec<f32>> {
    let (s, hq, hkv, dh) = (cap.s, cap.n_heads, cap.n_kv_heads, cap.head_dim);
    let mut k_hat = cap.k.clone();
    let mut v_hat = cap.v.clone();
    fake_quant_cache(&mut k_hat, true, spec, hkv, s, dh, group)?;
    fake_quant_cache(&mut v_hat, false, spec, hkv, s, dh, group)?;
    let mut probs = vec![0f32; hq * s * s];
    let mut out = vec![0f32; hq * s * dh];
    causal_attention(cap, &k_hat, &v_hat, &mut probs, &mut out);
    Ok(probs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrecisionPair;
    use crate::util::rng::Rng;

    fn capture(s: usize, seed: u64) -> LayerCapture {
        let (hq, hkv, dh) = (4, 2, 16);
        let mut r = Rng::seed(seed);
        let mut gen = |n: usize| (0..n).map(|_| r.normal() as f32).collect::<Vec<f32>>();
        LayerCapture {
            q: gen(s * hq * dh),
            k: gen(hkv * s * dh),
            v: gen(hkv * s * dh),
            s,
            n_heads: hq,
            n_kv_heads: hkv,
            head_dim: dh,
        }
    }

    #[test]
    fn fp_spec_is_exact() {
        let cap = capture(24, 0);
        let m = layer_errors(&cap, LayerSpec::fp(), 32).unwrap();
        assert_eq!(m.e_k, 0.0);
        assert_eq!(m.e_o, 0.0);
    }

    #[test]
    fn errors_monotone_in_precision() {
        let cap = capture(48, 1);
        let spec = |k, v| LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(k, v) };
        let m8 = layer_errors(&cap, spec(8, 8), 32).unwrap();
        let m4 = layer_errors(&cap, spec(4, 4), 32).unwrap();
        let m2 = layer_errors(&cap, spec(2, 2), 32).unwrap();
        assert!(m8.e_o < m4.e_o && m4.e_o < m2.e_o, "{} {} {}", m8.e_o, m4.e_o, m2.e_o);
        assert!(m8.e_a < m4.e_a && m4.e_a < m2.e_a);
    }

    #[test]
    fn key_matters_more_than_value() {
        // K4V2 should beat K2V4 on e_o at equal memory (paper Table 3). The
        // effect needs moderately concentrated attention (Lemma 1's regime):
        // sharpen the queries the way the engineered temp profile does.
        let mut cap = capture(64, 2);
        for q in cap.q.iter_mut() {
            *q *= 3.0;
        }
        let spec = |k, v| LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(k, v) };
        let k_first = layer_errors(&cap, spec(4, 2), 32).unwrap();
        let v_first = layer_errors(&cap, spec(2, 4), 32).unwrap();
        assert!(k_first.e_o < v_first.e_o, "{} vs {}", k_first.e_o, v_first.e_o);
    }

    #[test]
    fn probs_rows_sum_to_one() {
        let cap = capture(16, 3);
        let p = attention_probs(&cap, LayerSpec::fp(), 32).unwrap();
        let s = cap.s;
        for h in 0..cap.n_heads {
            for i in 0..s {
                let row: f32 = p[(h * s + i) * s..(h * s + i) * s + i + 1].iter().sum();
                assert!((row - 1.0).abs() < 1e-4);
            }
        }
    }
}
