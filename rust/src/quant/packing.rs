//! Bit-packing, mirroring `python/compile/kernels/packing.py` exactly:
//! codes pack along the head dim, channel `d` in byte `d / per_byte` at bit
//! offset `bits * (d % per_byte)`.

use anyhow::{bail, Result};

pub const SUPPORTED_BITS: [u8; 3] = [2, 4, 8];

pub fn packed_width(head_dim: usize, bits: u8) -> Result<usize> {
    if !SUPPORTED_BITS.contains(&bits) {
        bail!("bits must be 2/4/8, got {bits}");
    }
    if head_dim * bits as usize % 8 != 0 {
        bail!("head_dim={head_dim} not packable at {bits} bits");
    }
    Ok(head_dim * bits as usize / 8)
}

/// Pack one row of codes (values < 2^bits) into `out` (len = packed_width).
pub fn pack_row(codes: &[u8], bits: u8, out: &mut [u8]) {
    match bits {
        8 => out.copy_from_slice(codes),
        4 => {
            for (i, chunk) in codes.chunks_exact(2).enumerate() {
                out[i] = chunk[0] | (chunk[1] << 4);
            }
        }
        2 => {
            for (i, chunk) in codes.chunks_exact(4).enumerate() {
                out[i] = chunk[0] | (chunk[1] << 2) | (chunk[2] << 4) | (chunk[3] << 6);
            }
        }
        _ => unreachable!("unsupported bits {bits}"),
    }
}

/// Unpack one packed row into `out` (len = head_dim).
pub fn unpack_row(packed: &[u8], bits: u8, out: &mut [u8]) {
    match bits {
        8 => out.copy_from_slice(packed),
        4 => {
            for (i, &b) in packed.iter().enumerate() {
                out[2 * i] = b & 0x0F;
                out[2 * i + 1] = b >> 4;
            }
        }
        2 => {
            for (i, &b) in packed.iter().enumerate() {
                out[4 * i] = b & 0x03;
                out[4 * i + 1] = (b >> 2) & 0x03;
                out[4 * i + 2] = (b >> 4) & 0x03;
                out[4 * i + 3] = (b >> 6) & 0x03;
            }
        }
        _ => unreachable!("unsupported bits {bits}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(packed_width(64, 8).unwrap(), 64);
        assert_eq!(packed_width(64, 4).unwrap(), 32);
        assert_eq!(packed_width(64, 2).unwrap(), 16);
        assert!(packed_width(64, 3).is_err());
        assert!(packed_width(3, 2).is_err());
    }

    #[test]
    fn roundtrip_all_bits() {
        for bits in SUPPORTED_BITS {
            let dh = 32;
            let max = 1usize << bits;
            let codes: Vec<u8> = (0..dh).map(|i| (i * 7 % max) as u8).collect();
            let mut packed = vec![0u8; packed_width(dh, bits).unwrap()];
            pack_row(&codes, bits, &mut packed);
            let mut back = vec![0u8; dh];
            unpack_row(&packed, bits, &mut back);
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn layout_matches_python() {
        // channel order: ch0 low bits first (see python test_unpack_channel_order)
        let codes = [0u8, 1, 2, 3, 4, 5, 6, 7];
        let mut packed = vec![0u8; 4];
        pack_row(&codes, 4, &mut packed);
        assert_eq!(packed, vec![0x10, 0x32, 0x54, 0x76]);
    }
}
