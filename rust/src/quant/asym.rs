//! Rust-native asymmetric round-to-nearest quantization — the offline
//! substrate behind the tuner's error profiler, the reference engine's
//! fake-quant arms, and the property tests. Mirrors the Pallas kernels in
//! `python/compile/kernels/quant.py` bit for bit (same eps, same rounding).

use anyhow::Result;

use super::packing::{pack_row, packed_width, unpack_row};

const EPS: f32 = 1e-8;

/// Quantized chunk of shape [tokens, head_dim] for a single (batch, head).
#[derive(Debug, Clone)]
pub struct QuantChunk {
    pub codes: Vec<u8>,   // packed, [tokens, packed_width]
    pub scale: Vec<f32>,  // per-token: [tokens]; per-channel: [head_dim]
    pub zero: Vec<f32>,
    pub bits: u8,
    pub per_channel: bool,
    pub tokens: usize,
    pub head_dim: usize,
}

/// Per-token-asym: one (scale, zero) per token over its head_dim channels.
pub fn quantize_per_token(x: &[f32], tokens: usize, head_dim: usize, bits: u8) -> Result<QuantChunk> {
    assert_eq!(x.len(), tokens * head_dim);
    let dhp = packed_width(head_dim, bits)?;
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut codes = vec![0u8; tokens * dhp];
    let mut scale = vec![0f32; tokens];
    let mut zero = vec![0f32; tokens];
    let mut row = vec![0u8; head_dim];
    for t in 0..tokens {
        let xs = &x[t * head_dim..(t + 1) * head_dim];
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in xs {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let s = ((hi - lo) / qmax).max(EPS);
        for (d, &v) in xs.iter().enumerate() {
            row[d] = (((v - lo) / s).round().clamp(0.0, qmax)) as u8;
        }
        pack_row(&row, bits, &mut codes[t * dhp..(t + 1) * dhp]);
        scale[t] = s;
        zero[t] = lo;
    }
    Ok(QuantChunk { codes, scale, zero, bits, per_channel: false, tokens, head_dim })
}

/// Per-channel-asym: one (scale, zero) per channel over the chunk's tokens
/// (KIVI-style key quantization over a token group).
pub fn quantize_per_channel(x: &[f32], tokens: usize, head_dim: usize, bits: u8) -> Result<QuantChunk> {
    assert_eq!(x.len(), tokens * head_dim);
    let dhp = packed_width(head_dim, bits)?;
    let qmax = ((1u32 << bits) - 1) as f32;
    let mut lo = vec![f32::INFINITY; head_dim];
    let mut hi = vec![f32::NEG_INFINITY; head_dim];
    for t in 0..tokens {
        for d in 0..head_dim {
            let v = x[t * head_dim + d];
            lo[d] = lo[d].min(v);
            hi[d] = hi[d].max(v);
        }
    }
    let scale: Vec<f32> = lo.iter().zip(&hi).map(|(&l, &h)| ((h - l) / qmax).max(EPS)).collect();
    let mut codes = vec![0u8; tokens * dhp];
    let mut row = vec![0u8; head_dim];
    for t in 0..tokens {
        for d in 0..head_dim {
            let v = x[t * head_dim + d];
            row[d] = (((v - lo[d]) / scale[d]).round().clamp(0.0, qmax)) as u8;
        }
        pack_row(&row, bits, &mut codes[t * dhp..(t + 1) * dhp]);
    }
    Ok(QuantChunk { codes, scale, zero: lo, bits, per_channel: true, tokens, head_dim })
}

impl QuantChunk {
    /// Dequantize the whole chunk into `out` ([tokens, head_dim]).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.tokens * self.head_dim);
        let dhp = self.codes.len() / self.tokens;
        let mut row = vec![0u8; self.head_dim];
        for t in 0..self.tokens {
            unpack_row(&self.codes[t * dhp..(t + 1) * dhp], self.bits, &mut row);
            let o = &mut out[t * self.head_dim..(t + 1) * self.head_dim];
            if self.per_channel {
                for d in 0..self.head_dim {
                    o[d] = row[d] as f32 * self.scale[d] + self.zero[d];
                }
            } else {
                let (s, z) = (self.scale[t], self.zero[t]);
                for d in 0..self.head_dim {
                    o[d] = row[d] as f32 * s + z;
                }
            }
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.tokens * self.head_dim];
        self.dequantize_into(&mut out);
        out
    }
}

/// Quantize + dequantize in place (the error-profiling primitive; the whole
/// slice is one group).
pub fn fake_quant(x: &mut [f32], tokens: usize, head_dim: usize, bits: u8, per_channel: bool) -> Result<()> {
    if bits >= 16 {
        return Ok(());
    }
    let q = if per_channel {
        quantize_per_channel(x, tokens, head_dim, bits)?
    } else {
        quantize_per_token(x, tokens, head_dim, bits)?
    };
    q.dequantize_into(x);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::seed(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn error_bound_per_token() {
        let (t, dh) = (16, 32);
        let x = randv(t * dh, 1);
        for bits in [2u8, 4, 8] {
            let q = quantize_per_token(&x, t, dh, bits).unwrap();
            let y = q.dequantize();
            for ti in 0..t {
                for d in 0..dh {
                    let e = (x[ti * dh + d] - y[ti * dh + d]).abs();
                    assert!(e <= q.scale[ti] * 0.5 + 1e-6, "bits={bits} e={e}");
                }
            }
        }
    }

    #[test]
    fn error_bound_per_channel() {
        let (t, dh) = (32, 16);
        let x = randv(t * dh, 2);
        let q = quantize_per_channel(&x, t, dh, 4).unwrap();
        let y = q.dequantize();
        for ti in 0..t {
            for d in 0..dh {
                let e = (x[ti * dh + d] - y[ti * dh + d]).abs();
                assert!(e <= q.scale[d] * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn monotone_in_bits() {
        let (t, dh) = (32, 32);
        let x = randv(t * dh, 3);
        let err = |bits| {
            let mut y = x.clone();
            fake_quant(&mut y, t, dh, bits, false).unwrap();
            x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum::<f32>() / x.len() as f32
        };
        assert!(err(2) > err(4) && err(4) > err(8));
        assert_eq!(err(16), 0.0);
    }

    #[test]
    fn channel_outliers_favor_per_channel() {
        let (t, dh) = (64, 32);
        let mut x = randv(t * dh, 4);
        for ti in 0..t {
            x[ti * dh] *= 30.0; // channel-0 outlier
        }
        let e = |pc| {
            let mut y = x.clone();
            fake_quant(&mut y, t, dh, 4, pc).unwrap();
            x.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum::<f32>() / x.len() as f32
        };
        assert!(e(true) < e(false) * 0.5, "pc={} tok={}", e(true), e(false));
    }
}
