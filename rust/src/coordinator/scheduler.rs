//! Continuous-batching scheduler: owns one Engine (and therefore one PJRT
//! client, pinned to this thread), interleaves prefill admission with
//! batched decode steps, and completes requests through their response
//! channels. This is the serving loop the throughput tables run on.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::Engine;

use super::batcher::{Batcher, BatcherOptions};
use super::metrics::Metrics;
use super::request::{Request, Response};

struct ActiveSlot {
    req: Request,
    generated: Vec<i32>,
    next_token: i32,
    started: Instant,
    ttft: Duration,
}

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Arc<Metrics>,
    slots: Vec<Option<ActiveSlot>>,
    pub name: String,
}

pub struct SchedulerOptions {
    pub batcher: BatcherOptions,
    pub idle_poll: Duration,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { batcher: BatcherOptions::default(), idle_poll: Duration::from_millis(5) }
    }
}

impl Scheduler {
    pub fn new(engine: Engine, name: &str, opts: SchedulerOptions, metrics: Arc<Metrics>) -> Scheduler {
        let batch = engine.batch;
        Scheduler {
            engine,
            batcher: Batcher::new(opts.batcher),
            metrics,
            slots: (0..batch).map(|_| None).collect(),
            name: name.to_string(),
        }
    }

    fn free_slots(&self) -> Vec<usize> {
        self.slots.iter().enumerate().filter(|(_, s)| s.is_none()).map(|(i, _)| i).collect()
    }

    fn busy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Admit waiting requests into free slots (prefill them now).
    fn admit(&mut self) -> Result<()> {
        let free = self.free_slots();
        if free.is_empty() || self.batcher.is_empty() {
            return Ok(());
        }
        let admits = self.batcher.admit(free.len());
        for (req, slot) in admits.into_iter().zip(free) {
            let started = Instant::now();
            self.engine.cache.reset_slot(slot);
            // clamp the prompt to what the slot can hold with generation room
            let cap = self.engine.s_max.saturating_sub(req.max_new_tokens + 1);
            let prompt: Vec<i32> = if req.prompt.len() > cap {
                req.prompt[req.prompt.len() - cap..].to_vec()
            } else {
                req.prompt.clone()
            };
            let t0 = Instant::now();
            match self.engine.prefill(slot, &prompt) {
                Ok(first) => {
                    let ttft = started.elapsed();
                    self.metrics.record_prefill(t0.elapsed());
                    self.slots[slot] = Some(ActiveSlot {
                        req,
                        generated: vec![first],
                        next_token: first,
                        started,
                        ttft,
                    });
                }
                Err(e) => {
                    let _ = req.respond.send(Response {
                        id: req.id,
                        tokens: Vec::new(),
                        ttft: Duration::ZERO,
                        total: started.elapsed(),
                        engine: self.name.clone(),
                        error: Some(format!("prefill failed: {e:#}")),
                    });
                }
            }
        }
        Ok(())
    }

    /// One batched decode step over all active slots; completes finished
    /// requests. Returns number of active slots before the step.
    fn decode_tick(&mut self) -> Result<usize> {
        let batch = self.slots.len();
        let mut tokens = vec![0i32; batch];
        let mut active = vec![false; batch];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(a) = s {
                tokens[i] = a.next_token;
                active[i] = true;
            }
        }
        let busy = self.busy();
        if busy == 0 {
            return Ok(0);
        }
        let t0 = Instant::now();
        let next = self.engine.decode_step(&tokens, &active)?;
        self.metrics.record_decode(t0.elapsed(), busy, busy);

        for i in 0..batch {
            let done = if let Some(a) = &mut self.slots[i] {
                if active[i] {
                    a.generated.push(next[i]);
                    a.next_token = next[i];
                }
                a.generated.len() > a.req.max_new_tokens
                    || self.engine.cache.pos[i] as usize >= self.engine.s_max
            } else {
                false
            };
            if done {
                let a = self.slots[i].take().unwrap();
                let mut toks = a.generated;
                toks.truncate(a.req.max_new_tokens);
                let total = a.started.elapsed();
                self.metrics.record_completion(a.ttft, total);
                let _ = a.req.respond.send(Response {
                    id: a.req.id,
                    tokens: toks,
                    ttft: a.ttft,
                    total,
                    engine: self.name.clone(),
                    error: None,
                });
                self.engine.cache.reset_slot(i);
            }
        }
        Ok(busy)
    }

    /// Serve until `shutdown` flips and all in-flight work drains.
    pub fn run(
        &mut self,
        rx: Receiver<Request>,
        shutdown: Arc<AtomicBool>,
        inflight: Arc<AtomicUsize>,
    ) -> Result<()> {
        loop {
            // drain new arrivals without blocking
            loop {
                match rx.try_recv() {
                    Ok(r) => {
                        if !self.batcher.push(r) {
                            // rejected: backpressure counter already bumped
                        }
                    }
                    Err(_) => break,
                }
            }
            self.admit()?;
            let busy = self.decode_tick()?;
            inflight.store(busy + self.batcher.len(), Ordering::Relaxed);

            if busy == 0 && self.batcher.is_empty() {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                // idle: block briefly for the next request
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(r) => {
                        self.batcher.push(r);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
        }
    }
}
