//! Continuous-batching scheduler: owns one Engine (and therefore one PJRT
//! client, pinned to this thread), interleaves prefill admission with
//! batched decode steps, and completes requests through their response
//! channels. This is the serving loop the throughput tables run on.
//!
//! With a paged engine the loop additionally admits by *block availability*
//! (not just free slots), reuses cached prompt-prefix pages, and runs a
//! preemption policy: when the next decode step would need more pages than
//! the pool has free, the youngest request is evicted back to a resume queue
//! and re-prefilled (prompt + tokens generated so far) once pages free up —
//! recompute-style preemption, so the pool can oversubscribe.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::Engine;
use crate::kvcache::{CacheBackend, OutOfPages};

use super::batcher::{Batcher, BatcherOptions};
use super::metrics::Metrics;
use super::request::{Request, Response};

struct ActiveSlot {
    req: Request,
    generated: Vec<i32>,
    next_token: i32,
    started: Instant,
    ttft: Duration,
}

/// A preempted request waiting to resume: its generated tokens are kept so
/// re-prefill restores the exact decode state (modulo prefill-path
/// quantization of the recomputed tokens).
struct Preempted {
    req: Request,
    generated: Vec<i32>,
    started: Instant,
    ttft: Duration,
}

/// Completion predicate for one request after a decode step has pushed its
/// token. `generated` includes the prefill's first token, so a request is
/// done at exactly `max_new` tokens — the old `>` comparison ran one extra
/// batched step whose token was then truncated.
pub fn generation_done(generated: usize, max_new: usize, pos: usize, s_max: usize) -> bool {
    generated >= max_new || pos >= s_max
}

pub struct Scheduler {
    pub engine: Engine,
    pub batcher: Batcher,
    pub metrics: Arc<Metrics>,
    slots: Vec<Option<ActiveSlot>>,
    preempted: VecDeque<Preempted>,
    pub name: String,
}

pub struct SchedulerOptions {
    pub batcher: BatcherOptions,
    pub idle_poll: Duration,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { batcher: BatcherOptions::default(), idle_poll: Duration::from_millis(5) }
    }
}

impl Scheduler {
    pub fn new(engine: Engine, name: &str, opts: SchedulerOptions, metrics: Arc<Metrics>) -> Scheduler {
        let batch = engine.batch;
        Scheduler {
            engine,
            batcher: Batcher::new(opts.batcher),
            metrics,
            slots: (0..batch).map(|_| None).collect(),
            preempted: VecDeque::new(),
            name: name.to_string(),
        }
    }

    fn busy(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Clamp a prompt to what a slot can hold with generation room.
    fn clamp_prompt(&self, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let cap = self.engine.s_max.saturating_sub(max_new + 1);
        if prompt.len() > cap {
            prompt[prompt.len() - cap..].to_vec()
        } else {
            prompt.to_vec()
        }
    }

    fn respond_error(&self, req: Request, started: Instant, msg: String) {
        let _ = req.respond.send(Response {
            id: req.id,
            tokens: Vec::new(),
            ttft: Duration::ZERO,
            total: started.elapsed(),
            engine: self.name.clone(),
            error: Some(msg),
        });
    }

    /// Complete a request: truncate, record, respond, release the slot.
    /// `error` marks degraded completions (e.g. pool-exhaustion truncation)
    /// while still delivering the tokens generated so far.
    fn finish(&mut self, slot: usize, a: ActiveSlot, error: Option<String>) {
        let mut toks = a.generated;
        toks.truncate(a.req.max_new_tokens);
        let total = a.started.elapsed();
        self.metrics.record_completion(a.ttft, total);
        let _ = a.req.respond.send(Response {
            id: a.req.id,
            tokens: toks,
            ttft: a.ttft,
            total,
            engine: self.name.clone(),
            error,
        });
        self.engine.cache.reset_slot(slot);
    }

    /// True when a freshly (re-)prefilled request needs no decode step at
    /// all (tiny `max_new_tokens` or a full cache) — completing it here
    /// avoids a wasted batched step whose token would be truncated.
    fn done_after_prefill(&self, a: &ActiveSlot, slot: usize) -> bool {
        generation_done(
            a.generated.len(),
            a.req.max_new_tokens,
            self.engine.cache.pos(slot) as usize,
            self.engine.s_max,
        )
    }

    /// Prefill `ctx` into `slot`, reusing shared prefix pages when the
    /// backend has them. Returns the first generated token. Prefix metrics
    /// are recorded only on success so an `OutOfPages` retry does not
    /// double-count.
    fn prefill_with_reuse(&mut self, slot: usize, ctx: &[i32]) -> Result<i32> {
        self.engine.cache.reset_slot(slot);
        let reused = self.engine.cache.prefill_reuse(slot, ctx);
        let t0 = Instant::now();
        let first = self.engine.prefill(slot, &ctx[reused..])?;
        self.metrics.record_prefill(t0.elapsed());
        self.metrics.record_prefix(reused);
        self.engine.cache.register_prefix(slot, ctx);
        Ok(first)
    }

    /// Admit waiting work into free slots: resumptions first (they hold
    /// partial progress), then fresh requests FIFO. Paged engines gate on
    /// page availability instead of admitting blindly.
    fn admit(&mut self) -> Result<()> {
        let mut admitted = 0usize;
        while admitted < self.batcher.opts.max_admit_per_tick {
            let Some(slot) = self.slots.iter().position(|s| s.is_none()) else { break };

            if let Some(pe) = self.preempted.pop_front() {
                // resume context = clamped prompt + all generated but the
                // last token (which becomes the next decode input)
                let mut ctx = self.clamp_prompt(&pe.req.prompt, pe.req.max_new_tokens);
                ctx.extend_from_slice(&pe.generated[..pe.generated.len() - 1]);
                if !self.engine.cache.can_admit(ctx.len(), pe.req.max_new_tokens) {
                    if self.busy() == 0 {
                        self.respond_error(
                            pe.req,
                            pe.started,
                            "request exceeds the kv page pool budget".into(),
                        );
                        admitted += 1;
                        continue;
                    }
                    self.preempted.push_front(pe);
                    break;
                }
                match self.prefill_with_reuse(slot, &ctx) {
                    Ok(_recomputed_first) => {
                        let next = *pe.generated.last().unwrap();
                        let a = ActiveSlot {
                            req: pe.req,
                            generated: pe.generated,
                            next_token: next,
                            started: pe.started,
                            ttft: pe.ttft,
                        };
                        if self.done_after_prefill(&a, slot) {
                            self.finish(slot, a, None);
                        } else {
                            self.slots[slot] = Some(a);
                        }
                    }
                    Err(e) => {
                        if e.downcast_ref::<OutOfPages>().is_some() && self.busy() > 0 {
                            // pages will free as in-flight work completes
                            self.engine.cache.reset_slot(slot);
                            self.preempted.push_front(pe);
                            break;
                        }
                        self.respond_error(pe.req, pe.started, format!("resume failed: {e:#}"));
                    }
                }
                admitted += 1;
                continue;
            }

            let Some(front) = self.batcher.peek() else { break };
            let max_new = front.max_new_tokens;
            let cap = self.engine.s_max.saturating_sub(max_new + 1);
            let plen = front.prompt.len().min(cap);
            if !self.engine.cache.can_admit(plen, max_new) {
                if self.busy() == 0 && self.preempted.is_empty() {
                    // nothing in flight will ever free pages: fail it loud
                    let req = self.batcher.pop().unwrap();
                    let started = Instant::now();
                    self.respond_error(
                        req,
                        started,
                        "request exceeds the kv page pool budget".into(),
                    );
                    admitted += 1;
                    continue;
                }
                break;
            }
            let req = self.batcher.pop().unwrap();
            let started = Instant::now();
            let prompt = self.clamp_prompt(&req.prompt, req.max_new_tokens);
            match self.prefill_with_reuse(slot, &prompt) {
                Ok(first) => {
                    let ttft = started.elapsed();
                    let a = ActiveSlot {
                        req,
                        generated: vec![first],
                        next_token: first,
                        started,
                        ttft,
                    };
                    if self.done_after_prefill(&a, slot) {
                        self.finish(slot, a, None);
                    } else {
                        self.slots[slot] = Some(a);
                    }
                }
                Err(e) => {
                    if e.downcast_ref::<OutOfPages>().is_some()
                        && (self.busy() > 0 || !self.preempted.is_empty())
                    {
                        // admission raced the estimate; retry once pages free
                        self.engine.cache.reset_slot(slot);
                        self.batcher.push_front(req);
                        break;
                    }
                    self.respond_error(req, started, format!("prefill failed: {e:#}"));
                }
            }
            admitted += 1;
        }
        Ok(())
    }

    /// Evict the youngest request(s) until the next decode step fits in the
    /// page pool (no-op for the dense arm). A lone request that exhausts the
    /// pool by itself is completed with what it has — there is nothing left
    /// to evict.
    fn preempt_for_headroom(&mut self) {
        loop {
            let active: Vec<usize> = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.as_ref().map(|_| i))
                .collect();
            if active.is_empty() {
                return;
            }
            if self.engine.cache.decode_block_shortfall(&active) == 0 {
                return;
            }
            if active.len() == 1 {
                // nothing left to evict: deliver what we have, marked as
                // truncated so the client can tell it from natural completion
                let i = active[0];
                let a = self.slots[i].take().unwrap();
                let got = a.generated.len();
                let want = a.req.max_new_tokens;
                self.finish(
                    i,
                    a,
                    Some(format!(
                        "kv page pool exhausted: generation truncated at {got}/{want} tokens"
                    )),
                );
                return;
            }
            let victim = *active
                .iter()
                .max_by_key(|&&i| self.slots[i].as_ref().unwrap().started)
                .unwrap();
            let a = self.slots[victim].take().unwrap();
            self.engine.cache.reset_slot(victim);
            self.metrics.record_preemption();
            self.preempted.push_front(Preempted {
                req: a.req,
                generated: a.generated,
                started: a.started,
                ttft: a.ttft,
            });
        }
    }

    /// One batched decode step over all active slots; completes finished
    /// requests. Returns number of active slots before the step.
    fn decode_tick(&mut self) -> Result<usize> {
        let batch = self.slots.len();
        let mut tokens = vec![0i32; batch];
        let mut active = vec![false; batch];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(a) = s {
                tokens[i] = a.next_token;
                active[i] = true;
            }
        }
        let busy = self.busy();
        if busy == 0 {
            return Ok(0);
        }
        let t0 = Instant::now();
        let next = self.engine.decode_step(&tokens, &active)?;
        self.metrics.record_decode(t0.elapsed(), busy, busy);

        for i in 0..batch {
            let done = if let Some(a) = &mut self.slots[i] {
                if active[i] {
                    a.generated.push(next[i]);
                    a.next_token = next[i];
                }
                generation_done(
                    a.generated.len(),
                    a.req.max_new_tokens,
                    self.engine.cache.pos(i) as usize,
                    self.engine.s_max,
                )
            } else {
                false
            };
            if done {
                let a = self.slots[i].take().unwrap();
                self.finish(i, a, None);
            }
        }
        Ok(busy)
    }

    /// Serve until `shutdown` flips and all in-flight work drains.
    pub fn run(
        &mut self,
        rx: Receiver<Request>,
        shutdown: Arc<AtomicBool>,
        inflight: Arc<AtomicUsize>,
    ) -> Result<()> {
        loop {
            // drain new arrivals without blocking
            loop {
                match rx.try_recv() {
                    Ok(r) => {
                        if !self.batcher.push(r) {
                            // rejected: backpressure counter already bumped
                        }
                    }
                    Err(_) => break,
                }
            }
            self.admit()?;
            self.preempt_for_headroom();
            let busy = self.decode_tick()?;
            inflight.store(
                busy + self.batcher.len() + self.preempted.len(),
                Ordering::Relaxed,
            );

            if busy == 0 && self.batcher.is_empty() && self.preempted.is_empty() {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                // idle: block briefly for the next request
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(r) => {
                        self.batcher.push(r);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::generation_done;

    #[test]
    fn completion_has_no_extra_decode_step() {
        // regression: `generated.len() > max_new` ran one wasted step whose
        // token was truncated; completion must hit at exactly max_new
        assert!(!generation_done(3, 4, 10, 256));
        assert!(generation_done(4, 4, 10, 256));
        assert!(generation_done(5, 4, 10, 256));
        // cache-full still completes early
        assert!(generation_done(1, 8, 256, 256));
        assert!(!generation_done(1, 8, 255, 256));
        // max_new = 0 completes immediately after prefill's token
        assert!(generation_done(1, 0, 1, 256));
    }
}
