//! Continuous-batching scheduler: owns one Engine (and therefore one PJRT
//! client, pinned to this thread), interleaves prefill admission with
//! batched decode steps, and completes requests through their response
//! channels. This is the serving loop the throughput tables run on.
//!
//! Prefill is *chunked*: an admitted prompt advances `prefill_chunk` tokens
//! per tick (`advance_prefills`) between batched decode steps, so a long
//! prompt never stalls in-flight decodes — each chunk emits a
//! `prefill_chunk` trace span, making the interleaving visible in the
//! Chrome export. Chunk boundaries do not change numerics: the native
//! engine's prefill decomposes identically wherever it is split
//! (block-vs-tokenwise parity), so chunked and monolithic prefill leave
//! bit-identical KV state. `chunked_prefill: false` runs each prefill to
//! completion in one tick — the oracle arm of the differential-churn
//! harness (`tests/batched_decode.rs`).
//!
//! With a paged engine the loop additionally admits by *block availability*
//! (not just free slots), reuses cached prompt-prefix pages, and runs a
//! preemption policy when the next decode step would need more pages than
//! the pool has free:
//!
//! * **Victim selection** is cost-aware: the evicted request is the one with
//!   the largest `pages_held x remaining_tokens` — the request that would
//!   otherwise pin the most page-time, so one eviction buys the most
//!   headroom, and whose one-time eviction cost amortizes over the most
//!   remaining work. Ties fall to the youngest (the old policy).
//! * **Eviction mechanism** is chosen per victim by `--swap-policy`:
//!   recompute (drop pages, later re-prefill prompt + generated-so-far) or
//!   swap-out to the host tier (pages move in packed quantized form and come
//!   back bit-exact, zero re-prefill). `auto` compares the swap's byte
//!   traffic against a chunked-prefill cost model; see
//!   `choose_preempt_action`.
//! * **Resume** is strictly FIFO over preempted requests (the longest-waiting
//!   victim resumes first), and swap-aware: a swapped sequence resumes only
//!   when its pages fit back into the pool (`can_swap_in`); if its re-linked
//!   prefix pages were recycled while it was away, it falls back to the
//!   recompute path.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::engine::EngineCore;
use crate::faults::{point, FaultInjector, StepFault, SwapInFault};
use crate::kvcache::{CacheBackend, OutOfPages, SwapHandle, SwapPolicy};
use crate::obs::{CounterHandle, Counters, EventKind, TraceSink};

use super::batcher::{Batcher, BatcherOptions};
use super::failure::{Failure, FailureKind};
use super::metrics::Metrics;
use super::request::{Request, Response};

/// A transiently-faulted swap-in retries (with the injector-chosen
/// per-retry backoff) at most this many times before it is treated as
/// permanently lost and the request falls back to re-prefill.
pub const SWAP_RETRY_MAX: u32 = 4;

/// A recompute resume that keeps hitting `OutOfPages` requeues at most this
/// many times before failing with a typed `PoolExhausted` — bounds resume-
/// queue churn when the pool is pathologically oversubscribed.
pub const OOP_RETRY_MAX: u32 = 256;

/// Consecutive injected faults on one path are capped so a rate-1.0 plan
/// degrades the path it targets without livelocking the scheduler.
const FAULT_STREAK_MAX: u32 = 64;

/// Deadline check applied at every enforcement boundary: admission,
/// prefill chunk, and decode tick.
fn deadline_expired(req: &Request) -> bool {
    req.deadline.is_some_and(|d| Instant::now() >= d)
}

struct ActiveSlot {
    req: Request,
    generated: Vec<i32>,
    next_token: i32,
    started: Instant,
    ttft: Duration,
}

/// A slot mid-chunked-prefill: its context advances `prefill_chunk` tokens
/// per tick until the final chunk runs the lm head and the slot goes
/// `Active`. Holds pages but takes no part in decode steps.
struct PrefillingSlot {
    req: Request,
    /// Full context to prefill: the clamped prompt, plus the already-
    /// generated tokens (minus the pending decode input) on a recompute
    /// resume.
    ctx: Vec<i32>,
    /// Tokens of `ctx` already in the cache (reused prefix + done chunks).
    done: usize,
    /// Prefix tokens served from the shared-prefix index at admission.
    reused: usize,
    started: Instant,
    /// `Some((generated, ttft))` on a recompute resume: the tokens produced
    /// before preemption (the re-prefill's recomputed first token is
    /// discarded) and the original time-to-first-token.
    resume: Option<(Vec<i32>, Duration)>,
    /// `OutOfPages` requeue count carried across preemption round trips
    /// (bounded by [`OOP_RETRY_MAX`]).
    retries: u32,
    /// Consecutive injected alloc faults on this slot; past
    /// [`FAULT_STREAK_MAX`] the injection point stops rolling.
    fault_streak: u32,
}

/// One engine slot's scheduling state.
enum Slot {
    Idle,
    Prefilling(PrefillingSlot),
    Active(ActiveSlot),
}

impl Slot {
    fn is_idle(&self) -> bool {
        matches!(self, Slot::Idle)
    }
}

/// A preempted request waiting to resume. `swap: Some` means its KV state
/// sits in the host tier and comes back bit-exact without re-prefill;
/// `None` means recompute — the generated tokens are kept so re-prefill
/// restores the exact decode state (modulo prefill-path quantization of the
/// recomputed tokens).
struct Preempted {
    req: Request,
    generated: Vec<i32>,
    started: Instant,
    ttft: Duration,
    swap: Option<SwapHandle>,
    /// Transient swap-in retries ([`SWAP_RETRY_MAX`]) / `OutOfPages`
    /// requeues ([`OOP_RETRY_MAX`]) consumed so far.
    retries: u32,
    /// Earliest scheduler tick this entry may re-attempt admission — the
    /// backoff window a transient swap-in fault opened (0 = no window).
    retry_at: u64,
}

/// FIFO bookkeeping for preempted requests, separated so the ordering policy
/// is testable: preemption enqueues at the back, resume pops the front, and
/// a popped-but-unadmittable entry is requeued at the front (order kept).
/// Regression note: the scheduler used `push_front` + `pop_front` (LIFO), so
/// the most-recently-preempted request resumed first and repeatedly starved
/// the oldest victims under sustained pressure.
pub struct ResumeQueue<T> {
    q: VecDeque<T>,
}

impl<T> Default for ResumeQueue<T> {
    fn default() -> Self {
        ResumeQueue { q: VecDeque::new() }
    }
}

impl<T> ResumeQueue<T> {
    pub fn enqueue(&mut self, t: T) {
        self.q.push_back(t);
    }

    pub fn next(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn requeue(&mut self, t: T) {
        self.q.push_front(t);
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Cost-aware victim score: the page-time a request would pin if kept
/// resident (`pages_held x remaining_tokens`). Preempting the max-score
/// victim frees the most pages for the longest expected absence, and its
/// one-time eviction cost (re-prefill or swap round trip) amortizes over
/// the most remaining decode work — "cheap victims first" in cost per page
/// of relief. The `max(1)` floors keep zero-page / zero-remaining requests
/// comparable instead of collapsing every score to zero.
pub fn victim_score(pages_held: usize, remaining_tokens: usize) -> u64 {
    pages_held.max(1) as u64 * remaining_tokens.max(1) as u64
}

/// How one preemption victim is evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptAction {
    SwapOut,
    Recompute,
}

/// Pick the eviction mechanism for one victim.
///
/// `auto` compares, in device-byte units:
/// * swap cost = `2 x swap_out_bytes` (copy out + copy back; pages re-linked
///   through the prefix index move nothing), against
/// * recompute cost = `recompute_tokens^2 / prefill_chunk x per_token_bytes`
///   — chunked re-prefill runs `T/chunk` layer sweeps, each reading the
///   O(T)-token cache written so far, so the re-read traffic grows
///   quadratically with context length.
///
/// The crossover sits near `T = 2 x prefill_chunk`: short contexts re-prefill
/// cheaply (one or two chunk sweeps) while long contexts — the KVQuant-style
/// workloads the swap tier exists for — get swapped.
pub fn choose_preempt_action(
    policy: SwapPolicy,
    swap_available: bool,
    swap_out_bytes: usize,
    recompute_tokens: usize,
    per_token_kv_bytes: usize,
    prefill_chunk: usize,
) -> PreemptAction {
    if !swap_available || policy == SwapPolicy::Off {
        return PreemptAction::Recompute;
    }
    if policy == SwapPolicy::Always {
        return PreemptAction::SwapOut;
    }
    let swap_cost = 2 * swap_out_bytes as u64;
    let t = recompute_tokens as u64;
    let recompute_cost =
        t * t * per_token_kv_bytes.max(1) as u64 / prefill_chunk.max(1) as u64;
    if swap_cost < recompute_cost {
        PreemptAction::SwapOut
    } else {
        PreemptAction::Recompute
    }
}

/// Pre-registered counter handles for the memory-hierarchy time series the
/// scheduler publishes once per tick: device page-pool occupancy, host
/// swap-arena occupancy, swap/staging byte rates (EWMA bandwidth), queue
/// depths and batch width. Registration happens once at scheduler
/// construction; per-tick publication is a handful of lock-free seqlock
/// writes, and a scheduler built without counters skips all of it.
struct HierarchyTracks {
    pool_blocks_live: CounterHandle,
    pool_blocks_free: CounterHandle,
    pool_blocks_total: CounterHandle,
    pool_bytes_live: CounterHandle,
    pool_frag_bytes: CounterHandle,
    host_swap_bytes_used: CounterHandle,
    host_swap_bytes_total: CounterHandle,
    swap_out_bytes: CounterHandle,
    swap_in_bytes: CounterHandle,
    gather_bytes: CounterHandle,
    resume_queue_depth: CounterHandle,
    admission_queue_depth: CounterHandle,
    prefill_backlog_tokens: CounterHandle,
    active_batch: CounterHandle,
    busy_slots: CounterHandle,
}

impl HierarchyTracks {
    fn register(c: &Counters) -> HierarchyTracks {
        HierarchyTracks {
            pool_blocks_live: c.gauge(
                "pool_blocks_live",
                "blocks",
                "device page-pool blocks currently held by live sequences",
            ),
            pool_blocks_free: c.gauge(
                "pool_blocks_free",
                "blocks",
                "device page-pool free-list depth",
            ),
            pool_blocks_total: c.gauge(
                "pool_blocks_total",
                "blocks",
                "device page-pool capacity in blocks",
            ),
            pool_bytes_live: c.gauge(
                "pool_bytes_live",
                "bytes",
                "quantized KV bytes resident in the device arena",
            ),
            pool_frag_bytes: c.gauge(
                "pool_frag_bytes",
                "bytes",
                "bytes lost to partially filled tail pages",
            ),
            host_swap_bytes_used: c.gauge(
                "host_swap_bytes_used",
                "bytes",
                "host swap-arena bytes pinned by outstanding swap handles",
            ),
            host_swap_bytes_total: c.gauge(
                "host_swap_bytes_total",
                "bytes",
                "host swap-arena reservation",
            ),
            swap_out_bytes: c.rate(
                "swap_out_bytes",
                "bytes",
                "cumulative bytes copied device-to-host at preemption",
            ),
            swap_in_bytes: c.rate(
                "swap_in_bytes",
                "bytes",
                "cumulative bytes copied host-to-device at resume",
            ),
            gather_bytes: c.rate(
                "gather_bytes",
                "bytes",
                "cumulative gather-to-dense staging bytes (XLA arm; native is 0)",
            ),
            resume_queue_depth: c.gauge(
                "resume_queue_depth",
                "requests",
                "preempted requests waiting to resume",
            ),
            admission_queue_depth: c.gauge(
                "admission_queue_depth",
                "requests",
                "requests queued behind admission",
            ),
            prefill_backlog_tokens: c.gauge(
                "prefill_backlog_tokens",
                "tokens",
                "context tokens still to prefill across mid-prefill slots",
            ),
            active_batch: c.gauge(
                "active_batch",
                "slots",
                "slots that took part in the last batched decode step",
            ),
            busy_slots: c.gauge(
                "busy_slots",
                "slots",
                "slots holding a request in any stage (prefilling or decoding)",
            ),
        }
    }
}

/// Completion predicate for one request after a decode step has pushed its
/// token. `generated` includes the prefill's first token, so a request is
/// done at exactly `max_new` tokens — the old `>` comparison ran one extra
/// batched step whose token was then truncated.
pub fn generation_done(generated: usize, max_new: usize, pos: usize, s_max: usize) -> bool {
    generated >= max_new || pos >= s_max
}

pub struct Scheduler {
    pub engine: Box<dyn EngineCore>,
    pub batcher: Batcher,
    pub metrics: Arc<Metrics>,
    slots: Vec<Slot>,
    preempted: ResumeQueue<Preempted>,
    swap_policy: SwapPolicy,
    /// Advance prompts `prefill_chunk` tokens per tick between decode steps
    /// (the continuous-batching default); `false` runs every prefill to
    /// completion in one tick — the differential harness's oracle arm.
    chunked_prefill: bool,
    /// Copy each request's final-step logits into its `Response` (harness
    /// bit-comparison); off by default — no vocab-sized copy in serving.
    capture_logits: bool,
    /// Persistent decode-step buffers (tokens / active mask / next tokens),
    /// refilled in place so the serving loop allocates nothing per step.
    step_tokens: Vec<i32>,
    step_active: Vec<bool>,
    step_next: Vec<i32>,
    /// Lifecycle trace sink; `None` keeps the serving loop emission-free.
    trace: Option<TraceSink>,
    /// Memory-hierarchy counter tracks, published once per tick; `None`
    /// keeps the serving loop publication-free.
    hier: Option<HierarchyTracks>,
    /// Drift alerts already traced, so each new envelope violation emits
    /// exactly one `EventKind::Drift` instant.
    drift_seen: u64,
    /// Seeded fault injector; `None` (production default) keeps every
    /// injection point a single never-taken branch.
    faults: Option<FaultInjector>,
    /// Monotonic tick counter (first tick = 1): the time base for injected
    /// worker death and transient-fault backoff windows.
    tick_no: u64,
    /// Consecutive injected step faults, capped by [`FAULT_STREAK_MAX`].
    step_fault_streak: u32,
    pub name: String,
}

pub struct SchedulerOptions {
    pub batcher: BatcherOptions,
    pub idle_poll: Duration,
    /// Preemption eviction policy (recompute vs host swap); only effective
    /// when the engine's cache backend has a swap tier.
    pub swap_policy: SwapPolicy,
    /// Chunked-prefill interleaving (default on); `false` is the
    /// run-to-completion oracle arm.
    pub chunked_prefill: bool,
    /// Attach final-step logits to each `Response` (harness only).
    pub capture_logits: bool,
    /// Lifecycle trace sink (worker-tagged handle on the shared ring).
    pub trace: Option<TraceSink>,
    /// Counter registry for the per-tick memory-hierarchy time series
    /// (`None` disables publication entirely).
    pub counters: Option<Arc<Counters>>,
    /// Seeded fault injector (chaos testing / `--fault-plan`); `None`
    /// disables injection entirely.
    pub faults: Option<FaultInjector>,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            batcher: BatcherOptions::default(),
            idle_poll: Duration::from_millis(5),
            swap_policy: SwapPolicy::default(),
            chunked_prefill: true,
            capture_logits: false,
            trace: None,
            counters: None,
            faults: None,
        }
    }
}

impl Scheduler {
    pub fn new(
        engine: Box<dyn EngineCore>,
        name: &str,
        opts: SchedulerOptions,
        metrics: Arc<Metrics>,
    ) -> Scheduler {
        let batch = engine.batch();
        Scheduler {
            engine,
            batcher: Batcher::new(opts.batcher),
            metrics,
            slots: (0..batch).map(|_| Slot::Idle).collect(),
            preempted: ResumeQueue::default(),
            swap_policy: opts.swap_policy,
            chunked_prefill: opts.chunked_prefill,
            capture_logits: opts.capture_logits,
            step_tokens: vec![0; batch],
            step_active: vec![false; batch],
            step_next: vec![0; batch],
            trace: opts.trace,
            hier: opts.counters.as_deref().map(HierarchyTracks::register),
            drift_seen: 0,
            faults: opts.faults,
            tick_no: 0,
            step_fault_streak: 0,
            name: name.to_string(),
        }
    }

    fn trace_instant(&self, kind: EventKind, req: u64, slot: usize, arg: u64) {
        if let Some(t) = &self.trace {
            t.instant(kind, req, slot as u32, arg);
        }
    }

    fn trace_span(&self, kind: EventKind, req: u64, slot: usize, start: Instant, arg: u64) {
        if let Some(t) = &self.trace {
            t.span(kind, req, slot as u32, start, arg);
        }
    }

    /// Slots holding a request in any stage (prefilling or decoding).
    fn busy(&self) -> usize {
        self.slots.iter().filter(|s| !s.is_idle()).count()
    }

    /// Nothing queued, preempted, or in a slot — the drive-by-tick loop's
    /// stop condition.
    pub fn is_idle(&self) -> bool {
        self.busy() == 0 && self.batcher.is_empty() && self.preempted.is_empty()
    }

    /// Enqueue one request (the harness's direct-injection path; the
    /// serving loop feeds the batcher from its channel instead). Returns
    /// `false` when the admission queue is full.
    pub fn submit(&mut self, req: Request) -> bool {
        self.batcher.push(req)
    }

    /// Clamp a prompt to what a slot can hold with generation room.
    fn clamp_prompt(&self, prompt: &[i32], max_new: usize) -> Vec<i32> {
        let cap = self.engine.s_max().saturating_sub(max_new + 1);
        if prompt.len() > cap {
            prompt[prompt.len() - cap..].to_vec()
        } else {
            prompt.to_vec()
        }
    }

    /// Fail a request that produced no deliverable tokens.
    fn respond_error(&self, req: Request, started: Instant, failure: Failure) {
        self.respond_failure(req, Vec::new(), Duration::ZERO, started, failure);
    }

    /// Fail a request with a typed failure, delivering any tokens generated
    /// before it. Every failure path funnels here so the per-kind tally
    /// (`kvtuner_requests_failed_total{kind}`) stays complete.
    fn respond_failure(
        &self,
        req: Request,
        tokens: Vec<i32>,
        ttft: Duration,
        started: Instant,
        failure: Failure,
    ) {
        self.metrics.record_failure(failure.kind);
        let _ = req.respond.send(Response {
            id: req.id,
            tokens,
            ttft,
            total: started.elapsed(),
            engine: self.name.clone(),
            error: Some(failure),
            final_logits: None,
        });
    }

    /// Complete a request: truncate, record, respond, release the slot.
    /// `error` marks degraded completions (e.g. pool-exhaustion truncation)
    /// while still delivering the tokens generated so far.
    fn finish(&mut self, slot: usize, a: ActiveSlot, error: Option<Failure>) {
        let mut toks = a.generated;
        toks.truncate(a.req.max_new_tokens);
        let total = a.started.elapsed();
        self.metrics.record_completion(a.ttft, total, toks.len());
        if let Some(f) = &error {
            self.metrics.record_failure(f.kind);
        }
        self.trace_instant(EventKind::Complete, a.req.id, slot, toks.len() as u64);
        let final_logits =
            if self.capture_logits { Some(self.engine.logits(slot).to_vec()) } else { None };
        let _ = a.req.respond.send(Response {
            id: a.req.id,
            tokens: toks,
            ttft: a.ttft,
            total,
            engine: self.name.clone(),
            error,
            final_logits,
        });
        self.engine.cache_mut().reset_slot(slot);
    }

    /// True when a freshly (re-)prefilled request needs no decode step at
    /// all (tiny `max_new_tokens` or a full cache) — completing it here
    /// avoids a wasted batched step whose token would be truncated.
    fn done_after_prefill(&self, a: &ActiveSlot, slot: usize) -> bool {
        generation_done(
            a.generated.len(),
            a.req.max_new_tokens,
            self.engine.cache().pos(slot) as usize,
            self.engine.s_max(),
        )
    }

    /// Install a request into `slot` for chunked prefill: reset, claim any
    /// shared prefix pages, and let `advance_prefills` drive the chunks.
    /// Prefix *metrics* are deferred to prefill completion so an
    /// `OutOfPages` retry does not double-count.
    fn start_prefill(
        &mut self,
        slot: usize,
        req: Request,
        ctx: Vec<i32>,
        started: Instant,
        resume: Option<(Vec<i32>, Duration)>,
        retries: u32,
    ) {
        self.engine.cache_mut().reset_slot(slot);
        let reused = self.engine.cache_mut().prefill_reuse(slot, &ctx);
        self.slots[slot] = Slot::Prefilling(PrefillingSlot {
            req,
            ctx,
            done: reused,
            reused,
            started,
            resume,
            retries,
            fault_streak: 0,
        });
    }

    /// Place a resumed/admitted request into its slot (or finish it when no
    /// decode step is needed at all).
    fn occupy(&mut self, slot: usize, a: ActiveSlot) {
        if self.done_after_prefill(&a, slot) {
            self.finish(slot, a, None);
        } else {
            self.slots[slot] = Slot::Active(a);
        }
    }

    /// Admit waiting work into free slots: resumptions first (they hold
    /// partial progress, FIFO over preemption order), then fresh requests
    /// FIFO. Paged engines gate on page availability instead of admitting
    /// blindly; swapped sequences additionally gate on their pages fitting
    /// back (`can_swap_in`).
    fn admit(&mut self) -> Result<()> {
        let mut admitted = 0usize;
        while admitted < self.batcher.opts.max_admit_per_tick {
            let Some(slot) = self.slots.iter().position(|s| s.is_idle()) else { break };

            if let Some(mut pe) = self.preempted.next() {
                if deadline_expired(&pe.req) {
                    // abandon before re-admission: release any swapped
                    // state, deliver the tokens generated before preemption
                    if let Some(sh) = pe.swap.take() {
                        self.engine.cache_mut().release_swap(sh);
                    }
                    let got = pe.generated.len() as u64;
                    self.trace_instant(EventKind::DeadlineExceeded, pe.req.id, slot, got);
                    self.respond_failure(
                        pe.req,
                        pe.generated,
                        pe.ttft,
                        pe.started,
                        Failure::new(
                            FailureKind::DeadlineExceeded,
                            format!("deadline passed with {got} tokens generated"),
                        ),
                    );
                    admitted += 1;
                    continue;
                }
                if pe.retry_at > self.tick_no {
                    // a transient fault's backoff window is still open;
                    // FIFO order is preserved while it waits at the head
                    self.preempted.requeue(pe);
                    break;
                }
                if let Some(sh) = pe.swap.take() {
                    // the seeded swap-in fault rolls before any engine call,
                    // so injected failures leave cache state untouched
                    let injected = self.faults.as_mut().and_then(|f| f.swap_in_fault());
                    match injected {
                        Some(SwapInFault::Transient { delay_ticks })
                            if pe.retries < SWAP_RETRY_MAX =>
                        {
                            // transient swap-in I/O fault: bounded
                            // retry-with-backoff before the loss fallback
                            pe.retries += 1;
                            pe.retry_at = self.tick_no + delay_ticks;
                            pe.swap = Some(sh);
                            self.metrics.record_fault();
                            self.metrics.record_retry();
                            self.trace_instant(
                                EventKind::Fault,
                                pe.req.id,
                                slot,
                                point::SWAP_IN_TRANSIENT,
                            );
                            self.trace_instant(
                                EventKind::Retry,
                                pe.req.id,
                                slot,
                                pe.retries as u64,
                            );
                            self.preempted.requeue(pe);
                            break;
                        }
                        Some(fault) => {
                            // permanent loss — or a transient past the retry
                            // budget, which the policy treats the same:
                            // release the handle, re-prefill below
                            self.metrics.record_fault();
                            let pt = if fault == SwapInFault::Lost {
                                point::SWAP_IN_LOST
                            } else {
                                point::SWAP_IN_TRANSIENT
                            };
                            self.trace_instant(EventKind::Fault, pe.req.id, slot, pt);
                            self.engine.cache_mut().release_swap(sh);
                            self.metrics.record_swap_fallback();
                        }
                        None => {
                            // swapped resume: pages re-link / copy back, no
                            // re-prefill
                            if self.engine.cache().can_swap_in(&sh) {
                                match self.engine.cache_mut().swap_in(slot, &sh) {
                                    Ok(()) => {
                                        self.metrics.record_swap_in(sh.host_bytes);
                                        self.trace_instant(
                                            EventKind::SwapIn,
                                            pe.req.id,
                                            slot,
                                            sh.host_bytes as u64,
                                        );
                                        // swapped state restores bit-exact: no
                                        // re-prefill, so the resume's arg is 0
                                        self.trace_instant(
                                            EventKind::Resume,
                                            pe.req.id,
                                            slot,
                                            0,
                                        );
                                        self.engine.cache_mut().release_swap(sh);
                                        // swapped-in bytes are live again:
                                        // sample so the peak reflects them
                                        // before the next step
                                        self.engine.sample_kv_live();
                                        let next = *pe.generated.last().unwrap();
                                        let a = ActiveSlot {
                                            req: pe.req,
                                            generated: pe.generated,
                                            next_token: next,
                                            started: pe.started,
                                            ttft: pe.ttft,
                                        };
                                        self.occupy(slot, a);
                                        admitted += 1;
                                        continue;
                                    }
                                    Err(_) => {
                                        // swapped state unrecoverable (re-
                                        // linked prefix pages were recycled):
                                        // release the handle and re-prefill
                                        // below instead
                                        self.engine.cache_mut().release_swap(sh);
                                        self.engine.cache_mut().reset_slot(slot);
                                        self.metrics.record_swap_fallback();
                                    }
                                }
                            } else if self.busy() > 0 {
                                // its pages do not fit yet; in-flight
                                // completions will free some — keep it at
                                // the head of the queue
                                pe.swap = Some(sh);
                                self.preempted.requeue(pe);
                                break;
                            } else {
                                // nothing in flight will ever free pages: a
                                // clamped re-prefill may fit where the full
                                // page set cannot
                                self.engine.cache_mut().release_swap(sh);
                                self.metrics.record_swap_fallback();
                            }
                        }
                    }
                }

                // recompute resume: context = clamped prompt + all generated
                // but the last token (which becomes the next decode input);
                // re-prefilling it restores the exact pre-preemption state,
                // chunked like any fresh prompt
                let mut ctx = self.clamp_prompt(&pe.req.prompt, pe.req.max_new_tokens);
                ctx.extend_from_slice(&pe.generated[..pe.generated.len() - 1]);
                if !self.engine.cache().can_admit(ctx.len(), pe.req.max_new_tokens) {
                    if self.busy() == 0 {
                        self.respond_failure(
                            pe.req,
                            pe.generated,
                            pe.ttft,
                            pe.started,
                            Failure::new(
                                FailureKind::PoolExhausted,
                                "request exceeds the kv page pool budget",
                            ),
                        );
                        admitted += 1;
                        continue;
                    }
                    self.preempted.requeue(pe);
                    break;
                }
                let retries = pe.retries;
                self.start_prefill(
                    slot,
                    pe.req,
                    ctx,
                    pe.started,
                    Some((pe.generated, pe.ttft)),
                    retries,
                );
                admitted += 1;
                continue;
            }

            let Some(front) = self.batcher.peek() else { break };
            if deadline_expired(front) {
                // expired while queued: fail typed before spending any
                // prefill work on it
                let req = self.batcher.pop().unwrap();
                let started = req.arrival;
                self.trace_instant(EventKind::DeadlineExceeded, req.id, slot, 0);
                self.respond_error(
                    req,
                    started,
                    Failure::new(FailureKind::DeadlineExceeded, "deadline passed before admission"),
                );
                admitted += 1;
                continue;
            }
            let max_new = front.max_new_tokens;
            let cap = self.engine.s_max().saturating_sub(max_new + 1);
            let plen = front.prompt.len().min(cap);
            if !self.engine.cache().can_admit(plen, max_new) {
                if self.busy() == 0 && self.preempted.is_empty() {
                    // nothing in flight will ever free pages: fail it loud
                    let req = self.batcher.pop().unwrap();
                    let started = Instant::now();
                    self.respond_error(
                        req,
                        started,
                        Failure::new(
                            FailureKind::PoolExhausted,
                            "request exceeds the kv page pool budget",
                        ),
                    );
                    admitted += 1;
                    continue;
                }
                break;
            }
            let req = self.batcher.pop().unwrap();
            let started = Instant::now();
            let prompt = self.clamp_prompt(&req.prompt, req.max_new_tokens);
            self.trace_instant(EventKind::Admit, req.id, slot, prompt.len() as u64);
            self.start_prefill(slot, req, prompt, started, None, 0);
            admitted += 1;
        }
        // cumulative staging-copy traffic (prefill gathers included); the
        // native backend reports a structural 0 here
        self.metrics
            .gather_bytes
            .store(self.engine.gather_bytes(), Ordering::Relaxed);
        Ok(())
    }

    /// Advance every mid-prefill slot by one chunk (or to completion when
    /// chunked prefill is off). The final chunk runs the lm head, produces
    /// the first token, and flips the slot `Active`; non-final chunks only
    /// extend the KV state. Runs between decode steps, so a long prompt
    /// costs each in-flight decode at most one chunk of latency per tick.
    fn advance_prefills(&mut self) -> Result<()> {
        for slot in 0..self.slots.len() {
            if !matches!(self.slots[slot], Slot::Prefilling(_)) {
                continue;
            }
            let Slot::Prefilling(mut p) = std::mem::replace(&mut self.slots[slot], Slot::Idle)
            else {
                unreachable!()
            };
            if deadline_expired(&p.req) {
                // expired mid-prefill: free the slot's partial state and
                // deliver any pre-preemption tokens a resume carried
                self.engine.cache_mut().reset_slot(slot);
                let (tokens, ttft) = p.resume.unwrap_or((Vec::new(), Duration::ZERO));
                self.trace_instant(EventKind::DeadlineExceeded, p.req.id, slot, tokens.len() as u64);
                self.respond_failure(
                    p.req,
                    tokens,
                    ttft,
                    p.started,
                    Failure::new(FailureKind::DeadlineExceeded, "deadline passed during prefill"),
                );
                continue;
            }
            if p.fault_streak < FAULT_STREAK_MAX
                && self.faults.as_mut().is_some_and(|f| f.alloc_fails())
            {
                // injected spurious OutOfPages, rolled before the chunk runs:
                // the slot makes no progress this tick and retries the same
                // chunk next tick with its pages intact
                p.fault_streak += 1;
                self.metrics.record_fault();
                self.metrics.record_retry();
                self.trace_instant(EventKind::Fault, p.req.id, slot, point::ALLOC);
                self.trace_instant(EventKind::Retry, p.req.id, slot, p.fault_streak as u64);
                self.slots[slot] = Slot::Prefilling(p);
                continue;
            }
            let chunk =
                if self.chunked_prefill { self.engine.prefill_chunk().max(1) } else { usize::MAX };
            let remaining = p.ctx.len() - p.done;
            if remaining > chunk {
                // non-final chunk: KV state only, no lm head
                let t0 = Instant::now();
                match self.engine.prefill_extend(slot, &p.ctx[p.done..p.done + chunk]) {
                    Ok(()) => {
                        self.metrics.record_prefill(t0.elapsed(), chunk);
                        self.trace_span(EventKind::PrefillChunk, p.req.id, slot, t0, chunk as u64);
                        p.done += chunk;
                        self.slots[slot] = Slot::Prefilling(p);
                    }
                    Err(e) => self.fail_prefill(slot, p, e),
                }
                continue;
            }
            // final chunk: compute logits + first token
            let t0 = Instant::now();
            match self.engine.prefill(slot, &p.ctx[p.done..]) {
                Ok(first) => {
                    self.metrics.record_prefill(t0.elapsed(), remaining);
                    self.trace_span(EventKind::PrefillChunk, p.req.id, slot, t0, remaining as u64);
                    self.metrics.record_prefix(p.reused);
                    self.engine.cache_mut().register_prefix(slot, &p.ctx);
                    let a = match p.resume {
                        Some((generated, ttft)) => {
                            // the recomputed first token is discarded: the
                            // pending decode input is the last generated one
                            self.metrics.record_reprefill(p.ctx.len() - p.reused);
                            self.trace_instant(
                                EventKind::Resume,
                                p.req.id,
                                slot,
                                (p.ctx.len() - p.reused) as u64,
                            );
                            let next = *generated.last().unwrap();
                            ActiveSlot {
                                req: p.req,
                                generated,
                                next_token: next,
                                started: p.started,
                                ttft,
                            }
                        }
                        None => {
                            let ttft = p.started.elapsed();
                            ActiveSlot {
                                req: p.req,
                                generated: vec![first],
                                next_token: first,
                                started: p.started,
                                ttft,
                            }
                        }
                    };
                    self.occupy(slot, a);
                }
                Err(e) => self.fail_prefill(slot, p, e),
            }
        }
        Ok(())
    }

    /// A prefill chunk failed: free the slot's partial state, then retry
    /// later (`OutOfPages` with other work in flight — requeued at the
    /// front so ordering is preserved) or fail the request loudly.
    fn fail_prefill(&mut self, slot: usize, p: PrefillingSlot, e: anyhow::Error) {
        self.engine.cache_mut().reset_slot(slot);
        let oop = e.downcast_ref::<OutOfPages>().is_some();
        match p.resume {
            // a resume retries only while other slots hold pages that will
            // free; with nothing in flight, retrying would spin forever, and
            // past the requeue budget it fails typed instead of churning
            Some((generated, ttft)) if oop && self.busy() > 0 && p.retries < OOP_RETRY_MAX => {
                self.metrics.record_retry();
                self.preempted.requeue(Preempted {
                    req: p.req,
                    generated,
                    started: p.started,
                    ttft,
                    swap: None,
                    retries: p.retries + 1,
                    retry_at: 0,
                })
            }
            // a fresh request additionally waits on preempted peers, which
            // re-admit ahead of it and then either drain or fail loudly
            None if oop && (self.busy() > 0 || !self.preempted.is_empty()) => {
                self.metrics.record_retry();
                self.batcher.push_front(p.req)
            }
            resume => {
                let kind =
                    if oop { FailureKind::PoolExhausted } else { FailureKind::EngineFault };
                let (tokens, ttft) = resume.unwrap_or((Vec::new(), Duration::ZERO));
                self.respond_failure(
                    p.req,
                    tokens,
                    ttft,
                    p.started,
                    Failure::new(kind, format!("prefill failed: {e:#}")),
                );
            }
        }
    }

    /// Evict request(s) until the next decode step fits in the page pool
    /// (no-op for the dense arm). Victims are chosen by `victim_score` and
    /// evicted by swap-out or recompute per `choose_preempt_action`. A lone
    /// request that exhausts the pool by itself is completed with what it
    /// has — there is nothing left to evict.
    fn preempt_for_headroom(&mut self) {
        loop {
            let active: Vec<usize> = self
                .slots
                .iter()
                .enumerate()
                .filter_map(|(i, s)| matches!(s, Slot::Active(_)).then_some(i))
                .collect();
            if active.is_empty() {
                return;
            }
            if self.engine.cache().decode_block_shortfall(&active) == 0 {
                return;
            }
            if active.len() == 1 {
                // before truncating the lone decoding request, cancel a
                // mid-prefill slot: requeueing a prompt that has produced
                // nothing yet is strictly cheaper than cutting short a
                // generation already under way
                if let Some(pslot) =
                    self.slots.iter().position(|s| matches!(s, Slot::Prefilling(_)))
                {
                    self.cancel_prefill(pslot);
                    continue;
                }
                // nothing left to evict: deliver what we have, marked as
                // truncated so the client can tell it from natural completion
                let i = active[0];
                let Slot::Active(a) = std::mem::replace(&mut self.slots[i], Slot::Idle) else {
                    unreachable!()
                };
                let got = a.generated.len();
                let want = a.req.max_new_tokens;
                self.finish(
                    i,
                    a,
                    Some(Failure::new(
                        FailureKind::Truncated,
                        format!(
                            "kv page pool exhausted: generation truncated at {got}/{want} tokens"
                        ),
                    )),
                );
                return;
            }
            // regression fix: victim selection used `.unwrap()` on
            // `max_by_key`; the guard above makes an empty candidate list
            // unreachable today, but a panic here would take the whole
            // worker down — bail out of preemption instead
            let Some(victim) = active.iter().copied().max_by_key(|&i| {
                let Slot::Active(a) = &self.slots[i] else { unreachable!() };
                let pages = self.engine.cache().slot_pages(i);
                let remaining = a.req.max_new_tokens.saturating_sub(a.generated.len());
                // ties fall to the youngest (largest start time)
                (victim_score(pages, remaining), a.started)
            }) else {
                return;
            };
            let pages_held = self.engine.cache().slot_pages(victim);
            let Slot::Active(a) = std::mem::replace(&mut self.slots[victim], Slot::Idle) else {
                unreachable!()
            };
            // capture the victim's live-KV peak before eviction removes its
            // bytes from `layer_kv_live` (the step path only samples after)
            self.engine.sample_kv_live();
            // what a recompute resume would have to re-prefill
            let cap = self.engine.s_max().saturating_sub(a.req.max_new_tokens + 1);
            let recompute_tokens = a.req.prompt.len().min(cap) + a.generated.len() - 1;
            // swap_out_bytes walks the victim's block table; skip it (and the
            // cost model) entirely on the default recompute-only path
            let action = if self.swap_policy != SwapPolicy::Off
                && self.engine.cache().swap_enabled()
            {
                choose_preempt_action(
                    self.swap_policy,
                    true,
                    self.engine.cache().swap_out_bytes(victim),
                    recompute_tokens,
                    self.engine.cache().per_token_kv_bytes(),
                    self.engine.prefill_chunk(),
                )
            } else {
                PreemptAction::Recompute
            };
            let swap = if action == PreemptAction::SwapOut {
                if self.faults.as_mut().is_some_and(|f| f.swap_out_fails()) {
                    // injected swap-out I/O failure, rolled before the copy
                    // starts: the victim falls back to recompute exactly as
                    // on a real full host arena
                    self.metrics.record_fault();
                    self.trace_instant(EventKind::Fault, a.req.id, victim, point::SWAP_OUT);
                    self.metrics.record_swap_stall();
                    None
                } else {
                    match self.engine.cache_mut().swap_out(victim) {
                        Ok(h) => {
                            self.metrics.record_swap_out(h.host_bytes);
                            self.trace_instant(
                                EventKind::SwapOut,
                                a.req.id,
                                victim,
                                h.host_bytes as u64,
                            );
                            Some(h)
                        }
                        Err(_) => {
                            // host arena full: recompute instead
                            self.metrics.record_swap_stall();
                            None
                        }
                    }
                }
            } else {
                None
            };
            if swap.is_none() {
                self.engine.cache_mut().reset_slot(victim);
            }
            self.metrics.record_preemption();
            self.trace_instant(
                EventKind::Preempt { swap: swap.is_some() },
                a.req.id,
                victim,
                pages_held as u64,
            );
            self.preempted.enqueue(Preempted {
                req: a.req,
                generated: a.generated,
                started: a.started,
                ttft: a.ttft,
                swap,
                retries: 0,
                retry_at: 0,
            });
        }
    }

    /// Cancel a mid-prefill slot: free its pages and send its request back
    /// to where it came from (front of the admission queue, or head of the
    /// resume queue for a recompute resume) so ordering is preserved.
    fn cancel_prefill(&mut self, slot: usize) {
        let Slot::Prefilling(p) = std::mem::replace(&mut self.slots[slot], Slot::Idle) else {
            unreachable!()
        };
        let pages = self.engine.cache().slot_pages(slot);
        // capture the pre-eviction live-KV peak, as for decode victims
        self.engine.sample_kv_live();
        self.engine.cache_mut().reset_slot(slot);
        self.metrics.record_preemption();
        self.trace_instant(EventKind::Preempt { swap: false }, p.req.id, slot, pages as u64);
        match p.resume {
            Some((generated, ttft)) => self.preempted.requeue(Preempted {
                req: p.req,
                generated,
                started: p.started,
                ttft,
                swap: None,
                retries: p.retries,
                retry_at: 0,
            }),
            None => self.batcher.push_front(p.req),
        }
    }

    /// One batched decode step over all decoding slots; completes finished
    /// requests. Returns the number of decoding slots before the step. The
    /// step's buffers are engine-resident (`decode_step_into`) plus the
    /// scheduler's persistent token/mask vectors — no per-step allocation.
    fn decode_tick(&mut self) -> Result<usize> {
        let tick_no = self.tick_no;
        match self.faults.as_mut().and_then(|f| f.step_fault(tick_no)) {
            Some(StepFault::Panic) => {
                // injected worker death at a tick boundary: no Request is on
                // the unwound stack (they all live in `self`), so the
                // router's catch_unwind + evacuate path can redispatch
                // every orphan
                self.metrics.record_fault();
                self.trace_instant(EventKind::Fault, 0, 0, point::STEP_PANIC);
                panic!("injected worker death (tick {tick_no})");
            }
            Some(StepFault::Transient) if self.step_fault_streak < FAULT_STREAK_MAX => {
                // transient engine fault: skip this batched step (no state
                // mutated — the injection displaces the engine call) and
                // retry the identical step next tick
                self.step_fault_streak += 1;
                self.metrics.record_fault();
                self.metrics.record_retry();
                self.trace_instant(EventKind::Fault, 0, 0, point::STEP_TRANSIENT);
                self.trace_instant(EventKind::Retry, 0, 0, self.step_fault_streak as u64);
                return Ok(0);
            }
            // past the streak cap a rate-1.0 plan stops stalling decode
            Some(StepFault::Transient) => {}
            None => self.step_fault_streak = 0,
        }
        let batch = self.slots.len();
        let mut busy = 0usize;
        for (i, s) in self.slots.iter().enumerate() {
            if let Slot::Active(a) = s {
                self.step_tokens[i] = a.next_token;
                self.step_active[i] = true;
                busy += 1;
            } else {
                self.step_active[i] = false;
            }
        }
        if busy == 0 {
            return Ok(0);
        }
        let t0 = Instant::now();
        self.engine.decode_step_into(&self.step_tokens, &self.step_active, &mut self.step_next)?;
        // record_decode also stores the per-step wall-time gauge
        // (last_decode_nanos), updated here each tick like gather_bytes
        self.metrics.record_decode(t0.elapsed(), busy, busy);
        self.metrics
            .gather_bytes
            .store(self.engine.gather_bytes(), Ordering::Relaxed);
        let drift = self.engine.drift_alerts();
        self.metrics.drift_alerts.store(drift, Ordering::Relaxed);
        if drift > self.drift_seen {
            self.trace_instant(EventKind::Drift, 0, 0, drift);
            self.drift_seen = drift;
        }
        if self.trace.is_some() {
            // one span per active slot so each slot's track shows its share
            // of the batched step
            for i in 0..batch {
                if self.step_active[i] {
                    if let Slot::Active(a) = &self.slots[i] {
                        self.trace_span(EventKind::DecodeStep, a.req.id, i, t0, 1);
                    }
                }
            }
        }

        for i in 0..batch {
            let (done, expired) = if let Slot::Active(a) = &mut self.slots[i] {
                if self.step_active[i] {
                    a.generated.push(self.step_next[i]);
                    a.next_token = self.step_next[i];
                }
                let done = generation_done(
                    a.generated.len(),
                    a.req.max_new_tokens,
                    self.engine.cache().pos(i) as usize,
                    self.engine.s_max(),
                );
                (done, !done && deadline_expired(&a.req))
            } else {
                (false, false)
            };
            if done || expired {
                let Slot::Active(a) = std::mem::replace(&mut self.slots[i], Slot::Idle) else {
                    unreachable!()
                };
                if done {
                    self.finish(i, a, None);
                } else {
                    // deadline passed mid-generation: deliver the tokens
                    // generated so far, typed DeadlineExceeded
                    let got = a.generated.len() as u64;
                    self.trace_instant(EventKind::DeadlineExceeded, a.req.id, i, got);
                    self.finish(
                        i,
                        a,
                        Some(Failure::new(
                            FailureKind::DeadlineExceeded,
                            format!("deadline passed after {got} tokens"),
                        )),
                    );
                }
            }
        }
        Ok(busy)
    }

    /// Publish the per-tick memory-hierarchy time series: device page-pool
    /// occupancy and free-list depth, host swap-arena occupancy, swap and
    /// staging byte totals (the tracks' EWMA turns them into bandwidth),
    /// queue depths, prefill backlog and batch width. A scheduler built
    /// without counters pays a single branch here.
    fn publish_counters(&mut self, decoded: usize) {
        let Some(h) = &self.hier else { return };
        let ms = self.engine.cache().mem_stats();
        h.pool_blocks_live.record(ms.blocks_live as f64);
        h.pool_blocks_free.record(ms.blocks_free as f64);
        h.pool_blocks_total.record(ms.blocks_total as f64);
        h.pool_bytes_live.record(ms.bytes_live as f64);
        h.pool_frag_bytes.record(ms.frag_bytes as f64);
        h.host_swap_bytes_used.record(ms.host_bytes_used as f64);
        h.host_swap_bytes_total.record(ms.host_bytes_total as f64);
        h.swap_out_bytes.record(self.metrics.swap_bytes_out.load(Ordering::Relaxed) as f64);
        h.swap_in_bytes.record(self.metrics.swap_bytes_in.load(Ordering::Relaxed) as f64);
        h.gather_bytes.record(self.metrics.gather_bytes.load(Ordering::Relaxed) as f64);
        h.resume_queue_depth.record(self.preempted.len() as f64);
        h.admission_queue_depth.record(self.batcher.len() as f64);
        let backlog: usize = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Prefilling(p) => p.ctx.len() - p.done,
                _ => 0,
            })
            .sum();
        h.prefill_backlog_tokens.record(backlog as f64);
        h.active_batch.record(decoded as f64);
        h.busy_slots.record(self.busy() as f64);
        // per-layer-per-precision arena bytes, published by the engine's
        // own sampling hook so the same tracks update inside decode steps
        self.engine.sample_kv_live();
    }

    /// One scheduling round: admit waiting work, advance chunked prefills,
    /// make decode headroom, then run one batched decode step. Returns the
    /// number of slots that decoded. This is the unit the serving loop —
    /// and the differential-churn harness — drives.
    pub fn tick(&mut self) -> Result<usize> {
        // 1-based: the first tick a scheduler runs is tick 1 (the time base
        // for `FaultRates::death_tick` and transient backoff windows)
        self.tick_no += 1;
        self.admit()?;
        self.advance_prefills()?;
        self.preempt_for_headroom();
        let decoded = self.decode_tick()?;
        if let Some(t) = &self.trace {
            // ring-overflow accounting so truncated traces are detectable
            // from any metrics surface
            self.metrics.trace_dropped.store(t.tracer.dropped(), Ordering::Relaxed);
        }
        self.publish_counters(decoded);
        Ok(decoded)
    }

    /// Enqueue an arrival, or — when the admission queue is full — answer
    /// it immediately with a typed `QueueFull` failure instead of silently
    /// dropping it (the old behavior left the client to discover the drop
    /// as a closed channel).
    fn enqueue_or_reject(&mut self, r: Request) {
        if self.batcher.len() >= self.batcher.opts.max_queue {
            self.batcher.rejected += 1;
            let started = r.arrival;
            self.respond_error(
                r,
                started,
                Failure::new(FailureKind::QueueFull, "admission queue full"),
            );
        } else {
            self.batcher.push(r);
        }
    }

    /// Strip every request out of the scheduler: queued, preempted, and
    /// slotted, in that order. Called by the router's failure domain after
    /// a caught panic, when the engine may be in an arbitrary state — so
    /// this touches no engine or cache method: swap handles are dropped
    /// unreleased (their arena dies with the worker) and slots are
    /// abandoned, not reset. Generated tokens are discarded: a redispatched
    /// request restarts fresh on its new worker and, with deterministic
    /// numerics, regenerates the identical stream.
    pub fn evacuate(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.batcher.pop() {
            out.push(r);
        }
        while let Some(pe) = self.preempted.next() {
            out.push(pe.req);
        }
        for s in self.slots.iter_mut() {
            match std::mem::replace(s, Slot::Idle) {
                Slot::Idle => {}
                Slot::Prefilling(p) => out.push(p.req),
                Slot::Active(a) => out.push(a.req),
            }
        }
        out
    }

    /// Serve until `shutdown` flips and all in-flight work drains. Takes
    /// the receiver by reference so the router can drain requests that
    /// arrived between a caught panic and the channel teardown.
    pub fn run(
        &mut self,
        rx: &Receiver<Request>,
        shutdown: Arc<AtomicBool>,
        inflight: Arc<AtomicUsize>,
    ) -> Result<()> {
        loop {
            // drain new arrivals without blocking
            while let Ok(r) = rx.try_recv() {
                self.enqueue_or_reject(r);
            }
            self.tick()?;
            // busy() counts prefilling slots too: a worker mid-chunked-
            // prefill is in flight even when nothing decoded this tick
            inflight.store(
                self.busy() + self.batcher.len() + self.preempted.len(),
                Ordering::Relaxed,
            );

            if self.is_idle() {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                // idle: block briefly for the next request
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(r) => self.enqueue_or_reject(r),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return Ok(()),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_has_no_extra_decode_step() {
        // regression: `generated.len() > max_new` ran one wasted step whose
        // token was truncated; completion must hit at exactly max_new
        assert!(!generation_done(3, 4, 10, 256));
        assert!(generation_done(4, 4, 10, 256));
        assert!(generation_done(5, 4, 10, 256));
        // cache-full still completes early
        assert!(generation_done(1, 8, 256, 256));
        assert!(!generation_done(1, 8, 255, 256));
        // max_new = 0 completes immediately after prefill's token
        assert!(generation_done(1, 0, 1, 256));
    }

    #[test]
    fn preempted_requests_resume_in_fifo_order() {
        // regression: push_front + pop_front (LIFO) resumed the most recent
        // victim first, starving the oldest under sustained pressure
        let mut q = ResumeQueue::default();
        q.enqueue("a");
        q.enqueue("b");
        q.enqueue("c");
        assert_eq!(q.next(), Some("a"), "oldest victim resumes first");
        // could not admit "a" yet: it keeps its place at the head
        q.requeue("a");
        q.enqueue("d");
        assert_eq!(q.len(), 4);
        assert_eq!(q.next(), Some("a"));
        assert_eq!(q.next(), Some("b"));
        assert_eq!(q.next(), Some("c"));
        assert_eq!(q.next(), Some("d"));
        assert!(q.is_empty());
    }

    #[test]
    fn victim_score_ranks_by_page_time() {
        // long-context mid-generation request outranks a short nearly-done one
        assert!(victim_score(9, 50) > victim_score(3, 2));
        // same pages: more remaining work -> better victim (eviction cost
        // amortizes over more future decode steps)
        assert!(victim_score(4, 30) > victim_score(4, 3));
        // floors keep degenerate inputs ordered rather than all-zero
        assert_eq!(victim_score(0, 0), 1);
        assert!(victim_score(2, 0) > victim_score(0, 0));
    }

    #[test]
    fn preempt_action_policy_table() {
        use PreemptAction::*;
        let ptb = 64; // per-token kv bytes
        let chunk = 32;
        // off / no swap tier: always recompute
        assert_eq!(choose_preempt_action(SwapPolicy::Off, true, 1 << 20, 512, ptb, chunk), Recompute);
        assert_eq!(choose_preempt_action(SwapPolicy::Auto, false, 0, 512, ptb, chunk), Recompute);
        // always: swap whenever a tier exists
        assert_eq!(choose_preempt_action(SwapPolicy::Always, true, 1 << 20, 8, ptb, chunk), SwapOut);
        // auto crossover: short context recomputes, long context swaps.
        // swap bytes ~ ctx tokens * ptb (fully private pages)
        let short = 32;
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, short * ptb, short, ptb, chunk),
            Recompute,
            "one-chunk re-prefill beats a 2x byte round trip"
        );
        let long = 512;
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, long * ptb, long, ptb, chunk),
            SwapOut,
            "quadratic re-prefill traffic dwarfs the swap copy"
        );
        // prefix-shared victim: most pages re-link, so swapping gets cheaper
        assert_eq!(
            choose_preempt_action(SwapPolicy::Auto, true, 8 * ptb, 96, ptb, chunk),
            SwapOut
        );
    }
}
