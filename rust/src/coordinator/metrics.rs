//! Serving metrics: throughput, TTFT/TPOT latencies, engine utilization.
//!
//! Entirely lock-free: counters are atomics and latency samples go into
//! bounded log-bucket histograms ([`crate::obs::LogHistogram`] — 64 atomic
//! buckets each), so recording never blocks, memory is constant regardless
//! of request count, and `snapshot()` only loads atomics — it neither sorts
//! nor mutates anything. (The previous design pushed every completion into
//! a `Vec<f64>` under a mutex and re-sorted it per snapshot: O(n log n)
//! per call, unbounded growth, and a poisoning hazard if any worker
//! panicked while holding the lock.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::failure::FailureKind;
use crate::obs::{Exposition, HistSnapshot, LogHistogram, SCHEMA_VERSION};
use crate::util::json::{num, obj, Json};

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefill_chunks: AtomicU64,
    pub decode_nanos: AtomicU64,
    /// Wall time of the most recent decode step (gauge, nanoseconds) —
    /// stored by the scheduler each tick alongside `gather_bytes`, so a
    /// snapshot shows current per-step latency, not just the lifetime mean.
    pub last_decode_nanos: AtomicU64,
    pub prefill_nanos: AtomicU64,
    /// Prompt tokens actually prefilled (prefix-reused tokens excluded);
    /// with `prefill_nanos` this yields prefill tokens/sec.
    pub prefill_tokens: AtomicU64,
    pub busy_slots_sum: AtomicU64,
    /// Paged serving: requests evicted back to the resume queue.
    pub preemptions: AtomicU64,
    /// Paged serving: prompts that reused shared prefix pages / tokens saved.
    pub prefix_hits: AtomicU64,
    pub prefix_tokens_reused: AtomicU64,
    /// Host swap tier: eviction decisions that moved state device<->host.
    pub swap_outs: AtomicU64,
    pub swap_ins: AtomicU64,
    pub swap_bytes_out: AtomicU64,
    pub swap_bytes_in: AtomicU64,
    /// Swap-out chosen by the cost model but refused (host arena full);
    /// the victim fell back to recompute.
    pub swap_stalls: AtomicU64,
    /// Swapped state unrecoverable at resume (re-linked prefix pages were
    /// recycled) or permanently unadmittable; resumed by re-prefill instead.
    pub swap_fallbacks: AtomicU64,
    /// Tokens re-prefilled to resume recompute-preempted requests — the
    /// work a swap-out avoids.
    pub reprefill_tokens: AtomicU64,
    /// Cumulative gather-to-dense staging bytes (XLA paged arm: live pages
    /// copied into the dense artifact layout every layer step). The native
    /// block-direct backend reports a structural 0 — this counter is
    /// exactly the traffic it eliminates (`table10_kernel` quantifies it).
    pub gather_bytes: AtomicU64,
    /// Online sensitivity probe: cumulative envelope-exceeded drift alerts
    /// (a layer's sampled quantization error left the offline calibration
    /// envelope). Stored by the scheduler each tick from the engine's probe.
    pub drift_alerts: AtomicU64,
    /// Trace-ring overflow: lifecycle events overwritten before export.
    /// Stored by the scheduler each tick from its tracer so truncated
    /// traces are detectable from any metrics surface.
    pub trace_dropped: AtomicU64,
    /// Seeded faults the injector actually fired (0 unless a fault plan is
    /// armed — the injection points compile in but never roll).
    pub faults_injected: AtomicU64,
    /// Operations re-attempted after a transient failure (swap-in retries,
    /// page-wait requeues, prefill-chunk re-tries).
    pub retries: AtomicU64,
    /// Requests that ended in a typed failure, tallied by
    /// [`FailureKind::index`].
    requests_failed: [AtomicU64; FailureKind::COUNT],
    /// Time to first token, per completed request.
    ttft: LogHistogram,
    /// End-to-end latency, per completed request.
    total: LogHistogram,
    /// Per-request mean time-per-output-token, `(total - ttft) / (n - 1)`;
    /// one sample per completed request with 2+ tokens.
    tpot: LogHistogram,
    /// Decode-step wall time, one sample per batched step.
    step: LogHistogram,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub decode_secs: f64,
    /// Mean decode wall time per step (ms).
    pub decode_ms_per_step: f64,
    /// Wall time of the most recent decode step (ms).
    pub last_decode_ms: f64,
    pub prefill_secs: f64,
    pub prefill_tokens: u64,
    /// Prefill throughput over tokens actually computed (reused prefix
    /// tokens excluded).
    pub prefill_tokens_per_sec: f64,
    pub tokens_per_sec_decode: f64,
    pub mean_batch_occupancy: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub ttft_p99: f64,
    pub total_p50: f64,
    pub total_p95: f64,
    pub total_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p95: f64,
    pub tpot_p99: f64,
    pub step_p50: f64,
    pub step_p95: f64,
    pub step_p99: f64,
    pub preemptions: u64,
    pub prefix_hits: u64,
    pub prefix_tokens_reused: u64,
    pub swap_outs: u64,
    pub swap_ins: u64,
    pub swap_bytes_out: u64,
    pub swap_bytes_in: u64,
    pub swap_stalls: u64,
    pub swap_fallbacks: u64,
    pub reprefill_tokens: u64,
    pub gather_bytes: u64,
    pub drift_alerts: u64,
    /// Lifecycle trace events lost to ring wraparound (0 when untraced).
    pub trace_dropped: u64,
    /// Seeded faults fired (0 when no fault plan armed).
    pub faults_injected: u64,
    /// Transient-failure retries (swap-in, page-wait, prefill chunk).
    pub retries: u64,
    /// Per-kind failed-request tallies, indexed by [`FailureKind::index`].
    pub requests_failed: [u64; FailureKind::COUNT],
    /// Full bucket dumps backing the percentile fields above.
    pub ttft_hist: HistSnapshot,
    pub total_hist: HistSnapshot,
    pub tpot_hist: HistSnapshot,
    pub step_hist: HistSnapshot,
}

impl Metrics {
    pub fn record_decode(&self, d: Duration, busy: usize, tokens: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.last_decode_nanos.store(d.as_nanos() as u64, Ordering::Relaxed);
        self.busy_slots_sum.fetch_add(busy as u64, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.step.record(d);
    }

    pub fn record_prefill(&self, d: Duration, tokens: usize) {
        self.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        self.prefill_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn record_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_prefix(&self, tokens_reused: usize) {
        if tokens_reused > 0 {
            self.prefix_hits.fetch_add(1, Ordering::Relaxed);
            self.prefix_tokens_reused.fetch_add(tokens_reused as u64, Ordering::Relaxed);
        }
    }

    pub fn record_swap_out(&self, bytes: usize) {
        self.swap_outs.fetch_add(1, Ordering::Relaxed);
        self.swap_bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_swap_in(&self, bytes: usize) {
        self.swap_ins.fetch_add(1, Ordering::Relaxed);
        self.swap_bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_swap_stall(&self) {
        self.swap_stalls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap_fallback(&self) {
        self.swap_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reprefill(&self, tokens: usize) {
        self.reprefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn record_fault(&self) {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_failure(&self, kind: FailureKind) {
        self.requests_failed[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Total failed requests across all kinds.
    pub fn failures_total(&self) -> u64 {
        self.requests_failed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// One completed request: TTFT, end-to-end latency, and — when the
    /// request produced 2+ tokens — its mean inter-token latency (TPOT).
    pub fn record_completion(&self, ttft: Duration, total: Duration, tokens: usize) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        self.ttft.record(ttft);
        self.total.record(total);
        if tokens > 1 {
            let decode = total.saturating_sub(ttft);
            self.tpot.record_nanos(decode.as_nanos() as u64 / (tokens as u64 - 1));
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let decode_secs = self.decode_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let steps = self.decode_steps.load(Ordering::Relaxed);
        let tokens = self.tokens_generated.load(Ordering::Relaxed);
        let prefill_secs = self.prefill_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let prefill_tokens = self.prefill_tokens.load(Ordering::Relaxed);
        let ttft = self.ttft.snapshot();
        let total = self.total.snapshot();
        let tpot = self.tpot.snapshot();
        let step = self.step.snapshot();
        Snapshot {
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            tokens_generated: tokens,
            decode_steps: steps,
            decode_secs,
            decode_ms_per_step: if steps > 0 { decode_secs * 1e3 / steps as f64 } else { 0.0 },
            last_decode_ms: self.last_decode_nanos.load(Ordering::Relaxed) as f64 / 1e6,
            prefill_secs,
            prefill_tokens,
            prefill_tokens_per_sec: if prefill_secs > 0.0 {
                prefill_tokens as f64 / prefill_secs
            } else {
                0.0
            },
            tokens_per_sec_decode: if decode_secs > 0.0 { tokens as f64 / decode_secs } else { 0.0 },
            mean_batch_occupancy: if steps > 0 {
                self.busy_slots_sum.load(Ordering::Relaxed) as f64 / steps as f64
            } else {
                0.0
            },
            ttft_p50: ttft.percentile(0.50),
            ttft_p95: ttft.percentile(0.95),
            ttft_p99: ttft.percentile(0.99),
            total_p50: total.percentile(0.50),
            total_p95: total.percentile(0.95),
            total_p99: total.percentile(0.99),
            tpot_p50: tpot.percentile(0.50),
            tpot_p95: tpot.percentile(0.95),
            tpot_p99: tpot.percentile(0.99),
            step_p50: step.percentile(0.50),
            step_p95: step.percentile(0.95),
            step_p99: step.percentile(0.99),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_tokens_reused: self.prefix_tokens_reused.load(Ordering::Relaxed),
            swap_outs: self.swap_outs.load(Ordering::Relaxed),
            swap_ins: self.swap_ins.load(Ordering::Relaxed),
            swap_bytes_out: self.swap_bytes_out.load(Ordering::Relaxed),
            swap_bytes_in: self.swap_bytes_in.load(Ordering::Relaxed),
            swap_stalls: self.swap_stalls.load(Ordering::Relaxed),
            swap_fallbacks: self.swap_fallbacks.load(Ordering::Relaxed),
            reprefill_tokens: self.reprefill_tokens.load(Ordering::Relaxed),
            gather_bytes: self.gather_bytes.load(Ordering::Relaxed),
            drift_alerts: self.drift_alerts.load(Ordering::Relaxed),
            trace_dropped: self.trace_dropped.load(Ordering::Relaxed),
            faults_injected: self.faults_injected.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            requests_failed: std::array::from_fn(|i| {
                self.requests_failed[i].load(Ordering::Relaxed)
            }),
            ttft_hist: ttft,
            total_hist: total,
            tpot_hist: tpot,
            step_hist: step,
        }
    }
}

impl Snapshot {
    /// This snapshot's tally for one failure kind.
    pub fn failed(&self, kind: FailureKind) -> u64 {
        self.requests_failed[kind.index()]
    }

    /// Failed requests summed across all kinds.
    pub fn failures_total(&self) -> u64 {
        self.requests_failed.iter().sum()
    }

    /// Full machine-readable snapshot: every scalar plus the four latency
    /// histograms' bucket dumps. Benches emit this as a `BENCH_JSON` line;
    /// serve writes it to `--metrics-out`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", num(SCHEMA_VERSION as f64)),
            ("requests_completed", num(self.requests_completed as f64)),
            ("tokens_generated", num(self.tokens_generated as f64)),
            ("decode_steps", num(self.decode_steps as f64)),
            ("decode_secs", num(self.decode_secs)),
            ("decode_ms_per_step", num(self.decode_ms_per_step)),
            ("last_decode_ms", num(self.last_decode_ms)),
            ("prefill_secs", num(self.prefill_secs)),
            ("prefill_tokens", num(self.prefill_tokens as f64)),
            ("prefill_tokens_per_sec", num(self.prefill_tokens_per_sec)),
            ("tokens_per_sec_decode", num(self.tokens_per_sec_decode)),
            ("mean_batch_occupancy", num(self.mean_batch_occupancy)),
            ("ttft_p50_s", num(self.ttft_p50)),
            ("ttft_p95_s", num(self.ttft_p95)),
            ("ttft_p99_s", num(self.ttft_p99)),
            ("total_p50_s", num(self.total_p50)),
            ("total_p95_s", num(self.total_p95)),
            ("total_p99_s", num(self.total_p99)),
            ("tpot_p50_s", num(self.tpot_p50)),
            ("tpot_p95_s", num(self.tpot_p95)),
            ("tpot_p99_s", num(self.tpot_p99)),
            ("step_p50_s", num(self.step_p50)),
            ("step_p95_s", num(self.step_p95)),
            ("step_p99_s", num(self.step_p99)),
            ("preemptions", num(self.preemptions as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            ("prefix_tokens_reused", num(self.prefix_tokens_reused as f64)),
            ("swap_outs", num(self.swap_outs as f64)),
            ("swap_ins", num(self.swap_ins as f64)),
            ("swap_bytes_out", num(self.swap_bytes_out as f64)),
            ("swap_bytes_in", num(self.swap_bytes_in as f64)),
            ("swap_stalls", num(self.swap_stalls as f64)),
            ("swap_fallbacks", num(self.swap_fallbacks as f64)),
            ("reprefill_tokens", num(self.reprefill_tokens as f64)),
            ("gather_bytes", num(self.gather_bytes as f64)),
            ("drift_alerts", num(self.drift_alerts as f64)),
            ("trace_dropped", num(self.trace_dropped as f64)),
            ("faults_injected", num(self.faults_injected as f64)),
            ("retries", num(self.retries as f64)),
            (
                "requests_failed",
                obj(FailureKind::ALL
                    .iter()
                    .map(|k| (k.as_str(), num(self.failed(*k) as f64)))
                    .collect()),
            ),
            ("ttft_hist", self.ttft_hist.to_json()),
            ("total_hist", self.total_hist.to_json()),
            ("tpot_hist", self.tpot_hist.to_json()),
            ("step_hist", self.step_hist.to_json()),
        ])
    }

    /// Render the end-of-run aggregates into a Prometheus exposition under
    /// one `engine` label: lifetime counters as `counter`s, current levels
    /// and throughputs as `gauge`s, and the four latency histograms as
    /// quantile-labeled `summary` series.
    pub fn render_prometheus(&self, expo: &mut Exposition, engine: &str) {
        let l = &[("engine", engine)][..];
        let counters: &[(&str, &str, f64)] = &[
            ("requests_completed", "completed requests", self.requests_completed as f64),
            ("tokens_generated", "decoded tokens", self.tokens_generated as f64),
            ("decode_steps", "batched decode steps", self.decode_steps as f64),
            ("prefill_tokens_computed", "prompt tokens prefilled", self.prefill_tokens as f64),
            ("preemptions", "requests evicted under page pressure", self.preemptions as f64),
            ("prefix_hits", "prompts that reused shared prefix pages", self.prefix_hits as f64),
            ("prefix_tokens_reused", "prompt tokens reused", self.prefix_tokens_reused as f64),
            ("swap_outs", "evictions that moved KV state to the host tier", self.swap_outs as f64),
            ("swap_ins", "swapped resumes restored from the host tier", self.swap_ins as f64),
            ("swap_stalls", "swap-outs refused by a full host arena", self.swap_stalls as f64),
            ("swap_fallbacks", "resumes that fell back to re-prefill", self.swap_fallbacks as f64),
            ("reprefill_tokens", "tokens re-prefilled on resume", self.reprefill_tokens as f64),
            ("drift_alerts", "quantization error left the envelope", self.drift_alerts as f64),
            ("trace_dropped_events", "lost to tracer ring wraparound", self.trace_dropped as f64),
            ("faults_injected", "seeded faults fired by the injector", self.faults_injected as f64),
            ("retries", "transient-failure retries", self.retries as f64),
        ];
        for &(name, help, v) in counters {
            expo.add(&format!("kvtuner_{name}_total"), "counter", help, l, v);
        }
        // Full failure family, every kind emitted even at zero so scrapers
        // discover the label set before the first failure.
        for k in FailureKind::ALL {
            expo.add(
                "kvtuner_requests_failed_total",
                "counter",
                "requests ended in a typed failure, by kind",
                &[("engine", engine), ("kind", k.as_str())],
                self.failed(k) as f64,
            );
        }
        let gauges: &[(&str, &str, f64)] = &[
            ("decode_tokens_per_sec", "decode throughput", self.tokens_per_sec_decode),
            ("prefill_tokens_per_sec", "prefill throughput", self.prefill_tokens_per_sec),
            ("decode_step_seconds_last", "last decode step wall time", self.last_decode_ms / 1e3),
            ("decode_step_seconds_mean", "mean decode step time", self.decode_ms_per_step / 1e3),
            ("mean_batch_occupancy", "mean busy slots per decode step", self.mean_batch_occupancy),
        ];
        for &(name, help, v) in gauges {
            expo.add(&format!("kvtuner_{name}"), "gauge", help, l, v);
        }
        let summaries: &[(&str, &str, [f64; 3], &HistSnapshot)] = &[
            (
                "ttft_seconds",
                "time to first token",
                [self.ttft_p50, self.ttft_p95, self.ttft_p99],
                &self.ttft_hist,
            ),
            (
                "request_seconds",
                "end-to-end request latency",
                [self.total_p50, self.total_p95, self.total_p99],
                &self.total_hist,
            ),
            (
                "tpot_seconds",
                "per-request mean time per output token",
                [self.tpot_p50, self.tpot_p95, self.tpot_p99],
                &self.tpot_hist,
            ),
            (
                "decode_step_seconds",
                "batched decode step wall time",
                [self.step_p50, self.step_p95, self.step_p99],
                &self.step_hist,
            ),
        ];
        for &(name, help, qs, hist) in summaries {
            let family = format!("kvtuner_{name}");
            for (q, v) in [("0.5", qs[0]), ("0.95", qs[1]), ("0.99", qs[2])] {
                expo.add(&family, "summary", help, &[("engine", engine), ("quantile", q)], v);
            }
            expo.add_suffixed(&family, "_count", "summary", help, l, hist.total as f64);
            expo.add_suffixed(&family, "_sum", "summary", help, l, hist.sum_nanos as f64 / 1e9);
        }
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req={} tok={} decode_tok/s={:.1} decode_ms/step={:.2}(last {:.2}) prefill_tok/s={:.0} occ={:.2} ttft p50/p95/p99={:.1}/{:.1}/{:.1}ms total p50/p95/p99={:.1}/{:.1}/{:.1}ms tpot p50/p95/p99={:.2}/{:.2}/{:.2}ms preempt={} reuse={}tok/{}hit swap={}out/{}in({}/{}KiB) reprefill={}tok gather={}KiB drift={} faults={} retries={} failed={}",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_per_sec_decode,
            self.decode_ms_per_step,
            self.last_decode_ms,
            self.prefill_tokens_per_sec,
            self.mean_batch_occupancy,
            self.ttft_p50 * 1e3,
            self.ttft_p95 * 1e3,
            self.ttft_p99 * 1e3,
            self.total_p50 * 1e3,
            self.total_p95 * 1e3,
            self.total_p99 * 1e3,
            self.tpot_p50 * 1e3,
            self.tpot_p95 * 1e3,
            self.tpot_p99 * 1e3,
            self.preemptions,
            self.prefix_tokens_reused,
            self.prefix_hits,
            self.swap_outs,
            self.swap_ins,
            self.swap_bytes_out / 1024,
            self.swap_bytes_in / 1024,
            self.reprefill_tokens,
            self.gather_bytes / 1024,
            self.drift_alerts,
            self.faults_injected,
            self.retries,
            self.failures_total(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One histogram bucket's ratio — the tolerance a bucketed percentile
    /// may deviate from an exact sample by.
    fn tol() -> f64 {
        10f64.powf(9.0 / 64.0)
    }

    fn close(bucketed: f64, exact: f64) -> bool {
        bucketed > 0.0 && bucketed / exact < tol() && exact / bucketed < tol()
    }

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.record_decode(Duration::from_millis(10), 2, 2);
        m.record_decode(Duration::from_millis(10), 1, 1);
        m.record_completion(Duration::from_millis(5), Duration::from_millis(50), 1);
        let s = m.snapshot();
        assert_eq!(s.tokens_generated, 3);
        assert_eq!(s.decode_steps, 2);
        assert!((s.mean_batch_occupancy - 1.5).abs() < 1e-9);
        assert!((s.tokens_per_sec_decode - 150.0).abs() < 1.0);
        assert!(close(s.ttft_p50, 0.005), "ttft p50 {} vs 5ms", s.ttft_p50);
        assert!(close(s.total_p99, 0.050), "total p99 {} vs 50ms", s.total_p99);
        assert!(close(s.step_p50, 0.010), "step p50 {} vs 10ms", s.step_p50);
        assert!((s.decode_ms_per_step - 10.0).abs() < 1e-6);
        assert!((s.last_decode_ms - 10.0).abs() < 1e-6);
    }

    #[test]
    fn snapshot_does_not_mutate() {
        let m = Metrics::default();
        m.record_completion(Duration::from_millis(5), Duration::from_millis(50), 4);
        let a = m.snapshot();
        let b = m.snapshot();
        assert_eq!(a.ttft_hist, b.ttft_hist, "snapshots of unchanged metrics are identical");
        assert_eq!(a.ttft_p50, b.ttft_p50);
    }

    #[test]
    fn tpot_is_decode_time_over_tokens_minus_one() {
        let m = Metrics::default();
        // 10ms TTFT + 100ms of decode producing 10 more tokens: TPOT = 10ms
        m.record_completion(Duration::from_millis(10), Duration::from_millis(110), 11);
        let s = m.snapshot();
        assert!(close(s.tpot_p50, 0.010), "tpot p50 {} vs 10ms", s.tpot_p50);
        assert_eq!(s.tpot_hist.total, 1);
        // a 1-token request has no inter-token gap and must not sample TPOT
        m.record_completion(Duration::from_millis(10), Duration::from_millis(10), 1);
        assert_eq!(m.snapshot().tpot_hist.total, 1);
    }

    #[test]
    fn per_step_gauge_tracks_the_latest_tick() {
        let m = Metrics::default();
        m.record_decode(Duration::from_millis(30), 1, 1);
        m.record_decode(Duration::from_millis(10), 1, 1);
        let s = m.snapshot();
        assert!((s.last_decode_ms - 10.0).abs() < 1e-6, "gauge = most recent step");
        assert!((s.decode_ms_per_step - 20.0).abs() < 1e-6, "mean over both steps");
    }

    #[test]
    fn prefill_tokens_per_sec() {
        let m = Metrics::default();
        m.record_prefill(Duration::from_millis(50), 100);
        m.record_prefill(Duration::from_millis(50), 100);
        let s = m.snapshot();
        assert_eq!(s.prefill_tokens, 200);
        assert!((s.prefill_tokens_per_sec - 2000.0).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.tokens_per_sec_decode, 0.0);
        assert_eq!(s.prefill_tokens_per_sec, 0.0);
        assert_eq!(s.decode_ms_per_step, 0.0);
        assert_eq!(s.ttft_p95, 0.0);
        assert_eq!(s.tpot_p99, 0.0);
    }

    #[test]
    fn failure_tallies_are_per_kind_and_exported() {
        let m = Metrics::default();
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::DeadlineExceeded);
        m.record_failure(FailureKind::WorkerDied);
        m.record_fault();
        m.record_retry();
        let s = m.snapshot();
        assert_eq!(s.failed(FailureKind::DeadlineExceeded), 2);
        assert_eq!(s.failed(FailureKind::WorkerDied), 1);
        assert_eq!(s.failed(FailureKind::Timeout), 0);
        assert_eq!(s.failures_total(), 3);
        assert_eq!(s.faults_injected, 1);
        assert_eq!(s.retries, 1);
        let j = Json::parse(&s.to_json().to_string_pretty()).unwrap();
        let rf = j.get("requests_failed").unwrap();
        assert_eq!(rf.get("deadline_exceeded").unwrap().as_usize().unwrap(), 2);
        assert_eq!(rf.get("unroutable").unwrap().as_usize().unwrap(), 0);
        let mut expo = Exposition::new();
        s.render_prometheus(&mut expo, "t");
        let body = expo.render();
        assert!(body.contains("kvtuner_requests_failed_total{engine=\"t\",kind=\"worker_died\"} 1"));
        assert!(
            body.contains("kvtuner_requests_failed_total{engine=\"t\",kind=\"queue_full\"} 0"),
            "zero-valued kinds still emitted for discoverability"
        );
        assert!(body.contains("kvtuner_faults_injected_total"));
        assert!(body.contains("kvtuner_retries_total"));
    }

    #[test]
    fn snapshot_json_parses() {
        let m = Metrics::default();
        m.record_decode(Duration::from_millis(10), 1, 1);
        m.record_completion(Duration::from_millis(5), Duration::from_millis(50), 5);
        let j = m.snapshot().to_json();
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.get("requests_completed").unwrap().as_usize().unwrap(), 1);
        assert!(re.get("ttft_p99_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(re.get("tpot_p50_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(re.get("step_hist").unwrap().get("count").unwrap().as_usize().unwrap(), 1);
    }
}
