//! Serving metrics: throughput, TTFT/TPOT latencies, engine utilization.
//! Lock-light: counters are atomics; latency samples batch under one mutex.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefill_chunks: AtomicU64,
    pub decode_nanos: AtomicU64,
    /// Wall time of the most recent decode step (gauge, nanoseconds) —
    /// stored by the scheduler each tick alongside `gather_bytes`, so a
    /// snapshot shows current per-step latency, not just the lifetime mean.
    pub last_decode_nanos: AtomicU64,
    pub prefill_nanos: AtomicU64,
    /// Prompt tokens actually prefilled (prefix-reused tokens excluded);
    /// with `prefill_nanos` this yields prefill tokens/sec.
    pub prefill_tokens: AtomicU64,
    pub busy_slots_sum: AtomicU64,
    /// Paged serving: requests evicted back to the resume queue.
    pub preemptions: AtomicU64,
    /// Paged serving: prompts that reused shared prefix pages / tokens saved.
    pub prefix_hits: AtomicU64,
    pub prefix_tokens_reused: AtomicU64,
    /// Host swap tier: eviction decisions that moved state device<->host.
    pub swap_outs: AtomicU64,
    pub swap_ins: AtomicU64,
    pub swap_bytes_out: AtomicU64,
    pub swap_bytes_in: AtomicU64,
    /// Swap-out chosen by the cost model but refused (host arena full);
    /// the victim fell back to recompute.
    pub swap_stalls: AtomicU64,
    /// Swapped state unrecoverable at resume (re-linked prefix pages were
    /// recycled) or permanently unadmittable; resumed by re-prefill instead.
    pub swap_fallbacks: AtomicU64,
    /// Tokens re-prefilled to resume recompute-preempted requests — the
    /// work a swap-out avoids.
    pub reprefill_tokens: AtomicU64,
    /// Cumulative gather-to-dense staging bytes (XLA paged arm: live pages
    /// copied into the dense artifact layout every layer step). The native
    /// block-direct backend reports a structural 0 — this counter is
    /// exactly the traffic it eliminates (`table10_kernel` quantifies it).
    pub gather_bytes: AtomicU64,
    latencies: Mutex<LatencySamples>,
}

#[derive(Default)]
struct LatencySamples {
    ttft: Vec<f64>,
    total: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub decode_secs: f64,
    /// Mean decode wall time per step (ms).
    pub decode_ms_per_step: f64,
    /// Wall time of the most recent decode step (ms).
    pub last_decode_ms: f64,
    pub prefill_secs: f64,
    pub prefill_tokens: u64,
    /// Prefill throughput over tokens actually computed (reused prefix
    /// tokens excluded).
    pub prefill_tokens_per_sec: f64,
    pub tokens_per_sec_decode: f64,
    pub mean_batch_occupancy: f64,
    pub ttft_p50: f64,
    pub ttft_p95: f64,
    pub total_p50: f64,
    pub total_p95: f64,
    pub preemptions: u64,
    pub prefix_hits: u64,
    pub prefix_tokens_reused: u64,
    pub swap_outs: u64,
    pub swap_ins: u64,
    pub swap_bytes_out: u64,
    pub swap_bytes_in: u64,
    pub swap_stalls: u64,
    pub swap_fallbacks: u64,
    pub reprefill_tokens: u64,
    pub gather_bytes: u64,
}

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx]
}

impl Metrics {
    pub fn record_decode(&self, d: Duration, busy: usize, tokens: usize) {
        self.decode_steps.fetch_add(1, Ordering::Relaxed);
        self.decode_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.last_decode_nanos.store(d.as_nanos() as u64, Ordering::Relaxed);
        self.busy_slots_sum.fetch_add(busy as u64, Ordering::Relaxed);
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn record_prefill(&self, d: Duration, tokens: usize) {
        self.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        self.prefill_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn record_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_prefix(&self, tokens_reused: usize) {
        if tokens_reused > 0 {
            self.prefix_hits.fetch_add(1, Ordering::Relaxed);
            self.prefix_tokens_reused.fetch_add(tokens_reused as u64, Ordering::Relaxed);
        }
    }

    pub fn record_swap_out(&self, bytes: usize) {
        self.swap_outs.fetch_add(1, Ordering::Relaxed);
        self.swap_bytes_out.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_swap_in(&self, bytes: usize) {
        self.swap_ins.fetch_add(1, Ordering::Relaxed);
        self.swap_bytes_in.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_swap_stall(&self) {
        self.swap_stalls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap_fallback(&self) {
        self.swap_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reprefill(&self, tokens: usize) {
        self.reprefill_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
    }

    pub fn record_completion(&self, ttft: Duration, total: Duration) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        l.ttft.push(ttft.as_secs_f64());
        l.total.push(total.as_secs_f64());
    }

    pub fn snapshot(&self) -> Snapshot {
        let decode_secs = self.decode_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let steps = self.decode_steps.load(Ordering::Relaxed);
        let tokens = self.tokens_generated.load(Ordering::Relaxed);
        let prefill_secs = self.prefill_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let prefill_tokens = self.prefill_tokens.load(Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        l.ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        l.total.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Snapshot {
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            tokens_generated: tokens,
            decode_steps: steps,
            decode_secs,
            decode_ms_per_step: if steps > 0 { decode_secs * 1e3 / steps as f64 } else { 0.0 },
            last_decode_ms: self.last_decode_nanos.load(Ordering::Relaxed) as f64 / 1e6,
            prefill_secs,
            prefill_tokens,
            prefill_tokens_per_sec: if prefill_secs > 0.0 {
                prefill_tokens as f64 / prefill_secs
            } else {
                0.0
            },
            tokens_per_sec_decode: if decode_secs > 0.0 { tokens as f64 / decode_secs } else { 0.0 },
            mean_batch_occupancy: if steps > 0 {
                self.busy_slots_sum.load(Ordering::Relaxed) as f64 / steps as f64
            } else {
                0.0
            },
            ttft_p50: pct(&l.ttft, 0.5),
            ttft_p95: pct(&l.ttft, 0.95),
            total_p50: pct(&l.total, 0.5),
            total_p95: pct(&l.total, 0.95),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_tokens_reused: self.prefix_tokens_reused.load(Ordering::Relaxed),
            swap_outs: self.swap_outs.load(Ordering::Relaxed),
            swap_ins: self.swap_ins.load(Ordering::Relaxed),
            swap_bytes_out: self.swap_bytes_out.load(Ordering::Relaxed),
            swap_bytes_in: self.swap_bytes_in.load(Ordering::Relaxed),
            swap_stalls: self.swap_stalls.load(Ordering::Relaxed),
            swap_fallbacks: self.swap_fallbacks.load(Ordering::Relaxed),
            reprefill_tokens: self.reprefill_tokens.load(Ordering::Relaxed),
            gather_bytes: self.gather_bytes.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Display for Snapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "req={} tok={} decode_tok/s={:.1} decode_ms/step={:.2}(last {:.2}) prefill_tok/s={:.0} occ={:.2} ttft p50/p95={:.1}/{:.1}ms total p50/p95={:.1}/{:.1}ms preempt={} reuse={}tok/{}hit swap={}out/{}in({}/{}KiB) reprefill={}tok gather={}KiB",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_per_sec_decode,
            self.decode_ms_per_step,
            self.last_decode_ms,
            self.prefill_tokens_per_sec,
            self.mean_batch_occupancy,
            self.ttft_p50 * 1e3,
            self.ttft_p95 * 1e3,
            self.total_p50 * 1e3,
            self.total_p95 * 1e3,
            self.preemptions,
            self.prefix_tokens_reused,
            self.prefix_hits,
            self.swap_outs,
            self.swap_ins,
            self.swap_bytes_out / 1024,
            self.swap_bytes_in / 1024,
            self.reprefill_tokens,
            self.gather_bytes / 1024,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.record_decode(Duration::from_millis(10), 2, 2);
        m.record_decode(Duration::from_millis(10), 1, 1);
        m.record_completion(Duration::from_millis(5), Duration::from_millis(50));
        let s = m.snapshot();
        assert_eq!(s.tokens_generated, 3);
        assert_eq!(s.decode_steps, 2);
        assert!((s.mean_batch_occupancy - 1.5).abs() < 1e-9);
        assert!((s.tokens_per_sec_decode - 150.0).abs() < 1.0);
        assert!((s.ttft_p50 - 0.005).abs() < 1e-9);
        assert!((s.decode_ms_per_step - 10.0).abs() < 1e-6);
        assert!((s.last_decode_ms - 10.0).abs() < 1e-6);
    }

    #[test]
    fn per_step_gauge_tracks_the_latest_tick() {
        let m = Metrics::default();
        m.record_decode(Duration::from_millis(30), 1, 1);
        m.record_decode(Duration::from_millis(10), 1, 1);
        let s = m.snapshot();
        assert!((s.last_decode_ms - 10.0).abs() < 1e-6, "gauge = most recent step");
        assert!((s.decode_ms_per_step - 20.0).abs() < 1e-6, "mean over both steps");
    }

    #[test]
    fn prefill_tokens_per_sec() {
        let m = Metrics::default();
        m.record_prefill(Duration::from_millis(50), 100);
        m.record_prefill(Duration::from_millis(50), 100);
        let s = m.snapshot();
        assert_eq!(s.prefill_tokens, 200);
        assert!((s.prefill_tokens_per_sec - 2000.0).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.tokens_per_sec_decode, 0.0);
        assert_eq!(s.prefill_tokens_per_sec, 0.0);
        assert_eq!(s.decode_ms_per_step, 0.0);
        assert_eq!(s.ttft_p95, 0.0);
    }
}
