//! Typed failure taxonomy for the serving path. Every way a request can end
//! other than natural completion gets a [`FailureKind`], so clients branch
//! on an enum instead of parsing error strings, metrics tally failures
//! per kind (`kvtuner_requests_failed_total{kind=...}`), and the chaos
//! harness can assert *which* failure a fault produced.

/// Why a request failed (or completed degraded).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailureKind {
    /// The request's deadline passed before it completed; any tokens
    /// generated so far are still delivered.
    DeadlineExceeded,
    /// The request cannot fit the KV page pool even alone (or exhausted its
    /// retry budget waiting for pages).
    PoolExhausted,
    /// Pool exhausted mid-generation with nothing left to evict: the tokens
    /// generated so far are delivered, marked degraded.
    Truncated,
    /// The admission queue was full at submit time.
    QueueFull,
    /// The worker serving this request died (panic or thread loss) and no
    /// sibling could take it over.
    WorkerDied,
    /// The engine itself reported an error (prefill or decode step).
    EngineFault,
    /// The client-side wait timed out before a response arrived.
    Timeout,
    /// No routable worker: every candidate is dead, or the router is
    /// draining and no longer admits work.
    Unroutable,
}

impl FailureKind {
    /// Every kind, in a fixed order — metrics index tallies by position and
    /// the Prometheus exposition emits the full family even at zero so
    /// scrapers can discover it before the first failure.
    pub const ALL: [FailureKind; 8] = [
        FailureKind::DeadlineExceeded,
        FailureKind::PoolExhausted,
        FailureKind::Truncated,
        FailureKind::QueueFull,
        FailureKind::WorkerDied,
        FailureKind::EngineFault,
        FailureKind::Timeout,
        FailureKind::Unroutable,
    ];

    pub const COUNT: usize = FailureKind::ALL.len();

    /// Stable label (metrics `kind` label, JSON keys).
    pub fn as_str(self) -> &'static str {
        match self {
            FailureKind::DeadlineExceeded => "deadline_exceeded",
            FailureKind::PoolExhausted => "pool_exhausted",
            FailureKind::Truncated => "truncated",
            FailureKind::QueueFull => "queue_full",
            FailureKind::WorkerDied => "worker_died",
            FailureKind::EngineFault => "engine_fault",
            FailureKind::Timeout => "timeout",
            FailureKind::Unroutable => "unroutable",
        }
    }

    /// Position in [`FailureKind::ALL`] (the metrics tally index).
    pub fn index(self) -> usize {
        FailureKind::ALL.iter().position(|k| *k == self).unwrap_or(0)
    }
}

/// A typed failure: the kind plus human-readable detail. This is what rides
/// in `Response::error` and inside routing errors (downcastable from
/// `anyhow::Error`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Failure {
    pub kind: FailureKind,
    pub detail: String,
}

impl Failure {
    pub fn new(kind: FailureKind, detail: impl Into<String>) -> Failure {
        Failure { kind, detail: detail.into() }
    }
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.detail.is_empty() {
            write!(f, "{}", self.kind.as_str())
        } else {
            write!(f, "{}: {}", self.kind.as_str(), self.detail)
        }
    }
}

impl std::error::Error for Failure {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_have_unique_stable_labels_and_indices() {
        let mut seen = std::collections::HashSet::new();
        for (i, k) in FailureKind::ALL.iter().enumerate() {
            assert!(seen.insert(k.as_str()), "duplicate label {}", k.as_str());
            assert_eq!(k.index(), i);
        }
        assert_eq!(seen.len(), FailureKind::COUNT);
    }

    #[test]
    fn failure_downcasts_from_anyhow() {
        let e = anyhow::Error::new(Failure::new(FailureKind::Unroutable, "no workers"));
        let f = e.downcast_ref::<Failure>().expect("typed failure survives anyhow");
        assert_eq!(f.kind, FailureKind::Unroutable);
        assert_eq!(format!("{f}"), "unroutable: no workers");
        assert_eq!(format!("{}", Failure::new(FailureKind::Timeout, "")), "timeout");
    }
}
