//! Request/response types flowing between clients, the router, and the
//! engine workers. Plain data + channels: PJRT objects are thread-pinned
//! (no Send), so engines never cross threads — requests do.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::failure::{Failure, FailureKind};

/// Client-declared accuracy requirement: the router maps this to an engine
/// whose tuned config meets it (paper Sec. 1 issue 3 — multiple deployed
/// LLM configs, per-request adaptation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccuracyClass {
    /// Nearly lossless generation (e.g. KV8 or a high-bits tuned config).
    High,
    /// Tuned trade-off (the KVTuner-C* config).
    Balanced,
    /// Maximum throughput; accuracy best-effort.
    Efficient,
}

impl AccuracyClass {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "high" => AccuracyClass::High,
            "balanced" => AccuracyClass::Balanced,
            "efficient" => AccuracyClass::Efficient,
            _ => anyhow::bail!("unknown accuracy class {s:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AccuracyClass::High => "high",
            AccuracyClass::Balanced => "balanced",
            AccuracyClass::Efficient => "efficient",
        }
    }
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub class: AccuracyClass,
    pub arrival: Instant,
    /// `Some` = the scheduler abandons this request (typed
    /// `DeadlineExceeded`, tokens-so-far delivered) once the deadline
    /// passes — checked at admission, prefill-chunk, and decode-tick
    /// boundaries. `None` = run to completion.
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (prefill latency).
    pub ttft: Duration,
    /// Total request latency.
    pub total: Duration,
    pub engine: String,
    /// `Some` = the request failed or completed degraded; the kind is the
    /// machine-readable taxonomy, `tokens` still carries whatever was
    /// generated before the failure.
    pub error: Option<Failure>,
    /// Final-step logits for the request's slot, captured only when the
    /// scheduler runs with `capture_logits` (the differential-churn harness
    /// compares them bit-for-bit across scheduler arms). `None` in normal
    /// serving — no per-request vocab-sized copy on the hot path.
    pub final_logits: Option<Vec<f32>>,
}

/// Client-side handle: submit and wait.
pub struct Submission {
    pub id: u64,
    pub rx: mpsc::Receiver<Response>,
}

impl Submission {
    /// A synthesized response for submissions whose worker disappeared or
    /// whose wait expired: no channel hang ever reaches the client — every
    /// outcome is a `Response`, failures typed through `error`.
    pub(crate) fn failed(id: u64, kind: FailureKind, detail: &str) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            ttft: Duration::ZERO,
            total: Duration::ZERO,
            engine: String::new(),
            error: Some(Failure::new(kind, detail)),
            final_logits: None,
        }
    }

    /// Block until the response arrives. A dropped response channel (the
    /// worker died with the request still queued, past the router's
    /// redispatch window) comes back as a typed `WorkerDied` failure
    /// instead of a channel error.
    pub fn wait(self) -> anyhow::Result<Response> {
        Ok(self.rx.recv().unwrap_or_else(|_| {
            Submission::failed(
                self.id,
                FailureKind::WorkerDied,
                "response channel closed before a response arrived",
            )
        }))
    }

    /// Block at most `d`. An expired wait is a typed `Timeout` failure; a
    /// dropped channel is a typed `WorkerDied` failure — the caller always
    /// gets a `Response`.
    pub fn wait_timeout(self, d: Duration) -> anyhow::Result<Response> {
        use mpsc::RecvTimeoutError;
        Ok(match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Submission::failed(
                self.id,
                FailureKind::Timeout,
                &format!("no response within {:.3}s", d.as_secs_f64()),
            ),
            Err(RecvTimeoutError::Disconnected) => Submission::failed(
                self.id,
                FailureKind::WorkerDied,
                "response channel closed before a response arrived",
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite regression: the expired arm of `wait_timeout` — previously
    /// unexercised — must come back as a typed `Timeout` failure, not a
    /// channel error.
    #[test]
    fn wait_timeout_expired_path_is_a_typed_timeout() {
        let (tx, rx) = mpsc::channel::<Response>();
        let sub = Submission { id: 7, rx };
        let r = sub.wait_timeout(Duration::from_millis(5)).unwrap();
        assert_eq!(r.id, 7);
        let f = r.error.expect("expired wait must carry a typed failure");
        assert_eq!(f.kind, FailureKind::Timeout);
        assert!(r.tokens.is_empty());
        drop(tx);
    }

    #[test]
    fn wait_on_a_dropped_channel_is_a_typed_worker_death() {
        let (tx, rx) = mpsc::channel::<Response>();
        drop(tx);
        let r = Submission { id: 3, rx }.wait().unwrap();
        assert_eq!(r.error.unwrap().kind, FailureKind::WorkerDied);
        let (tx2, rx2) = mpsc::channel::<Response>();
        drop(tx2);
        let r2 = Submission { id: 4, rx: rx2 }
            .wait_timeout(Duration::from_secs(1))
            .unwrap();
        assert_eq!(r2.error.unwrap().kind, FailureKind::WorkerDied);
    }
}
