//! Request/response types flowing between clients, the router, and the
//! engine workers. Plain data + channels: PJRT objects are thread-pinned
//! (no Send), so engines never cross threads — requests do.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Client-declared accuracy requirement: the router maps this to an engine
/// whose tuned config meets it (paper Sec. 1 issue 3 — multiple deployed
/// LLM configs, per-request adaptation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccuracyClass {
    /// Nearly lossless generation (e.g. KV8 or a high-bits tuned config).
    High,
    /// Tuned trade-off (the KVTuner-C* config).
    Balanced,
    /// Maximum throughput; accuracy best-effort.
    Efficient,
}

impl AccuracyClass {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "high" => AccuracyClass::High,
            "balanced" => AccuracyClass::Balanced,
            "efficient" => AccuracyClass::Efficient,
            _ => anyhow::bail!("unknown accuracy class {s:?}"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AccuracyClass::High => "high",
            AccuracyClass::Balanced => "balanced",
            AccuracyClass::Efficient => "efficient",
        }
    }
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub class: AccuracyClass,
    pub arrival: Instant,
    pub respond: mpsc::Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (prefill latency).
    pub ttft: Duration,
    /// Total request latency.
    pub total: Duration,
    pub engine: String,
    pub error: Option<String>,
    /// Final-step logits for the request's slot, captured only when the
    /// scheduler runs with `capture_logits` (the differential-churn harness
    /// compares them bit-for-bit across scheduler arms). `None` in normal
    /// serving — no per-request vocab-sized copy on the hot path.
    pub final_logits: Option<Vec<f32>>,
}

/// Client-side handle: submit and wait.
pub struct Submission {
    pub id: u64,
    pub rx: mpsc::Receiver<Response>,
}

impl Submission {
    pub fn wait(self) -> anyhow::Result<Response> {
        Ok(self.rx.recv()?)
    }

    pub fn wait_timeout(self, d: Duration) -> anyhow::Result<Response> {
        Ok(self.rx.recv_timeout(d)?)
    }
}
