//! L3 serving coordinator: request types, admission batcher, continuous-
//! batching scheduler, multi-engine router, and metrics.

pub mod batcher;
pub mod failure;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use batcher::{Batcher, BatcherOptions};
pub use failure::{Failure, FailureKind};
pub use metrics::{Metrics, Snapshot};
pub use request::{AccuracyClass, Request, Response, Submission};
pub use router::{EngineReport, Router, WorkerSpec};
pub use scheduler::{
    choose_preempt_action, victim_score, PreemptAction, Scheduler, SchedulerOptions,
};
