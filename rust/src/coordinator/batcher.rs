//! Admission queue / dynamic batcher: requests wait here until the
//! continuous-batching scheduler has free slots. Policy: admit immediately
//! when slots are free; cap per-admission burst so prefill doesn't starve
//! decode (prefill/decode interleaving, the Orca/vLLM scheduling shape).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Debug, Clone)]
pub struct BatcherOptions {
    /// Max requests admitted per scheduling tick (prefill burst cap).
    pub max_admit_per_tick: usize,
    /// Queue capacity; beyond this, submissions are rejected (backpressure).
    pub max_queue: usize,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        BatcherOptions { max_admit_per_tick: 2, max_queue: 1024 }
    }
}

pub struct Batcher {
    queue: VecDeque<Request>,
    pub opts: BatcherOptions,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(opts: BatcherOptions) -> Batcher {
        Batcher { queue: VecDeque::new(), opts, rejected: 0 }
    }

    /// Enqueue; returns false (and drops the request) when full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.opts.max_queue {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Head of the queue, for admission checks that must not skip ahead
    /// (FIFO fairness: the scheduler blocks on the head rather than starving
    /// large requests; the `max_admit_per_tick` burst cap is applied there).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Return a request to the head of the queue (admission raced the page
    /// pool and must retry; not counted against capacity).
    pub fn push_front(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest waiting request.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| now.duration_since(r.arrival))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep rx alive via leak to avoid send errors in tests that respond
        std::mem::forget(_rx);
        Request {
            id,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            class: super::super::request::AccuracyClass::Balanced,
            arrival: Instant::now(),
            deadline: None,
            respond: tx,
        }
    }

    #[test]
    fn fifo_peek_pop_and_requeue() {
        let mut b = Batcher::new(BatcherOptions { max_admit_per_tick: 2, max_queue: 10 });
        for i in 0..3 {
            assert!(b.push(req(i)));
        }
        assert_eq!(b.peek().unwrap().id, 0);
        assert_eq!(b.pop().unwrap().id, 0);
        assert_eq!(b.pop().unwrap().id, 1);
        // a requeued request (admission raced the page pool) goes back first
        b.push_front(req(9));
        assert_eq!(b.peek().unwrap().id, 9);
        assert_eq!(b.pop().unwrap().id, 9);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatcherOptions { max_admit_per_tick: 2, max_queue: 2 });
        assert!(b.push(req(0)));
        assert!(b.push(req(1)));
        assert!(!b.push(req(2)));
        assert_eq!(b.rejected, 1);
    }
}
