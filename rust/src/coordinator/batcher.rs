//! Admission queue / dynamic batcher: requests wait here until the
//! continuous-batching scheduler has free slots. Policy: admit immediately
//! when slots are free; cap per-admission burst so prefill doesn't starve
//! decode (prefill/decode interleaving, the Orca/vLLM scheduling shape).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

#[derive(Debug, Clone)]
pub struct BatcherOptions {
    /// Max requests admitted per scheduling tick (prefill burst cap).
    pub max_admit_per_tick: usize,
    /// Queue capacity; beyond this, submissions are rejected (backpressure).
    pub max_queue: usize,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        BatcherOptions { max_admit_per_tick: 2, max_queue: 1024 }
    }
}

pub struct Batcher {
    queue: VecDeque<Request>,
    pub opts: BatcherOptions,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(opts: BatcherOptions) -> Batcher {
        Batcher { queue: VecDeque::new(), opts, rejected: 0 }
    }

    /// Enqueue; returns false (and drops the request) when full.
    pub fn push(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.opts.max_queue {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Admit up to `free_slots` requests (bounded by the burst cap), FIFO.
    pub fn admit(&mut self, free_slots: usize) -> Vec<Request> {
        let n = free_slots.min(self.opts.max_admit_per_tick).min(self.queue.len());
        self.queue.drain(..n).collect()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest waiting request.
    pub fn oldest_wait(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|r| now.duration_since(r.arrival))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep rx alive via leak to avoid send errors in tests that respond
        std::mem::forget(_rx);
        Request {
            id,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            class: super::super::request::AccuracyClass::Balanced,
            arrival: Instant::now(),
            respond: tx,
        }
    }

    #[test]
    fn fifo_admission_with_burst_cap() {
        let mut b = Batcher::new(BatcherOptions { max_admit_per_tick: 2, max_queue: 10 });
        for i in 0..5 {
            assert!(b.push(req(i)));
        }
        let a = b.admit(4);
        assert_eq!(a.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let a = b.admit(1);
        assert_eq!(a[0].id, 2);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn backpressure_rejects() {
        let mut b = Batcher::new(BatcherOptions { max_admit_per_tick: 2, max_queue: 2 });
        assert!(b.push(req(0)));
        assert!(b.push(req(1)));
        assert!(!b.push(req(2)));
        assert_eq!(b.rejected, 1);
    }
}
