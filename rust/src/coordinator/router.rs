//! Multi-engine router: each engine worker runs on its own thread with its
//! own PJRT client and precision config; the router maps a request's
//! accuracy class to a matching worker and load-balances within the class.
//! This is the paper's deployment story — several configs of the same model
//! served side by side, per-request precision selection at zero decode cost.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{LayerSpec, ModelConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
#[cfg(feature = "xla")]
use crate::engine::Engine;
use crate::engine::{BackendKind, EngineCore, NativeEngine};
use crate::faults::{FaultInjector, FaultPlan};
use crate::kvcache::PagedOptions;
use crate::obs::{
    Counters, EventKind, ProbeConfig, ProfileSnapshot, SensitivityShared, SensitivitySnapshot,
    TraceSink, Tracer,
};
#[cfg(feature = "xla")]
use crate::runtime::Runtime;

use super::failure::{Failure, FailureKind};
use super::metrics::Snapshot;
use super::request::{AccuracyClass, Request, Submission};

/// Spec for one engine worker.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub name: String,
    pub model: String,
    pub specs: Vec<LayerSpec>,
    pub class: AccuracyClass,
    pub batch: usize,
    pub s_max: usize,
    pub prefill_chunk: usize,
    /// `Some` = run on the paged cache arm with this pool sizing; the
    /// scheduler then admits by page availability and preempts on pressure.
    pub paged: Option<PagedOptions>,
    /// Which engine implementation backs this worker: `Xla` (PJRT
    /// executables, needs artifacts + the XLA extension) or `Native`
    /// (in-process kernels, zero artifacts).
    pub backend: BackendKind,
    /// Kernel-pool width for the native backend (each worker sizes its own
    /// pool; 1 = the scalar engine, bit-identical to any other value). The
    /// XLA backend ignores it — PJRT manages its own execution.
    pub threads: usize,
    /// Shared lifecycle tracer (`--trace-out`). Each worker's scheduler
    /// emits through a `TraceSink` carrying the worker's index as the Chrome
    /// trace `pid`. `None` = no tracing, no overhead.
    pub trace: Option<Arc<Tracer>>,
    /// Enable the engine's per-layer/per-phase profiler (`--profile-serve`).
    pub profile: bool,
    /// `Some(cfg)` = arm the engine's online sensitivity probe
    /// (`--probe-every`); the worker publishes the probe's live accumulator
    /// for mid-run streaming. `None` = no probe, no overhead.
    pub probe: Option<ProbeConfig>,
    /// `Some(cfg)` = build the engine on synthetic weights for `cfg`
    /// instead of loading a model from the artifact dir (native backend
    /// only — smoke tests and CI runs that have no artifacts).
    pub synthetic: Option<ModelConfig>,
    /// `Some` = this worker's counter-track registry (`--metrics-listen`,
    /// `--trace-out`): the scheduler publishes memory-hierarchy occupancy
    /// per tick and the engine per-layer live-KV bytes into it. One
    /// registry per worker; `None` = no tracks, no overhead.
    pub counters: Option<Arc<Counters>>,
    /// `Some` = arm this worker's seeded fault injector (`--fault-plan`).
    /// The injector is salted with the worker index, so one plan drives a
    /// distinct deterministic fault stream per worker. `None` = faults
    /// compiled in but unarmed — a single never-taken branch per injection
    /// point.
    pub faults: Option<FaultPlan>,
    /// Capture each request's final-step logits into its `Response`
    /// (differential harnesses only; a per-request vocab-sized copy).
    pub capture_logits: bool,
}

impl Default for WorkerSpec {
    fn default() -> WorkerSpec {
        WorkerSpec {
            name: String::new(),
            model: String::new(),
            specs: Vec::new(),
            class: AccuracyClass::Balanced,
            batch: 1,
            s_max: 64,
            prefill_chunk: 16,
            paged: None,
            backend: BackendKind::default(),
            threads: 1,
            trace: None,
            profile: false,
            probe: None,
            synthetic: None,
            counters: None,
            faults: None,
            capture_logits: false,
        }
    }
}

/// Construct the worker's engine per its backend kind. Runs on the worker
/// thread (PJRT objects never cross threads; the native engine does not
/// care).
fn build_worker_engine(dir: &std::path::Path, ws: &WorkerSpec) -> Result<Box<dyn EngineCore>> {
    let mut engine: Box<dyn EngineCore> = match ws.backend {
        BackendKind::Native => {
            let (cfg, weights) = match &ws.synthetic {
                Some(cfg) => (cfg.clone(), crate::model::Weights::synthetic(cfg, 7)),
                None => {
                    let manifest = crate::config::Manifest::load(dir)?;
                    let weights = crate::model::Weights::load(&manifest, &ws.model)?;
                    (manifest.config, weights)
                }
            };
            Box::new(NativeEngine::new(
                &cfg,
                weights,
                ws.specs.clone(),
                ws.batch,
                ws.s_max,
                ws.prefill_chunk,
                ws.threads,
                ws.paged.clone(),
            )?)
        }
        #[cfg(feature = "xla")]
        BackendKind::Xla => {
            anyhow::ensure!(
                ws.synthetic.is_none(),
                "worker {}: synthetic weights need the native backend (the \
                 XLA backend serves only AOT artifacts)",
                ws.name
            );
            let rt = Arc::new(Runtime::load(dir)?);
            let eng = match ws.paged.clone() {
                None => Engine::new(
                    rt,
                    &ws.model,
                    ws.specs.clone(),
                    ws.batch,
                    ws.s_max,
                    ws.prefill_chunk,
                )?,
                Some(opts) => Engine::new_paged(
                    rt,
                    &ws.model,
                    ws.specs.clone(),
                    ws.batch,
                    ws.s_max,
                    ws.prefill_chunk,
                    opts,
                )?,
            };
            Box::new(eng)
        }
        #[cfg(not(feature = "xla"))]
        BackendKind::Xla => anyhow::bail!(
            "worker {}: this build has no XLA backend (compiled without the \
             `xla` feature); use the native backend",
            ws.name
        ),
    };
    if ws.profile {
        engine.set_profiling(true);
    }
    if let Some(p) = &ws.probe {
        engine.set_probe(p.clone());
    }
    if let Some(c) = &ws.counters {
        engine.set_counters(c);
    }
    Ok(engine)
}

/// One worker's routing-relevant state, shared (via [`Fleet`]) with every
/// worker thread so a dying worker can redispatch its orphans without going
/// back through the `Router` (which the caller owns).
struct FleetWorker {
    name: String,
    class: AccuracyClass,
    tx: Sender<Request>,
    /// Cleared when the worker's thread dies (caught panic) or its request
    /// channel is found closed; a dead worker is never routed to again.
    alive: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

/// The shared worker registry: built before any worker thread spawns, held
/// by the router and by every worker thread. `mpsc::Sender` is `Sync`, so
/// cloning senders into one shared table is sound.
struct Fleet {
    workers: Vec<FleetWorker>,
}

impl Fleet {
    /// Re-send an orphaned request to a live worker, preferring the
    /// request's accuracy class (mirroring `Router::submit`) and never the
    /// dead worker `skip`. Returns the surviving worker's index, or the
    /// request back when no live worker can take it.
    fn redispatch(&self, skip: usize, mut req: Request) -> std::result::Result<usize, Request> {
        for same_class_only in [true, false] {
            loop {
                let target = self
                    .workers
                    .iter()
                    .enumerate()
                    .filter(|(i, w)| {
                        *i != skip
                            && w.alive.load(Ordering::Relaxed)
                            && (!same_class_only || w.class == req.class)
                    })
                    .min_by_key(|(_, w)| w.inflight.load(Ordering::Relaxed));
                let Some((ti, target)) = target else { break };
                match target.tx.send(req) {
                    Ok(()) => return Ok(ti),
                    Err(e) => {
                        // sibling's receiver is gone too: mark it dead and
                        // keep looking with the request we got back
                        eprintln!(
                            "worker {}: unreachable during redispatch; marking dead",
                            target.name
                        );
                        target.alive.store(false, Ordering::Relaxed);
                        req = e.0;
                    }
                }
            }
        }
        Err(req)
    }
}

pub struct WorkerHandle {
    pub spec: WorkerSpec,
    pub tx: Sender<Request>,
    /// `false` once the worker's thread has died; the router stops routing
    /// to it and `shutdown()` tolerates its join.
    pub alive: Arc<AtomicBool>,
    pub inflight: Arc<AtomicUsize>,
    pub metrics: Arc<Metrics>,
    /// The engine's final per-layer profile, captured by the worker thread
    /// right before it exits (`None` until shutdown, or when profiling was
    /// off).
    pub profile: Arc<Mutex<Option<ProfileSnapshot>>>,
    /// The probe's live accumulator table, published by the worker thread
    /// right after the engine builds (`None` until then, or when no probe is
    /// armed). Streaming readers snapshot it mid-run without stopping the
    /// serving loop.
    pub sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>>,
    pub join: JoinHandle<Result<()>>,
}

/// One worker's mid-run observables, handed to streaming readers (the
/// `--metrics-interval` JSONL loop, the `/metrics` scrape endpoint): its
/// metrics atomics, the probe's live accumulator slot, and its counter-track
/// registry. All snapshot-safe while the worker serves.
#[derive(Clone)]
pub struct WorkerObserver {
    pub name: String,
    pub metrics: Arc<Metrics>,
    pub sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>>,
    pub counters: Option<Arc<Counters>>,
}

/// Everything one worker reports at shutdown: its serving metrics snapshot
/// plus (when `--profile-serve` was on) the engine's per-layer profile.
#[derive(Debug, Clone)]
pub struct EngineReport {
    pub name: String,
    pub snapshot: Snapshot,
    pub profile: Option<ProfileSnapshot>,
    /// Final sensitivity snapshot (`--probe-every`); `None` when no probe
    /// was armed.
    pub sensitivity: Option<SensitivitySnapshot>,
}

pub struct Router {
    pub workers: Vec<WorkerHandle>,
    pub shutdown: Arc<AtomicBool>,
    next_id: AtomicU64,
}

impl Router {
    /// Spawn one thread per worker; each constructs its own Runtime + Engine
    /// (PJRT objects never cross threads). Every thread holds the shared
    /// [`Fleet`] registry so a caught panic can redispatch in-flight work to
    /// surviving siblings.
    pub fn start(artifact_dir: std::path::PathBuf, specs: Vec<WorkerSpec>) -> Result<Router> {
        let shutdown = Arc::new(AtomicBool::new(false));
        // Pass 1: channels + liveness state, so the full fleet registry
        // exists before any worker thread spawns (a worker's panic path may
        // need siblings that start after it).
        let mut rxs = Vec::with_capacity(specs.len());
        let mut fleet_workers = Vec::with_capacity(specs.len());
        for ws in &specs {
            let (tx, rx) = mpsc::channel::<Request>();
            rxs.push(rx);
            fleet_workers.push(FleetWorker {
                name: ws.name.clone(),
                class: ws.class,
                tx,
                alive: Arc::new(AtomicBool::new(true)),
                inflight: Arc::new(AtomicUsize::new(0)),
            });
        }
        let fleet = Arc::new(Fleet { workers: fleet_workers });

        // Pass 2: spawn, with a readiness handshake so start() fails fast
        // on bad configs.
        let mut workers = Vec::new();
        for (wi, (wspec, rx)) in specs.into_iter().zip(rxs).enumerate() {
            let tx = fleet.workers[wi].tx.clone();
            let alive = fleet.workers[wi].alive.clone();
            let inflight = fleet.workers[wi].inflight.clone();
            let metrics = Arc::new(Metrics::default());
            let profile: Arc<Mutex<Option<ProfileSnapshot>>> = Arc::new(Mutex::new(None));
            let sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>> =
                Arc::new(Mutex::new(None));
            let dir = artifact_dir.clone();
            let ws = wspec.clone();
            let sd = shutdown.clone();
            let inf = inflight.clone();
            let alv = alive.clone();
            let met = metrics.clone();
            let prof = profile.clone();
            let sens = sensitivity.clone();
            let flt = fleet.clone();
            // engine readiness signal so start() fails fast on bad configs
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let join = std::thread::Builder::new()
                .name(format!("engine-{}", ws.name))
                .spawn(move || -> Result<()> {
                    let engine = match build_worker_engine(&dir, &ws) {
                        Ok(e) => e,
                        Err(e) => {
                            alv.store(false, Ordering::Relaxed);
                            let _ = ready_tx.send(Err(e));
                            return Ok(());
                        }
                    };
                    let _ = ready_tx.send(Ok(()));
                    // publish the probe's accumulator so streaming readers
                    // can snapshot it while the serving loop runs
                    *sens.lock().unwrap_or_else(|e| e.into_inner()) =
                        engine.sensitivity_shared();
                    let sink = ws
                        .trace
                        .as_ref()
                        .map(|t| TraceSink { tracer: t.clone(), worker: wi as u32 });
                    // the swap policy rides inside the paged options so
                    // WorkerSpec stays one struct per engine arm
                    let opts = SchedulerOptions {
                        swap_policy: ws
                            .paged
                            .as_ref()
                            .map(|p| p.swap_policy)
                            .unwrap_or_default(),
                        trace: sink.clone(),
                        counters: ws.counters.clone(),
                        capture_logits: ws.capture_logits,
                        // salt by worker index: one plan, a distinct
                        // deterministic fault stream per worker
                        faults: ws.faults.as_ref().map(|p| FaultInjector::new(p, wi as u64)),
                        ..SchedulerOptions::default()
                    };
                    let mut sched = Scheduler::new(engine, &ws.name, opts, met.clone());
                    // Failure domain: a panic inside the serving loop (an
                    // injected worker death, or a real engine bug) is caught
                    // here and confined to this worker. Injected panics fire
                    // at the tick boundary, where every request lives inside
                    // the scheduler — none is lost on the unwound stack.
                    let out = catch_unwind(AssertUnwindSafe(|| sched.run(&rx, sd, inf.clone())));
                    match out {
                        Ok(result) => {
                            // capture the engine's profile before it is
                            // dropped so shutdown() can report it
                            *prof.lock().unwrap_or_else(|e| e.into_inner()) =
                                sched.engine.profile();
                            result
                        }
                        Err(_) => {
                            alv.store(false, Ordering::Relaxed);
                            // strip every request out of the dead scheduler
                            // and out of the channel behind it
                            let mut orphans = sched.evacuate();
                            while let Ok(r) = rx.try_recv() {
                                orphans.push(r);
                            }
                            if let Some(s) = &sink {
                                s.instant(EventKind::WorkerDeath, 0, 0, orphans.len() as u64);
                            }
                            eprintln!(
                                "worker {}: died mid-serve; redispatching {} orphaned \
                                 request(s)",
                                ws.name,
                                orphans.len()
                            );
                            for r in orphans {
                                let id = r.id;
                                match flt.redispatch(wi, r) {
                                    Ok(ti) => {
                                        if let Some(s) = &sink {
                                            s.instant(EventKind::Redispatch, id, 0, ti as u64);
                                        }
                                    }
                                    Err(r) => {
                                        met.record_failure(FailureKind::WorkerDied);
                                        let _ = r.respond.send(Submission::failed(
                                            id,
                                            FailureKind::WorkerDied,
                                            &format!(
                                                "worker {} died with no live sibling to \
                                                 take over",
                                                ws.name
                                            ),
                                        ));
                                    }
                                }
                            }
                            inf.store(0, Ordering::Relaxed);
                            // the panic is handled: join cleanly so one dead
                            // worker cannot poison Router::shutdown()
                            Ok(())
                        }
                    }
                })
                .context("spawning engine worker")?;
            ready_rx
                .recv()
                .context("worker died before ready")?
                .with_context(|| format!("starting worker {}", wspec.name))?;
            workers.push(WorkerHandle {
                spec: wspec,
                tx,
                alive,
                inflight,
                metrics,
                profile,
                sensitivity,
                join,
            });
        }
        Ok(Router { workers, shutdown, next_id: AtomicU64::new(1) })
    }

    /// Route by accuracy class, least-loaded within the class; fall back to
    /// any live worker when no engine advertises the class.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        class: AccuracyClass,
    ) -> Result<Submission> {
        self.submit_with_deadline(prompt, max_new_tokens, class, None)
    }

    /// [`Router::submit`] with a per-request deadline: the scheduler abandons
    /// the request (typed `DeadlineExceeded`, tokens-so-far delivered) once
    /// `deadline` passes.
    ///
    /// Routing never panics: dead workers are filtered out up front, a
    /// worker found dead at send time is marked and the next candidate
    /// tried, and exhausting every candidate is a typed `Unroutable` error
    /// — the old code `min_by_key(...).unwrap()`'d over an unfiltered
    /// candidate list and trusted `send` to a single pick.
    pub fn submit_with_deadline(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        class: AccuracyClass,
        deadline: Option<Instant>,
    ) -> Result<Submission> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let mut req = Request {
            id,
            prompt,
            max_new_tokens,
            class,
            arrival: Instant::now(),
            deadline,
            respond: tx,
        };
        for same_class_only in [true, false] {
            loop {
                let target = self
                    .workers
                    .iter()
                    .filter(|w| {
                        w.alive.load(Ordering::Relaxed)
                            && (!same_class_only || w.spec.class == class)
                    })
                    .min_by_key(|w| w.inflight.load(Ordering::Relaxed));
                let Some(w) = target else { break };
                match w.tx.send(req) {
                    Ok(()) => return Ok(Submission { id, rx }),
                    Err(e) => {
                        w.alive.store(false, Ordering::Relaxed);
                        req = e.0;
                    }
                }
            }
        }
        Err(anyhow::Error::new(Failure::new(
            FailureKind::Unroutable,
            "no live engine worker can accept this request",
        )))
    }

    /// Wait up to `timeout` for every live worker's in-flight count to reach
    /// zero. Returns `true` when the fleet drained, `false` on timeout —
    /// either way the router is still usable; callers decide whether to
    /// proceed to `shutdown()`.
    pub fn drain(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        loop {
            let pending: usize = self
                .workers
                .iter()
                .filter(|w| w.alive.load(Ordering::Relaxed))
                .map(|w| w.inflight.load(Ordering::Relaxed))
                .sum();
            if pending == 0 {
                return true;
            }
            if start.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Per-worker observables for mid-run streaming readers. All fields are
    /// snapshot-safe from any thread while the workers serve.
    pub fn observers(&self) -> Vec<WorkerObserver> {
        self.workers
            .iter()
            .map(|w| WorkerObserver {
                name: w.spec.name.clone(),
                metrics: w.metrics.clone(),
                sensitivity: w.sensitivity.clone(),
                counters: w.spec.counters.clone(),
            })
            .collect()
    }

    /// Graceful shutdown: signal, then join all workers. Each worker's final
    /// metrics snapshot (and profile + sensitivity, when enabled) comes back
    /// in an `EngineReport` — including dead workers', whose metrics atomics
    /// outlive their threads. A failed join is reported on stderr, never
    /// propagated: one dead worker cannot poison the whole fleet's report.
    pub fn shutdown(self) -> Result<Vec<EngineReport>> {
        self.shutdown.store(true, Ordering::Relaxed);
        let mut out = Vec::new();
        for w in self.workers {
            drop(w.tx);
            match w.join.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    eprintln!("worker {}: exited with error: {e:#}", w.spec.name)
                }
                Err(_) => {
                    // a panic that escaped the serving loop's failure domain
                    // (e.g. during engine construction teardown)
                    eprintln!("worker {}: panicked outside the failure domain", w.spec.name)
                }
            }
            let snapshot = w.metrics.snapshot();
            let profile = w.profile.lock().unwrap_or_else(|e| e.into_inner()).take();
            let sensitivity = w
                .sensitivity
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .as_ref()
                .map(|s| s.snapshot());
            out.push(EngineReport { name: w.spec.name, snapshot, profile, sensitivity });
        }
        Ok(out)
    }
}
