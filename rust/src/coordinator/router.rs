//! Multi-engine router: each engine worker runs on its own thread with its
//! own PJRT client and precision config; the router maps a request's
//! accuracy class to a matching worker and load-balances within the class.
//! This is the paper's deployment story — several configs of the same model
//! served side by side, per-request precision selection at zero decode cost.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{LayerSpec, ModelConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};
#[cfg(feature = "xla")]
use crate::engine::Engine;
use crate::engine::{BackendKind, EngineCore, NativeEngine};
use crate::kvcache::PagedOptions;
use crate::obs::{
    Counters, ProbeConfig, ProfileSnapshot, SensitivityShared, SensitivitySnapshot, TraceSink,
    Tracer,
};
#[cfg(feature = "xla")]
use crate::runtime::Runtime;

use super::metrics::Snapshot;
use super::request::{AccuracyClass, Request, Submission};

/// Spec for one engine worker.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    pub name: String,
    pub model: String,
    pub specs: Vec<LayerSpec>,
    pub class: AccuracyClass,
    pub batch: usize,
    pub s_max: usize,
    pub prefill_chunk: usize,
    /// `Some` = run on the paged cache arm with this pool sizing; the
    /// scheduler then admits by page availability and preempts on pressure.
    pub paged: Option<PagedOptions>,
    /// Which engine implementation backs this worker: `Xla` (PJRT
    /// executables, needs artifacts + the XLA extension) or `Native`
    /// (in-process kernels, zero artifacts).
    pub backend: BackendKind,
    /// Kernel-pool width for the native backend (each worker sizes its own
    /// pool; 1 = the scalar engine, bit-identical to any other value). The
    /// XLA backend ignores it — PJRT manages its own execution.
    pub threads: usize,
    /// Shared lifecycle tracer (`--trace-out`). Each worker's scheduler
    /// emits through a `TraceSink` carrying the worker's index as the Chrome
    /// trace `pid`. `None` = no tracing, no overhead.
    pub trace: Option<Arc<Tracer>>,
    /// Enable the engine's per-layer/per-phase profiler (`--profile-serve`).
    pub profile: bool,
    /// `Some(cfg)` = arm the engine's online sensitivity probe
    /// (`--probe-every`); the worker publishes the probe's live accumulator
    /// for mid-run streaming. `None` = no probe, no overhead.
    pub probe: Option<ProbeConfig>,
    /// `Some(cfg)` = build the engine on synthetic weights for `cfg`
    /// instead of loading a model from the artifact dir (native backend
    /// only — smoke tests and CI runs that have no artifacts).
    pub synthetic: Option<ModelConfig>,
    /// `Some` = this worker's counter-track registry (`--metrics-listen`,
    /// `--trace-out`): the scheduler publishes memory-hierarchy occupancy
    /// per tick and the engine per-layer live-KV bytes into it. One
    /// registry per worker; `None` = no tracks, no overhead.
    pub counters: Option<Arc<Counters>>,
}

impl Default for WorkerSpec {
    fn default() -> WorkerSpec {
        WorkerSpec {
            name: String::new(),
            model: String::new(),
            specs: Vec::new(),
            class: AccuracyClass::Balanced,
            batch: 1,
            s_max: 64,
            prefill_chunk: 16,
            paged: None,
            backend: BackendKind::default(),
            threads: 1,
            trace: None,
            profile: false,
            probe: None,
            synthetic: None,
            counters: None,
        }
    }
}

/// Construct the worker's engine per its backend kind. Runs on the worker
/// thread (PJRT objects never cross threads; the native engine does not
/// care).
fn build_worker_engine(dir: &std::path::Path, ws: &WorkerSpec) -> Result<Box<dyn EngineCore>> {
    let mut engine: Box<dyn EngineCore> = match ws.backend {
        BackendKind::Native => {
            let (cfg, weights) = match &ws.synthetic {
                Some(cfg) => (cfg.clone(), crate::model::Weights::synthetic(cfg, 7)),
                None => {
                    let manifest = crate::config::Manifest::load(dir)?;
                    let weights = crate::model::Weights::load(&manifest, &ws.model)?;
                    (manifest.config, weights)
                }
            };
            Box::new(NativeEngine::new(
                &cfg,
                weights,
                ws.specs.clone(),
                ws.batch,
                ws.s_max,
                ws.prefill_chunk,
                ws.threads,
                ws.paged.clone(),
            )?)
        }
        #[cfg(feature = "xla")]
        BackendKind::Xla => {
            anyhow::ensure!(
                ws.synthetic.is_none(),
                "worker {}: synthetic weights need the native backend (the \
                 XLA backend serves only AOT artifacts)",
                ws.name
            );
            let rt = Arc::new(Runtime::load(dir)?);
            let eng = match ws.paged.clone() {
                None => Engine::new(
                    rt,
                    &ws.model,
                    ws.specs.clone(),
                    ws.batch,
                    ws.s_max,
                    ws.prefill_chunk,
                )?,
                Some(opts) => Engine::new_paged(
                    rt,
                    &ws.model,
                    ws.specs.clone(),
                    ws.batch,
                    ws.s_max,
                    ws.prefill_chunk,
                    opts,
                )?,
            };
            Box::new(eng)
        }
        #[cfg(not(feature = "xla"))]
        BackendKind::Xla => bail!(
            "worker {}: this build has no XLA backend (compiled without the \
             `xla` feature); use the native backend",
            ws.name
        ),
    };
    if ws.profile {
        engine.set_profiling(true);
    }
    if let Some(p) = &ws.probe {
        engine.set_probe(p.clone());
    }
    if let Some(c) = &ws.counters {
        engine.set_counters(c);
    }
    Ok(engine)
}

pub struct WorkerHandle {
    pub spec: WorkerSpec,
    pub tx: Sender<Request>,
    pub inflight: Arc<AtomicUsize>,
    pub metrics: Arc<Metrics>,
    /// The engine's final per-layer profile, captured by the worker thread
    /// right before it exits (`None` until shutdown, or when profiling was
    /// off).
    pub profile: Arc<Mutex<Option<ProfileSnapshot>>>,
    /// The probe's live accumulator table, published by the worker thread
    /// right after the engine builds (`None` until then, or when no probe is
    /// armed). Streaming readers snapshot it mid-run without stopping the
    /// serving loop.
    pub sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>>,
    pub join: JoinHandle<Result<()>>,
}

/// One worker's mid-run observables, handed to streaming readers (the
/// `--metrics-interval` JSONL loop, the `/metrics` scrape endpoint): its
/// metrics atomics, the probe's live accumulator slot, and its counter-track
/// registry. All snapshot-safe while the worker serves.
#[derive(Clone)]
pub struct WorkerObserver {
    pub name: String,
    pub metrics: Arc<Metrics>,
    pub sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>>,
    pub counters: Option<Arc<Counters>>,
}

/// Everything one worker reports at shutdown: its serving metrics snapshot
/// plus (when `--profile-serve` was on) the engine's per-layer profile.
#[derive(Debug, Clone)]
pub struct EngineReport {
    pub name: String,
    pub snapshot: Snapshot,
    pub profile: Option<ProfileSnapshot>,
    /// Final sensitivity snapshot (`--probe-every`); `None` when no probe
    /// was armed.
    pub sensitivity: Option<SensitivitySnapshot>,
}

pub struct Router {
    pub workers: Vec<WorkerHandle>,
    pub shutdown: Arc<AtomicBool>,
    next_id: AtomicU64,
}

impl Router {
    /// Spawn one thread per worker; each constructs its own Runtime + Engine
    /// (PJRT objects never cross threads).
    pub fn start(artifact_dir: std::path::PathBuf, specs: Vec<WorkerSpec>) -> Result<Router> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::new();
        for (wi, wspec) in specs.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Request>();
            let inflight = Arc::new(AtomicUsize::new(0));
            let metrics = Arc::new(Metrics::default());
            let profile: Arc<Mutex<Option<ProfileSnapshot>>> = Arc::new(Mutex::new(None));
            let sensitivity: Arc<Mutex<Option<Arc<SensitivityShared>>>> =
                Arc::new(Mutex::new(None));
            let dir = artifact_dir.clone();
            let ws = wspec.clone();
            let sd = shutdown.clone();
            let inf = inflight.clone();
            let met = metrics.clone();
            let prof = profile.clone();
            let sens = sensitivity.clone();
            // engine readiness signal so start() fails fast on bad configs
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let join = std::thread::Builder::new()
                .name(format!("engine-{}", ws.name))
                .spawn(move || -> Result<()> {
                    let engine = match build_worker_engine(&dir, &ws) {
                        Ok(e) => e,
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return Ok(());
                        }
                    };
                    let _ = ready_tx.send(Ok(()));
                    // publish the probe's accumulator so streaming readers
                    // can snapshot it while the serving loop runs
                    *sens.lock().unwrap_or_else(|e| e.into_inner()) =
                        engine.sensitivity_shared();
                    // the swap policy rides inside the paged options so
                    // WorkerSpec stays one struct per engine arm
                    let opts = SchedulerOptions {
                        swap_policy: ws
                            .paged
                            .as_ref()
                            .map(|p| p.swap_policy)
                            .unwrap_or_default(),
                        trace: ws
                            .trace
                            .as_ref()
                            .map(|t| TraceSink { tracer: t.clone(), worker: wi as u32 }),
                        counters: ws.counters.clone(),
                        ..SchedulerOptions::default()
                    };
                    let mut sched = Scheduler::new(engine, &ws.name, opts, met);
                    let out = sched.run(rx, sd, inf);
                    // capture the engine's profile before it is dropped so
                    // shutdown() can report it
                    *prof.lock().unwrap_or_else(|e| e.into_inner()) = sched.engine.profile();
                    out
                })
                .context("spawning engine worker")?;
            ready_rx
                .recv()
                .context("worker died before ready")?
                .with_context(|| format!("starting worker {}", wspec.name))?;
            workers.push(WorkerHandle {
                spec: wspec,
                tx,
                inflight,
                metrics,
                profile,
                sensitivity,
                join,
            });
        }
        Ok(Router { workers, shutdown, next_id: AtomicU64::new(1) })
    }

    /// Route by accuracy class, least-loaded within the class; fall back to
    /// any worker when no engine advertises the class.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        class: AccuracyClass,
    ) -> Result<Submission> {
        let candidates: Vec<&WorkerHandle> = {
            let matching: Vec<&WorkerHandle> =
                self.workers.iter().filter(|w| w.spec.class == class).collect();
            if matching.is_empty() {
                self.workers.iter().collect()
            } else {
                matching
            }
        };
        if candidates.is_empty() {
            bail!("no engine workers");
        }
        let w = candidates
            .iter()
            .min_by_key(|w| w.inflight.load(Ordering::Relaxed))
            .unwrap();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        w.tx.send(Request {
            id,
            prompt,
            max_new_tokens,
            class,
            arrival: Instant::now(),
            respond: tx,
        })
        .map_err(|_| anyhow::anyhow!("worker {} is gone", w.spec.name))?;
        Ok(Submission { id, rx })
    }

    /// Per-worker observables for mid-run streaming readers. All fields are
    /// snapshot-safe from any thread while the workers serve.
    pub fn observers(&self) -> Vec<WorkerObserver> {
        self.workers
            .iter()
            .map(|w| WorkerObserver {
                name: w.spec.name.clone(),
                metrics: w.metrics.clone(),
                sensitivity: w.sensitivity.clone(),
                counters: w.spec.counters.clone(),
            })
            .collect()
    }

    /// Graceful shutdown: signal, then join all workers. Each worker's final
    /// metrics snapshot (and profile + sensitivity, when enabled) comes back
    /// in an `EngineReport`.
    pub fn shutdown(self) -> Result<Vec<EngineReport>> {
        self.shutdown.store(true, Ordering::Relaxed);
        let mut out = Vec::new();
        for w in self.workers {
            drop(w.tx);
            w.join.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
            let snapshot = w.metrics.snapshot();
            let profile = w.profile.lock().unwrap_or_else(|e| e.into_inner()).take();
            let sensitivity = w
                .sensitivity
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .as_ref()
                .map(|s| s.snapshot());
            out.push(EngineReport { name: w.spec.name, snapshot, profile, sensitivity });
        }
        Ok(out)
    }
}
