//! KVTuner: sensitivity-aware layer-wise mixed-precision KV cache
//! quantization for LLM serving (ICML 2025 reproduction).
//!
//! Three-layer architecture:
//! - L1/L2 (build-time Python): Pallas kernels + JAX layer graphs, AOT-lowered
//!   to HLO-text artifacts (`python/compile/`).
//! - L3 (this crate): PJRT runtime, mixed-precision KV cache manager, serving
//!   coordinator, and the KVTuner offline calibration pipeline.

pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod faults;
pub mod kernel;
pub mod kvcache;
pub mod model;
pub mod obs;
pub mod quant;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod tensor;
pub mod tuner;
pub mod util;

pub use cli::cli_main;

/// Bench support: measure decode throughput for one precision map (Table 8).
#[cfg(feature = "xla")]
pub fn measure_throughput(
    rt: &std::sync::Arc<runtime::Runtime>,
    model: &str,
    specs: Vec<config::LayerSpec>,
    batch: usize,
    s_max: usize,
    input_len: usize,
    steps: usize,
) -> anyhow::Result<cli::throughput_cmd::ThroughputRow> {
    cli::throughput_cmd::measure(rt, model, specs, batch, s_max, input_len, steps, false, None)
}

/// Bench support: the uniform KIVI settings grid of Table 8.
pub fn cli_settings_grid(
    n_layers: usize,
) -> anyhow::Result<Vec<(String, Vec<config::LayerSpec>)>> {
    cli::throughput_cmd::settings_grid(n_layers, &[])
}

/// A representative KVTuner-style mixed map (K8V4 edges, K4V2 middle) for
/// benches that want a tuned-shaped config without running the search.
pub fn tuned_style_map(n_layers: usize) -> Vec<config::LayerSpec> {
    (0..n_layers)
        .map(|l| config::LayerSpec {
            mode: config::Mode::Kivi,
            pair: if l == 0 || l + 1 == n_layers {
                config::PrecisionPair::new(8, 4)
            } else {
                config::PrecisionPair::new(4, 2)
            },
        })
        .collect()
}

/// Default artifact directory: `$KVTUNER_ARTIFACTS` or `<repo>/artifacts/tiny`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("KVTUNER_ARTIFACTS") {
        return dir.into();
    }
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p.push("tiny");
    p
}
