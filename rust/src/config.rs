//! Model / artifact configuration parsed from `artifacts/<cfg>/manifest.json`
//! (emitted by `python -m compile.aot`), plus the per-layer precision types
//! that are the currency of the whole system.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// KV cache quantization mode for one layer (paper App. C):
/// `Token` = per-token-asym for both K and V; `Kivi` = key per-channel-asym +
/// value per-token-asym with fp residual; `Fp` = the 16-bit reference arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Mode {
    Fp,
    Token,
    Kivi,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Fp => "fp",
            Mode::Token => "token",
            Mode::Kivi => "kivi",
        }
    }

    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "fp" => Mode::Fp,
            "token" | "per-token-asym" => Mode::Token,
            "kivi" | "channel" | "per-channel-asym" => Mode::Kivi,
            _ => bail!("unknown quant mode {s:?}"),
        })
    }
}

/// A layer's KV precision pair, e.g. K8V4. Bits are 2/4/8, or 16 for fp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PrecisionPair {
    pub k_bits: u8,
    pub v_bits: u8,
}

pub const PAIRS: [PrecisionPair; 9] = [
    PrecisionPair { k_bits: 8, v_bits: 8 },
    PrecisionPair { k_bits: 8, v_bits: 4 },
    PrecisionPair { k_bits: 8, v_bits: 2 },
    PrecisionPair { k_bits: 4, v_bits: 8 },
    PrecisionPair { k_bits: 4, v_bits: 4 },
    PrecisionPair { k_bits: 4, v_bits: 2 },
    PrecisionPair { k_bits: 2, v_bits: 8 },
    PrecisionPair { k_bits: 2, v_bits: 4 },
    PrecisionPair { k_bits: 2, v_bits: 2 },
];

impl PrecisionPair {
    pub fn new(k_bits: u8, v_bits: u8) -> Self {
        PrecisionPair { k_bits, v_bits }
    }

    pub const FP: PrecisionPair = PrecisionPair { k_bits: 16, v_bits: 16 };

    /// Mean equivalent bits, the paper's `f_m` numerator contribution.
    pub fn equivalent_bits(&self) -> f64 {
        (self.k_bits as f64 + self.v_bits as f64) / 2.0
    }

    pub fn label(&self) -> String {
        if self.k_bits == self.v_bits {
            format!("KV{}", self.k_bits)
        } else {
            format!("K{}V{}", self.k_bits, self.v_bits)
        }
    }

    /// Parse "K8V4", "KV4", "8:4" etc.
    pub fn parse(s: &str) -> Result<PrecisionPair> {
        let t = s.trim().to_uppercase();
        if let Some((k, v)) = t.split_once(':') {
            return Ok(PrecisionPair::new(k.parse()?, v.parse()?));
        }
        if let Some(rest) = t.strip_prefix("KV") {
            let b: u8 = rest.parse()?;
            return Ok(PrecisionPair::new(b, b));
        }
        if let Some(rest) = t.strip_prefix('K') {
            if let Some((k, v)) = rest.split_once('V') {
                return Ok(PrecisionPair::new(k.parse()?, v.parse()?));
            }
        }
        bail!("cannot parse precision pair {s:?}")
    }
}

/// One layer's complete quantization spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerSpec {
    pub mode: Mode,
    pub pair: PrecisionPair,
}

impl LayerSpec {
    pub fn fp() -> LayerSpec {
        LayerSpec { mode: Mode::Fp, pair: PrecisionPair::FP }
    }

    pub fn uniform(mode: Mode, pair: PrecisionPair, n_layers: usize) -> Vec<LayerSpec> {
        vec![LayerSpec { mode, pair }; n_layers]
    }

    pub fn equivalent_bits(specs: &[LayerSpec]) -> f64 {
        specs.iter().map(|s| s.pair.equivalent_bits()).sum::<f64>() / specs.len() as f64
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f64,
    pub group: usize,
    pub residual: usize,
    pub rms_eps: f64,
}

impl ModelConfig {
    /// A small self-contained config for synthetic-weight runs (`serve
    /// --synthetic`, CI smoke tests): no artifact dir, manifest, or weights
    /// file required — pair with `Weights::synthetic`.
    pub fn synthetic(name: &str) -> ModelConfig {
        ModelConfig {
            name: name.to_string(),
            n_layers: 4,
            d_model: 64,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            d_ff: 128,
            vocab: 256,
            rope_theta: 10000.0,
            group: 32,
            residual: 32,
            rms_eps: 1e-5,
        }
    }

    fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            n_layers: j.get("n_layers")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            group: j.get("group")?.as_usize()?,
            residual: j.get("residual")?.as_usize()?,
            rms_eps: j.get("rms_eps")?.as_f64()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub offset: usize, // in f32 elements
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub weights_file: String,
    pub tensors: BTreeMap<String, TensorEntry>,
    pub outlier_profile: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String, // layer | quant | embed | lmhead
    pub mode: Option<Mode>,
    pub k_bits: u8,
    pub v_bits: u8,
    pub bits: u8,
    pub batch: usize,
    pub t: usize,
    pub s_max: usize,
    pub chunk: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.opt("name").map(|n| n.as_str().unwrap_or("").to_string()).unwrap_or_default(),
                dtype: e.get("dtype")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_shape()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text)?;
        let config = ModelConfig::from_json(j.get("config")?)?;

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let mut tensors = BTreeMap::new();
            for (tn, te) in m.get("tensors")?.as_obj()? {
                tensors.insert(
                    tn.clone(),
                    TensorEntry {
                        offset: te.get("offset")?.as_usize()?,
                        shape: te.get("shape")?.as_shape()?,
                    },
                );
            }
            let outlier_profile = m
                .get("outlier_profile")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelEntry {
                    weights_file: m.get("weights")?.as_str()?.to_string(),
                    tensors,
                    outlier_profile,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts")?.as_arr()? {
            let kind = a.get("kind")?.as_str()?.to_string();
            let meta = ArtifactMeta {
                name: a.get("name")?.as_str()?.to_string(),
                file: a.get("file")?.as_str()?.to_string(),
                mode: match a.opt("mode") {
                    Some(m) => Some(Mode::parse(m.as_str()?)?),
                    None => None,
                },
                k_bits: a.opt("k_bits").map(|x| x.as_i64().unwrap_or(0) as u8).unwrap_or(0),
                v_bits: a.opt("v_bits").map(|x| x.as_i64().unwrap_or(0) as u8).unwrap_or(0),
                bits: a.opt("bits").map(|x| x.as_i64().unwrap_or(0) as u8).unwrap_or(0),
                batch: a.opt("batch").map(|x| x.as_usize().unwrap_or(0)).unwrap_or(0),
                t: a.opt("t").map(|x| x.as_usize().unwrap_or(0)).unwrap_or(0),
                s_max: a.opt("s_max").map(|x| x.as_usize().unwrap_or(0)).unwrap_or(0),
                chunk: a.opt("chunk").map(|x| x.as_usize().unwrap_or(0)).unwrap_or(0),
                inputs: io_specs(a.get("inputs")?)?,
                outputs: io_specs(a.get("outputs")?)?,
                kind,
            };
            artifacts.insert(meta.name.clone(), meta);
        }
        Ok(Manifest { dir, config, models, artifacts })
    }

    /// Artifact name for a layer step.
    pub fn layer_name(mode: Mode, pair: PrecisionPair, b: usize, t: usize, s: usize) -> String {
        match mode {
            Mode::Fp => format!("layer_fp_b{b}_t{t}_s{s}"),
            _ => format!(
                "layer_{}_k{}v{}_b{b}_t{t}_s{s}",
                mode.as_str(),
                pair.k_bits,
                pair.v_bits
            ),
        }
    }

    pub fn quant_name(per_channel: bool, bits: u8, b: usize, chunk: usize) -> String {
        let m = if per_channel { "channel" } else { "token" };
        format!("quant_{m}_{bits}_b{b}_c{chunk}")
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest (re-run make artifacts with matching buckets)"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| format!("model {name:?} not in manifest"))
    }

    /// Batch sizes available for decode (t == 1) layer steps.
    pub fn decode_batches(&self) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.kind == "layer" && a.t == 1)
            .map(|a| a.batch)
            .collect();
        bs.sort_unstable();
        bs.dedup();
        bs
    }

    pub fn prefill_ts(&self) -> Vec<usize> {
        let mut ts: Vec<usize> = self
            .artifacts
            .values()
            .filter(|a| a.kind == "layer" && a.t > 1)
            .map(|a| a.t)
            .collect();
        ts.sort_unstable();
        ts.dedup();
        ts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_labels_and_parse() {
        assert_eq!(PrecisionPair::new(8, 4).label(), "K8V4");
        assert_eq!(PrecisionPair::new(4, 4).label(), "KV4");
        assert_eq!(PrecisionPair::parse("K8V4").unwrap(), PrecisionPair::new(8, 4));
        assert_eq!(PrecisionPair::parse("kv2").unwrap(), PrecisionPair::new(2, 2));
        assert_eq!(PrecisionPair::parse("8:2").unwrap(), PrecisionPair::new(8, 2));
        assert!(PrecisionPair::parse("x").is_err());
    }

    #[test]
    fn equivalent_bits() {
        assert_eq!(PrecisionPair::new(8, 4).equivalent_bits(), 6.0);
        let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 2), 4);
        assert_eq!(LayerSpec::equivalent_bits(&specs), 3.0);
    }

    #[test]
    fn layer_names() {
        assert_eq!(
            Manifest::layer_name(Mode::Kivi, PrecisionPair::new(4, 2), 2, 1, 256),
            "layer_kivi_k4v2_b2_t1_s256"
        );
        assert_eq!(
            Manifest::layer_name(Mode::Fp, PrecisionPair::FP, 1, 32, 256),
            "layer_fp_b1_t32_s256"
        );
    }
}
