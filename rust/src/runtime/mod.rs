//! PJRT runtime: loads HLO-text artifacts, compiles them on the CPU client,
//! and executes them from the L3 hot path. Adapted from
//! /opt/xla-example/src/bin/load_hlo.rs (HLO text interchange — see
//! DESIGN.md and aot.py for why text, not serialized protos).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{ArtifactMeta, Manifest};
use crate::tensor::Tensor;

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    exes: Mutex<HashMap<String, std::sync::Arc<PjRtLoadedExecutable>>>,
    pub compile_stats: Mutex<CompileStats>,
}

#[derive(Debug, Default, Clone)]
pub struct CompileStats {
    pub compiled: usize,
    pub total_secs: f64,
}

impl Runtime {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exes: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(CompileStats::default()),
        })
    }

    /// Compile-on-demand with caching. Compilation happens once per artifact
    /// per process; the serving hot path only ever hits the cache.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.artifact(name)?;
        let path = self.manifest.dir.join(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {name}"))?,
        );
        {
            let mut st = self.compile_stats.lock().unwrap();
            st.compiled += 1;
            st.total_secs += t0.elapsed().as_secs_f64();
        }
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (startup warm-up so the serving path
    /// never compiles).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with host tensors; returns the flattened tuple of
    /// output tensors. (All artifacts are lowered with return_tuple=True.)
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.execute_literals(name, &lits)
    }

    pub fn execute_literals(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Validate that `inputs` match the artifact's manifest input specs
    /// (shape + dtype); used by tests and debug paths, skipped on hot paths.
    pub fn check_inputs(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{}: got {} inputs, expected {}",
            meta.name,
            inputs.len(),
            meta.inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&meta.inputs) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "{}: input {} shape {:?} != {:?}",
                meta.name,
                spec.name,
                t.shape,
                spec.shape
            );
        }
        Ok(())
    }
}
