//! Request lifecycle tracing: a fixed-capacity ring buffer of typed events
//! stamped with request id / worker / slot / monotonic nanoseconds.
//!
//! The scheduler emits events at points where it already holds `Instant`s,
//! so tracing adds one short mutex-protected ring write per event (the
//! scheduler thread is effectively the only writer per worker; the lock is
//! poison-tolerant so one panicking worker cannot cascade). The ring is
//! bounded: under sustained load the oldest events are overwritten and
//! `dropped()` reports how many.
//!
//! Export formats:
//! * Chrome trace-event JSON (`.json`) — loadable in Perfetto /
//!   `chrome://tracing`; one process per worker, one track (tid) per slot,
//!   spans (`ph: "X"`) for prefill chunks and decode steps, instants
//!   (`ph: "i"`) for admissions, preemptions, swaps, resumes, completions.
//! * JSONL (`.jsonl`) — one compact event object per line for ad-hoc
//!   scripting.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};

/// Typed lifecycle event kinds. `arg` in [`TraceEvent`] is kind-specific:
/// tokens for admit/prefill/decode/complete, bytes for swap out/in, pages
/// held for preempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Fresh request admitted into a slot (arg = prompt tokens).
    Admit,
    /// One prefill call for a slot (span; arg = tokens computed).
    PrefillChunk,
    /// One batched decode step, emitted per active slot (span; arg = 1).
    DecodeStep,
    /// Request evicted under page pressure (arg = pages held).
    Preempt { swap: bool },
    /// KV state moved to the host tier (arg = bytes).
    SwapOut,
    /// KV state restored from the host tier (arg = bytes).
    SwapIn,
    /// Preempted request re-entered a slot (arg = re-prefilled tokens; 0
    /// for a swapped resume, which restores state without re-prefill).
    Resume,
    /// Request finished and responded (arg = tokens delivered).
    Complete,
    /// Online quantization error exceeded the calibrated envelope (arg =
    /// cumulative drift-alert count at emission time).
    Drift,
    /// A seeded fault fired (arg = index into
    /// [`crate::faults::FAULT_POINTS`] naming the injection point).
    Fault,
    /// A faulted operation was scheduled for retry (arg = the request's
    /// retry count after this increment).
    Retry,
    /// The request's deadline passed and it was abandoned (arg = tokens
    /// delivered so far).
    DeadlineExceeded,
    /// A worker thread died — panic or engine loss (arg = requests orphaned
    /// on the dead worker, emitted with `req = 0`).
    WorkerDeath,
    /// An orphaned request was re-sent to a surviving worker (arg = the
    /// surviving worker index).
    Redispatch,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::DecodeStep => "decode_step",
            EventKind::Preempt { swap: true } => "preempt_swap",
            EventKind::Preempt { swap: false } => "preempt_recompute",
            EventKind::SwapOut => "swap_out",
            EventKind::SwapIn => "swap_in",
            EventKind::Resume => "resume",
            EventKind::Complete => "complete",
            EventKind::Drift => "drift",
            EventKind::Fault => "fault",
            EventKind::Retry => "retry",
            EventKind::DeadlineExceeded => "deadline_exceeded",
            EventKind::WorkerDeath => "worker_death",
            EventKind::Redispatch => "redispatch",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub req: u64,
    pub worker: u32,
    pub slot: u32,
    /// Nanoseconds since the tracer's epoch.
    pub t_nanos: u64,
    /// Span duration (0 = instant event).
    pub dur_nanos: u64,
    /// Kind-specific payload (see [`EventKind`]).
    pub arg: u64,
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<TraceEvent>,
    /// Next write position once the buffer has reached capacity.
    next: usize,
    /// Lifetime event count (>= buf.len(); the excess was overwritten).
    total: u64,
}

/// Shared event sink: one per serve run, shared by every worker.
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    cap: usize,
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new(capacity: usize) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            cap: capacity.max(1),
            ring: Mutex::new(Ring::default()),
        }
    }

    /// Default capacity: 64Ki events (~3.5 MiB resident).
    pub fn with_default_capacity() -> Tracer {
        Tracer::new(1 << 16)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// The tracer's epoch `Instant` — share it with
    /// [`crate::obs::Counters::with_epoch`] so counter samples and trace
    /// events land on one timeline.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Nanoseconds since this tracer's epoch for an `Instant` the caller
    /// already holds (0 for instants that predate the epoch).
    pub fn nanos_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch).map(|d| d.as_nanos() as u64).unwrap_or(0)
    }

    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    pub fn emit(&self, ev: TraceEvent) {
        let mut r = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if r.buf.len() < self.cap {
            r.buf.push(ev);
        } else {
            let i = r.next;
            r.buf[i] = ev;
            r.next = (i + 1) % self.cap;
        }
        r.total += 1;
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let r = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = Vec::with_capacity(r.buf.len());
        out.extend_from_slice(&r.buf[r.next..]);
        out.extend_from_slice(&r.buf[..r.next]);
        out
    }

    /// Events overwritten by ring wraparound.
    pub fn dropped(&self) -> u64 {
        let r = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        r.total - r.buf.len() as u64
    }

    /// Lifetime event count, including overwritten events.
    pub fn total(&self) -> u64 {
        let r = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        r.total
    }

    /// Chrome trace-event JSON (the "JSON object format"): load in Perfetto
    /// or `chrome://tracing`. pid = worker, tid = slot, ts/dur in µs.
    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self
            .events()
            .iter()
            .map(|ev| {
                let mut pairs = vec![
                    ("name", s(ev.kind.as_str())),
                    ("cat", s("kvtuner")),
                    ("ph", s(if ev.dur_nanos > 0 { "X" } else { "i" })),
                    ("ts", num(ev.t_nanos as f64 / 1e3)),
                    ("pid", num(ev.worker as f64)),
                    ("tid", num(ev.slot as f64)),
                    (
                        "args",
                        obj(vec![("req", num(ev.req as f64)), ("arg", num(ev.arg as f64))]),
                    ),
                ];
                if ev.dur_nanos > 0 {
                    pairs.push(("dur", num(ev.dur_nanos as f64 / 1e3)));
                } else {
                    // instant scope: thread-local marker on the slot's track
                    pairs.push(("s", s("t")));
                }
                obj(pairs)
            })
            .collect();
        obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", s("ms")),
            ("schema_version", num(crate::obs::SCHEMA_VERSION as f64)),
            // ring-wraparound accounting so truncated traces are detectable
            // (Perfetto ignores unknown top-level keys)
            ("droppedEvents", num(self.dropped() as f64)),
            ("totalEvents", num(self.total() as f64)),
        ])
    }

    /// JSONL export: a `trace_meta` header line (schema version + ring-drop
    /// accounting), then one compact event object per line.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let meta = obj(vec![
            ("kind", s("trace_meta")),
            ("schema_version", num(crate::obs::SCHEMA_VERSION as f64)),
            ("dropped", num(self.dropped() as f64)),
            ("total", num(self.total() as f64)),
            ("capacity", num(self.cap as f64)),
        ]);
        out.push_str(&meta.to_string_compact());
        out.push('\n');
        for ev in self.events() {
            let j = obj(vec![
                ("kind", s(ev.kind.as_str())),
                ("req", num(ev.req as f64)),
                ("worker", num(ev.worker as f64)),
                ("slot", num(ev.slot as f64)),
                ("t_ns", num(ev.t_nanos as f64)),
                ("dur_ns", num(ev.dur_nanos as f64)),
                ("arg", num(ev.arg as f64)),
            ]);
            out.push_str(&j.to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Write to `path`: `.jsonl` selects JSONL, anything else Chrome JSON.
    pub fn write(&self, path: &std::path::Path) -> Result<()> {
        let body = if path.extension().is_some_and(|e| e == "jsonl") {
            self.to_jsonl()
        } else {
            self.to_chrome_json().to_string_pretty()
        };
        std::fs::write(path, body)?;
        Ok(())
    }
}

/// One worker's handle on the shared tracer: carries the worker id so the
/// scheduler emits with the right Chrome `pid` without knowing about the
/// router.
#[derive(Debug, Clone)]
pub struct TraceSink {
    pub tracer: Arc<Tracer>,
    pub worker: u32,
}

impl TraceSink {
    pub fn instant(&self, kind: EventKind, req: u64, slot: u32, arg: u64) {
        self.tracer.emit(TraceEvent {
            kind,
            req,
            worker: self.worker,
            slot,
            t_nanos: self.tracer.now_nanos(),
            dur_nanos: 0,
            arg,
        });
    }

    /// Span from an `Instant` the caller already holds to now.
    pub fn span(&self, kind: EventKind, req: u64, slot: u32, start: Instant, arg: u64) {
        self.tracer.emit(TraceEvent {
            kind,
            req,
            worker: self.worker,
            slot,
            t_nanos: self.tracer.nanos_of(start),
            dur_nanos: start.elapsed().as_nanos() as u64,
            arg,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            kind: EventKind::DecodeStep,
            req: i,
            worker: 0,
            slot: 0,
            t_nanos: i * 100,
            dur_nanos: 10,
            arg: 1,
        }
    }

    #[test]
    fn ring_wraps_keeping_the_newest() {
        let t = Tracer::new(8);
        for i in 0..20 {
            t.emit(ev(i));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 8);
        assert_eq!(t.dropped(), 12);
        let reqs: Vec<u64> = evs.iter().map(|e| e.req).collect();
        assert_eq!(reqs, (12..20).collect::<Vec<_>>(), "oldest-first, newest retained");
    }

    #[test]
    fn under_capacity_keeps_everything_in_order() {
        let t = Tracer::new(8);
        for i in 0..5 {
            t.emit(ev(i));
        }
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.events().iter().map(|e| e.req).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn chrome_json_is_well_formed() {
        let t = Tracer::new(16);
        t.emit(ev(1));
        let sink = TraceSink { tracer: Arc::new(Tracer::new(16)), worker: 3 };
        sink.instant(EventKind::Admit, 7, 2, 42);
        let j = sink.tracer.to_chrome_json();
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        let evs = re.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("name").unwrap().as_str().unwrap(), "admit");
        assert_eq!(evs[0].get("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(evs[0].get("pid").unwrap().as_usize().unwrap(), 3);
        assert_eq!(evs[0].get("tid").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            evs[0].get("args").unwrap().get("req").unwrap().as_usize().unwrap(),
            7
        );
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let t = Tracer::new(16);
        for i in 0..3 {
            t.emit(ev(i));
        }
        let body = t.to_jsonl();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 4, "trace_meta header + 3 events");
        let meta = Json::parse(lines[0]).unwrap();
        assert_eq!(meta.get("kind").unwrap().as_str().unwrap(), "trace_meta");
        assert_eq!(
            meta.get("schema_version").unwrap().as_usize().unwrap(),
            crate::obs::SCHEMA_VERSION as usize
        );
        assert_eq!(meta.get("dropped").unwrap().as_usize().unwrap(), 0);
        for l in &lines[1..] {
            let j = Json::parse(l).unwrap();
            assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "decode_step");
        }
    }

    #[test]
    fn both_exports_report_ring_drops() {
        let t = Tracer::new(4);
        for i in 0..10 {
            t.emit(ev(i));
        }
        let chrome = Json::parse(&t.to_chrome_json().to_string_pretty()).unwrap();
        assert_eq!(chrome.get("droppedEvents").unwrap().as_usize().unwrap(), 6);
        assert_eq!(chrome.get("totalEvents").unwrap().as_usize().unwrap(), 10);
        let jsonl = t.to_jsonl();
        let meta = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(meta.get("dropped").unwrap().as_usize().unwrap(), 6);
        assert_eq!(meta.get("total").unwrap().as_usize().unwrap(), 10);
    }
}
