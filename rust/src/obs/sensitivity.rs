//! Online quantization-error sensitivity probe: the serving-path twin of
//! the offline profiler (`tuner/profiler.rs`).
//!
//! At a configurable sampling interval (`--probe-every N` KIVI groups;
//! 0 = disabled, and — like the [`crate::obs::Profiler`] — the disabled
//! probe is zero-cost: every hot-path hook is an `#[inline]` method whose
//! first instruction returns), the engine hands the probe the fp shadow of
//! a committed group's Q/K/V *before* quantize-at-commit. The probe then
//! runs the exact same simulated quantize→dequantize →
//! [`crate::quant::error::ErrorMetrics`] computation the offline profiler
//! uses, and accumulates the results per (layer, mode, precision pair) in
//! an atomic table shared with reader threads ([`SensitivityShared`]).
//!
//! Three consumers hang off that table:
//! * **Snapshots** ([`SensitivitySnapshot`]) — mean per-cell errors,
//!   exported via `--sensitivity-out` and embedded in the serve metrics
//!   JSON; with full sampling and one group the numbers are bit-for-bit
//!   the offline profiler's (the parity test in `tests/sensitivity.rs`).
//! * **Drift detection** — an offline-calibrated [`Envelope`] (per-layer
//!   error bounds recorded at tuner search time, carried inside
//!   `TunedConfig`) is compared against each sampled group's error for the
//!   layer's *served* spec; exceeding `bound × headroom` bumps an atomic
//!   drift counter the scheduler turns into a typed trace event and a
//!   metrics gauge.
//! * **Live streaming** — the serve CLI polls the shared table on its
//!   metrics-interval thread, so long runs are observable in flight.
//!
//! The probe is strictly read-only with respect to the forward pass:
//! enabling it never changes a logit bit (asserted by the probed arm of
//! `table11_native_mt`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair, PAIRS};
use crate::quant::error::{kv_errors, layer_errors, ErrorMetrics, LayerCapture};
use crate::util::json::{arr, num, obj, s, Json};

/// Modes with a probe-table row (Fp is never recorded — it has no error).
const N_MODES: usize = 2;

fn mode_idx(mode: Mode) -> Option<usize> {
    match mode {
        Mode::Token => Some(0),
        Mode::Kivi => Some(1),
        Mode::Fp => None,
    }
}

fn pair_idx(pair: PrecisionPair) -> Option<usize> {
    PAIRS.iter().position(|p| *p == pair)
}

/// f64 accumulators in `AtomicU64` bit form. The engine thread is the only
/// writer (one probe per engine), so relaxed load-modify-store keeps the
/// sums exact; atomics exist so snapshot readers on other threads (the
/// metrics streamer) never race the writer.
fn add_f64(a: &AtomicU64, v: f64) {
    let cur = f64::from_bits(a.load(Ordering::Relaxed));
    a.store((cur + v).to_bits(), Ordering::Relaxed);
}

fn max_f64(a: &AtomicU64, v: f64) {
    let cur = f64::from_bits(a.load(Ordering::Relaxed));
    if v > cur {
        a.store(v.to_bits(), Ordering::Relaxed);
    }
}

/// Per-layer calibration bounds recorded by the offline tuner: the maximum
/// error the calibration prompt set produced at each layer's metric. An
/// online sample past `bound × headroom` means the live workload sits
/// outside the distribution the precision map was searched on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnvelopeBound {
    pub e_k: f64,
    pub e_v: f64,
    pub e_a: f64,
    pub e_o: f64,
}

/// The full per-layer calibration envelope (one bound per layer, indexed by
/// layer). Serialized inside `TunedConfig` JSON under `"envelope"`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Envelope {
    pub layers: Vec<EnvelopeBound>,
}

impl Envelope {
    pub fn to_json(&self) -> Json {
        arr(self.layers.iter().map(|b| {
            obj(vec![
                ("e_k", num(b.e_k)),
                ("e_v", num(b.e_v)),
                ("e_a", num(b.e_a)),
                ("e_o", num(b.e_o)),
            ])
        }))
    }

    pub fn from_json(j: &Json) -> Result<Envelope> {
        let layers = j
            .as_arr()?
            .iter()
            .map(|b| {
                Ok(EnvelopeBound {
                    e_k: b.get("e_k")?.as_f64()?,
                    e_v: b.get("e_v")?.as_f64()?,
                    e_a: b.get("e_a")?.as_f64()?,
                    e_o: b.get("e_o")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Envelope { layers })
    }
}

/// Probe configuration, carried through `WorkerSpec` into the engines.
#[derive(Debug, Clone)]
pub struct ProbeConfig {
    /// Sample every Nth KIVI group (decode-step groups and prefill blocks
    /// alike); 0 disables the probe entirely.
    pub every: usize,
    /// Drift fires when a sampled error exceeds `bound × headroom` — the
    /// slack above the calibrated peak before a workload counts as
    /// out-of-distribution.
    pub headroom: f64,
    /// Offline calibration bounds (`TunedConfig::envelope`); `None` keeps
    /// the probe measuring without drift detection.
    pub envelope: Option<Envelope>,
    /// Mode override: when non-empty, every layer evaluates these modes'
    /// full pair grid from the fp shadow instead of only its served mode —
    /// the offline profiler's grid, used by the parity test. Empty (the
    /// serving default) evaluates each layer's own non-Fp mode only.
    pub modes: Vec<Mode>,
}

impl Default for ProbeConfig {
    fn default() -> ProbeConfig {
        ProbeConfig { every: 0, headroom: 1.5, envelope: None, modes: Vec::new() }
    }
}

/// One (layer, mode, pair) accumulator cell.
#[derive(Default)]
struct Cell {
    sum_e_k: AtomicU64,
    sum_e_v: AtomicU64,
    sum_e_a: AtomicU64,
    max_e_a: AtomicU64,
    sum_e_o: AtomicU64,
    count: AtomicU64,
}

/// The atomic sensitivity table: written by the engine thread, snapshotted
/// by anyone holding the `Arc` (the metrics streamer, the router at
/// shutdown).
pub struct SensitivityShared {
    specs: Vec<LayerSpec>,
    /// True for engines that can only shadow K/V, not Q (the XLA arm): the
    /// attention-divergence columns stay zero there.
    kv_only: bool,
    cells: Vec<Cell>,
    layer_drift: Vec<AtomicU64>,
    drift_alerts: AtomicU64,
}

impl SensitivityShared {
    pub fn new(specs: &[LayerSpec], kv_only: bool) -> SensitivityShared {
        SensitivityShared {
            kv_only,
            cells: (0..specs.len() * N_MODES * PAIRS.len()).map(|_| Cell::default()).collect(),
            layer_drift: (0..specs.len()).map(|_| AtomicU64::new(0)).collect(),
            drift_alerts: AtomicU64::new(0),
            specs: specs.to_vec(),
        }
    }

    fn cell(&self, layer: usize, mode: Mode, pair: PrecisionPair) -> Option<&Cell> {
        let (mi, pi) = (mode_idx(mode)?, pair_idx(pair)?);
        self.cells.get((layer * N_MODES + mi) * PAIRS.len() + pi)
    }

    pub fn record(&self, layer: usize, mode: Mode, pair: PrecisionPair, m: &ErrorMetrics) {
        let Some(c) = self.cell(layer, mode, pair) else { return };
        add_f64(&c.sum_e_k, m.e_k);
        add_f64(&c.sum_e_v, m.e_v);
        add_f64(&c.sum_e_a, m.e_a);
        max_f64(&c.max_e_a, m.e_a_max);
        add_f64(&c.sum_e_o, m.e_o);
        c.count.fetch_add(1, Ordering::Relaxed);
    }

    /// K/V-only sample (engines without a query shadow).
    pub fn record_kv(&self, layer: usize, mode: Mode, pair: PrecisionPair, e_k: f64, e_v: f64) {
        let Some(c) = self.cell(layer, mode, pair) else { return };
        add_f64(&c.sum_e_k, e_k);
        add_f64(&c.sum_e_v, e_v);
        c.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_drift(&self, layer: usize) {
        if let Some(d) = self.layer_drift.get(layer) {
            d.fetch_add(1, Ordering::Relaxed);
        }
        self.drift_alerts.fetch_add(1, Ordering::Relaxed);
    }

    /// Total envelope violations so far (the scheduler polls this each tick
    /// and emits a trace event on every increase).
    pub fn drift_alerts(&self) -> u64 {
        self.drift_alerts.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> SensitivitySnapshot {
        let np = PAIRS.len();
        let layers = self
            .specs
            .iter()
            .enumerate()
            .map(|(l, sp)| {
                let mut errors = Vec::new();
                for (mi, mode) in [Mode::Token, Mode::Kivi].into_iter().enumerate() {
                    for (pi, pair) in PAIRS.iter().enumerate() {
                        let c = &self.cells[(l * N_MODES + mi) * np + pi];
                        let count = c.count.load(Ordering::Relaxed);
                        if count == 0 {
                            continue;
                        }
                        let n = count as f64;
                        let m = ErrorMetrics {
                            e_k: f64::from_bits(c.sum_e_k.load(Ordering::Relaxed)) / n,
                            e_v: f64::from_bits(c.sum_e_v.load(Ordering::Relaxed)) / n,
                            e_a: f64::from_bits(c.sum_e_a.load(Ordering::Relaxed)) / n,
                            e_a_max: f64::from_bits(c.max_e_a.load(Ordering::Relaxed)),
                            e_o: f64::from_bits(c.sum_e_o.load(Ordering::Relaxed)) / n,
                        };
                        errors.push((mode, *pair, count, m));
                    }
                }
                LayerSensitivity {
                    layer: l,
                    spec: *sp,
                    drift_alerts: self.layer_drift[l].load(Ordering::Relaxed),
                    errors,
                }
            })
            .collect();
        SensitivitySnapshot {
            kv_only: self.kv_only,
            drift_alerts: self.drift_alerts.load(Ordering::Relaxed),
            layers,
        }
    }
}

/// One layer's accumulated online sensitivity.
#[derive(Debug, Clone)]
pub struct LayerSensitivity {
    pub layer: usize,
    /// The spec this layer actually serves (drift is only checked on it).
    pub spec: LayerSpec,
    pub drift_alerts: u64,
    /// Mean errors per probed (mode, pair), with the sample count.
    pub errors: Vec<(Mode, PrecisionPair, u64, ErrorMetrics)>,
}

/// Point-in-time view of the sensitivity table.
#[derive(Debug, Clone)]
pub struct SensitivitySnapshot {
    pub kv_only: bool,
    pub drift_alerts: u64,
    pub layers: Vec<LayerSensitivity>,
}

impl SensitivitySnapshot {
    /// Mean metrics for one probed cell, if it ever sampled.
    pub fn metrics(&self, layer: usize, mode: Mode, pair: PrecisionPair) -> Option<ErrorMetrics> {
        self.layers
            .iter()
            .find(|l| l.layer == layer)?
            .errors
            .iter()
            .find(|(m, p, _, _)| *m == mode && *p == pair)
            .map(|(_, _, _, e)| *e)
    }

    /// Total samples across every cell (probed-arm liveness checks).
    pub fn samples(&self) -> u64 {
        self.layers.iter().flat_map(|l| l.errors.iter().map(|e| e.2)).sum()
    }

    /// The `--sensitivity-out` schema: per layer, the served spec, its
    /// drift count, and one row per probed (mode, pair) with mean errors.
    pub fn to_json(&self) -> Json {
        arr_layers(self)
    }
}

fn arr_layers(snap: &SensitivitySnapshot) -> Json {
    obj(vec![
        ("kv_only", num(if snap.kv_only { 1.0 } else { 0.0 })),
        ("drift_alerts", num(snap.drift_alerts as f64)),
        (
            "layers",
            arr(snap.layers.iter().map(|l| {
                obj(vec![
                    ("layer", num(l.layer as f64)),
                    ("mode", s(l.spec.mode.as_str())),
                    ("pair", s(l.spec.pair.label())),
                    ("drift_alerts", num(l.drift_alerts as f64)),
                    (
                        "errors",
                        arr(l.errors.iter().map(|(m, p, c, e)| {
                            obj(vec![
                                ("mode", s(m.as_str())),
                                ("pair", s(p.label())),
                                ("count", num(*c as f64)),
                                ("e_k", num(e.e_k)),
                                ("e_v", num(e.e_v)),
                                ("e_a", num(e.e_a)),
                                ("e_a_max", num(e.e_a_max)),
                                ("e_o", num(e.e_o)),
                            ])
                        })),
                    ),
                ])
            })),
        ),
    ])
}

/// One in-flight fp group being assembled row by row (decode / tokenwise
/// prefill path).
#[derive(Default)]
struct Pending {
    start: usize,
    rows: usize,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

/// The engine-resident probe. Owns the sampling state; publishes results
/// into its [`SensitivityShared`] table.
pub struct SensitivityProbe {
    every: usize,
    headroom: f64,
    envelope: Option<Envelope>,
    shared: Option<Arc<SensitivityShared>>,
    /// Modes evaluated per layer (the full-grid override, or the layer's
    /// own served mode; empty for Fp layers under the default).
    layer_modes: Vec<Vec<Mode>>,
    specs: Vec<LayerSpec>,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
    group: usize,
    n_layers: usize,
    /// (slot, layer)-indexed partial groups for the row-at-a-time path.
    pending: Vec<Pending>,
    /// (slot, layer)-indexed KIVI commit counters for the group-at-commit
    /// path (XLA arm).
    commit_seq: Vec<u64>,
}

impl SensitivityProbe {
    /// The inert probe: every hook returns immediately, no allocation.
    pub fn disabled() -> SensitivityProbe {
        SensitivityProbe {
            every: 0,
            headroom: 1.0,
            envelope: None,
            shared: None,
            layer_modes: Vec::new(),
            specs: Vec::new(),
            n_heads: 0,
            n_kv_heads: 0,
            head_dim: 0,
            group: 1,
            n_layers: 0,
            pending: Vec::new(),
            commit_seq: Vec::new(),
        }
    }

    /// `kv_only`: the engine has no query shadow (XLA arm) — only
    /// `record_kv_group` will feed the table.
    pub fn new(
        cfg: &ModelConfig,
        specs: &[LayerSpec],
        batch: usize,
        pc: &ProbeConfig,
        kv_only: bool,
    ) -> SensitivityProbe {
        if pc.every == 0 {
            return SensitivityProbe::disabled();
        }
        let n_layers = specs.len();
        let layer_modes = specs
            .iter()
            .map(|sp| {
                if !pc.modes.is_empty() {
                    pc.modes.clone()
                } else if sp.mode != Mode::Fp {
                    vec![sp.mode]
                } else {
                    Vec::new()
                }
            })
            .collect();
        SensitivityProbe {
            every: pc.every,
            headroom: pc.headroom,
            envelope: pc.envelope.clone(),
            shared: Some(Arc::new(SensitivityShared::new(specs, kv_only))),
            layer_modes,
            specs: specs.to_vec(),
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim,
            group: cfg.group,
            n_layers,
            pending: (0..batch * n_layers).map(|_| Pending::default()).collect(),
            commit_seq: vec![0; batch * n_layers],
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.every > 0
    }

    pub fn shared(&self) -> Option<Arc<SensitivityShared>> {
        self.shared.clone()
    }

    pub fn snapshot(&self) -> Option<SensitivitySnapshot> {
        self.shared.as_ref().map(|sh| sh.snapshot())
    }

    pub fn drift_alerts(&self) -> u64 {
        self.shared.as_ref().map_or(0, |sh| sh.drift_alerts())
    }

    /// Drop a slot's partial groups (new request entering the slot; its
    /// rows must not splice onto the previous occupant's).
    #[inline]
    pub fn reset_slot(&mut self, slot: usize) {
        if self.every == 0 {
            return;
        }
        for l in 0..self.n_layers {
            self.pending[slot * self.n_layers + l].rows = 0;
        }
    }

    /// Block-prefill hook: one whole group's fp Q/K/V, already in the
    /// capture layouts (`qs` [g, Hq·Dh] row-major ≡ [S, Hq, Dh]; `kt`/`vt`
    /// head-major [Hkv, g, Dh]). `pos` is the group-aligned base position.
    #[inline]
    pub fn record_block(&mut self, l: usize, pos: usize, qs: &[f32], kt: &[f32], vt: &[f32]) {
        if self.every == 0 {
            return;
        }
        if (pos / self.group) % self.every != 0 {
            return;
        }
        let cap = LayerCapture {
            q: qs.to_vec(),
            k: kt.to_vec(),
            v: vt.to_vec(),
            s: self.group,
            n_heads: self.n_heads,
            n_kv_heads: self.n_kv_heads,
            head_dim: self.head_dim,
        };
        self.eval_capture(l, &cap);
    }

    /// Row-at-a-time hook (decode steps and tokenwise prefill): one
    /// position's fp q [Hq·Dh] / k / v [Hkv·Dh], post-RoPE, pre-commit.
    /// Rows accumulate per (slot, layer) until a full group is assembled;
    /// a discontinuity (preemption, mid-group entry) drops the partial
    /// group — only bit-faithful whole groups are ever evaluated.
    #[inline]
    pub fn record_row(
        &mut self,
        l: usize,
        slot: usize,
        pos: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) {
        if self.every == 0 {
            return;
        }
        let g = self.group;
        if (pos / g) % self.every != 0 {
            return;
        }
        let cap;
        {
            let p = &mut self.pending[slot * self.n_layers + l];
            if pos % g == 0 {
                p.start = pos;
                p.rows = 0;
                p.q.clear();
                p.k.clear();
                p.v.clear();
            } else if p.rows == 0 || p.start + p.rows != pos {
                p.rows = 0;
                return;
            }
            p.q.extend_from_slice(q);
            p.k.extend_from_slice(k);
            p.v.extend_from_slice(v);
            p.rows += 1;
            if p.rows < g {
                return;
            }
            // token-major rows [g, Hkv·Dh] -> head-major capture [Hkv, g, Dh]
            let (hkv, dh) = (self.n_kv_heads, self.head_dim);
            let mut kt = vec![0f32; hkv * g * dh];
            let mut vt = vec![0f32; hkv * g * dh];
            for r in 0..g {
                for h in 0..hkv {
                    let src = (r * hkv + h) * dh;
                    let dst = (h * g + r) * dh;
                    kt[dst..dst + dh].copy_from_slice(&p.k[src..src + dh]);
                    vt[dst..dst + dh].copy_from_slice(&p.v[src..src + dh]);
                }
            }
            cap = LayerCapture {
                q: std::mem::take(&mut p.q),
                k: kt,
                v: vt,
                s: g,
                n_heads: self.n_heads,
                n_kv_heads: self.n_kv_heads,
                head_dim: self.head_dim,
            };
            p.rows = 0;
        }
        self.eval_capture(l, &cap);
    }

    /// KIVI group-commit hook for engines without a query shadow (XLA arm):
    /// `k`/`v` are the group's fp residual chunk, already head-major
    /// [Hkv, g, Dh] (the `residual_chunk` layout). Samples by per-(slot,
    /// layer) commit ordinal; records `e_k`/`e_v` only, over the layer's
    /// probed modes × all pairs.
    #[inline]
    pub fn record_kv_group(&mut self, l: usize, slot: usize, k: &[f32], v: &[f32]) {
        if self.every == 0 {
            return;
        }
        let idx = slot * self.n_layers + l;
        let seq = self.commit_seq[idx];
        self.commit_seq[idx] += 1;
        if seq % self.every as u64 != 0 {
            return;
        }
        let Some(shared) = &self.shared else { return };
        let g = self.group;
        let (hkv, dh) = (self.n_kv_heads, self.head_dim);
        let spec = self.specs[l];
        for &mode in &self.layer_modes[l] {
            for pair in PAIRS {
                let probe_spec = LayerSpec { mode, pair };
                if let Ok((e_k, e_v)) = kv_errors(k, v, probe_spec, hkv, g, dh, g) {
                    shared.record_kv(l, mode, pair, e_k, e_v);
                    if mode == spec.mode && pair == spec.pair {
                        if let Some(b) = self.bound(l) {
                            let h = self.headroom;
                            if e_k > b.e_k * h || e_v > b.e_v * h {
                                shared.note_drift(l);
                            }
                        }
                    }
                }
            }
        }
    }

    fn bound(&self, l: usize) -> Option<EnvelopeBound> {
        self.envelope.as_ref()?.layers.get(l).copied()
    }

    /// Run the offline error simulation over this layer's probed modes ×
    /// all pairs, publish each result, and drift-check the served spec.
    fn eval_capture(&self, l: usize, cap: &LayerCapture) {
        let Some(shared) = &self.shared else { return };
        let spec = self.specs[l];
        for &mode in &self.layer_modes[l] {
            for pair in PAIRS {
                let probe_spec = LayerSpec { mode, pair };
                let Ok(m) = layer_errors(cap, probe_spec, self.group) else { continue };
                shared.record(l, mode, pair, &m);
                if mode == spec.mode && pair == spec.pair {
                    if let Some(b) = self.bound(l) {
                        let h = self.headroom;
                        if m.e_o > b.e_o * h
                            || m.e_a > b.e_a * h
                            || m.e_k > b.e_k * h
                            || m.e_v > b.e_v * h
                        {
                            shared.note_drift(l);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig::synthetic("probe-test")
    }

    fn rand_rows(n: usize, r: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| r.normal() as f32).collect()
    }

    /// One group's worth of fp Q/K/V in the block-hook layouts.
    fn group_capture(c: &ModelConfig, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let g = c.group;
        let mut r = Rng::seed(seed);
        let qs = rand_rows(g * c.n_heads * c.head_dim, &mut r);
        let kt = rand_rows(c.n_kv_heads * g * c.head_dim, &mut r);
        let vt = rand_rows(c.n_kv_heads * g * c.head_dim, &mut r);
        (qs, kt, vt)
    }

    #[test]
    fn disabled_probe_is_inert() {
        let c = cfg();
        let mut p = SensitivityProbe::disabled();
        let (qs, kt, vt) = group_capture(&c, 1);
        p.record_block(0, 0, &qs, &kt, &vt);
        p.record_row(0, 0, 0, &qs[..c.n_heads * c.head_dim], &kt[..32], &vt[..32]);
        p.record_kv_group(0, 0, &kt, &vt);
        p.reset_slot(0);
        assert!(!p.enabled());
        assert!(p.snapshot().is_none());
        assert!(p.shared().is_none());
        assert_eq!(p.drift_alerts(), 0);
        // ProbeConfig { every: 0 } builds the same inert probe
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), c.n_layers);
        let p2 = SensitivityProbe::new(&c, &specs, 2, &ProbeConfig::default(), false);
        assert!(!p2.enabled());
        assert!(p2.snapshot().is_none());
    }

    #[test]
    fn block_sample_matches_offline_layer_errors_bitwise() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), c.n_layers);
        let pc = ProbeConfig { every: 1, ..ProbeConfig::default() };
        let mut p = SensitivityProbe::new(&c, &specs, 1, &pc, false);
        let (qs, kt, vt) = group_capture(&c, 2);
        p.record_block(1, 0, &qs, &kt, &vt);
        let snap = p.snapshot().unwrap();
        // the layer's own (mode, pair) grid: all 9 pairs sampled once
        for pair in PAIRS {
            let got = snap.metrics(1, Mode::Kivi, pair).unwrap();
            let cap = LayerCapture {
                q: qs.clone(),
                k: kt.clone(),
                v: vt.clone(),
                s: c.group,
                n_heads: c.n_heads,
                n_kv_heads: c.n_kv_heads,
                head_dim: c.head_dim,
            };
            let want =
                layer_errors(&cap, LayerSpec { mode: Mode::Kivi, pair }, c.group).unwrap();
            assert_eq!(got.e_k, want.e_k, "{}", pair.label());
            assert_eq!(got.e_v, want.e_v, "{}", pair.label());
            assert_eq!(got.e_a, want.e_a, "{}", pair.label());
            assert_eq!(got.e_a_max, want.e_a_max, "{}", pair.label());
            assert_eq!(got.e_o, want.e_o, "{}", pair.label());
        }
        // other layers and the unprobed mode stay empty
        assert!(snap.metrics(0, Mode::Kivi, PAIRS[0]).is_none());
        assert!(snap.metrics(1, Mode::Token, PAIRS[0]).is_none());
        assert_eq!(snap.samples(), PAIRS.len() as u64);
    }

    #[test]
    fn sampling_interval_skips_groups() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(8, 8), c.n_layers);
        let pc = ProbeConfig { every: 2, ..ProbeConfig::default() };
        let mut p = SensitivityProbe::new(&c, &specs, 1, &pc, false);
        let (qs, kt, vt) = group_capture(&c, 3);
        p.record_block(0, 0, &qs, &kt, &vt); // group 0: sampled
        p.record_block(0, c.group, &qs, &kt, &vt); // group 1: skipped
        p.record_block(0, 2 * c.group, &qs, &kt, &vt); // group 2: sampled
        let snap = p.snapshot().unwrap();
        let row = &snap.layers[0].errors;
        assert!(row.iter().all(|(_, _, count, _)| *count == 2), "2 of 3 groups sampled");
    }

    #[test]
    fn row_path_assembles_full_groups_and_drops_discontinuities() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), c.n_layers);
        let pc = ProbeConfig { every: 1, ..ProbeConfig::default() };
        let mut p = SensitivityProbe::new(&c, &specs, 2, &pc, false);
        let g = c.group;
        let (hq, hkv, dh) = (c.n_heads, c.n_kv_heads, c.head_dim);
        let mut r = Rng::seed(4);
        let rows: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..g)
            .map(|_| {
                (
                    rand_rows(hq * dh, &mut r),
                    rand_rows(hkv * dh, &mut r),
                    rand_rows(hkv * dh, &mut r),
                )
            })
            .collect();
        // full aligned group on slot 0 -> one sample per pair
        for (i, (q, k, v)) in rows.iter().enumerate() {
            p.record_row(0, 0, i, q, k, v);
        }
        assert_eq!(p.snapshot().unwrap().samples(), PAIRS.len() as u64);
        // the row path must agree with the block path bit-for-bit: feed the
        // same rows through record_block on layer 1
        let mut qs = Vec::new();
        let mut kt = vec![0f32; hkv * g * dh];
        let mut vt = vec![0f32; hkv * g * dh];
        for (i, (q, k, v)) in rows.iter().enumerate() {
            qs.extend_from_slice(q);
            for h in 0..hkv {
                kt[(h * g + i) * dh..(h * g + i + 1) * dh]
                    .copy_from_slice(&k[h * dh..(h + 1) * dh]);
                vt[(h * g + i) * dh..(h * g + i + 1) * dh]
                    .copy_from_slice(&v[h * dh..(h + 1) * dh]);
            }
        }
        p.record_block(1, 0, &qs, &kt, &vt);
        let snap = p.snapshot().unwrap();
        for pair in PAIRS {
            let a = snap.metrics(0, Mode::Token, pair).unwrap();
            let b = snap.metrics(1, Mode::Token, pair).unwrap();
            assert_eq!(a.e_o, b.e_o, "row path == block path for {}", pair.label());
            assert_eq!(a.e_k, b.e_k);
        }
        // discontinuity: a partial group interrupted by a slot reset never
        // completes, and rows resuming mid-group are dropped
        let mut p2 = SensitivityProbe::new(&c, &specs, 1, &pc, false);
        for (i, (q, k, v)) in rows.iter().enumerate().take(g / 2) {
            p2.record_row(0, 0, i, q, k, v);
        }
        p2.reset_slot(0);
        for (i, (q, k, v)) in rows.iter().enumerate().skip(g / 2) {
            p2.record_row(0, 0, i, q, k, v);
        }
        assert_eq!(p2.snapshot().unwrap().samples(), 0, "no bit-faithful whole group");
    }

    #[test]
    fn mode_override_evaluates_full_grid() {
        let c = cfg();
        // Fp specs would probe nothing by default; the override forces the
        // offline profiler's grid (the parity-test configuration)
        let specs = LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, c.n_layers);
        let pc = ProbeConfig {
            every: 1,
            modes: vec![Mode::Token, Mode::Kivi],
            ..ProbeConfig::default()
        };
        let mut p = SensitivityProbe::new(&c, &specs, 1, &pc, false);
        let (qs, kt, vt) = group_capture(&c, 5);
        p.record_block(0, 0, &qs, &kt, &vt);
        let snap = p.snapshot().unwrap();
        assert_eq!(snap.layers[0].errors.len(), 2 * PAIRS.len());
        assert!(snap.metrics(0, Mode::Token, PAIRS[8]).is_some());
        assert!(snap.metrics(0, Mode::Kivi, PAIRS[0]).is_some());
        // default (no override) on Fp specs probes nothing at all
        let mut p2 = SensitivityProbe::new(
            &c,
            &specs,
            1,
            &ProbeConfig { every: 1, ..ProbeConfig::default() },
            false,
        );
        p2.record_block(0, 0, &qs, &kt, &vt);
        assert_eq!(p2.snapshot().unwrap().samples(), 0);
    }

    #[test]
    fn drift_fires_only_past_the_envelope() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(2, 2), c.n_layers);
        let (qs, kt, vt) = group_capture(&c, 6);
        // generous bounds: no drift
        let wide = Envelope {
            layers: vec![EnvelopeBound { e_k: 1e9, e_v: 1e9, e_a: 1e9, e_o: 1e9 }; c.n_layers],
        };
        let mut p = SensitivityProbe::new(
            &c,
            &specs,
            1,
            &ProbeConfig { every: 1, envelope: Some(wide), ..ProbeConfig::default() },
            false,
        );
        p.record_block(0, 0, &qs, &kt, &vt);
        assert_eq!(p.drift_alerts(), 0);
        // zero bounds: every sampled group on the served spec violates
        let tight = Envelope { layers: vec![EnvelopeBound::default(); c.n_layers] };
        let mut p2 = SensitivityProbe::new(
            &c,
            &specs,
            1,
            &ProbeConfig { every: 1, envelope: Some(tight), ..ProbeConfig::default() },
            false,
        );
        p2.record_block(0, 0, &qs, &kt, &vt);
        p2.record_block(2, 0, &qs, &kt, &vt);
        assert_eq!(p2.drift_alerts(), 2, "one violation per sampled group on the served spec");
        let snap = p2.snapshot().unwrap();
        assert_eq!(snap.layers[0].drift_alerts, 1);
        assert_eq!(snap.layers[1].drift_alerts, 0);
        assert_eq!(snap.layers[2].drift_alerts, 1);
        assert_eq!(snap.drift_alerts, 2);
    }

    #[test]
    fn kv_group_hook_records_kv_split_only() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), c.n_layers);
        let pc = ProbeConfig { every: 2, ..ProbeConfig::default() };
        let mut p = SensitivityProbe::new(&c, &specs, 1, &pc, true);
        let g = c.group;
        let mut r = Rng::seed(7);
        let k = rand_rows(g * c.n_kv_heads * c.head_dim, &mut r);
        let v = rand_rows(g * c.n_kv_heads * c.head_dim, &mut r);
        p.record_kv_group(0, 0, &k, &v); // commit 0: sampled
        p.record_kv_group(0, 0, &k, &v); // commit 1: skipped (every=2)
        p.record_kv_group(0, 0, &k, &v); // commit 2: sampled
        let snap = p.snapshot().unwrap();
        assert!(snap.kv_only);
        let m = snap.metrics(0, Mode::Kivi, PrecisionPair::new(4, 4)).unwrap();
        assert!(m.e_k > 0.0 && m.e_v > 0.0, "kv errors measured");
        assert_eq!(m.e_a, 0.0, "no attention shadow on the kv-only path");
        assert_eq!(m.e_o, 0.0);
        let (_, _, count, _) = snap.layers[0].errors[0];
        assert_eq!(count, 2, "commit ordinal sampling: 2 of 3");
    }

    #[test]
    fn envelope_json_round_trips() {
        let env = Envelope {
            layers: vec![
                EnvelopeBound { e_k: 0.01, e_v: 0.02, e_a: 0.003, e_o: 0.04 },
                EnvelopeBound { e_k: 0.05, e_v: 0.06, e_a: 0.007, e_o: 0.08 },
            ],
        };
        let j = env.to_json();
        let re = Envelope::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(env, re);
    }

    #[test]
    fn snapshot_json_schema() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), c.n_layers);
        let pc = ProbeConfig { every: 1, ..ProbeConfig::default() };
        let mut p = SensitivityProbe::new(&c, &specs, 1, &pc, false);
        let (qs, kt, vt) = group_capture(&c, 8);
        p.record_block(0, 0, &qs, &kt, &vt);
        let j = p.snapshot().unwrap().to_json();
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(re.get("drift_alerts").unwrap().as_usize().unwrap(), 0);
        let layers = re.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), c.n_layers);
        assert_eq!(layers[0].get("mode").unwrap().as_str().unwrap(), "kivi");
        assert_eq!(layers[0].get("pair").unwrap().as_str().unwrap(), "K8V4");
        let errors = layers[0].get("errors").unwrap().as_arr().unwrap();
        assert_eq!(errors.len(), PAIRS.len());
        assert!(errors[0].get("e_o").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(errors[0].get("count").unwrap().as_usize().unwrap(), 1);
    }
}
