//! Named time-series counter tracks: the continuous occupancy/bandwidth
//! signals the end-of-run aggregates in [`crate::coordinator::Metrics`]
//! cannot provide.
//!
//! Each track is a bounded ring of `(t_nanos, value)` samples written with
//! a per-slot seqlock — the publisher does a handful of relaxed/release
//! atomic stores and never blocks, and a snapshot reads the ring without
//! taking any lock (a sample the writer is mid-overwrite on is simply
//! skipped). Two flavors:
//!
//! * [`CounterKind::Gauge`] — instantaneous level (pool occupancy, queue
//!   depth, live bytes). Exported as a Prometheus `gauge`.
//! * [`CounterKind::Rate`] — a monotonically nondecreasing cumulative
//!   total (swap bytes, gather bytes). The publisher additionally folds
//!   each delta into an EWMA per-second rate with a wall-clock time
//!   constant, so the exposition can report live bandwidth next to the
//!   raw counter. Exported as a Prometheus `counter` (`_total`) plus an
//!   `_ewma_per_sec` gauge.
//!
//! The registry ([`Counters`]) hands out cheaply cloneable
//! [`CounterHandle`]s at registration time (the only locking point) so hot
//! paths publish through a pre-resolved `Arc` with zero lookups. Tracks
//! carry Prometheus-style labels (e.g. `layer="03"`, `spec="kivi K8V4"`),
//! letting one logical series name fan out per layer / per precision.
//!
//! Timestamps are nanoseconds since the registry epoch; construct with
//! [`Counters::with_epoch`] sharing the [`crate::obs::Tracer`]'s epoch and
//! the samples land on the same Perfetto timeline as the lifecycle spans.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};

/// Default per-track ring capacity (~4 KiB of samples per track).
pub const DEFAULT_TRACK_CAPACITY: usize = 256;

/// EWMA time constant for [`CounterKind::Rate`] tracks, seconds. Chosen so
/// bandwidth readings settle within a couple of seconds of a load change
/// while still smoothing over per-tick burstiness.
const EWMA_TAU_S: f64 = 1.5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterKind {
    /// Instantaneous level; each sample stands alone.
    Gauge,
    /// Monotonic cumulative total; deltas between samples are folded into
    /// an EWMA per-second rate.
    Rate,
}

impl CounterKind {
    pub fn as_str(self) -> &'static str {
        match self {
            CounterKind::Gauge => "gauge",
            CounterKind::Rate => "rate",
        }
    }
}

/// One `(t_nanos, value)` point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Nanoseconds since the registry epoch.
    pub t_nanos: u64,
    pub value: f64,
}

/// One ring slot: a seqlock triple. `seq` is odd while the writer is
/// mid-store and `2 * (generation + 1)` once the sample for `generation`
/// is fully published.
#[derive(Debug)]
struct Slot {
    seq: AtomicU64,
    t: AtomicU64,
    /// f64 bits.
    v: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot { seq: AtomicU64::new(0), t: AtomicU64::new(0), v: AtomicU64::new(0) }
    }
}

#[derive(Debug)]
struct Track {
    name: String,
    labels: Vec<(String, String)>,
    unit: &'static str,
    help: &'static str,
    kind: CounterKind,
    slots: Vec<Slot>,
    /// Lifetime publish count; `head` is stored last (release) so a reader
    /// that observes generation `g` in `head` can rely on slot `g % cap`
    /// having an even seq for some generation >= g.
    head: AtomicU64,
    // Rate bookkeeping. Written only by publishers; torn reads across the
    // three cells would merely perturb one EWMA step, and in practice each
    // track has a single publishing thread.
    prev_t: AtomicU64,
    prev_v: AtomicU64,
    has_prev: AtomicU64,
    /// EWMA per-second rate, f64 bits.
    ewma: AtomicU64,
}

impl Track {
    fn publish(&self, t_nanos: u64, value: f64) {
        if self.kind == CounterKind::Rate {
            self.fold_rate(t_nanos, value);
        }
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head as usize) % self.slots.len()];
        // canonical seqlock write: odd seq, release fence, data, even seq
        slot.seq.store(2 * head + 1, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::Release);
        slot.t.store(t_nanos, Ordering::Relaxed);
        slot.v.store(value.to_bits(), Ordering::Relaxed);
        slot.seq.store(2 * (head + 1), Ordering::Release);
        self.head.store(head + 1, Ordering::Release);
    }

    fn fold_rate(&self, t_nanos: u64, value: f64) {
        if self.has_prev.load(Ordering::Relaxed) == 1 {
            let pt = self.prev_t.load(Ordering::Relaxed);
            let pv = f64::from_bits(self.prev_v.load(Ordering::Relaxed));
            if t_nanos > pt {
                let dt = (t_nanos - pt) as f64 / 1e9;
                // clamp negative deltas (counter reset) to zero rate
                let rate = (value - pv).max(0.0) / dt;
                let alpha = 1.0 - (-dt / EWMA_TAU_S).exp();
                let old = f64::from_bits(self.ewma.load(Ordering::Relaxed));
                self.ewma.store((old + alpha * (rate - old)).to_bits(), Ordering::Relaxed);
            }
        }
        self.prev_t.store(t_nanos, Ordering::Relaxed);
        self.prev_v.store(value.to_bits(), Ordering::Relaxed);
        self.has_prev.store(1, Ordering::Relaxed);
    }

    /// Lock-free read of the retained samples, oldest first. A slot the
    /// writer is concurrently overwriting (odd seq, or seq from a newer
    /// generation) is skipped rather than waited on.
    fn samples(&self) -> Vec<Sample> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = head.saturating_sub(cap);
        let mut out = Vec::with_capacity((head - start) as usize);
        for g in start..head {
            let slot = &self.slots[(g % cap) as usize];
            let want = 2 * (g + 1);
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 != want {
                continue; // overwritten (or mid-overwrite) by a newer generation
            }
            let t = slot.t.load(Ordering::Relaxed);
            let v = f64::from_bits(slot.v.load(Ordering::Relaxed));
            // canonical seqlock read: acquire fence, then re-check seq
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == want {
                out.push(Sample { t_nanos: t, value: v });
            }
        }
        out
    }

    fn snapshot(&self) -> TrackSnapshot {
        TrackSnapshot {
            name: self.name.clone(),
            labels: self.labels.clone(),
            unit: self.unit,
            kind: self.kind,
            published: self.head.load(Ordering::Acquire),
            ewma_per_sec: match self.kind {
                CounterKind::Rate => Some(f64::from_bits(self.ewma.load(Ordering::Relaxed))),
                CounterKind::Gauge => None,
            },
            samples: self.samples(),
        }
    }
}

/// Point-in-time copy of one track: identity, the retained ring, and the
/// EWMA rate for [`CounterKind::Rate`] tracks.
#[derive(Debug, Clone)]
pub struct TrackSnapshot {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub unit: &'static str,
    pub kind: CounterKind,
    /// Lifetime publish count (`> samples.len()` means the ring wrapped).
    pub published: u64,
    pub ewma_per_sec: Option<f64>,
    /// Retained samples, oldest first.
    pub samples: Vec<Sample>,
}

impl TrackSnapshot {
    pub fn latest(&self) -> Option<Sample> {
        self.samples.last().copied()
    }

    /// Compact JSON: identity plus the latest sample (and EWMA rate), the
    /// shape the `--metrics-interval` JSONL stream carries per tick.
    pub fn to_json_latest(&self) -> Json {
        let mut pairs = vec![
            ("name", s(&self.name)),
            ("kind", s(self.kind.as_str())),
            ("unit", s(self.unit)),
            (
                "labels",
                obj(self.labels.iter().map(|(k, v)| (k.as_str(), s(v.as_str()))).collect()),
            ),
        ];
        if let Some(sm) = self.latest() {
            pairs.push(("t_ns", num(sm.t_nanos as f64)));
            pairs.push(("value", num(sm.value)));
        }
        if let Some(r) = self.ewma_per_sec {
            pairs.push(("ewma_per_sec", num(r)));
        }
        obj(pairs)
    }
}

/// Registry of counter tracks sharing one epoch. Registration takes a
/// short mutex; publishing and snapshotting never do.
#[derive(Debug)]
pub struct Counters {
    epoch: Instant,
    cap: usize,
    tracks: Mutex<Vec<Arc<Track>>>,
}

impl Default for Counters {
    fn default() -> Counters {
        Counters::new()
    }
}

impl Counters {
    pub fn new() -> Counters {
        Counters::with_epoch(Instant::now())
    }

    /// Share an epoch with another time source (the [`crate::obs::Tracer`])
    /// so counter samples and trace spans land on one timeline.
    pub fn with_epoch(epoch: Instant) -> Counters {
        Counters::with_capacity(epoch, DEFAULT_TRACK_CAPACITY)
    }

    pub fn with_capacity(epoch: Instant, cap: usize) -> Counters {
        Counters { epoch, cap: cap.max(2), tracks: Mutex::new(Vec::new()) }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Register (or re-attach to) the track with this `(name, labels)`
    /// identity. Idempotent: a second registration returns a handle on the
    /// same ring, so restarts and multiple publishers compose.
    pub fn register(
        &self,
        name: &str,
        labels: Vec<(String, String)>,
        unit: &'static str,
        help: &'static str,
        kind: CounterKind,
    ) -> CounterHandle {
        let mut tracks = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(t) = tracks.iter().find(|t| t.name == name && t.labels == labels) {
            return CounterHandle { track: Arc::clone(t), epoch: self.epoch };
        }
        let track = Arc::new(Track {
            name: name.to_string(),
            labels,
            unit,
            help,
            kind,
            slots: (0..self.cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            prev_t: AtomicU64::new(0),
            prev_v: AtomicU64::new(0),
            has_prev: AtomicU64::new(0),
            ewma: AtomicU64::new(0f64.to_bits()),
        });
        tracks.push(Arc::clone(&track));
        CounterHandle { track, epoch: self.epoch }
    }

    pub fn gauge(&self, name: &str, unit: &'static str, help: &'static str) -> CounterHandle {
        self.register(name, Vec::new(), unit, help, CounterKind::Gauge)
    }

    pub fn gauge_with(
        &self,
        name: &str,
        labels: Vec<(String, String)>,
        unit: &'static str,
        help: &'static str,
    ) -> CounterHandle {
        self.register(name, labels, unit, help, CounterKind::Gauge)
    }

    pub fn rate(&self, name: &str, unit: &'static str, help: &'static str) -> CounterHandle {
        self.register(name, Vec::new(), unit, help, CounterKind::Rate)
    }

    /// Help text for a track name (first registration wins).
    pub fn help_of(&self, name: &str) -> Option<&'static str> {
        let tracks = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
        tracks.iter().find(|t| t.name == name).map(|t| t.help)
    }

    /// Snapshot every track: identity + retained ring + rates. Lock-free
    /// except for cloning the (short) track list.
    pub fn snapshot(&self) -> Vec<TrackSnapshot> {
        let tracks: Vec<Arc<Track>> = {
            let guard = self.tracks.lock().unwrap_or_else(|e| e.into_inner());
            guard.clone()
        };
        tracks.iter().map(|t| t.snapshot()).collect()
    }
}

/// Cheap cloneable publishing handle on one track.
#[derive(Debug, Clone)]
pub struct CounterHandle {
    track: Arc<Track>,
    epoch: Instant,
}

impl CounterHandle {
    /// Publish a sample stamped "now".
    pub fn record(&self, value: f64) {
        self.track.publish(self.epoch.elapsed().as_nanos() as u64, value);
    }

    /// Publish with an explicit timestamp (nanoseconds since the registry
    /// epoch) — deterministic rate math in tests, or batched publication
    /// from a caller that already stamped the tick.
    pub fn record_at(&self, t_nanos: u64, value: f64) {
        self.track.publish(t_nanos, value);
    }

    /// Current EWMA per-second rate (0.0 for gauges or before two samples).
    pub fn ewma_per_sec(&self) -> f64 {
        f64::from_bits(self.track.ewma.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_keeping_the_newest_samples() {
        let c = Counters::with_capacity(Instant::now(), 8);
        let h = c.gauge("depth", "reqs", "test gauge");
        for i in 0..20u64 {
            h.record_at(i * 1_000, i as f64);
        }
        let snap = &c.snapshot()[0];
        assert_eq!(snap.published, 20);
        assert_eq!(snap.samples.len(), 8);
        let vals: Vec<f64> = snap.samples.iter().map(|s| s.value).collect();
        assert_eq!(vals, (12..20).map(|i| i as f64).collect::<Vec<_>>());
        let ts: Vec<u64> = snap.samples.iter().map(|s| s.t_nanos).collect();
        assert!(ts.windows(2).all(|w| w[0] < w[1]), "samples oldest-first: {ts:?}");
        assert_eq!(snap.latest().unwrap().value, 19.0);
    }

    #[test]
    fn under_capacity_keeps_everything() {
        let c = Counters::with_capacity(Instant::now(), 16);
        let h = c.gauge("x", "", "");
        for i in 0..5u64 {
            h.record_at(i, i as f64);
        }
        let snap = &c.snapshot()[0];
        assert_eq!(snap.samples.len(), 5);
        assert_eq!(snap.published, 5);
        assert!(snap.ewma_per_sec.is_none());
    }

    #[test]
    fn rate_track_ewma_matches_closed_form() {
        let c = Counters::with_capacity(Instant::now(), 16);
        let h = c.rate("bytes", "bytes", "cumulative");
        // steady 1000 bytes/sec in 1s steps: ewma_n = R * (1 - (1-a)^n)
        h.record_at(0, 0.0);
        let a = 1.0 - (-1.0 / EWMA_TAU_S).exp();
        let mut expect = 0.0;
        for i in 1..=5u64 {
            h.record_at(i * 1_000_000_000, (i * 1000) as f64);
            expect += a * (1000.0 - expect);
            let got = h.ewma_per_sec();
            assert!(
                (got - expect).abs() < 1e-9,
                "step {i}: ewma {got} != expected {expect}"
            );
        }
        let snap = &c.snapshot()[0];
        assert!((snap.ewma_per_sec.unwrap() - expect).abs() < 1e-9);
    }

    #[test]
    fn rate_counter_reset_clamps_to_zero_not_negative() {
        let c = Counters::new();
        let h = c.rate("bytes", "bytes", "");
        h.record_at(0, 1000.0);
        h.record_at(1_000_000_000, 0.0); // reset
        assert!(h.ewma_per_sec() >= 0.0);
    }

    #[test]
    fn registration_is_idempotent_by_name_and_labels() {
        let c = Counters::new();
        let l = vec![("layer".to_string(), "03".to_string())];
        let a = c.gauge_with("layer_kv_live", l.clone(), "bytes", "");
        let b = c.gauge_with("layer_kv_live", l, "bytes", "");
        a.record_at(1, 7.0);
        b.record_at(2, 8.0);
        let snaps = c.snapshot();
        assert_eq!(snaps.len(), 1, "same identity must share one ring");
        assert_eq!(snaps[0].samples.len(), 2);
        // different labels → distinct track
        c.gauge_with("layer_kv_live", vec![("layer".into(), "04".into())], "bytes", "");
        assert_eq!(c.snapshot().len(), 2);
    }

    #[test]
    fn concurrent_publish_and_snapshot_stay_coherent() {
        use std::sync::atomic::AtomicBool;
        let c = Arc::new(Counters::with_capacity(Instant::now(), 32));
        let h = c.gauge("hot", "", "");
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    h.record_at(i, i as f64);
                    i += 1;
                }
                i
            })
        };
        for _ in 0..200 {
            for snap in c.snapshot() {
                // every accepted sample must be internally consistent
                for sm in &snap.samples {
                    assert_eq!(sm.t_nanos as f64, sm.value);
                }
                let ts: Vec<u64> = snap.samples.iter().map(|s| s.t_nanos).collect();
                assert!(ts.windows(2).all(|w| w[0] < w[1]), "monotone: {ts:?}");
            }
        }
        stop.store(true, Ordering::Relaxed);
        let total = writer.join().unwrap();
        assert!(total > 0);
        assert_eq!(c.snapshot()[0].published, total);
    }

    #[test]
    fn latest_json_round_trips() {
        let c = Counters::new();
        let h = c.gauge_with(
            "pool_blocks_live",
            vec![("engine".into(), "tuned".into())],
            "blocks",
            "live device pages",
        );
        h.record_at(5_000, 17.0);
        let j = c.snapshot()[0].to_json_latest();
        let re = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(re.get("name").unwrap().as_str().unwrap(), "pool_blocks_live");
        assert_eq!(re.get("kind").unwrap().as_str().unwrap(), "gauge");
        assert_eq!(re.get("value").unwrap().as_f64().unwrap(), 17.0);
        assert_eq!(
            re.get("labels").unwrap().get("engine").unwrap().as_str().unwrap(),
            "tuned"
        );
    }
}
