//! Bounded log-bucket latency histogram (HDR-style, fixed bucket count).
//!
//! 64 geometric buckets span 1µs to 1000s (nine decades, ratio
//! `R = 10^(9/64) ≈ 1.38` per bucket), each an atomic counter: recording is
//! two relaxed atomic adds, memory is constant regardless of sample count,
//! and a snapshot copies the counters without sorting or mutating anything.
//! A percentile is reported as the geometric midpoint of its bucket, so the
//! worst-case relative error is `sqrt(R) - 1 ≈ 17.6%` — bounded by
//! construction, and the tolerance the oracle tests check against.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::{arr, num, obj, Json};

/// Number of buckets; fixed so the struct is allocation-free.
pub const BUCKETS: usize = 64;
/// Lower bound of bucket 0 in nanoseconds (1µs). Samples below it land in
/// bucket 0 (reported as ~1µs — serving-path latencies never sit there).
const MIN_NANOS: f64 = 1e3;
/// Decades covered: 1µs .. 1e3 * 10^9 ns = 1000s. Larger samples saturate
/// into the last bucket.
const DECADES: f64 = 9.0;

/// log10 bucket width: each bucket covers a `10^(DECADES/BUCKETS)` ratio.
fn bucket_width_log10() -> f64 {
    DECADES / BUCKETS as f64
}

/// Bucket index for a sample (saturating at both ends).
pub fn bucket_index(nanos: u64) -> usize {
    let n = nanos as f64;
    if n <= MIN_NANOS {
        return 0;
    }
    let i = ((n / MIN_NANOS).log10() / bucket_width_log10()) as usize;
    i.min(BUCKETS - 1)
}

/// `[lo, hi)` bounds of one bucket in nanoseconds.
pub fn bucket_bounds(i: usize) -> (f64, f64) {
    let w = bucket_width_log10();
    (
        MIN_NANOS * 10f64.powf(i as f64 * w),
        MIN_NANOS * 10f64.powf((i + 1) as f64 * w),
    )
}

/// Representative value of a bucket: the geometric midpoint of its bounds.
fn bucket_value(i: usize) -> f64 {
    let (lo, hi) = bucket_bounds(i);
    (lo * hi).sqrt()
}

/// All-atomic histogram; `&self` recording from any thread.
#[derive(Debug)]
pub struct LogHistogram {
    counts: [AtomicU64; BUCKETS],
    total: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    pub fn record(&self, d: Duration) {
        self.record_nanos(d.as_nanos() as u64);
    }

    pub fn record_secs(&self, secs: f64) {
        self.record_nanos((secs.max(0.0) * 1e9) as u64);
    }

    pub fn record_nanos(&self, nanos: u64) {
        self.counts[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
            total: self.total.load(Ordering::Relaxed),
            sum_nanos: self.sum_nanos.load(Ordering::Relaxed),
        }
    }
}

/// Immutable copy of the counters; all reads (percentiles, mean, JSON) run
/// off this, so the live histogram is never locked or mutated.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub total: u64,
    pub sum_nanos: u64,
}

impl HistSnapshot {
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Percentile in seconds (0.0 for an empty histogram). `p` in [0, 1];
    /// the returned value is the geometric midpoint of the bucket holding
    /// the rank-`ceil(p * total)` sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_value(i) / 1e9;
            }
        }
        bucket_value(BUCKETS - 1) / 1e9
    }

    /// Exact mean in seconds (the sum is tracked outside the buckets).
    pub fn mean_secs(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_nanos as f64 / self.total as f64 / 1e9
        }
    }

    /// Machine-readable dump: quantiles plus the non-empty buckets as
    /// `[index, count]` pairs (the full shape stays diffable without 64
    /// mostly-zero entries).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| arr(vec![num(i as f64), num(c as f64)]))
            .collect();
        obj(vec![
            ("count", num(self.total as f64)),
            ("mean_s", num(self.mean_secs())),
            ("p50_s", num(self.percentile(0.50))),
            ("p95_s", num(self.percentile(0.95))),
            ("p99_s", num(self.percentile(0.99))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One bucket's ratio: the tolerance a bucketed percentile is allowed
    /// to deviate from a sorted-reference oracle by (midpoint reporting
    /// guarantees sqrt of this; a rank landing one sample over a boundary
    /// costs at most the full ratio).
    fn bucket_ratio() -> f64 {
        10f64.powf(DECADES / BUCKETS as f64)
    }

    #[test]
    fn bucket_boundaries_saturate_and_stay_monotonic() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(999), 0);
        assert_eq!(bucket_index(1_000), 0);
        // a sample just past a bucket's upper bound lands in the next bucket
        let (_, hi0) = bucket_bounds(0);
        assert_eq!(bucket_index(hi0 as u64 + 1), 1);
        // the top of the range saturates instead of indexing out of bounds
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_index(2_000_000_000_000), BUCKETS - 1); // 2000 s
        let mut prev = 0usize;
        for e in 0..12 {
            let i = bucket_index(10u64.pow(e));
            assert!(i >= prev, "bucket index must be monotone in the sample");
            prev = i;
        }
    }

    #[test]
    fn bounds_tile_the_range() {
        for i in 0..BUCKETS - 1 {
            let (_, hi) = bucket_bounds(i);
            let (lo_next, _) = bucket_bounds(i + 1);
            assert!((hi - lo_next).abs() / hi < 1e-12, "buckets must tile without gaps");
        }
        let (lo, _) = bucket_bounds(0);
        assert_eq!(lo, MIN_NANOS);
        let (_, hi) = bucket_bounds(BUCKETS - 1);
        assert!((hi / 1e12 - 1.0).abs() < 1e-9, "range top is 1000 s");
    }

    #[test]
    fn percentiles_match_sorted_oracle_within_bucket_tolerance() {
        let h = LogHistogram::default();
        let mut samples: Vec<f64> = Vec::new();
        // deterministic multiplicative scramble over ~4 decades (µs..10ms)
        let mut x = 1u64;
        for _ in 0..2000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let nanos = 1_000 + x % 10_000_000;
            h.record_nanos(nanos);
            samples.push(nanos as f64 / 1e9);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = h.snapshot();
        let tol = bucket_ratio();
        for &p in &[0.5, 0.9, 0.95, 0.99] {
            let oracle = samples[(((samples.len() as f64) * p).ceil() as usize - 1).min(samples.len() - 1)];
            let got = s.percentile(p);
            let ratio = got / oracle;
            assert!(
                ratio < tol && ratio > 1.0 / tol,
                "p{p}: histogram {got:.6}s vs oracle {oracle:.6}s (ratio {ratio:.3}, tol {tol:.3})"
            );
        }
    }

    #[test]
    fn empty_and_single_sample() {
        let h = LogHistogram::default();
        assert_eq!(h.snapshot().percentile(0.99), 0.0);
        assert_eq!(h.snapshot().mean_secs(), 0.0);
        h.record(Duration::from_millis(5));
        let s = h.snapshot();
        let tol = bucket_ratio().sqrt() * 1.0001;
        for &p in &[0.0, 0.5, 1.0] {
            let v = s.percentile(p);
            assert!(v / 0.005 < tol && 0.005 / v < tol, "single sample p{p} = {v}");
        }
        assert!((s.mean_secs() - 0.005).abs() < 1e-9, "mean is exact, not bucketed");
    }

    #[test]
    fn json_roundtrip_parses() {
        let h = LogHistogram::default();
        for ms in [1u64, 2, 4, 8, 1000] {
            h.record(Duration::from_millis(ms));
        }
        let j = h.snapshot().to_json();
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.get("count").unwrap().as_usize().unwrap(), 5);
        assert!(re.get("p99_s").unwrap().as_f64().unwrap() > 0.5);
    }
}
