//! Zero-cost-when-disabled per-layer / per-kernel profiling.
//!
//! The engines hold a [`Profiler`] and bracket each phase of the forward
//! pass with `start()` / `stop()`. Disabled (the default), `start()` returns
//! `None` without reading a clock and `stop()` of a `None` is a branch on an
//! immutable bool — nothing is timed, nothing is written, and the
//! bit-exactness suites run unchanged. Enabled (`--profile-serve` or
//! `KVTUNER_PROFILE=1`), each phase costs two `Instant` reads and two
//! relaxed atomic adds into a flat `(layers + 1) × phases` table; the extra
//! row holds the model-level lm_head projection, which no layer owns.
//!
//! Alongside timings, the engines feed per-layer *live KV bytes* (what the
//! cache actually holds right now, not its capacity) so the per-layer table
//! shows where the precision map puts the memory — the signal the runtime
//! precision-adaptation roadmap item needs. Peaks are kept with `fetch_max`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::bench::{fmt_secs, Table};
use crate::util::json::{num, obj, s, Json};

/// Phases of one forward step. Native instruments the first five; the XLA
/// arm cannot see inside a compiled layer so it reports the whole-layer
/// [`Phase::Exec`] plus the commit/lm_head phases it runs host-side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// RMS norm + q/k/v projections + RoPE.
    Qkv,
    /// Quantize-and-commit of the new KV row/block into the cache.
    QuantCommit,
    /// Attention over the cache + output projection + residual.
    Attend,
    /// Second norm + FFN + residual.
    Mlp,
    /// Final norm + vocab projection (model-level row).
    LmHead,
    /// Whole-layer device execution (XLA arm only).
    Exec,
}

pub const N_PHASES: usize = 6;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::Qkv,
        Phase::QuantCommit,
        Phase::Attend,
        Phase::Mlp,
        Phase::LmHead,
        Phase::Exec,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Qkv => "qkv",
            Phase::QuantCommit => "quant_commit",
            Phase::Attend => "attend",
            Phase::Mlp => "mlp",
            Phase::LmHead => "lm_head",
            Phase::Exec => "exec",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Qkv => 0,
            Phase::QuantCommit => 1,
            Phase::Attend => 2,
            Phase::Mlp => 3,
            Phase::LmHead => 4,
            Phase::Exec => 5,
        }
    }
}

/// Flat atomic accumulator table; `&self` recording from the engine's
/// worker threads (output-partitioned threading never splits a phase across
/// layers, so per-cell relaxed adds are exact).
#[derive(Debug, Default)]
pub struct Profiler {
    enabled: bool,
    /// One label per layer (precision-pair string), plus a final "lm_head"
    /// row for the model-level projection.
    labels: Vec<String>,
    nanos: Vec<AtomicU64>,
    counts: Vec<AtomicU64>,
    kv_live_peak: Vec<AtomicU64>,
}

impl Profiler {
    /// The default state: no rows, no clock reads, `snapshot()` is `None`.
    pub fn disabled() -> Profiler {
        Profiler::default()
    }

    /// Enabled profiler with one row per layer label plus the lm_head row.
    pub fn new(layer_labels: Vec<String>) -> Profiler {
        let mut labels = layer_labels;
        labels.push("lm_head".to_string());
        let cells = labels.len() * N_PHASES;
        Profiler {
            enabled: true,
            nanos: (0..cells).map(|_| AtomicU64::new(0)).collect(),
            counts: (0..cells).map(|_| AtomicU64::new(0)).collect(),
            kv_live_peak: (0..labels.len()).map(|_| AtomicU64::new(0)).collect(),
            labels,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Row index of the model-level lm_head row (pass as `layer` with
    /// [`Phase::LmHead`]).
    pub fn lm_head_row(&self) -> usize {
        self.labels.len().saturating_sub(1)
    }

    /// Begin timing a phase; `None` when disabled, so the hot path pays one
    /// predictable branch and no clock read.
    #[inline]
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a phase opened by [`Profiler::start`]. A `None` token (the
    /// disabled path) is a no-op.
    #[inline]
    pub fn stop(&self, layer: usize, phase: Phase, t0: Option<Instant>) {
        let Some(t0) = t0 else { return };
        let cell = layer * N_PHASES + phase.idx();
        if cell < self.nanos.len() {
            self.nanos[cell].fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.counts[cell].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a layer's current live KV bytes; the snapshot keeps the peak.
    #[inline]
    pub fn note_kv_live(&self, layer: usize, bytes: u64) {
        if self.enabled {
            if let Some(c) = self.kv_live_peak.get(layer) {
                c.fetch_max(bytes, Ordering::Relaxed);
            }
        }
    }

    pub fn snapshot(&self) -> Option<ProfileSnapshot> {
        if !self.enabled {
            return None;
        }
        let layers = self
            .labels
            .iter()
            .enumerate()
            .map(|(row, label)| LayerProfile {
                label: label.clone(),
                nanos: std::array::from_fn(|p| {
                    self.nanos[row * N_PHASES + p].load(Ordering::Relaxed)
                }),
                counts: std::array::from_fn(|p| {
                    self.counts[row * N_PHASES + p].load(Ordering::Relaxed)
                }),
                kv_live_peak: self.kv_live_peak[row].load(Ordering::Relaxed),
            })
            .collect();
        Some(ProfileSnapshot { layers })
    }
}

/// One row of the per-layer profile: accumulated nanos and call counts per
/// phase plus the peak live KV bytes observed for that layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerProfile {
    pub label: String,
    pub nanos: [u64; N_PHASES],
    pub counts: [u64; N_PHASES],
    pub kv_live_peak: u64,
}

impl LayerProfile {
    pub fn nanos_of(&self, p: Phase) -> u64 {
        self.nanos[p.idx()]
    }

    pub fn calls_of(&self, p: Phase) -> u64 {
        self.counts[p.idx()]
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProfileSnapshot {
    pub layers: Vec<LayerProfile>,
}

impl ProfileSnapshot {
    /// Total nanos across all rows and phases.
    pub fn total_nanos(&self) -> u64 {
        self.layers.iter().map(|l| l.nanos.iter().sum::<u64>()).sum()
    }

    /// Per-layer table: one row per layer (label = precision pair), one
    /// column per phase, plus the peak live KV bytes.
    pub fn table(&self, title: &str) -> Table {
        let mut header = vec!["layer".to_string(), "spec".to_string()];
        header.extend(Phase::ALL.iter().map(|p| p.as_str().to_string()));
        header.push("kv live peak".to_string());
        let mut t = Table::with_headers(title, header);
        for (i, l) in self.layers.iter().enumerate() {
            let mut cells = vec![
                if i + 1 == self.layers.len() { "-".to_string() } else { i.to_string() },
                l.label.clone(),
            ];
            cells.extend(Phase::ALL.iter().map(|p| {
                let n = l.nanos_of(*p);
                if n == 0 {
                    "-".to_string()
                } else {
                    fmt_secs(n as f64 / 1e9)
                }
            }));
            cells.push(if l.kv_live_peak == 0 {
                "-".to_string()
            } else {
                format!("{:.1}KiB", l.kv_live_peak as f64 / 1024.0)
            });
            t.row(cells);
        }
        t
    }

    /// Machine-readable dump: per layer, the non-empty phases as
    /// `{nanos, calls}` plus the live-KV peak.
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                let phases: Vec<(&str, Json)> = Phase::ALL
                    .iter()
                    .filter(|p| l.calls_of(**p) > 0)
                    .map(|p| {
                        (
                            p.as_str(),
                            obj(vec![
                                ("nanos", num(l.nanos_of(*p) as f64)),
                                ("calls", num(l.calls_of(*p) as f64)),
                            ]),
                        )
                    })
                    .collect();
                obj(vec![
                    ("label", s(l.label.as_str())),
                    ("kv_live_peak_bytes", num(l.kv_live_peak as f64)),
                    ("phases", obj(phases)),
                ])
            })
            .collect();
        obj(vec![("layers", Json::Arr(layers))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_inert() {
        let p = Profiler::disabled();
        assert!(!p.enabled());
        assert!(p.start().is_none());
        p.stop(0, Phase::Qkv, None);
        p.note_kv_live(0, 1 << 20);
        assert!(p.snapshot().is_none());
    }

    #[test]
    fn accumulates_per_layer_and_phase() {
        let p = Profiler::new(vec!["kivi K8V4".into(), "kivi K4V2".into()]);
        let t0 = p.start();
        assert!(t0.is_some());
        p.stop(0, Phase::Qkv, t0);
        p.stop(1, Phase::Attend, p.start());
        p.stop(1, Phase::Attend, p.start());
        p.stop(p.lm_head_row(), Phase::LmHead, p.start());
        let snap = p.snapshot().unwrap();
        assert_eq!(snap.layers.len(), 3, "two layers plus the lm_head row");
        assert_eq!(snap.layers[0].calls_of(Phase::Qkv), 1);
        assert_eq!(snap.layers[1].calls_of(Phase::Attend), 2);
        assert_eq!(snap.layers[2].label, "lm_head");
        assert_eq!(snap.layers[2].calls_of(Phase::LmHead), 1);
        assert_eq!(snap.layers[0].calls_of(Phase::Mlp), 0);
    }

    #[test]
    fn kv_live_keeps_the_peak() {
        let p = Profiler::new(vec!["l0".into()]);
        p.note_kv_live(0, 100);
        p.note_kv_live(0, 300);
        p.note_kv_live(0, 200);
        assert_eq!(p.snapshot().unwrap().layers[0].kv_live_peak, 300);
    }

    #[test]
    fn out_of_range_rows_are_ignored() {
        let p = Profiler::new(vec!["l0".into()]);
        p.stop(99, Phase::Qkv, p.start());
        p.note_kv_live(99, 7);
        let snap = p.snapshot().unwrap();
        assert_eq!(snap.total_nanos(), 0);
        assert!(snap.layers.iter().all(|l| l.kv_live_peak == 0));
    }

    #[test]
    fn table_and_json_shapes() {
        let p = Profiler::new(vec!["kivi K8V4".into()]);
        p.stop(0, Phase::Qkv, p.start());
        p.note_kv_live(0, 2048);
        let snap = p.snapshot().unwrap();
        let t = snap.table("profile");
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.header.len(), 2 + N_PHASES + 1);
        let j = Json::parse(&snap.to_json().to_string_pretty()).unwrap();
        let layers = j.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert!(layers[0].get("phases").unwrap().get("qkv").is_ok());
        assert_eq!(
            layers[0].get("kv_live_peak_bytes").unwrap().as_usize().unwrap(),
            2048
        );
    }
}
