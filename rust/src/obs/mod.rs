//! Serving-path observability: the measurement substrate the scheduler,
//! engines and CLI feed, and that the throughput/latency roadmap items are
//! judged against.
//!
//! Three pieces, all lock-light and artifact-free:
//!
//! * [`hist::LogHistogram`] — bounded HDR-style latency histograms: 64
//!   geometric buckets spanning 1µs..1000s with atomic counts, so recording
//!   is a couple of relaxed atomic adds and a snapshot never sorts or
//!   mutates anything (the previous metrics path pushed every sample into a
//!   `Vec` forever and re-sorted it under a mutex per snapshot).
//! * [`trace`] — request lifecycle tracing: a fixed-capacity ring of typed
//!   events (admit, prefill chunk, decode step, preempt, swap out/in,
//!   resume, complete) stamped with request id / worker / slot / monotonic
//!   nanos, exportable as Chrome trace-event JSON (one track per slot,
//!   loadable in Perfetto) or JSONL.
//! * [`profile::Profiler`] — zero-cost-when-disabled per-layer phase timers
//!   (qkv, quantize-commit, attend, mlp, lm head, whole-layer exec on the
//!   XLA arm) plus per-layer live-KV-byte peaks broken down by precision
//!   pair, fed by the engines and dumped as a per-layer table / JSON.
//! * [`sensitivity::SensitivityProbe`] — a sampled online twin of the
//!   offline error profiler: fp shadows of committed KIVI groups run the
//!   same simulated quantize→dequantize [`crate::quant::error`] pipeline,
//!   accumulated per (layer, mode, pair) in an atomic table
//!   (`--sensitivity-out`), drift-checked against the offline
//!   [`sensitivity::Envelope`] and streamable mid-run
//!   (`--metrics-interval`).

pub mod hist;
pub mod profile;
pub mod sensitivity;
pub mod trace;

pub use hist::{HistSnapshot, LogHistogram};
pub use profile::{LayerProfile, Phase, ProfileSnapshot, Profiler};
pub use sensitivity::{
    Envelope, EnvelopeBound, LayerSensitivity, ProbeConfig, SensitivityProbe, SensitivityShared,
    SensitivitySnapshot,
};
pub use trace::{EventKind, TraceEvent, TraceSink, Tracer};
