//! Serving-path observability: the measurement substrate the scheduler,
//! engines and CLI feed, and that the throughput/latency roadmap items are
//! judged against.
//!
//! Three pieces, all lock-light and artifact-free:
//!
//! * [`hist::LogHistogram`] — bounded HDR-style latency histograms: 64
//!   geometric buckets spanning 1µs..1000s with atomic counts, so recording
//!   is a couple of relaxed atomic adds and a snapshot never sorts or
//!   mutates anything (the previous metrics path pushed every sample into a
//!   `Vec` forever and re-sorted it under a mutex per snapshot).
//! * [`trace`] — request lifecycle tracing: a fixed-capacity ring of typed
//!   events (admit, prefill chunk, decode step, preempt, swap out/in,
//!   resume, complete) stamped with request id / worker / slot / monotonic
//!   nanos, exportable as Chrome trace-event JSON (one track per slot,
//!   loadable in Perfetto) or JSONL.
//! * [`profile::Profiler`] — zero-cost-when-disabled per-layer phase timers
//!   (qkv, quantize-commit, attend, mlp, lm head, whole-layer exec on the
//!   XLA arm) plus per-layer live-KV-byte peaks broken down by precision
//!   pair, fed by the engines and dumped as a per-layer table / JSON.
//! * [`sensitivity::SensitivityProbe`] — a sampled online twin of the
//!   offline error profiler: fp shadows of committed KIVI groups run the
//!   same simulated quantize→dequantize [`crate::quant::error`] pipeline,
//!   accumulated per (layer, mode, pair) in an atomic table
//!   (`--sensitivity-out`), drift-checked against the offline
//!   [`sensitivity::Envelope`] and streamable mid-run
//!   (`--metrics-interval`).
//! * [`counters::Counters`] — named time-series counter tracks (bounded
//!   seqlock sample rings, gauge + monotonic-rate flavors with EWMA
//!   bandwidth) fed per scheduler tick with memory-hierarchy occupancy:
//!   page-pool blocks, per-layer-per-precision live KV bytes, host swap
//!   arena, swap/gather byte rates, queue depths, batch width.
//! * [`export`] — pull-based exporters over all of the above: Prometheus
//!   text exposition served from a std-`TcpListener` responder
//!   (`--metrics-listen`), and Chrome trace counter events (`"ph": "C"`)
//!   interleaved into the `--trace-out` export so Perfetto plots
//!   occupancy/bandwidth curves under the lifecycle spans.

pub mod counters;
pub mod export;
pub mod hist;
pub mod profile;
pub mod sensitivity;
pub mod trace;

/// Wire schema version stamped on every machine-readable telemetry
/// surface: `Snapshot::to_json`, the `--metrics-interval` JSONL stream,
/// the Prometheus exposition, and both trace export formats. Bump on any
/// breaking change to field names or shapes; the CI validators reject a
/// mismatch. v1 was the implicit pre-versioned schema of PRs 6–7; v2
/// added counter tracks, trace-drop accounting and the version stamp
/// itself.
pub const SCHEMA_VERSION: u64 = 2;

pub use counters::{CounterHandle, CounterKind, Counters, Sample, TrackSnapshot};
pub use export::{
    chrome_counter_events, chrome_trace_json, render_tracks, write_trace, Exposition,
    MetricsServer,
};
pub use hist::{HistSnapshot, LogHistogram};
pub use profile::{LayerProfile, Phase, ProfileSnapshot, Profiler};
pub use sensitivity::{
    Envelope, EnvelopeBound, LayerSensitivity, ProbeConfig, SensitivityProbe, SensitivityShared,
    SensitivitySnapshot,
};
pub use trace::{EventKind, TraceEvent, TraceSink, Tracer};
