//! Pull-based exporters for the observability substrate: Prometheus
//! text-exposition rendering of counter tracks and snapshot aggregates, a
//! minimal std-`TcpListener` HTTP responder serving `/metrics`
//! (`--metrics-listen ADDR` — no HTTP stack, no new deps), and the
//! Chrome-trace writer that interleaves counter events (`"ph": "C"`) with
//! the lifecycle spans so Perfetto renders occupancy/bandwidth curves
//! under the per-slot tracks.
//!
//! Exposition conventions: every metric is prefixed `kvtuner_`, every
//! per-engine series carries an `engine` label, [`CounterKind::Rate`]
//! tracks export as a `counter` named `<track>_total` plus a
//! `<track>_ewma_per_sec` gauge, and `kvtuner_schema_version` stamps the
//! wire schema (see [`crate::obs::SCHEMA_VERSION`]).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};

use super::counters::{CounterKind, TrackSnapshot};
use super::trace::Tracer;
use super::SCHEMA_VERSION;

/// Accumulates samples grouped by metric name, then renders the Prometheus
/// text exposition format (version 0.0.4): all samples of one metric under
/// a single `# HELP` / `# TYPE` header, labels escaped, one sample per
/// line.
#[derive(Debug, Default)]
pub struct Exposition {
    metrics: BTreeMap<String, Metric>,
}

#[derive(Debug)]
struct Metric {
    kind: &'static str,
    help: String,
    /// (sample name, rendered labels, value) — the sample name is usually
    /// the family name, but summaries also carry `_count`/`_sum` children.
    samples: Vec<(String, String, f64)>,
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

/// True iff `name` is a legal Prometheus metric name.
pub fn valid_metric_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().enumerate().all(|(i, c)| {
            c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit())
        })
}

impl Exposition {
    pub fn new() -> Exposition {
        let mut e = Exposition::default();
        e.add(
            "kvtuner_schema_version",
            "gauge",
            "wire schema version of every kvtuner telemetry surface",
            &[],
            SCHEMA_VERSION as f64,
        );
        e
    }

    /// Add one sample. The first `(kind, help)` seen for a family wins;
    /// all samples of that family render under one header regardless of
    /// insertion order.
    pub fn add(
        &mut self,
        name: &str,
        kind: &'static str,
        help: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.add_suffixed(name, "", kind, help, labels, value);
    }

    /// Add a child sample of family `name` whose sample name is
    /// `name<suffix>` — how a summary's `_count`/`_sum` series land under
    /// the parent family's single `# TYPE` header.
    pub fn add_suffixed(
        &mut self,
        name: &str,
        suffix: &str,
        kind: &'static str,
        help: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        debug_assert!(valid_metric_name(name), "bad metric name {name:?}");
        let rendered = if labels.is_empty() {
            String::new()
        } else {
            let body: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
            format!("{{{}}}", body.join(","))
        };
        self.metrics
            .entry(name.to_string())
            .or_insert_with(|| Metric { kind, help: help.to_string(), samples: Vec::new() })
            .samples
            .push((format!("{name}{suffix}"), rendered, value));
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (family, m) in &self.metrics {
            out.push_str(&format!("# HELP {family} {}\n", escape_help(&m.help)));
            out.push_str(&format!("# TYPE {family} {}\n", m.kind));
            for (name, labels, v) in &m.samples {
                if v.is_nan() {
                    out.push_str(&format!("{name}{labels} NaN\n"));
                } else if v.is_infinite() {
                    let sign = if *v < 0.0 { "-" } else { "+" };
                    out.push_str(&format!("{name}{labels} {sign}Inf\n"));
                } else {
                    out.push_str(&format!("{name}{labels} {v}\n"));
                }
            }
        }
        out
    }
}

/// Render one engine's counter tracks into the exposition: the latest
/// sample of every track, gauges as-is, rate tracks as `_total` counter +
/// `_ewma_per_sec` gauge.
pub fn render_tracks(expo: &mut Exposition, engine: &str, tracks: &[TrackSnapshot]) {
    for t in tracks {
        let Some(latest) = t.latest() else { continue };
        let mut labels: Vec<(&str, &str)> = vec![("engine", engine)];
        for (k, v) in &t.labels {
            labels.push((k.as_str(), v.as_str()));
        }
        match t.kind {
            CounterKind::Gauge => {
                expo.add(&format!("kvtuner_{}", t.name), "gauge", t.unit, &labels, latest.value);
            }
            CounterKind::Rate => {
                expo.add(
                    &format!("kvtuner_{}_total", t.name),
                    "counter",
                    t.unit,
                    &labels,
                    latest.value,
                );
                expo.add(
                    &format!("kvtuner_{}_ewma_per_sec", t.name),
                    "gauge",
                    t.unit,
                    &labels,
                    t.ewma_per_sec.unwrap_or(0.0),
                );
            }
        }
    }
}

/// Minimal HTTP responder for `/metrics`: a nonblocking accept loop on a
/// dedicated thread, rendering the exposition per scrape via the supplied
/// closure. Anything but `GET /metrics` (or `/`) gets a 404. Connection
/// handling is strictly one-shot (`Connection: close`).
#[derive(Debug)]
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl MetricsServer {
    pub fn start<F>(addr: &str, render: F) -> Result<MetricsServer>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // serve inline: scrapes are tiny and infrequent
                            let _ = handle(stream, &render);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })?;
        Ok(MetricsServer { addr: local, stop, join: Some(join) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle<F: Fn() -> String>(mut stream: TcpStream, render: &F) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // read until the end of the request head (or a sane cap)
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, body): (&str, String) = if method != "GET" {
        ("405 Method Not Allowed", "method not allowed\n".into())
    } else if path == "/metrics" || path.starts_with("/metrics?") || path == "/" {
        ("200 OK", render())
    } else {
        ("404 Not Found", "not found; scrape /metrics\n".into())
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

/// Chrome trace-event counter events (`"ph": "C"`) for one worker's
/// tracks: one named counter track per series, every retained ring sample
/// a point, so Perfetto draws the occupancy/bandwidth curve under that
/// worker's lifecycle spans. Per-layer series keep their labels in the
/// series key inside `args`, which Perfetto stacks on one track.
pub fn chrome_counter_events(worker: u32, tracks: &[TrackSnapshot]) -> Vec<Json> {
    let mut out = Vec::new();
    for t in tracks {
        let series = if t.labels.is_empty() {
            t.unit.to_string()
        } else {
            t.labels.iter().map(|(_, v)| v.as_str()).collect::<Vec<_>>().join(" ")
        };
        let series = if series.is_empty() { "value".to_string() } else { series };
        // gauges plot as-is; rate tracks plot the point-to-point bandwidth
        // between retained samples (the unbounded cumulative total would
        // render as a useless monotone ramp)
        let points: Vec<(u64, f64)> = match t.kind {
            CounterKind::Gauge => t.samples.iter().map(|sm| (sm.t_nanos, sm.value)).collect(),
            CounterKind::Rate => t
                .samples
                .windows(2)
                .filter(|w| w[1].t_nanos > w[0].t_nanos)
                .map(|w| {
                    let dt = (w[1].t_nanos - w[0].t_nanos) as f64 / 1e9;
                    (w[1].t_nanos, (w[1].value - w[0].value).max(0.0) / dt)
                })
                .collect(),
        };
        let name = match t.kind {
            CounterKind::Gauge => t.name.clone(),
            CounterKind::Rate => format!("{}_per_sec", t.name),
        };
        for (t_nanos, value) in points {
            out.push(obj(vec![
                ("name", s(name.as_str())),
                ("cat", s("kvtuner_counters")),
                ("ph", s("C")),
                ("ts", num(t_nanos as f64 / 1e3)),
                ("pid", num(worker as f64)),
                ("tid", num(0.0)),
                ("args", obj(vec![(series.as_str(), num(value))])),
            ]));
        }
    }
    out
}

/// Full Chrome trace document: the tracer's span/instant events plus
/// counter events for every worker's tracks, with ring-drop accounting at
/// the top level (Perfetto ignores unknown keys).
pub fn chrome_trace_json(tracer: &Tracer, counters: &[(u32, Vec<TrackSnapshot>)]) -> Json {
    let doc = tracer.to_chrome_json();
    let mut events: Vec<Json> = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map(|a| a.to_vec())
        .unwrap_or_default();
    for (worker, tracks) in counters {
        events.extend(chrome_counter_events(*worker, tracks));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", s("ms")),
        ("schema_version", num(SCHEMA_VERSION as f64)),
        ("droppedEvents", num(tracer.dropped() as f64)),
        ("totalEvents", num(tracer.total() as f64)),
    ])
}

/// Write a trace with counter tracks interleaved: `.jsonl` keeps the
/// line-per-event format (meta line first, then events, then one
/// `counter_track` line per series with the retained samples); anything
/// else writes the merged Chrome JSON.
pub fn write_trace(
    path: &std::path::Path,
    tracer: &Tracer,
    counters: &[(u32, Vec<TrackSnapshot>)],
) -> Result<()> {
    let body = if path.extension().is_some_and(|e| e == "jsonl") {
        let mut body = tracer.to_jsonl();
        for (worker, tracks) in counters {
            for t in tracks {
                let j = obj(vec![
                    ("kind", s("counter_track")),
                    ("worker", num(*worker as f64)),
                    ("name", s(t.name.as_str())),
                    (
                        "labels",
                        obj(t.labels.iter().map(|(k, v)| (k.as_str(), s(v.as_str()))).collect()),
                    ),
                    ("track_kind", s(t.kind.as_str())),
                    ("unit", s(t.unit)),
                    ("ewma_per_sec", num(t.ewma_per_sec.unwrap_or(0.0))),
                    (
                        "samples",
                        Json::Arr(
                            t.samples
                                .iter()
                                .map(|sm| {
                                    obj(vec![
                                        ("t_ns", num(sm.t_nanos as f64)),
                                        ("value", num(sm.value)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]);
                body.push_str(&j.to_string_compact());
                body.push('\n');
            }
        }
        body
    } else {
        chrome_trace_json(tracer, counters).to_string_pretty()
    };
    std::fs::write(path, body)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::counters::Counters;
    use super::*;

    /// Strict line-by-line parse of the text exposition format: HELP/TYPE
    /// comments, then `name{labels} value` samples.
    fn check_exposition(body: &str) -> usize {
        let mut samples = 0;
        let mut typed: BTreeMap<String, String> = BTreeMap::new();
        for line in body.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# HELP ") {
                assert!(rest.split_whitespace().next().is_some(), "HELP without name: {line}");
                continue;
            }
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().expect("TYPE without name").to_string();
                let kind = it.next().expect("TYPE without kind").to_string();
                let kinds = ["gauge", "counter", "summary", "histogram", "untyped"];
                assert!(kinds.contains(&kind.as_str()), "bad TYPE {kind} in {line}");
                typed.insert(name, kind);
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment: {line}");
            let (series, value) = line.rsplit_once(' ').expect("sample line without value");
            let name = series.split('{').next().unwrap();
            assert!(valid_metric_name(name), "bad metric name in {line}");
            assert!(
                typed.keys().any(|t| name == t.as_str() || name.starts_with(&format!("{t}_"))),
                "sample {name} has no TYPE header"
            );
            if let Some(rest) = series.strip_prefix(name) {
                if !rest.is_empty() {
                    assert!(rest.starts_with('{') && rest.ends_with('}'), "bad labels in {line}");
                }
            }
            value.parse::<f64>().or_else(|e| match value {
                "NaN" | "+Inf" | "-Inf" => Ok(0.0),
                _ => Err(e),
            }).unwrap_or_else(|_| panic!("unparseable value in {line}"));
            samples += 1;
        }
        samples
    }

    #[test]
    fn exposition_renders_and_parses() {
        let mut e = Exposition::new();
        e.add("kvtuner_pool_blocks_live", "gauge", "live pages", &[("engine", "a")], 7.0);
        e.add("kvtuner_pool_blocks_live", "gauge", "live pages", &[("engine", "b")], 9.0);
        e.add(
            "kvtuner_swap_out_bytes_total",
            "counter",
            "bytes",
            &[("engine", "a"), ("tier", "host\"1\"")],
            1234.5,
        );
        let body = e.render();
        let n = check_exposition(&body);
        assert_eq!(n, 4, "schema_version + 2 gauges + 1 counter:\n{body}");
        assert!(body.contains("kvtuner_schema_version 2"), "{body}");
        assert!(body.contains("tier=\"host\\\"1\\\"\""), "label escaping:\n{body}");
        // grouping: both engine samples under one header pair
        let headers = body.matches("# TYPE kvtuner_pool_blocks_live").count();
        assert_eq!(headers, 1);
    }

    #[test]
    fn tracks_render_with_engine_label_and_rate_split() {
        let c = Counters::new();
        let g = c.gauge_with(
            "layer_kv_live",
            vec![("layer".into(), "03".into()), ("spec".into(), "kivi K8V4".into())],
            "bytes",
            "",
        );
        let r = c.rate("swap_out_bytes", "bytes", "");
        g.record_at(10, 4096.0);
        r.record_at(0, 0.0);
        r.record_at(1_000_000_000, 8192.0);
        let mut e = Exposition::new();
        render_tracks(&mut e, "tuned-balanced", &c.snapshot());
        let body = e.render();
        check_exposition(&body);
        let series = "{engine=\"tuned-balanced\",layer=\"03\",spec=\"kivi K8V4\"}";
        assert!(body.contains(&format!("kvtuner_layer_kv_live{series} 4096")), "{body}");
        let sw = "kvtuner_swap_out_bytes_total{engine=\"tuned-balanced\"} 8192";
        assert!(body.contains(sw), "{body}");
        assert!(body.contains("kvtuner_swap_out_bytes_ewma_per_sec"), "{body}");
        assert!(body.contains("# TYPE kvtuner_swap_out_bytes_total counter"), "{body}");
    }

    #[test]
    fn metrics_server_serves_scrapes_and_404s() {
        let server = MetricsServer::start("127.0.0.1:0", || {
            let e = Exposition::new();
            e.render()
        })
        .unwrap();
        let addr = server.addr();
        let get = |path: &str| -> String {
            let mut st = TcpStream::connect(addr).unwrap();
            write!(st, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut out = String::new();
            st.read_to_string(&mut out).unwrap();
            out
        };
        let ok = get("/metrics");
        assert!(ok.starts_with("HTTP/1.1 200 OK"), "{ok}");
        assert!(ok.contains("text/plain; version=0.0.4"), "{ok}");
        let body = ok.split("\r\n\r\n").nth(1).unwrap();
        check_exposition(body);
        assert!(body.contains("kvtuner_schema_version"), "{body}");
        let missing = get("/nope");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
        server.stop();
    }

    #[test]
    fn chrome_counter_events_are_well_formed_and_ordered() {
        let c = Counters::new();
        let h = c.gauge("pool_blocks_live", "blocks", "");
        for i in 0..5u64 {
            h.record_at(i * 1_000, (i * 2) as f64);
        }
        let tracer = Tracer::new(8);
        let evs = chrome_counter_events(3, &c.snapshot());
        assert_eq!(evs.len(), 5);
        let doc = chrome_trace_json(&tracer, &[(3, c.snapshot())]);
        let re = Json::parse(&doc.to_string_pretty()).unwrap();
        let all = re.get("traceEvents").unwrap().as_arr().unwrap();
        let counters: Vec<&Json> = all
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "C")
            .collect();
        assert_eq!(counters.len(), 5);
        let mut last = f64::NEG_INFINITY;
        for ev in counters {
            assert_eq!(ev.get("name").unwrap().as_str().unwrap(), "pool_blocks_live");
            assert_eq!(ev.get("pid").unwrap().as_usize().unwrap(), 3);
            let ts = ev.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last, "counter events time-ordered per track");
            last = ts;
            ev.get("args").unwrap().get("blocks").unwrap().as_f64().unwrap();
        }
        assert_eq!(re.get("droppedEvents").unwrap().as_usize().unwrap(), 0);
        assert_eq!(re.get("schema_version").unwrap().as_usize().unwrap(), 2);
    }
}
