//! Host tensors: the typed, shape-carrying buffers that move between the
//! KV-cache manager, the quantization substrate, and PJRT literals.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use xla::{ElementType, Literal};

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape {shape:?} vs len {}", data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn u8(shape: &[usize], data: Vec<u8>) -> Tensor {
        assert_eq!(numel(shape), data.len());
        Tensor { shape: shape.to_vec(), data: Data::U8(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(numel(shape), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; numel(shape)])
    }

    pub fn zeros_u8(shape: &[usize]) -> Tensor {
        Tensor::u8(shape, vec![0u8; numel(shape)])
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor::i32(shape, vec![0i32; numel(shape)])
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn size_bytes(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len() * 4,
            Data::U8(v) => v.len(),
            Data::I32(v) => v.len() * 4,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            Data::U8(v) => Ok(v),
            _ => bail!("tensor is not u8"),
        }
    }

    pub fn as_u8_mut(&mut self) -> Result<&mut [u8]> {
        match &mut self.data {
            Data::U8(v) => Ok(v),
            _ => bail!("tensor is not u8"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let st = self.strides();
        idx.iter().zip(&st).map(|(i, s)| i * s).sum()
    }

    /// Convert to an XLA literal (dtype-preserving).
    #[cfg(feature = "xla")]
    pub fn to_literal(&self) -> Result<Literal> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::U8(_) => ElementType::U8,
            Data::I32(_) => ElementType::S32,
        };
        let bytes: &[u8] = match &self.data {
            Data::F32(v) => bytemuck_f32(v),
            Data::U8(v) => v,
            Data::I32(v) => bytemuck_i32(v),
        };
        Ok(Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)?)
    }

    /// Read a literal back into a host tensor.
    #[cfg(feature = "xla")]
    pub fn from_literal(lit: &Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(Tensor::f32(&dims, lit.to_vec::<f32>()?)),
            ElementType::U8 => Ok(Tensor::u8(&dims, lit.to_vec::<u8>()?)),
            ElementType::S32 => Ok(Tensor::i32(&dims, lit.to_vec::<i32>()?)),
            t => bail!("unsupported literal element type {t:?}"),
        }
    }
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut st = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        st[i] = st[i + 1] * shape[i + 1];
    }
    st
}

#[cfg(feature = "xla")]
fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(feature = "xla")]
fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_offsets() {
        let t = Tensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.offset(&[1, 2, 3]), 23);
    }

    #[test]
    fn size_bytes() {
        assert_eq!(Tensor::zeros_f32(&[2, 2]).size_bytes(), 16);
        assert_eq!(Tensor::zeros_u8(&[2, 2]).size_bytes(), 4);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(&[2, 2], vec![0.0; 3]);
    }
}
