//! `kvtuner profile` — offline error profiling (Tables 3/9, Figs 3/7/13–19).

use anyhow::Result;

use crate::config::{Mode, PrecisionPair, PAIRS};
use crate::tuner::{calib, profiler};
use crate::util::bench::Table;
use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    let (manifest, weights, model) = super::load_model(args)?;
    let cfg = &manifest.config;
    let modes = super::parse_modes(&args.str("mode", "both"))?;
    let n_prompts = args.usize("prompts", 6)?;
    let len = args.usize("len", 48)?;
    let exp = args.str("exp", "table9");

    let prompts = calib::calib_set(cfg.vocab, n_prompts, len, args.usize("seed", 2024)? as u64);
    eprintln!("[profile] model={model} prompts={n_prompts} len={len} modes={modes:?}");
    let prof = profiler::profile(cfg, &weights, &prompts, &modes)?;

    match exp.as_str() {
        // Table 9: model-averaged e_k/e_v/e_a/e_o per (mode, uniform precision)
        "table9" => {
            let mut t = Table::new("Table 9 — KV quantization error analysis (model-averaged)",
                &["precision", "mode", "e_k", "e_v", "e_a", "e_o"],
            );
            for bits in [8u8, 4, 2] {
                for &mode in &modes {
                    let pair = PrecisionPair::new(bits, bits);
                    let m = prof.model_avg(mode, pair);
                    t.row(vec![
                        pair.label(),
                        mode_label(mode),
                        format!("{:.6}", m.e_k),
                        format!("{:.6}", m.e_v),
                        format!("{:.6}", m.e_a),
                        format!("{:.6}", m.e_o),
                    ]);
                }
            }
            t.print();
        }
        // Table 3: model-averaged relative attention output error per pair
        "table3" => {
            for &mode in &modes {
                let mut t = Table::new(&format!("Table 3 — relative attention output error e_o ({})", mode_label(mode)),
                    &["metric", "KV8", "K8V4", "K8V2", "K4V8", "KV4", "K4V2", "K2V8", "K2V4", "KV2"],
                );
                let mut row = vec!["e_o".to_string()];
                for pair in table_pair_order() {
                    row.push(format!("{:.3}", prof.model_avg(mode, pair).e_o));
                }
                t.row(row);
                t.print();
            }
        }
        // Fig 3 / 13..19: per-layer e_a and e_o series per pair
        "fig3" => {
            for &mode in &modes {
                for metric in ["e_a", "e_o"] {
                    let mut t = Table::with_headers(&format!("Fig 3/13 — layer-wise {metric} ({})", mode_label(mode)),
                        {
                            let mut h = vec!["pair".to_string()];
                            h.extend((0..cfg.n_layers).map(|l| format!("L{l}")));
                            h
                        },
                    );
                    for pair in table_pair_order() {
                        let series = if metric == "e_a" {
                            prof.layer_series_ea(mode, pair)
                        } else {
                            prof.layer_series(mode, pair)
                        };
                        let mut row = vec![pair.label()];
                        row.extend(series.iter().map(|v| format!("{v:.4}")));
                        t.row(row);
                    }
                    t.print();
                }
            }
        }
        // Fig 7: per-layer e_k / e_v per mode and precision
        "fig7" => {
            for &mode in &modes {
                let mut t = Table::with_headers(&format!("Fig 7 — layer-wise e_k / e_v ({})", mode_label(mode)),
                    {
                        let mut h = vec!["metric".to_string()];
                        h.extend((0..cfg.n_layers).map(|l| format!("L{l}")));
                        h
                    },
                );
                for bits in [8u8, 4, 2] {
                    let pair = PrecisionPair::new(bits, bits);
                    for (nm, f) in [("e_k", true), ("e_v", false)] {
                        let mut row = vec![format!("{nm}@{bits}bit")];
                        for l in 0..cfg.n_layers {
                            let e = prof.errors[l].get(&(mode, pair)).copied().unwrap_or_default();
                            row.push(format!("{:.4}", if f { e.e_k } else { e.e_v }));
                        }
                        t.row(row);
                    }
                }
                t.print();
            }
        }
        "json" => println!("{}", prof.to_json().to_string_pretty()),
        other => anyhow::bail!("unknown --exp {other:?} (table9|table3|fig3|fig7|json)"),
    }
    Ok(())
}

fn mode_label(m: Mode) -> String {
    match m {
        Mode::Token => "per-token-asym".into(),
        Mode::Kivi => "kivi (K per-channel)".into(),
        Mode::Fp => "fp".into(),
    }
}

/// Table 2/3's column order.
pub(crate) fn table_pair_order() -> Vec<PrecisionPair> {
    vec![
        PrecisionPair::new(8, 8),
        PrecisionPair::new(8, 4),
        PrecisionPair::new(8, 2),
        PrecisionPair::new(4, 8),
        PrecisionPair::new(4, 4),
        PrecisionPair::new(4, 2),
        PrecisionPair::new(2, 8),
        PrecisionPair::new(2, 4),
        PrecisionPair::new(2, 2),
    ]
    .into_iter()
    .filter(|p| PAIRS.contains(p))
    .collect()
}
