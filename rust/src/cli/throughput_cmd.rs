//! `kvtuner throughput` — Table 8: decode throughput (tokens/s) across KV
//! precision settings and context lengths. Memory traffic genuinely scales
//! with the precision map (bit-packed cache buffers), which is what produces
//! the paper's ranking KV8 < K8V4 < KV4 < K4V2 < tuned.
//!
//! Two engine backends, selected by `--backend`:
//! * `xla` — the PJRT engine over AOT artifacts (the original path).
//! * `native` — in-process kernels with block-table-direct attention; needs
//!   only `manifest.json` + the weights file, no HLO artifacts and no XLA
//!   extension, so the grid runs anywhere (including hosted CI).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use crate::engine::{BackendKind, NativeEngine};
use crate::kvcache::{CacheBackend, PagedOptions};
use crate::model::Weights;
use crate::obs::{render_tracks, Counters, EventKind, Exposition, MetricsServer, TraceSink, Tracer};
use crate::tuner::TunedConfig;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::json::obj;

pub struct ThroughputRow {
    pub equiv_bits: f64,
    pub kv_mib: f64,
    pub toks_per_sec: f64,
    /// KV bytes actually touched per decode step (valid-fraction of buffers).
    pub kv_bytes_per_step: f64,
}

impl ThroughputRow {
    /// Projected decode throughput on a memory-bandwidth-bound device
    /// (attention decode is memory-bound — paper Sec. 6.4): tokens/s if each
    /// step's cost were reading the live KV cache once at `bw` bytes/s.
    pub fn projected_tps(&self, batch: usize, bw: f64) -> f64 {
        batch as f64 / (self.kv_bytes_per_step / bw)
    }
}

/// Measure steady-state decode throughput for one config at one context fill
/// on the PJRT (xla) engine.
#[cfg(feature = "xla")]
pub fn measure(
    rt: &std::sync::Arc<crate::runtime::Runtime>,
    model: &str,
    specs: Vec<LayerSpec>,
    batch: usize,
    s_max: usize,
    input_len: usize,
    steps: usize,
    real_fill: bool,
    paged: Option<PagedOptions>,
) -> Result<ThroughputRow> {
    use crate::engine::Engine;
    let mut eng = match paged {
        None => Engine::new(rt.clone(), model, specs, batch, s_max, 32)?,
        Some(opts) => Engine::new_paged(rt.clone(), model, specs, batch, s_max, 32, opts)?,
    };
    // fill the cache to input_len: honest chunked prefill, or synthetic fill
    // (identical memory traffic; buffers are zero-filled and masked valid)
    if real_fill {
        for slot in 0..batch {
            let prompt: Vec<i32> =
                (0..input_len).map(|i| ((i * 31 + slot * 7) % eng.cfg.vocab) as i32).collect();
            eng.prefill(slot, &prompt)?;
        }
    } else {
        for slot in 0..batch {
            eng.cache.synthetic_fill(slot, input_len)?;
        }
    }
    let bits = eng.equivalent_bits();
    let kv_mib = eng.kv_bytes() as f64 / (1024.0 * 1024.0);
    let kv_bytes_per_step = eng.cache.mem_stats().bytes_live as f64;

    let tokens = vec![1i32; batch];
    let active = vec![true; batch];
    for _ in 0..3 {
        eng.decode_step(&tokens, &active)?;
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        eng.decode_step(&tokens, &active)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(ThroughputRow {
        equiv_bits: bits,
        kv_mib,
        toks_per_sec: batch as f64 * steps as f64 / dt,
        kv_bytes_per_step,
    })
}

/// Measure the same grid point on the native backend: honest prefill
/// (group-blocked, kivi groups commit at the scalar path's boundaries) and
/// block-direct decode, over a `threads`-wide kernel pool.
#[allow(clippy::too_many_arguments)]
pub fn measure_native(
    cfg: &ModelConfig,
    weights: &Weights,
    specs: Vec<LayerSpec>,
    batch: usize,
    s_max: usize,
    input_len: usize,
    steps: usize,
    real_fill: bool,
    threads: usize,
    paged: Option<PagedOptions>,
) -> Result<ThroughputRow> {
    let mut eng = NativeEngine::new(cfg, weights.clone(), specs, batch, s_max, 32, threads, paged)?;
    if real_fill {
        for slot in 0..batch {
            let prompt: Vec<i32> =
                (0..input_len).map(|i| ((i * 31 + slot * 7) % eng.cfg.vocab) as i32).collect();
            eng.prefill(slot, &prompt)?;
        }
    } else {
        for slot in 0..batch {
            eng.cache.synthetic_fill(slot, input_len)?;
        }
    }
    let bits = eng.equivalent_bits();
    let kv_mib = eng.kv_bytes() as f64 / (1024.0 * 1024.0);
    let kv_bytes_per_step = eng.cache.mem_stats().bytes_live as f64;

    let tokens = vec![1i32; batch];
    let active = vec![true; batch];
    for _ in 0..3 {
        eng.decode_step(&tokens, &active)?;
    }
    let t0 = Instant::now();
    for _ in 0..steps {
        eng.decode_step(&tokens, &active)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok(ThroughputRow {
        equiv_bits: bits,
        kv_mib,
        toks_per_sec: batch as f64 * steps as f64 / dt,
        kv_bytes_per_step,
    })
}

pub fn settings_grid(
    n_layers: usize,
    configs: &[String],
) -> Result<Vec<(String, Vec<LayerSpec>)>> {
    let mut settings: Vec<(String, Vec<LayerSpec>)> = vec![
        ("KV8 (baseline)".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), n_layers)),
        ("K8V4".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), n_layers)),
        ("KV4".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 4), n_layers)),
        ("K4V2".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), n_layers)),
        ("KV2".into(), LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(2, 2), n_layers)),
    ];
    for cpath in configs {
        if cpath.is_empty() {
            continue;
        }
        let c = TunedConfig::load(std::path::Path::new(cpath))?;
        settings.push((c.label.clone(), c.specs.clone()));
    }
    Ok(settings)
}

/// Shared grid driver: `measure_fn(specs, input_len)` -> one cell.
fn run_grid(
    args: &Args,
    cfg: &ModelConfig,
    batch: usize,
    steps: usize,
    cache_arm: &str,
    backend: BackendKind,
    mut measure_fn: impl FnMut(&[LayerSpec], usize) -> Result<ThroughputRow>,
) -> Result<()> {
    let input_lens: Vec<usize> = args
        .list("input-lens", "64,128,192")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let settings = settings_grid(cfg.n_layers, &args.list("configs", ""))?;
    let mut t = Table::with_headers(
        &format!(
            "Table 8 — decode throughput, batch={batch}, steps={steps}, cache={cache_arm}, \
             backend={} (tokens/s)",
            backend.as_str()
        ),
        {
            let mut h = vec!["setting".to_string(), "bits".into(), "KV MiB".into()];
            h.extend(input_lens.iter().map(|l| format!("len={l}")));
            h.push("vs KV8".into());
            h
        },
    );
    // --trace-out: one DecodeStep span per grid cell (setting = track,
    // arg = input len) so a Perfetto view shows where grid time went
    let trace_out = args.opt_str("trace-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::with_default_capacity()));
    // --metrics-listen: serve grid progress as a Prometheus exposition while
    // the sweep runs (long grids are otherwise silent between rows)
    let counters = args.opt_str("metrics-listen").map(|_| Arc::new(Counters::new()));
    let metrics_server = match args.opt_str("metrics-listen") {
        Some(addr) => {
            let c = Arc::clone(counters.as_ref().unwrap());
            let engine = format!("throughput-{}", backend.as_str());
            let server = MetricsServer::start(addr, move || {
                let mut expo = Exposition::new();
                render_tracks(&mut expo, &engine, &c.snapshot());
                expo.render()
            })?;
            eprintln!(
                "[throughput] serving Prometheus exposition on http://{}/metrics",
                server.addr()
            );
            Some(server)
        }
        None => None,
    };
    let grid_tracks = counters.as_ref().map(|c| {
        (
            c.gauge("grid_cells_done", "cells", "completed grid cells"),
            c.gauge(
                "grid_cell_tokens_per_sec",
                "tokens/s",
                "decode throughput of the most recent grid cell",
            ),
            c.rate("grid_tokens_decoded", "tokens", "cumulative decoded tokens across grid cells"),
        )
    });
    let mut baseline: Vec<f64> = Vec::new();
    let mut cell: u64 = 0;
    for (i, (label, specs)) in settings.iter().enumerate() {
        let mut row = vec![label.clone()];
        let mut bits = 0.0;
        let mut mib = 0.0;
        let mut tps_list = Vec::new();
        for &il in &input_lens {
            let t_cell = Instant::now();
            let r = measure_fn(specs, il)?;
            if let Some(tr) = &tracer {
                TraceSink { tracer: tr.clone(), worker: 0 }.span(
                    EventKind::DecodeStep,
                    cell,
                    i as u32,
                    t_cell,
                    il as u64,
                );
            }
            cell += 1;
            if let Some((done, tps, decoded)) = &grid_tracks {
                done.record(cell as f64);
                tps.record(r.toks_per_sec);
                decoded.record((cell as usize * batch * steps) as f64);
            }
            bits = r.equiv_bits;
            mib = r.kv_mib;
            tps_list.push(r.toks_per_sec);
        }
        if i == 0 {
            baseline = tps_list.clone();
        }
        row.insert(1, format!("{bits:.2}"));
        row.insert(2, format!("{mib:.1}"));
        for &tps in &tps_list {
            row.push(format!("{tps:.0}"));
        }
        let speedup: f64 = tps_list
            .iter()
            .zip(&baseline)
            .map(|(a, b)| a / b)
            .sum::<f64>()
            / tps_list.len() as f64;
        row.push(format!("{:+.1}%", (speedup - 1.0) * 100.0));
        t.row(row);
        eprintln!("[throughput] {label} done");
    }
    t.print();
    if let (Some(path), Some(tr)) = (&trace_out, &tracer) {
        tr.write(path)?;
        eprintln!("[throughput] wrote {} trace events to {}", tr.events().len(), path.display());
    }
    if let Some(path) = args.opt_str("metrics-out") {
        let doc = obj(vec![("table", t.to_json())]);
        std::fs::write(path, doc.to_string_pretty())?;
        eprintln!("[throughput] wrote metrics JSON to {path}");
    }
    if let Some(server) = metrics_server {
        server.stop();
    }
    Ok(())
}

pub fn run(args: &Args) -> Result<()> {
    match super::backend_kind(args)? {
        BackendKind::Native => run_native(args),
        BackendKind::Xla => run_xla(args),
    }
}

fn run_native(args: &Args) -> Result<()> {
    let (manifest, weights, _model) = super::load_model(args)?;
    let cfg = manifest.config.clone();
    let batch = args.usize("batch", *manifest.decode_batches().last().unwrap_or(&1))?;
    let s_max = args.usize("smax", 256)?;
    let steps = args.usize("steps", 40)?;
    let real_fill = args.switch("real-fill");
    let threads = super::thread_count(args)?;
    let paged = super::paged_options(args)?;
    let cache_arm = super::cache_desc(&paged);
    eprintln!("[throughput] native backend, {threads} kernel threads");
    run_grid(args, &cfg, batch, steps, &cache_arm, BackendKind::Native, |specs, il| {
        measure_native(
            &cfg,
            &weights,
            specs.to_vec(),
            batch,
            s_max,
            il,
            steps,
            real_fill,
            threads,
            paged.clone(),
        )
    })
}

#[cfg(feature = "xla")]
fn run_xla(args: &Args) -> Result<()> {
    use std::sync::Arc;
    let dir = super::artifact_dir(args);
    let rt = Arc::new(crate::runtime::Runtime::load(&dir)?);
    let cfg = rt.manifest.config.clone();
    let model = args.str("model", &cfg.name);
    let batch = args.usize("batch", *rt.manifest.decode_batches().last().unwrap_or(&1))?;
    let s_max = args.usize("smax", 256)?;
    let steps = args.usize("steps", 40)?;
    let real_fill = args.switch("real-fill");
    let paged = super::paged_options(args)?;
    let cache_arm = super::cache_desc(&paged);
    run_grid(args, &cfg, batch, steps, &cache_arm, BackendKind::Xla, |specs, il| {
        measure(&rt, &model, specs.to_vec(), batch, s_max, il, steps, real_fill, paged.clone())
    })
}

#[cfg(not(feature = "xla"))]
fn run_xla(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "this build has no XLA backend (compiled without the `xla` feature); \
         run with --backend native"
    )
}
