//! `kvtuner eval` — accuracy tables:
//!   table2: pseudo-perplexity of the 9 uniform pairs across the model family
//!   table5: fidelity accuracy vs prompt length ("shots"), uniform + tuned
//!   table7: long-context fidelity (LongBench analogue)

use anyhow::Result;

use crate::config::{LayerSpec, Mode, PrecisionPair};
use crate::model::Weights;
use crate::tuner::{self, calib, TunedConfig};
use crate::util::bench::Table;
use crate::util::cli::Args;

use super::profile_cmd::table_pair_order;

pub fn run(args: &Args) -> Result<()> {
    match args.str("exp", "table2").as_str() {
        "table2" => table2(args),
        "table5" => table5(args),
        "table7" => table7(args),
        other => anyhow::bail!("unknown --exp {other:?} (table2|table5|table7)"),
    }
}

/// Table 2 — word-perplexity analogue across models × uniform pairs.
fn table2(args: &Args) -> Result<()> {
    let dir = super::artifact_dir(args);
    let manifest = crate::config::Manifest::load(&dir)?;
    let cfg = &manifest.config;
    let models = args.list("models", &manifest.models.keys().cloned().collect::<Vec<_>>().join(","));
    let mode = Mode::parse(&args.str("mode", "kivi"))?;
    let n_prompts = args.usize("prompts", 6)?;
    let len = args.usize("len", 32)?;
    let horizon = args.usize("horizon", 24)?;

    let mut t = Table::with_headers(&format!("Table 2 — pseudo-perplexity ({} mode)", mode.as_str()),
        {
            let mut h = vec!["model".to_string(), "FP".to_string()];
            h.extend(table_pair_order().iter().map(|p| p.label()));
            h
        },
    );
    for model in &models {
        let weights = Weights::load(&manifest, model)?;
        let prompts = calib::calib_set(cfg.vocab, n_prompts, len, 77);
        let reference = tuner::build_reference(cfg, &weights, &prompts, horizon)?;
        let mut row = vec![model.clone()];
        let fp = tuner::pseudo_perplexity(
            cfg, &weights, &reference,
            &LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
        )?;
        row.push(format!("{fp:.3}"));
        for pair in table_pair_order() {
            let specs = LayerSpec::uniform(mode, pair, cfg.n_layers);
            let ppl = tuner::pseudo_perplexity(cfg, &weights, &reference, &specs)?;
            row.push(format!("{ppl:.3}"));
        }
        t.row(row);
        eprintln!("[table2] {model} done");
    }
    t.print();
    Ok(())
}

/// Table 5/6 — fidelity accuracy vs prompt length, uniform pairs + KVTuner
/// configs (pass tuned configs via --configs a.json,b.json).
fn table5(args: &Args) -> Result<()> {
    let (manifest, weights, model) = super::load_model(args)?;
    let cfg = &manifest.config;
    let horizon = args.usize("horizon", 24)?;
    let lens: Vec<usize> = args
        .list("lens", "16,48,96")
        .iter()
        .map(|s| s.parse().unwrap())
        .collect();
    let n_prompts = args.usize("prompts", 6)?;

    // evaluated settings: BF16-style fp, uniform pairs per mode, tuned configs
    let mut settings: Vec<(String, Vec<LayerSpec>)> = vec![(
        "FP".into(),
        LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
    )];
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in [PrecisionPair::new(8, 8), PrecisionPair::new(4, 4), PrecisionPair::new(2, 2)] {
            settings.push((
                format!("{}/{}", mode.as_str(), pair.label()),
                LayerSpec::uniform(mode, pair, cfg.n_layers),
            ));
        }
    }
    for cpath in args.list("configs", "") {
        let c = TunedConfig::load(std::path::Path::new(&cpath))?;
        settings.push((c.label.clone(), c.specs.clone()));
    }

    let mut t = Table::with_headers(&format!("Table 5/6 — fidelity accuracy vs prompt length ({model})"),
        {
            let mut h = vec!["setting".to_string()];
            h.extend(lens.iter().map(|l| format!("len{l}")));
            h.push("average".into());
            h
        },
    );
    for (label, specs) in &settings {
        let mut row = vec![label.clone()];
        let mut sum = 0.0;
        for &len in &lens {
            let prompts = calib::calib_set(cfg.vocab, n_prompts, len, 55 + len as u64);
            let reference = tuner::build_reference(cfg, &weights, &prompts, horizon)?;
            let acc = tuner::fidelity_accuracy(cfg, &weights, &reference, specs)?;
            sum += acc;
            row.push(format!("{acc:.4}"));
        }
        row.push(format!("{:.4}", sum / lens.len() as f64));
        t.row(row);
        eprintln!("[table5] {label} done");
    }
    t.print();
    Ok(())
}

/// Table 7 — long-context generation fidelity (LongBench analogue): long
/// prompts near the reference engine capacity, same settings grid.
fn table7(args: &Args) -> Result<()> {
    let (manifest, weights, model) = super::load_model(args)?;
    let cfg = &manifest.config;
    let len = args.usize("len", 192)?;
    let horizon = args.usize("horizon", 32)?;
    let n_prompts = args.usize("prompts", 6)?;

    let mut settings: Vec<(String, Vec<LayerSpec>)> = vec![(
        "FP".into(),
        LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, cfg.n_layers),
    )];
    for mode in [Mode::Token, Mode::Kivi] {
        for pair in [PrecisionPair::new(8, 8), PrecisionPair::new(8, 4), PrecisionPair::new(4, 4)] {
            settings.push((
                format!("{}/{}", mode.as_str(), pair.label()),
                LayerSpec::uniform(mode, pair, cfg.n_layers),
            ));
        }
    }
    for cpath in args.list("configs", "") {
        let c = TunedConfig::load(std::path::Path::new(&cpath))?;
        settings.push((c.label.clone(), c.specs.clone()));
    }

    let prompts = calib::calib_set(cfg.vocab, n_prompts, len, 99);
    let reference = tuner::build_reference(cfg, &weights, &prompts, horizon)?;
    let mut t = Table::new(&format!("Table 7 — long-context fidelity (len={len}, {model})"),
        &["setting", "accuracy"],
    );
    for (label, specs) in &settings {
        let acc = tuner::fidelity_accuracy(cfg, &weights, &reference, specs)?;
        t.row(vec![label.clone(), format!("{acc:.4}")]);
        eprintln!("[table7] {label} done");
    }
    t.print();
    Ok(())
}
