//! `kvtuner patterns` — attention-pattern analysis (Fig 2/4/11/12): head
//! classification per layer, per-head attention shift under quantization,
//! and (with --tokens) token-level attention rows fp vs 4/2-bit key quant.

use anyhow::Result;

use crate::analysis;
use crate::config::{LayerSpec, Mode, PrecisionPair};
use crate::tuner::{calib, profiler};
use crate::util::bench::Table;
use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    let (manifest, weights, model) = super::load_model(args)?;
    let cfg = &manifest.config;
    let len = args.usize("len", 64)?;
    let prompts = calib::calib_set(cfg.vocab, 1, len, args.usize("seed", 31)? as u64);
    let captures = profiler::capture_prompts(cfg, &weights, &prompts)?;
    let caps = &captures[0];

    // Fig 11/12 — head classes per layer + block maps summary
    let mut t = Table::new(
        &format!("Fig 11/12 — attention head classification ({model})"),
        &["layer", "head", "top1 mass", "norm entropy", "class"],
    );
    let mut class_counts = std::collections::BTreeMap::<&str, usize>::new();
    for (l, cap) in caps.iter().enumerate() {
        for hp in analysis::classify_layer(cap, l, cfg.group)? {
            *class_counts.entry(hp.class.as_str()).or_default() += 1;
            t.row(vec![
                l.to_string(),
                hp.head.to_string(),
                format!("{:.3}", hp.top1_mass),
                format!("{:.3}", hp.entropy),
                hp.class.as_str().to_string(),
            ]);
        }
    }
    t.print();
    println!("class totals: {class_counts:?}");

    // Fig 2/4 — per-head attention shift (mean TV distance) under key quant
    let mode = Mode::parse(&args.str("mode", "token"))?;
    let mut ts = Table::new(
        "Fig 2/4 — per-head attention shift (mean TV distance) under key quantization",
        &["layer", "head", "K8", "K4", "K2"],
    );
    for (l, cap) in caps.iter().enumerate() {
        let mut per_bits = Vec::new();
        for kb in [8u8, 4, 2] {
            let spec = LayerSpec { mode, pair: PrecisionPair::new(kb, 8) };
            per_bits.push(analysis::head_shift_scores(cap, spec, cfg.group)?);
        }
        for h in 0..cfg.n_heads {
            ts.row(vec![
                l.to_string(),
                h.to_string(),
                format!("{:.4}", per_bits[0][h]),
                format!("{:.4}", per_bits[1][h]),
                format!("{:.4}", per_bits[2][h]),
            ]);
        }
    }
    ts.print();

    // --tokens: Fig 2's token-level rows for the most-shifted head
    if args.switch("tokens") {
        let layer = args.usize("layer", cfg.n_layers / 2)?;
        let cap = &caps[layer];
        let spec2 = LayerSpec { mode, pair: PrecisionPair::new(2, 8) };
        let shifts = analysis::head_shift_scores(cap, spec2, cfg.group)?;
        let head = shifts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(h, _)| h)
            .unwrap_or(0);
        let query = len - 1;
        println!("\ntoken-level attention of layer {layer} head {head}, query {query}:");
        let (fp_row, _) = analysis::attention_shift_row(cap, head, query, LayerSpec::fp(), cfg.group)?;
        print_row("fp16", &fp_row);
        for kb in [4u8, 2] {
            let spec = LayerSpec { mode, pair: PrecisionPair::new(kb, 8) };
            let (_, qrow) = analysis::attention_shift_row(cap, head, query, spec, cfg.group)?;
            print_row(&format!("K{kb}"), &qrow);
        }
    }
    Ok(())
}

fn print_row(label: &str, row: &[f32]) {
    let line: Vec<String> = row
        .iter()
        .map(|&p| {
            if p > 0.2 {
                "#".into()
            } else if p > 0.05 {
                "+".into()
            } else if p > 0.01 {
                ".".into()
            } else {
                " ".into()
            }
        })
        .collect();
    println!("{label:>6} |{}|  (top={:.3})", line.join(""), row.iter().cloned().fold(0f32, f32::max));
}
