//! `kvtuner serve` — run the multi-engine router on synthetic load and
//! report per-engine serving metrics. Demonstrates the deployment story:
//! multiple precision configs of one model served side by side, routed by
//! requested accuracy class.

use anyhow::Result;

use crate::config::{LayerSpec, Mode, PrecisionPair};
use crate::coordinator::{AccuracyClass, Router, WorkerSpec};
use crate::tuner::TunedConfig;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::rng::Rng;

pub fn run(args: &Args) -> Result<()> {
    let dir = super::artifact_dir(args);
    let manifest = crate::config::Manifest::load(&dir)?;
    let cfg = manifest.config.clone();
    let model = args.str("model", &cfg.name);
    let batch = args.usize("batch", *manifest.decode_batches().last().unwrap_or(&1))?;
    let s_max = args.usize("smax", 256)?;
    let n_requests = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 16)?;
    let paged = super::paged_options(args)?;
    let backend = super::backend_kind(args)?;
    // each router worker sizes its own kernel pool from this; an explicit
    // --threads applies per worker, while the default splits the machine
    // across the three concurrent workers so their pools do not
    // oversubscribe the host
    let threads = match args.opt_str("threads") {
        Some(_) => super::thread_count(args)?,
        None => (crate::kernel::default_threads() / 3).max(1),
    };

    // engine fleet: high = KV8, efficient = K4V2; balanced = tuned config if
    // given, else K8V4
    let mut workers = vec![
        WorkerSpec {
            name: "kv8-high".into(),
            model: model.clone(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers),
            class: AccuracyClass::High,
            batch,
            s_max,
            prefill_chunk: 32,
            paged: paged.clone(),
            backend,
            threads,
        },
        WorkerSpec {
            name: "k4v2-efficient".into(),
            model: model.clone(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers),
            class: AccuracyClass::Efficient,
            batch,
            s_max,
            prefill_chunk: 32,
            paged: paged.clone(),
            backend,
            threads,
        },
    ];
    let balanced_specs = match args.opt_str("config") {
        Some(p) => TunedConfig::load(std::path::Path::new(p))?.specs,
        None => LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), cfg.n_layers),
    };
    workers.push(WorkerSpec {
        name: "tuned-balanced".into(),
        model: model.clone(),
        specs: balanced_specs,
        class: AccuracyClass::Balanced,
        batch,
        s_max,
        prefill_chunk: 32,
        paged: paged.clone(),
        backend,
        threads,
    });

    eprintln!(
        "[serve] starting {} workers (batch={batch}, smax={s_max}, cache={}, backend={}, \
         threads={threads})",
        workers.len(),
        super::cache_desc(&paged),
        backend.as_str(),
    );
    let t0 = std::time::Instant::now();
    let router = Router::start(dir, workers)?;
    eprintln!("[serve] workers ready in {:.1}s", t0.elapsed().as_secs_f64());

    // synthetic open-loop load
    let mut rng = Rng::seed(5);
    let classes = [AccuracyClass::High, AccuracyClass::Balanced, AccuracyClass::Efficient];
    let mut subs = Vec::new();
    for i in 0..n_requests {
        let plen = rng.range(16, 64);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        let class = classes[i % classes.len()];
        subs.push((class, router.submit(prompt, max_new, class)?));
    }
    let mut t = Table::new(
        "serve — per-request results",
        &["id", "class", "engine", "tokens", "ttft ms", "total ms"],
    );
    for (class, sub) in subs {
        let r = sub.wait()?;
        anyhow::ensure!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        t.row(vec![
            r.id.to_string(),
            class.as_str().into(),
            r.engine.clone(),
            r.tokens.len().to_string(),
            format!("{:.1}", r.ttft.as_secs_f64() * 1e3),
            format!("{:.1}", r.total.as_secs_f64() * 1e3),
        ]);
    }
    t.print();

    let mut tm = Table::new("serve — per-engine metrics", &["engine", "summary"]);
    for (name, snap) in router.shutdown()? {
        tm.row(vec![name, snap.to_string()]);
    }
    tm.print();
    Ok(())
}
