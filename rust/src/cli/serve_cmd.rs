//! `kvtuner serve` — run the multi-engine router on synthetic load and
//! report per-engine serving metrics. Demonstrates the deployment story:
//! multiple precision configs of one model served side by side, routed by
//! requested accuracy class.
//!
//! Observability flags: `--trace-out` captures the request lifecycle
//! (admit / prefill / decode / preempt / swap / resume / complete) as a
//! Chrome trace; `--metrics-out` writes per-engine snapshot JSON with
//! latency histograms; `--profile-serve` (or `KVTUNER_PROFILE=1`) turns on
//! the engines' per-layer/per-phase profiler.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use crate::coordinator::{AccuracyClass, Router, WorkerSpec};
use crate::engine::BackendKind;
use crate::obs::Tracer;
use crate::tuner::TunedConfig;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::json::{arr, obj, s, Json};
use crate::util::rng::Rng;

pub fn run(args: &Args) -> Result<()> {
    let dir = super::artifact_dir(args);
    let backend = super::backend_kind(args)?;
    let synthetic = args.switch("synthetic");
    let (cfg, model, default_batch) = if synthetic {
        anyhow::ensure!(
            backend == BackendKind::Native,
            "--synthetic needs the native backend (the XLA backend serves only AOT artifacts)"
        );
        (ModelConfig::synthetic("sim-serve"), "synthetic".to_string(), 2)
    } else {
        let manifest = crate::config::Manifest::load(&dir)?;
        let cfg = manifest.config.clone();
        let model = args.str("model", &cfg.name);
        let db = *manifest.decode_batches().last().unwrap_or(&1);
        (cfg, model, db)
    };
    let batch = args.usize("batch", default_batch)?;
    let s_max = args.usize("smax", 256)?;
    let n_requests = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 16)?;
    let paged = super::paged_options(args)?;
    // each router worker sizes its own kernel pool from this; an explicit
    // --threads applies per worker, while the default splits the machine
    // across the three concurrent workers so their pools do not
    // oversubscribe the host
    let threads = match args.opt_str("threads") {
        Some(_) => super::thread_count(args)?,
        None => (crate::kernel::default_threads() / 3).max(1),
    };
    let trace_out = args.opt_str("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.opt_str("metrics-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::with_default_capacity()));
    let profile = args.switch("profile-serve")
        || std::env::var("KVTUNER_PROFILE").map(|v| v == "1").unwrap_or(false);

    // engine fleet: high = KV8, efficient = K4V2; balanced = tuned config if
    // given, else K8V4
    let common = WorkerSpec {
        model: model.clone(),
        batch,
        s_max,
        prefill_chunk: 32,
        paged: paged.clone(),
        backend,
        threads,
        trace: tracer.clone(),
        profile,
        synthetic: synthetic.then(|| cfg.clone()),
        ..WorkerSpec::default()
    };
    let mut workers = vec![
        WorkerSpec {
            name: "kv8-high".into(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers),
            class: AccuracyClass::High,
            ..common.clone()
        },
        WorkerSpec {
            name: "k4v2-efficient".into(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers),
            class: AccuracyClass::Efficient,
            ..common.clone()
        },
    ];
    let balanced_specs = match args.opt_str("config") {
        Some(p) => TunedConfig::load(std::path::Path::new(p))?.specs,
        None => LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), cfg.n_layers),
    };
    workers.push(WorkerSpec {
        name: "tuned-balanced".into(),
        specs: balanced_specs,
        class: AccuracyClass::Balanced,
        ..common
    });

    eprintln!(
        "[serve] starting {} workers (batch={batch}, smax={s_max}, cache={}, backend={}, \
         threads={threads}{}{})",
        workers.len(),
        super::cache_desc(&paged),
        backend.as_str(),
        if synthetic { ", synthetic weights" } else { "" },
        if profile { ", profiling" } else { "" },
    );
    let t0 = std::time::Instant::now();
    let router = Router::start(dir, workers)?;
    eprintln!("[serve] workers ready in {:.1}s", t0.elapsed().as_secs_f64());

    // synthetic open-loop load
    let mut rng = Rng::seed(5);
    let classes = [AccuracyClass::High, AccuracyClass::Balanced, AccuracyClass::Efficient];
    let mut subs = Vec::new();
    for i in 0..n_requests {
        let plen = rng.range(16, 64);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        let class = classes[i % classes.len()];
        subs.push((class, router.submit(prompt, max_new, class)?));
    }
    let mut t = Table::new(
        "serve — per-request results",
        &["id", "class", "engine", "tokens", "ttft ms", "total ms"],
    );
    for (class, sub) in subs {
        let r = sub.wait()?;
        anyhow::ensure!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        t.row(vec![
            r.id.to_string(),
            class.as_str().into(),
            r.engine.clone(),
            r.tokens.len().to_string(),
            format!("{:.1}", r.ttft.as_secs_f64() * 1e3),
            format!("{:.1}", r.total.as_secs_f64() * 1e3),
        ]);
    }
    t.print();

    let reports = router.shutdown()?;
    let mut tm = Table::new("serve — per-engine metrics", &["engine", "summary"]);
    for r in &reports {
        tm.row(vec![r.name.clone(), r.snapshot.to_string()]);
    }
    tm.print();
    for r in &reports {
        if let Some(p) = &r.profile {
            p.table(&format!("serve — per-layer profile ({})", r.name)).print();
        }
    }

    if let (Some(path), Some(tracer)) = (&trace_out, &tracer) {
        tracer.write(path)?;
        eprintln!(
            "[serve] wrote {} trace events to {} ({} dropped)",
            tracer.events().len(),
            path.display(),
            tracer.dropped(),
        );
    }
    if let Some(path) = &metrics_out {
        let engines: Vec<Json> = reports
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", s(r.name.clone())),
                    ("snapshot", r.snapshot.to_json()),
                    ("profile", r.profile.as_ref().map_or(Json::Null, |p| p.to_json())),
                ])
            })
            .collect();
        let doc = obj(vec![("engines", arr(engines))]);
        std::fs::write(path, doc.to_string_pretty())?;
        eprintln!("[serve] wrote metrics JSON to {}", path.display());
    }
    Ok(())
}
