//! `kvtuner serve` — run the multi-engine router on synthetic load and
//! report per-engine serving metrics. Demonstrates the deployment story:
//! multiple precision configs of one model served side by side, routed by
//! requested accuracy class.
//!
//! Observability flags: `--trace-out` captures the request lifecycle
//! (admit / prefill / decode / preempt / swap / resume / complete) as a
//! Chrome trace; `--metrics-out` writes per-engine snapshot JSON with
//! latency histograms; `--profile-serve` (or `KVTUNER_PROFILE=1`) turns on
//! the engines' per-layer/per-phase profiler; `--probe-every N` arms the
//! online sensitivity probe (fp shadow of every Nth committed KV group,
//! drift-checked against a tuned config's calibration envelope);
//! `--sensitivity-out` writes the per-engine sensitivity tables at exit;
//! `--metrics-interval SECS` streams mid-run snapshot + sensitivity (and
//! counter-track) JSONL; `--metrics-listen ADDR` serves the Prometheus
//! text exposition at `http://ADDR/metrics` for the run's duration.
//!
//! When any of `--metrics-listen`, `--trace-out` or `--metrics-interval`
//! is given, each worker gets a counter-track registry: the scheduler
//! publishes memory-hierarchy occupancy (page pool, host swap arena,
//! queues, swap/gather bandwidth) per tick and the engine per-layer live
//! KV bytes. The tracks ride the Chrome trace as `"ph":"C"` counter
//! events, so Perfetto draws the occupancy curves under the lifecycle
//! spans.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig, PrecisionPair};
use crate::coordinator::{AccuracyClass, FailureKind, Router, WorkerSpec};
use crate::engine::BackendKind;
use crate::faults::FaultPlan;
use crate::obs::{
    render_tracks, write_trace, Counters, Exposition, MetricsServer, ProbeConfig, TrackSnapshot,
    Tracer, SCHEMA_VERSION,
};
use crate::tuner::TunedConfig;
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::json::{arr, obj, s, Json};
use crate::util::rng::Rng;

pub fn run(args: &Args) -> Result<()> {
    let dir = super::artifact_dir(args);
    let backend = super::backend_kind(args)?;
    let synthetic = args.switch("synthetic");
    let (cfg, model, default_batch) = if synthetic {
        anyhow::ensure!(
            backend == BackendKind::Native,
            "--synthetic needs the native backend (the XLA backend serves only AOT artifacts)"
        );
        (ModelConfig::synthetic("sim-serve"), "synthetic".to_string(), 2)
    } else {
        let manifest = crate::config::Manifest::load(&dir)?;
        let cfg = manifest.config.clone();
        let model = args.str("model", &cfg.name);
        let db = *manifest.decode_batches().last().unwrap_or(&1);
        (cfg, model, db)
    };
    let batch = args.usize("batch", default_batch)?;
    let s_max = args.usize("smax", 256)?;
    // chunk size for interleaved (chunked) prefill: long prompts advance
    // this many tokens per scheduler tick between batched decode steps
    let prefill_chunk = args.usize("prefill-chunk", 32)?.max(1);
    let n_requests = args.usize("requests", 12)?;
    let max_new = args.usize("max-new", 16)?;
    let paged = super::paged_options(args)?;
    // each router worker sizes its own kernel pool from this; an explicit
    // --threads applies per worker, while the default splits the machine
    // across the three concurrent workers so their pools do not
    // oversubscribe the host
    let threads = match args.opt_str("threads") {
        Some(_) => super::thread_count(args)?,
        None => (crate::kernel::default_threads() / 3).max(1),
    };
    let trace_out = args.opt_str("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.opt_str("metrics-out").map(std::path::PathBuf::from);
    let sensitivity_out = args.opt_str("sensitivity-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| Arc::new(Tracer::with_default_capacity()));
    let profile = args.switch("profile-serve")
        || std::env::var("KVTUNER_PROFILE").map(|v| v == "1").unwrap_or(false);
    let probe_every = args.usize("probe-every", 0)?;
    let metrics_interval = args.f64("metrics-interval", 0.0)?;
    let metrics_listen = args.opt_str("metrics-listen").map(String::from);
    // chaos mode: a seeded fault plan armed on every worker (each salts the
    // seed with its index, so one plan drives distinct per-worker fault
    // streams); a no-op plan leaves the injectors unarmed entirely
    let fault_plan = match args.opt_str("fault-plan") {
        Some(spec) => {
            let plan = FaultPlan::parse(spec)?;
            if plan.is_noop() {
                eprintln!("[serve] --fault-plan has every rate at zero; injection stays unarmed");
                None
            } else {
                Some(plan)
            }
        }
        None => None,
    };
    // per-request deadline: the scheduler abandons a request (typed
    // DeadlineExceeded, tokens-so-far delivered) once this budget passes
    let deadline_ms = args.f64("deadline-ms", 0.0)?;
    // client-side wait bound during drain: an expired wait is a typed
    // Timeout response instead of blocking forever on a stuck fleet
    let request_timeout = args.f64("request-timeout", 0.0)?;
    // counter tracks are armed whenever any consumer exists: the /metrics
    // endpoint, the trace export, or the JSONL stream
    let want_counters =
        metrics_listen.is_some() || trace_out.is_some() || metrics_interval > 0.0;

    // load the tuned config once: its specs back the balanced worker and its
    // calibration envelope (when recorded) backs the probe's drift detector
    let tuned = match args.opt_str("config") {
        Some(p) => Some(TunedConfig::load(std::path::Path::new(p))?),
        None => None,
    };
    let probe = (probe_every > 0).then(|| ProbeConfig {
        every: probe_every,
        envelope: tuned.as_ref().and_then(|t| t.envelope.clone()),
        ..ProbeConfig::default()
    });

    // engine fleet: high = KV8, efficient = K4V2; balanced = tuned config if
    // given, else K8V4
    let common = WorkerSpec {
        model: model.clone(),
        batch,
        s_max,
        prefill_chunk,
        paged: paged.clone(),
        backend,
        threads,
        trace: tracer.clone(),
        profile,
        probe,
        synthetic: synthetic.then(|| cfg.clone()),
        faults: fault_plan.clone(),
        ..WorkerSpec::default()
    };
    let mut workers = vec![
        WorkerSpec {
            name: "kv8-high".into(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 8), cfg.n_layers),
            class: AccuracyClass::High,
            ..common.clone()
        },
        WorkerSpec {
            name: "k4v2-efficient".into(),
            specs: LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), cfg.n_layers),
            class: AccuracyClass::Efficient,
            ..common.clone()
        },
    ];
    let balanced_specs = match &tuned {
        Some(t) => t.specs.clone(),
        None => LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(8, 4), cfg.n_layers),
    };
    workers.push(WorkerSpec {
        name: "tuned-balanced".into(),
        specs: balanced_specs,
        class: AccuracyClass::Balanced,
        ..common
    });
    if want_counters {
        // one registry per worker, all sharing the tracer's epoch so the
        // counter samples land on the same Perfetto timeline as the spans
        let epoch = tracer.as_ref().map(|t| t.epoch()).unwrap_or_else(std::time::Instant::now);
        for w in &mut workers {
            w.counters = Some(Arc::new(Counters::with_epoch(epoch)));
        }
    }

    eprintln!(
        "[serve] starting {} workers (batch={batch}, smax={s_max}, cache={}, backend={}, \
         threads={threads}{}{})",
        workers.len(),
        super::cache_desc(&paged),
        backend.as_str(),
        if synthetic { ", synthetic weights" } else { "" },
        if profile { ", profiling" } else { "" },
    );
    let t0 = std::time::Instant::now();
    let router = Router::start(dir, workers)?;
    eprintln!("[serve] workers ready in {:.1}s", t0.elapsed().as_secs_f64());

    // pull-based exporter: each scrape renders every worker's snapshot
    // aggregates plus the latest sample of every counter track
    let metrics_server = match &metrics_listen {
        Some(addr) => {
            let observers = router.observers();
            let server = MetricsServer::start(addr, move || {
                let mut expo = Exposition::new();
                for o in &observers {
                    o.metrics.snapshot().render_prometheus(&mut expo, &o.name);
                    if let Some(c) = &o.counters {
                        render_tracks(&mut expo, &o.name, &c.snapshot());
                    }
                }
                expo.render()
            })?;
            eprintln!("[serve] serving Prometheus exposition on http://{}/metrics", server.addr());
            Some(server)
        }
        None => None,
    };

    // live metrics streaming: a reader thread snapshots every worker's
    // metrics (and armed probes) each interval and appends one JSONL line —
    // next to --metrics-out when given, else a METRICS_JSON stdout line
    let stream_stop = Arc::new(AtomicBool::new(false));
    let streamer = if metrics_interval > 0.0 {
        let observers = router.observers();
        let stop = stream_stop.clone();
        let jsonl = metrics_out.as_ref().map(|p| p.with_extension("jsonl"));
        let period = std::time::Duration::from_secs_f64(metrics_interval);
        Some(std::thread::spawn(move || -> Result<()> {
            use std::io::Write;
            let started = std::time::Instant::now();
            let mut file = match &jsonl {
                Some(p) => Some(
                    std::fs::OpenOptions::new().create(true).truncate(true).write(true).open(p)?,
                ),
                None => None,
            };
            loop {
                std::thread::sleep(period);
                let engines: Vec<Json> = observers
                    .iter()
                    .map(|o| {
                        let sens = o
                            .sensitivity
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .as_ref()
                            .map_or(Json::Null, |s| s.snapshot().to_json());
                        let mut pairs = vec![
                            ("name", s(o.name.clone())),
                            ("snapshot", o.metrics.snapshot().to_json()),
                            ("sensitivity", sens),
                        ];
                        if let Some(c) = &o.counters {
                            pairs.push((
                                "counters",
                                arr(c.snapshot().iter().map(|t| t.to_json_latest()).collect()),
                            ));
                        }
                        obj(pairs)
                    })
                    .collect();
                let line = obj(vec![
                    ("schema_version", crate::util::json::num(SCHEMA_VERSION as f64)),
                    ("t_s", crate::util::json::num(started.elapsed().as_secs_f64())),
                    ("engines", arr(engines)),
                ])
                .to_string_compact();
                match &mut file {
                    Some(f) => writeln!(f, "{line}")?,
                    None => println!("METRICS_JSON {line}"),
                }
                // check after emitting: even a run that finishes inside the
                // first interval streams at least one line
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Ok(())
        }))
    } else {
        None
    };

    // synthetic open-loop load
    let mut rng = Rng::seed(5);
    let classes = [AccuracyClass::High, AccuracyClass::Balanced, AccuracyClass::Efficient];
    let mut subs = Vec::new();
    for i in 0..n_requests {
        let plen = rng.range(16, 64);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
        let class = classes[i % classes.len()];
        let deadline = (deadline_ms > 0.0).then(|| {
            std::time::Instant::now() + std::time::Duration::from_secs_f64(deadline_ms / 1e3)
        });
        subs.push((class, router.submit_with_deadline(prompt, max_new, class, deadline)?));
    }
    let mut t = Table::new(
        "serve — per-request results",
        &["id", "class", "engine", "tokens", "status", "ttft ms", "total ms"],
    );
    let mut failed = 0u64;
    for (class, sub) in subs {
        let r = if request_timeout > 0.0 {
            sub.wait_timeout(std::time::Duration::from_secs_f64(request_timeout))?
        } else {
            sub.wait()?
        };
        let status = match &r.error {
            None => "ok".to_string(),
            Some(f) => {
                failed += 1;
                // typed failures are the expected outcome under an armed
                // fault plan or an explicit deadline/timeout budget; without
                // one, any failure is a real serving bug
                anyhow::ensure!(
                    fault_plan.is_some() || deadline_ms > 0.0 || request_timeout > 0.0,
                    "request {} failed: {f}",
                    r.id
                );
                f.kind.as_str().to_string()
            }
        };
        t.row(vec![
            r.id.to_string(),
            class.as_str().into(),
            r.engine.clone(),
            r.tokens.len().to_string(),
            status,
            format!("{:.1}", r.ttft.as_secs_f64() * 1e3),
            format!("{:.1}", r.total.as_secs_f64() * 1e3),
        ]);
    }
    t.print();
    if failed > 0 {
        eprintln!("[serve] {failed}/{n_requests} request(s) ended in a typed failure");
    }

    // stop the streamer before shutdown so its last line reflects a running
    // fleet, then drain the workers
    stream_stop.store(true, Ordering::Relaxed);
    if let Some(h) = streamer {
        h.join().map_err(|_| anyhow::anyhow!("metrics streamer panicked"))??;
    }
    // the registries outlive the router (Arc), so the trace export below
    // snapshots final counter state after the workers drain
    let worker_counters: Vec<(u32, Arc<Counters>)> = router
        .observers()
        .iter()
        .enumerate()
        .filter_map(|(wi, o)| o.counters.clone().map(|c| (wi as u32, c)))
        .collect();
    let reports = router.shutdown()?;
    let mut tm = Table::new("serve — per-engine metrics", &["engine", "summary"]);
    for r in &reports {
        tm.row(vec![r.name.clone(), r.snapshot.to_string()]);
    }
    tm.print();
    for r in &reports {
        if let Some(p) = &r.profile {
            p.table(&format!("serve — per-layer profile ({})", r.name)).print();
        }
    }
    // failure-domain summary: per-kind tallies plus the injected-fault and
    // retry counters, so a chaos run's outcome is auditable from the console
    if reports.iter().any(|r| r.snapshot.failures_total() > 0 || r.snapshot.faults_injected > 0) {
        let mut tf =
            Table::new("serve — failure domains", &["engine", "faults", "retries", "failed", "by kind"]);
        for r in &reports {
            let by_kind: Vec<String> = FailureKind::ALL
                .iter()
                .filter(|k| r.snapshot.failed(**k) > 0)
                .map(|k| format!("{}={}", k.as_str(), r.snapshot.failed(*k)))
                .collect();
            tf.row(vec![
                r.name.clone(),
                r.snapshot.faults_injected.to_string(),
                r.snapshot.retries.to_string(),
                r.snapshot.failures_total().to_string(),
                if by_kind.is_empty() { "-".to_string() } else { by_kind.join(" ") },
            ]);
        }
        tf.print();
    }
    for r in &reports {
        if let Some(sens) = &r.sensitivity {
            if sens.drift_alerts > 0 {
                eprintln!(
                    "[serve] {}: {} drift alert(s) — online quantization error \
                     left the calibrated envelope",
                    r.name, sens.drift_alerts
                );
            }
        }
    }

    if let (Some(path), Some(tracer)) = (&trace_out, &tracer) {
        let sets: Vec<(u32, Vec<TrackSnapshot>)> =
            worker_counters.iter().map(|(wi, c)| (*wi, c.snapshot())).collect();
        write_trace(path, tracer, &sets)?;
        eprintln!(
            "[serve] wrote {} trace events + {} counter tracks to {} ({} dropped)",
            tracer.events().len(),
            sets.iter().map(|(_, t)| t.len()).sum::<usize>(),
            path.display(),
            tracer.dropped(),
        );
    }
    if let Some(path) = &metrics_out {
        let engines: Vec<Json> = reports
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", s(r.name.clone())),
                    ("snapshot", r.snapshot.to_json()),
                    ("profile", r.profile.as_ref().map_or(Json::Null, |p| p.to_json())),
                    ("sensitivity", r.sensitivity.as_ref().map_or(Json::Null, |v| v.to_json())),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("schema_version", crate::util::json::num(SCHEMA_VERSION as f64)),
            ("engines", arr(engines)),
        ]);
        std::fs::write(path, doc.to_string_pretty())?;
        eprintln!("[serve] wrote metrics JSON to {}", path.display());
    }
    if let Some(path) = &sensitivity_out {
        let engines: Vec<Json> = reports
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", s(r.name.clone())),
                    ("sensitivity", r.sensitivity.as_ref().map_or(Json::Null, |v| v.to_json())),
                ])
            })
            .collect();
        let doc = obj(vec![("engines", arr(engines))]);
        std::fs::write(path, doc.to_string_pretty())?;
        eprintln!("[serve] wrote sensitivity JSON to {}", path.display());
    }
    if let Some(server) = metrics_server {
        server.stop();
    }
    Ok(())
}
