//! `kvtuner` CLI: every paper table/figure has a regeneration subcommand
//! (see DESIGN.md §5 for the experiment index).
//!
//!   kvtuner profile    — Table 3/9, Fig 3/7/13–19 (offline error profiling)
//!   kvtuner tune       — Table 4/10/11, Fig 5/8/9/10 (the KVTuner pipeline)
//!   kvtuner eval       — Table 2/5/6/7 (accuracy/perplexity tables)
//!   kvtuner throughput — Table 8 (serving throughput)
//!   kvtuner patterns   — Fig 2/4/11/12 (attention patterns & shifts)
//!   kvtuner serve      — demo serving loop with the router

mod eval_cmd;
mod patterns_cmd;
mod profile_cmd;
mod serve_cmd;
pub mod throughput_cmd;
mod tune_cmd;

use anyhow::Result;

use crate::util::cli::Args;

const USAGE: &str = "\
kvtuner — sensitivity-aware layer-wise mixed-precision KV cache quantization

USAGE: kvtuner <subcommand> [flags]

SUBCOMMANDS
  profile     offline error profiling (Table 3/9, Fig 3/7)
              --model tiny --mode token|kivi|both --prompts 6 --len 48
              --exp table9|table3|fig3|fig7
  tune        full KVTuner pipeline (Table 4/10/11, Fig 5/8/9; --no-prune = Fig 6/10)
              --model tiny --mode token|kivi --algorithm nsga2|moead
              --evals 120 --out tuned.json --no-prune
  eval        accuracy tables (Table 2/5/6/7)
              --exp table2|table5|table7 --model tiny --configs a.json,b.json
  throughput  serving throughput grid (Table 8)
              --model tiny --batch 2 --input-lens 64,128,192 --steps 40
  patterns    head classification + attention shift (Fig 2/4/11/12)
              --model tiny --layer 0 --tokens
  serve       run the multi-engine router on synthetic load
              --model tiny --requests 16 --batch 2
              --synthetic (native backend: synthetic weights, no artifacts)
              --prefill-chunk N (chunked-prefill interleaving: long prompts
              advance N tokens per scheduler tick between batched decode
              steps; default 32, numerics-neutral at any N)

COMMON FLAGS
  --artifacts DIR   artifact directory (default artifacts/tiny or $KVTUNER_ARTIFACTS)
  --backend B       serve/throughput engine backend: xla (PJRT executables,
                    needs AOT artifacts + the XLA extension) or native
                    (in-process kernels, block-table-direct attention, zero
                    artifacts — only manifest.json + the weights file)
  --threads N       serve/throughput, native backend: kernel thread-pool
                    width per engine worker (default: available
                    parallelism, or $KVTUNER_THREADS; serve divides the
                    default across its three workers). Results are
                    bit-identical for every N; N=1 is the scalar engine.
                    Rejects 0. The xla backend ignores it.
  --paged           serve/throughput: paged KV cache (block pool, prefix
                    sharing, preemption) instead of dense slot buffers
  --pool-blocks N   paged pool size in pages (page = quant group)
  --pool-mib MIB    paged pool byte budget (wins over the dense-equivalent
                    default; ignored when --pool-blocks is given)
  --swap-mib MIB    host swap-tier budget: preempted sequences can be
                    swapped out in packed quantized form and resumed
                    bit-exact instead of re-prefilled (needs --paged)
  --swap-policy P   off | always | auto (default auto when --swap-mib is
                    set): per-victim choice between swap-out and recompute

OBSERVABILITY (serve / throughput)
  --trace-out F     write the request-lifecycle trace at exit: Chrome
                    trace-event JSON (load in Perfetto / chrome://tracing;
                    one track per worker slot), or JSONL when F ends in
                    .jsonl
  --metrics-out F   write machine-readable metrics JSON at exit (per-engine
                    snapshot with ttft/total/tpot/step histograms and
                    percentiles, plus the per-layer profile when enabled)
  --profile-serve   serve: enable the per-layer/per-phase engine profiler
                    (also: KVTUNER_PROFILE=1); prints a per-layer table at
                    shutdown. Off = zero overhead.
  --probe-every N   serve: arm the online sensitivity probe — keep an fp
                    shadow of every Nth committed KV group and accumulate
                    the offline profiler's error metrics per layer; when the
                    served config carries a calibration envelope (tune
                    records one), alert on drift past it. 0/absent = no
                    probe, zero overhead.
  --sensitivity-out F
                    serve: write the per-engine sensitivity tables (mean
                    e_k/e_v/e_a/e_o per layer x mode x precision pair, plus
                    drift-alert counts) as JSON at exit
  --metrics-interval SECS
                    serve: stream one JSONL line per interval while serving
                    (metrics snapshot + live sensitivity + latest counter
                    samples per engine) — next to --metrics-out as
                    <file>.jsonl, else as METRICS_JSON stdout lines
  --metrics-listen ADDR
                    serve the Prometheus text exposition at
                    http://ADDR/metrics while the run lasts (e.g.
                    127.0.0.1:9464; port 0 picks a free port). Scrapes show
                    snapshot aggregates plus the latest sample of every
                    memory-hierarchy counter track (pool occupancy,
                    per-layer KV bytes, swap/gather bandwidth, queue depths)

FAILURE INJECTION / DEADLINES (serve)
  --fault-plan P    arm the seeded chaos injector on every worker. P is a
                    bare seed (derives 1-5% rates per injection point:
                    swap-out refusal, transient/lost swap-in, spurious
                    alloc failure, transient step error), an inline JSON
                    object pinning each rate (plus \"step_panic\" and
                    \"death_tick\" for worker-death drills), or a path to
                    such a JSON file. Same plan + seed = same fault
                    schedule. See README \"Failure semantics\".
  --deadline-ms N   per-request deadline: the scheduler abandons a request
                    past its budget with a typed deadline_exceeded failure,
                    delivering the tokens generated so far
  --request-timeout SECS
                    client-side wait bound while draining: an expired wait
                    is a typed timeout response, never a hang
";

pub fn cli_main() -> Result<()> {
    let args =
        Args::from_env(&["no-prune", "tokens", "real-fill", "paged", "profile-serve", "synthetic", "help"])?;
    if args.switch("help") {
        print!("{USAGE}");
        return Ok(());
    }
    if args.subcommand.is_empty() {
        // a missing subcommand is an error, not a success: print usage and
        // exit nonzero (regression: this used to exit 0, and before that the
        // parser was one refactor away from panicking on bare flags)
        eprintln!("missing subcommand\n");
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    match args.subcommand.as_str() {
        "profile" => profile_cmd::run(&args),
        "tune" => tune_cmd::run(&args),
        "eval" => eval_cmd::run(&args),
        "throughput" => throughput_cmd::run(&args),
        "patterns" => patterns_cmd::run(&args),
        "serve" => serve_cmd::run(&args),
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Shared: `--backend {xla,native}` -> engine backend kind. Defaults to the
/// strongest backend this build carries: xla when compiled in, else native.
pub(crate) fn backend_kind(args: &Args) -> Result<crate::engine::BackendKind> {
    match args.opt_str("backend") {
        Some(s) => crate::engine::BackendKind::parse(s),
        None => Ok(crate::engine::BackendKind::default()),
    }
}

/// Shared: `--threads N` -> kernel-pool width for native-backend workers.
/// Defaults to the machine's available parallelism (`KVTUNER_THREADS`
/// overrides); 0 is rejected rather than silently meaning "auto".
pub(crate) fn thread_count(args: &Args) -> Result<usize> {
    match args.opt_str("threads") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--threads expects a positive integer, got {v:?}"))?;
            anyhow::ensure!(n >= 1, "--threads must be >= 1 (use 1 for the scalar engine)");
            Ok(n)
        }
        None => Ok(crate::kernel::default_threads()),
    }
}

/// Shared: resolve the artifact dir from flags/env.
pub(crate) fn artifact_dir(args: &Args) -> std::path::PathBuf {
    match args.opt_str("artifacts") {
        Some(d) => d.into(),
        None => crate::default_artifact_dir(),
    }
}

/// Shared: load manifest + weights for `--model` (defaults to the config name).
pub(crate) fn load_model(
    args: &Args,
) -> Result<(crate::config::Manifest, crate::model::Weights, String)> {
    let dir = artifact_dir(args);
    let manifest = crate::config::Manifest::load(&dir)?;
    let model = args.str("model", &manifest.config.name);
    let weights = crate::model::Weights::load(&manifest, &model)?;
    Ok((manifest, weights, model))
}

/// Shared: `--paged` / `--pool-blocks` / `--pool-mib` / `--swap-mib` /
/// `--swap-policy` -> paged-arm options.
pub(crate) fn paged_options(args: &Args) -> Result<Option<crate::kvcache::PagedOptions>> {
    if !args.switch("paged") {
        // fail loud rather than silently serving dense without a swap tier
        anyhow::ensure!(
            args.opt_str("swap-mib").is_none() && args.opt_str("swap-policy").is_none(),
            "--swap-mib/--swap-policy need the paged cache arm: pass --paged"
        );
        return Ok(None);
    }
    let total_blocks = match args.opt_str("pool-blocks") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let budget_mib = match args.opt_str("pool-mib") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let swap_mib = match args.opt_str("swap-mib") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let swap_policy = match args.opt_str("swap-policy") {
        Some(v) => {
            let p = crate::kvcache::SwapPolicy::parse(v)?;
            anyhow::ensure!(
                p == crate::kvcache::SwapPolicy::Off || swap_mib.is_some(),
                "--swap-policy {} needs a host tier: pass --swap-mib",
                p.as_str()
            );
            p
        }
        // a swap budget without an explicit policy means "use it sensibly"
        None if swap_mib.is_some() => crate::kvcache::SwapPolicy::Auto,
        None => crate::kvcache::SwapPolicy::Off,
    };
    Ok(Some(crate::kvcache::PagedOptions {
        total_blocks,
        budget_mib,
        swap_mib,
        swap_policy,
    }))
}

/// One-line cache-arm description for serve/throughput headers.
pub(crate) fn cache_desc(paged: &Option<crate::kvcache::PagedOptions>) -> String {
    match paged {
        None => "dense".to_string(),
        Some(p) => match p.swap_mib {
            Some(mib) => format!("paged+swap({mib}MiB,{})", p.swap_policy.as_str()),
            None => "paged".to_string(),
        },
    }
}

pub(crate) fn parse_modes(s: &str) -> Result<Vec<crate::config::Mode>> {
    match s {
        "both" => Ok(vec![crate::config::Mode::Token, crate::config::Mode::Kivi]),
        m => Ok(vec![crate::config::Mode::parse(m)?]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Args {
        let v: Vec<String> = xs.iter().map(|s| s.to_string()).collect();
        Args::parse(&v, &[]).unwrap()
    }

    #[test]
    fn threads_flag_parses_and_rejects_zero() {
        assert_eq!(thread_count(&argv(&["serve", "--threads", "4"])).unwrap(), 4);
        assert_eq!(thread_count(&argv(&["serve", "--threads", "1"])).unwrap(), 1);
        assert!(thread_count(&argv(&["serve", "--threads", "0"])).is_err());
        assert!(thread_count(&argv(&["serve", "--threads", "lots"])).is_err());
        // default: machine parallelism (>= 1 by construction)
        assert!(thread_count(&argv(&["serve"])).unwrap() >= 1);
    }
}
