//! `kvtuner tune` — the full KVTuner pipeline. Prints Table 4 (intra-layer
//! pruning), Table 10 (clustering), Table 11 (searched configs), and the
//! Fig 5/8/9 Pareto-front series; `--no-prune` is the Fig 6/10 ablation.

use anyhow::Result;

use crate::config::Mode;
use crate::tuner::{self, Algorithm, MooOptions, TuneOptions};
use crate::util::bench::Table;
use crate::util::cli::Args;

pub fn run(args: &Args) -> Result<()> {
    let (manifest, weights, model) = super::load_model(args)?;
    let cfg = &manifest.config;
    let mode = Mode::parse(&args.str("mode", "token"))?;
    let algorithm = match args.str("algorithm", "nsga2").as_str() {
        "nsga2" => Algorithm::Nsga2,
        "moead" => Algorithm::Moead,
        a => anyhow::bail!("unknown --algorithm {a:?}"),
    };
    let opts = TuneOptions {
        mode,
        n_prompts: args.usize("prompts", 6)?,
        prompt_len: args.usize("len", 40)?,
        horizon: args.usize("horizon", 24)?,
        seed: args.usize("seed", 1234)? as u64,
        moo: MooOptions {
            evaluations: args.usize("evals", 120)?,
            population: args.usize("population", 16)?,
            seed: args.usize("seed", 1234)? as u64,
            bit_constraints: args
                .list("constraints", "4,6")
                .iter()
                .map(|s| s.parse::<f64>().unwrap())
                .collect(),
            mutation_rate: args.f64("mutation", 0.2)?,
        },
        algorithm,
        no_prune: args.switch("no-prune"),
        dbscan_eps: args.f64("eps", 0.05)?,
    };

    eprintln!(
        "[tune] model={model} mode={} algo={algorithm:?} evals={} no_prune={}",
        mode.as_str(),
        opts.moo.evaluations,
        opts.no_prune
    );
    let t0 = std::time::Instant::now();
    let result = tuner::run_pipeline(cfg, &weights, &opts)?;
    eprintln!("[tune] pipeline done in {:.1}s ({} evals)", t0.elapsed().as_secs_f64(), result.evals);

    // Table 4 — intra-layer pruning
    let mut t4 = Table::new(
        "Table 4 — intra-layer Pareto-pruned precision pairs",
        &["layer", "pruned candidate set"],
    );
    let mut by_sig: Vec<(String, Vec<usize>)> = Vec::new();
    for (l, cands) in result.pruned.iter().enumerate() {
        let sig = tuner::pareto::candidate_signature(cands);
        match by_sig.iter_mut().find(|(s, _)| *s == sig) {
            Some((_, ls)) => ls.push(l),
            None => by_sig.push((sig, vec![l])),
        }
    }
    for (sig, layers) in &by_sig {
        t4.row(vec![fmt_ids(layers), sig.clone()]);
    }
    t4.print();
    let (full, pruned) = tuner::pareto::search_space_log10(&result.pruned);
    println!(
        "search space: 10^{full:.1} -> 10^{pruned:.1} after intra-layer pruning, {} groups after clustering",
        result.groups.len()
    );

    // Table 10 — clustering
    let mut t10 = Table::new("Table 10 — inter-layer clustering", &["group", "layers", "candidates"]);
    for (g, grp) in result.groups.iter().enumerate() {
        t10.row(vec![
            format!("G{g}"),
            fmt_ids(&grp.layers),
            tuner::pareto::candidate_signature(&grp.candidates),
        ]);
    }
    t10.print();

    // Fig 5/8/9 (or 6/10 with --no-prune) — the Pareto frontier
    let mut tf = Table::new(
        &format!(
            "Fig {} — Pareto frontier (equiv bits vs fidelity accuracy)",
            if opts.no_prune { "6/10 (ablation: no pruning)" } else { "5/8/9" }
        ),
        &["equiv bits", "accuracy", "picks"],
    );
    for p in &result.front {
        tf.row(vec![
            format!("{:.2}", p.bits),
            format!("{:.4}", p.accuracy),
            p.picks.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(""),
        ]);
    }
    tf.print();

    // Table 11 — the selected layer-wise configs
    let mut t11 = Table::new(
        "Table 11 — searched layer-wise KV precision configs",
        &["config", "equiv bits", "accuracy", "layer pairs"],
    );
    for c in &result.configs {
        t11.row(vec![
            c.label.clone(),
            format!("{:.2}", c.equivalent_bits),
            format!("{:.4}", c.accuracy),
            c.specs.iter().map(|s| s.pair.label()).collect::<Vec<_>>().join(" "),
        ]);
    }
    t11.print();

    if let Some(out) = args.opt_str("out") {
        let base = std::path::Path::new(out);
        for c in &result.configs {
            let path = if result.configs.len() == 1 {
                base.to_path_buf()
            } else {
                base.with_file_name(format!(
                    "{}-{}.json",
                    base.file_stem().unwrap_or_default().to_string_lossy(),
                    c.label.replace("KVTuner-", "")
                ))
            };
            c.save(&path)?;
            eprintln!("[tune] wrote {}", path.display());
        }
    }
    Ok(())
}

fn fmt_ids(ids: &[usize]) -> String {
    // compress runs: 0,1,2,5 -> 0~2,5
    let mut out: Vec<String> = Vec::new();
    let mut i = 0;
    while i < ids.len() {
        let mut j = i;
        while j + 1 < ids.len() && ids[j + 1] == ids[j] + 1 {
            j += 1;
        }
        if j > i + 1 {
            out.push(format!("{}~{}", ids[i], ids[j]));
        } else {
            for k in i..=j {
                out.push(ids[k].to_string());
            }
        }
        i = j + 1;
    }
    out.join(",")
}
