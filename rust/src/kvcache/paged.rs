//! Paged mixed-precision KV cache: a block pool of fixed-size token pages
//! with lazy allocation, free-list recycling, hash-based prefix sharing with
//! copy-on-write, and budget-capped admission.
//!
//! Page size = the KIVI group `g`, so per-channel key scales are page-aligned
//! (one scale/zero vector per page) and kivi commits always land on a page
//! boundary. A `BlockId` names one page across *all* layers; each layer owns
//! arenas (codes, scales, zeros, fp) indexed by block id with a per-layer
//! per-precision stride, so a K8V4 layer's page is physically larger than a
//! K4V2 layer's while sharing the same id space and block tables.
//!
//! The PJRT layer-step artifacts still consume the dense `[B, H, S_max, ·]`
//! layout: at each layer step the live pages are gathered into transient
//! dense staging buffers (or a single-slot slice for B=1 prefill), and the
//! step's new-token outputs are scattered back into pages. Nothing changes on
//! the Python/AOT side; what the pool buys is *capacity* — the resident
//! footprint is the page pool, not `batch * s_max`, so a fixed `kv_bytes`
//! budget admits more concurrent requests than it has dense slots.

use std::collections::HashMap;

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use xla::Literal;

use crate::config::{LayerSpec, Mode, ModelConfig};
use crate::quant::packed_width;
use crate::tensor::Tensor;

use super::backend::{CacheBackend, MemStats, OutOfPages, PagedOptions};
use super::block::{BlockId, BlockPool};
use super::view::{KvView, PageAddr};
use super::swap::{
    self, HostArenaFull, HostSwapArena, SwapHandle, SwapLost, SwapPage, SwapPayload, SwapStats,
};

/// One layer's page arenas. Unused arenas for the layer's mode stay empty.
#[derive(Debug)]
struct PagedLayer {
    spec: LayerSpec,
    kp: usize,
    vp: usize,
    /// Bytes of one page in this layer (codes + scales + zeros or fp).
    block_bytes: usize,
    k_codes: Vec<u8>,
    k_scale: Vec<f32>,
    k_zero: Vec<f32>,
    v_codes: Vec<u8>,
    v_scale: Vec<f32>,
    v_zero: Vec<f32>,
    k_fp: Vec<f32>,
    v_fp: Vec<f32>,
    /// Kivi fp residual rings, per slot (outside the page pool): [B, H, R, Dh].
    k_res: Vec<f32>,
    v_res: Vec<f32>,
    cache_len: Vec<i32>,
    res_len: Vec<i32>,
}

impl PagedLayer {
    fn new(
        cfg: &ModelConfig,
        spec: LayerSpec,
        batch: usize,
        n_blocks: usize,
        page: usize,
    ) -> Result<PagedLayer> {
        let (h, dh, r) = (cfg.n_kv_heads, cfg.head_dim, cfg.residual);
        let mut l = PagedLayer {
            spec,
            kp: 0,
            vp: 0,
            block_bytes: 0,
            k_codes: Vec::new(),
            k_scale: Vec::new(),
            k_zero: Vec::new(),
            v_codes: Vec::new(),
            v_scale: Vec::new(),
            v_zero: Vec::new(),
            k_fp: Vec::new(),
            v_fp: Vec::new(),
            k_res: Vec::new(),
            v_res: Vec::new(),
            cache_len: vec![0; batch],
            res_len: vec![0; batch],
        };
        match spec.mode {
            Mode::Fp => {
                l.k_fp = vec![0.0; n_blocks * h * page * dh];
                l.v_fp = vec![0.0; n_blocks * h * page * dh];
                l.block_bytes = 2 * h * page * dh * 4;
            }
            Mode::Token => {
                l.kp = packed_width(dh, spec.pair.k_bits)?;
                l.vp = packed_width(dh, spec.pair.v_bits)?;
                l.k_codes = vec![0; n_blocks * h * page * l.kp];
                l.v_codes = vec![0; n_blocks * h * page * l.vp];
                l.k_scale = vec![0.0; n_blocks * h * page];
                l.k_zero = vec![0.0; n_blocks * h * page];
                l.v_scale = vec![0.0; n_blocks * h * page];
                l.v_zero = vec![0.0; n_blocks * h * page];
                l.block_bytes = h * page * (l.kp + l.vp) + 4 * h * page * 4;
            }
            Mode::Kivi => {
                l.kp = packed_width(dh, spec.pair.k_bits)?;
                l.vp = packed_width(dh, spec.pair.v_bits)?;
                l.k_codes = vec![0; n_blocks * h * page * l.kp];
                l.v_codes = vec![0; n_blocks * h * page * l.vp];
                // one per-channel scale/zero vector per page (page == group)
                l.k_scale = vec![0.0; n_blocks * h * dh];
                l.k_zero = vec![0.0; n_blocks * h * dh];
                l.v_scale = vec![0.0; n_blocks * h * page];
                l.v_zero = vec![0.0; n_blocks * h * page];
                l.k_res = vec![0.0; batch * h * r * dh];
                l.v_res = vec![0.0; batch * h * r * dh];
                l.block_bytes =
                    h * page * (l.kp + l.vp) + (2 * h * dh + 2 * h * page) * 4;
            }
        }
        Ok(l)
    }

    fn residual_bytes(&self) -> usize {
        (self.k_res.len() + self.v_res.len()) * 4
    }
}

/// Bytes of one page summed over all layers (a `BlockId`'s true cost).
fn per_block_bytes(cfg: &ModelConfig, specs: &[LayerSpec], page: usize) -> Result<usize> {
    let (h, dh) = (cfg.n_kv_heads, cfg.head_dim);
    let mut total = 0usize;
    for spec in specs {
        total += match spec.mode {
            Mode::Fp => 2 * h * page * dh * 4,
            Mode::Token => {
                let kp = packed_width(dh, spec.pair.k_bits)?;
                let vp = packed_width(dh, spec.pair.v_bits)?;
                h * page * (kp + vp) + 4 * h * page * 4
            }
            Mode::Kivi => {
                let kp = packed_width(dh, spec.pair.k_bits)?;
                let vp = packed_width(dh, spec.pair.v_bits)?;
                h * page * (kp + vp) + (2 * h * dh + 2 * h * page) * 4
            }
        };
    }
    Ok(total)
}

/// Serialize one physical page (all layers) into a host slot, with the same
/// per-layer per-precision strides the device arenas use, so a later
/// `deserialize_page` is a pure byte copy — bit-exact with never-evicted
/// state. Free function so callers can borrow the layer arenas and the host
/// arena disjointly.
fn serialize_page(layers: &[PagedLayer], h: usize, p: usize, dh: usize, id: usize, dst: &mut [u8]) {
    let mut off = 0usize;
    for l in layers {
        match l.spec.mode {
            Mode::Fp => {
                let n = h * p * dh;
                swap::write_f32s(dst, &mut off, &l.k_fp[id * n..(id + 1) * n]);
                swap::write_f32s(dst, &mut off, &l.v_fp[id * n..(id + 1) * n]);
            }
            Mode::Token => {
                let (nk, nv, ns) = (h * p * l.kp, h * p * l.vp, h * p);
                swap::write_u8s(dst, &mut off, &l.k_codes[id * nk..(id + 1) * nk]);
                swap::write_f32s(dst, &mut off, &l.k_scale[id * ns..(id + 1) * ns]);
                swap::write_f32s(dst, &mut off, &l.k_zero[id * ns..(id + 1) * ns]);
                swap::write_u8s(dst, &mut off, &l.v_codes[id * nv..(id + 1) * nv]);
                swap::write_f32s(dst, &mut off, &l.v_scale[id * ns..(id + 1) * ns]);
                swap::write_f32s(dst, &mut off, &l.v_zero[id * ns..(id + 1) * ns]);
            }
            Mode::Kivi => {
                let (nk, nv, nc, ns) = (h * p * l.kp, h * p * l.vp, h * dh, h * p);
                swap::write_u8s(dst, &mut off, &l.k_codes[id * nk..(id + 1) * nk]);
                swap::write_f32s(dst, &mut off, &l.k_scale[id * nc..(id + 1) * nc]);
                swap::write_f32s(dst, &mut off, &l.k_zero[id * nc..(id + 1) * nc]);
                swap::write_u8s(dst, &mut off, &l.v_codes[id * nv..(id + 1) * nv]);
                swap::write_f32s(dst, &mut off, &l.v_scale[id * ns..(id + 1) * ns]);
                swap::write_f32s(dst, &mut off, &l.v_zero[id * ns..(id + 1) * ns]);
            }
        }
    }
    debug_assert_eq!(off, dst.len(), "host slot size must equal block_bytes_all");
}

/// Inverse of `serialize_page`: scatter a host slot's bytes back into a
/// freshly allocated device page.
fn deserialize_page(
    layers: &mut [PagedLayer],
    h: usize,
    p: usize,
    dh: usize,
    id: usize,
    src: &[u8],
) {
    let mut off = 0usize;
    for l in layers {
        match l.spec.mode {
            Mode::Fp => {
                let n = h * p * dh;
                swap::read_f32s(src, &mut off, &mut l.k_fp[id * n..(id + 1) * n]);
                swap::read_f32s(src, &mut off, &mut l.v_fp[id * n..(id + 1) * n]);
            }
            Mode::Token => {
                let (nk, nv, ns) = (h * p * l.kp, h * p * l.vp, h * p);
                swap::read_u8s(src, &mut off, &mut l.k_codes[id * nk..(id + 1) * nk]);
                swap::read_f32s(src, &mut off, &mut l.k_scale[id * ns..(id + 1) * ns]);
                swap::read_f32s(src, &mut off, &mut l.k_zero[id * ns..(id + 1) * ns]);
                swap::read_u8s(src, &mut off, &mut l.v_codes[id * nv..(id + 1) * nv]);
                swap::read_f32s(src, &mut off, &mut l.v_scale[id * ns..(id + 1) * ns]);
                swap::read_f32s(src, &mut off, &mut l.v_zero[id * ns..(id + 1) * ns]);
            }
            Mode::Kivi => {
                let (nk, nv, nc, ns) = (h * p * l.kp, h * p * l.vp, h * dh, h * p);
                swap::read_u8s(src, &mut off, &mut l.k_codes[id * nk..(id + 1) * nk]);
                swap::read_f32s(src, &mut off, &mut l.k_scale[id * nc..(id + 1) * nc]);
                swap::read_f32s(src, &mut off, &mut l.k_zero[id * nc..(id + 1) * nc]);
                swap::read_u8s(src, &mut off, &mut l.v_codes[id * nv..(id + 1) * nv]);
                swap::read_f32s(src, &mut off, &mut l.v_scale[id * ns..(id + 1) * ns]);
                swap::read_f32s(src, &mut off, &mut l.v_zero[id * ns..(id + 1) * ns]);
            }
        }
    }
    debug_assert_eq!(off, src.len(), "host slot size must equal block_bytes_all");
}

fn chain_hash(parent: u64, toks: &[i32]) -> u64 {
    // FNV-1a over the parent hash and the page's token ids; exact token
    // comparison on lookup makes collisions harmless.
    let mut h = parent ^ 0x9e37_79b9_7f4a_7c15;
    for &t in toks {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

const PREFIX_SEED: u64 = 0xcbf2_9ce4_8422_2325;

#[derive(Debug)]
pub struct PagedKvCache {
    layers: Vec<PagedLayer>,
    /// Per-slot block tables: token block `i` of a slot lives in physical
    /// block `tables[slot][i]` of every layer's arena.
    tables: Vec<Vec<BlockId>>,
    pool: BlockPool,
    /// Prefix-chain hash -> physical block holding that page.
    index: HashMap<u64, BlockId>,
    block_hash: Vec<Option<u64>>,
    /// Per registered block: (parent chain hash, page tokens). Both are
    /// verified on lookup, so a 64-bit chain-hash collision can never serve
    /// KV pages computed under a different prefix (by induction over the
    /// chain: a page matches only if its parent matched the same way).
    block_tokens: Vec<Option<(u64, Vec<i32>)>>,
    pos: Vec<i32>,
    batch: usize,
    s_max: usize,
    page: usize,
    group: usize,
    residual: usize,
    h: usize,
    dh: usize,
    block_bytes_all: usize,
    /// Host swap tier (None = recompute-only preemption, PR 1 behavior).
    swap: Option<HostSwapArena>,
    pub cow_copies: u64,
    pub prefix_hits: u64,
    pub prefix_tokens_reused: u64,
    pub evictions: u64,
}

impl PagedKvCache {
    pub fn new(
        cfg: &ModelConfig,
        specs: &[LayerSpec],
        batch: usize,
        s_max: usize,
        opts: &PagedOptions,
    ) -> Result<PagedKvCache> {
        if specs.len() != cfg.n_layers {
            bail!("{} specs for {} layers", specs.len(), cfg.n_layers);
        }
        let page = cfg.group;
        if page == 0 {
            bail!("page size (group) must be > 0");
        }
        if specs.iter().any(|s| s.mode == Mode::Kivi) && s_max % page != 0 {
            bail!(
                "kivi layers require s_max ({s_max}) to be a multiple of the \
                 quantization group ({page})"
            );
        }
        let block_bytes_all = per_block_bytes(cfg, specs, page)?;
        let max_blocks_per_slot = (s_max + page - 1) / page;
        // per-slot kivi residual rings live outside the page pool but inside
        // the resident footprint: a byte budget must cover them first
        let residual_fixed = specs.iter().filter(|s| s.mode == Mode::Kivi).count()
            * batch
            * cfg.n_kv_heads
            * cfg.residual
            * cfg.head_dim
            * 4
            * 2;
        let total_blocks = match (opts.total_blocks, opts.budget_mib) {
            (Some(n), _) => n,
            (None, Some(mib)) => {
                let budget = (mib * 1024.0 * 1024.0) as usize;
                budget.saturating_sub(residual_fixed) / block_bytes_all
            }
            (None, None) => batch * max_blocks_per_slot,
        };
        if total_blocks == 0 {
            bail!(
                "page pool budget too small: one page costs {} bytes across \
                 all layers",
                block_bytes_all
            );
        }
        let layers = specs
            .iter()
            .map(|&sp| PagedLayer::new(cfg, sp, batch, total_blocks, page))
            .collect::<Result<Vec<_>>>()?;
        let swap = match opts.swap_mib {
            Some(mib) => Some(HostSwapArena::new(block_bytes_all, mib)?),
            None => None,
        };
        Ok(PagedKvCache {
            layers,
            tables: vec![Vec::new(); batch],
            pool: BlockPool::new(total_blocks),
            index: HashMap::new(),
            block_hash: vec![None; total_blocks],
            block_tokens: vec![None; total_blocks],
            pos: vec![0; batch],
            batch,
            s_max,
            page,
            group: cfg.group,
            residual: cfg.residual,
            h: cfg.n_kv_heads,
            dh: cfg.head_dim,
            block_bytes_all,
            swap,
            cow_copies: 0,
            prefix_hits: 0,
            prefix_tokens_reused: 0,
            evictions: 0,
        })
    }

    // ---- introspection (tests, benches, metrics) ----

    pub fn page_size(&self) -> usize {
        self.page
    }

    pub fn total_blocks(&self) -> usize {
        self.pool.total()
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_count()
    }

    pub fn block_table(&self, slot: usize) -> &[BlockId] {
        &self.tables[slot]
    }

    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.pool.ref_count(id)
    }

    /// Bytes of one page summed over all layers (host swap slot size).
    pub fn block_bytes(&self) -> usize {
        self.block_bytes_all
    }

    pub fn host_swap_slots(&self) -> Option<(usize, usize)> {
        self.swap.as_ref().map(|a| (a.free_slots(), a.total_slots()))
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        (tokens + self.page - 1) / self.page
    }

    /// One slot's kivi fp residual-ring bytes across layers (swapped along
    /// with the pages; they live outside the page pool).
    fn residual_slot_bytes(&self) -> usize {
        self.layers.iter().filter(|l| l.spec.mode == Mode::Kivi).count()
            * 2
            * self.h
            * self.residual
            * self.dh
            * 4
    }

    /// Resolve a recorded prefix link against the index, with the same
    /// exact-token verification `prefill_reuse` applies.
    fn lookup_linked(&self, hash: u64, parent: u64, tokens: &[i32]) -> Option<BlockId> {
        self.index.get(&hash).copied().filter(|&id| {
            self.block_tokens[id as usize]
                .as_ref()
                .map(|(par, t)| *par == parent && t.as_slice() == tokens)
                .unwrap_or(false)
        })
    }

    /// Whether this block is currently addressable through the prefix index.
    fn is_indexed(&self, id: BlockId) -> bool {
        matches!(self.block_hash[id as usize], Some(h) if self.index.get(&h) == Some(&id))
            && self.block_tokens[id as usize].is_some()
    }

    /// Whether a swap-out of one of this block's holders can record the page
    /// by chain hash instead of copying its bytes. Being indexed is not
    /// enough: after the victim's decref a refcount-0 page sits on the free
    /// list, and the very pool pressure that caused the preemption will
    /// recycle it before the sequence resumes — the link must be backed by
    /// another *resident* holder (refcount > 1), so the page stays live.
    /// Pages whose co-holders exit while the victim is away drop to the free
    /// list and can still be resurrected at swap-in; if even that fails the
    /// `SwapLost` fallback re-prefills.
    fn can_relink(&self, id: BlockId) -> bool {
        self.is_indexed(id) && self.pool.ref_count(id) > 1
    }

    // ---- allocation / copy-on-write ----

    /// Allocate a fresh block, recycling the least-recently-freed cached
    /// prefix page when necessary (its index entry is evicted).
    fn alloc_block(&mut self) -> Result<BlockId> {
        let Some(id) = self.pool.alloc() else {
            return Err(anyhow::Error::new(OutOfPages));
        };
        if let Some(h) = self.block_hash[id as usize].take() {
            if self.index.get(&h) == Some(&id) {
                self.index.remove(&h);
            }
            self.block_tokens[id as usize] = None;
            self.evictions += 1;
        }
        Ok(id)
    }

    /// Grow `slot`'s table until it covers `tokens_end` tokens.
    fn ensure_capacity(&mut self, slot: usize, tokens_end: usize) -> Result<()> {
        anyhow::ensure!(
            tokens_end <= self.s_max,
            "paged cache overflow (slot {slot}: {tokens_end} > {})",
            self.s_max
        );
        let need = self.blocks_for(tokens_end);
        while self.tables[slot].len() < need {
            let id = self.alloc_block()?;
            self.tables[slot].push(id);
        }
        Ok(())
    }

    /// Make `slot`'s `block_idx`-th page exclusively writable: shared pages
    /// (refcount > 1) are copied first — copy-on-write — and a sole-owned
    /// page that was published to the prefix index is unpublished, since its
    /// content is about to diverge. Every scatter path funnels through here.
    pub fn ensure_writable(&mut self, slot: usize, block_idx: usize) -> Result<BlockId> {
        let id = self.tables[slot][block_idx];
        if self.pool.ref_count(id) > 1 {
            let nid = self.alloc_block()?;
            self.copy_block(id, nid);
            self.pool.decref(id);
            self.tables[slot][block_idx] = nid;
            self.cow_copies += 1;
            return Ok(nid);
        }
        if let Some(h) = self.block_hash[id as usize].take() {
            if self.index.get(&h) == Some(&id) {
                self.index.remove(&h);
            }
            self.block_tokens[id as usize] = None;
        }
        Ok(id)
    }

    fn copy_block(&mut self, src: BlockId, dst: BlockId) {
        let (h, p, dh) = (self.h, self.page, self.dh);
        let (s, d) = (src as usize, dst as usize);
        for l in self.layers.iter_mut() {
            match l.spec.mode {
                Mode::Fp => {
                    let n = h * p * dh;
                    l.k_fp.copy_within(s * n..(s + 1) * n, d * n);
                    l.v_fp.copy_within(s * n..(s + 1) * n, d * n);
                }
                Mode::Token => {
                    let nk = h * p * l.kp;
                    let nv = h * p * l.vp;
                    let ns = h * p;
                    l.k_codes.copy_within(s * nk..(s + 1) * nk, d * nk);
                    l.v_codes.copy_within(s * nv..(s + 1) * nv, d * nv);
                    l.k_scale.copy_within(s * ns..(s + 1) * ns, d * ns);
                    l.k_zero.copy_within(s * ns..(s + 1) * ns, d * ns);
                    l.v_scale.copy_within(s * ns..(s + 1) * ns, d * ns);
                    l.v_zero.copy_within(s * ns..(s + 1) * ns, d * ns);
                }
                Mode::Kivi => {
                    let nk = h * p * l.kp;
                    let nv = h * p * l.vp;
                    let nc = h * dh;
                    let ns = h * p;
                    l.k_codes.copy_within(s * nk..(s + 1) * nk, d * nk);
                    l.v_codes.copy_within(s * nv..(s + 1) * nv, d * nv);
                    l.k_scale.copy_within(s * nc..(s + 1) * nc, d * nc);
                    l.k_zero.copy_within(s * nc..(s + 1) * nc, d * nc);
                    l.v_scale.copy_within(s * ns..(s + 1) * ns, d * ns);
                    l.v_zero.copy_within(s * ns..(s + 1) * ns, d * ns);
                }
            }
        }
    }

    // ---- gather: pages -> dense artifact layout ----

    /// Gather `slots` into dense cache tensors ([len(slots), H, S, ·]) in the
    /// layer artifact's argument order. Unwritten regions carry the same
    /// defaults the dense arm allocates with (scales 1.0, everything else 0),
    /// so a fresh dense cache and a paged gather are bit-identical.
    fn gather_layer(&self, layer: usize, slots: &[usize]) -> Result<Vec<Tensor>> {
        let lc = &self.layers[layer];
        let (h, p, dh, s, r) = (self.h, self.page, self.dh, self.s_max, self.residual);
        let b = slots.len();
        match lc.spec.mode {
            Mode::Fp => {
                let mut k = vec![0f32; b * h * s * dh];
                let mut v = vec![0f32; b * h * s * dh];
                for (di, &slot) in slots.iter().enumerate() {
                    let len = lc.cache_len[slot] as usize;
                    for bi in 0..self.blocks_for(len) {
                        let rows = (len - bi * p).min(p);
                        let id = self.tables[slot][bi] as usize;
                        for hh in 0..h {
                            let src = ((id * h + hh) * p) * dh;
                            let dst = ((di * h + hh) * s + bi * p) * dh;
                            k[dst..dst + rows * dh]
                                .copy_from_slice(&lc.k_fp[src..src + rows * dh]);
                            v[dst..dst + rows * dh]
                                .copy_from_slice(&lc.v_fp[src..src + rows * dh]);
                        }
                    }
                }
                Ok(vec![
                    Tensor::f32(&[b, h, s, dh], k),
                    Tensor::f32(&[b, h, s, dh], v),
                ])
            }
            Mode::Token => {
                let (kp, vp) = (lc.kp, lc.vp);
                let mut kc = vec![0u8; b * h * s * kp];
                let mut ks = vec![1f32; b * h * s];
                let mut kz = vec![0f32; b * h * s];
                let mut vc = vec![0u8; b * h * s * vp];
                let mut vs = vec![1f32; b * h * s];
                let mut vz = vec![0f32; b * h * s];
                for (di, &slot) in slots.iter().enumerate() {
                    let len = lc.cache_len[slot] as usize;
                    for bi in 0..self.blocks_for(len) {
                        let rows = (len - bi * p).min(p);
                        let id = self.tables[slot][bi] as usize;
                        for hh in 0..h {
                            let src = ((id * h + hh) * p) * kp;
                            let dst = ((di * h + hh) * s + bi * p) * kp;
                            kc[dst..dst + rows * kp]
                                .copy_from_slice(&lc.k_codes[src..src + rows * kp]);
                            let srcv = ((id * h + hh) * p) * vp;
                            let dstv = ((di * h + hh) * s + bi * p) * vp;
                            vc[dstv..dstv + rows * vp]
                                .copy_from_slice(&lc.v_codes[srcv..srcv + rows * vp]);
                            let ssrc = (id * h + hh) * p;
                            let sdst = (di * h + hh) * s + bi * p;
                            ks[sdst..sdst + rows]
                                .copy_from_slice(&lc.k_scale[ssrc..ssrc + rows]);
                            kz[sdst..sdst + rows]
                                .copy_from_slice(&lc.k_zero[ssrc..ssrc + rows]);
                            vs[sdst..sdst + rows]
                                .copy_from_slice(&lc.v_scale[ssrc..ssrc + rows]);
                            vz[sdst..sdst + rows]
                                .copy_from_slice(&lc.v_zero[ssrc..ssrc + rows]);
                        }
                    }
                }
                Ok(vec![
                    Tensor::u8(&[b, h, s, kp], kc),
                    Tensor::f32(&[b, h, s], ks),
                    Tensor::f32(&[b, h, s], kz),
                    Tensor::u8(&[b, h, s, vp], vc),
                    Tensor::f32(&[b, h, s], vs),
                    Tensor::f32(&[b, h, s], vz),
                ])
            }
            Mode::Kivi => {
                let (kp, vp) = (lc.kp, lc.vp);
                let ng = s / p;
                let mut kc = vec![0u8; b * h * s * kp];
                let mut ks = vec![1f32; b * h * ng * dh];
                let mut kz = vec![0f32; b * h * ng * dh];
                let mut vc = vec![0u8; b * h * s * vp];
                let mut vs = vec![1f32; b * h * s];
                let mut vz = vec![0f32; b * h * s];
                let mut kr = vec![0f32; b * h * r * dh];
                let mut vr = vec![0f32; b * h * r * dh];
                for (di, &slot) in slots.iter().enumerate() {
                    let len = lc.cache_len[slot] as usize; // multiple of p
                    for bi in 0..self.blocks_for(len) {
                        let rows = (len - bi * p).min(p);
                        let id = self.tables[slot][bi] as usize;
                        for hh in 0..h {
                            let src = ((id * h + hh) * p) * kp;
                            let dst = ((di * h + hh) * s + bi * p) * kp;
                            kc[dst..dst + rows * kp]
                                .copy_from_slice(&lc.k_codes[src..src + rows * kp]);
                            let srcv = ((id * h + hh) * p) * vp;
                            let dstv = ((di * h + hh) * s + bi * p) * vp;
                            vc[dstv..dstv + rows * vp]
                                .copy_from_slice(&lc.v_codes[srcv..srcv + rows * vp]);
                            // per-channel key scales: one vector per page
                            let csrc = (id * h + hh) * dh;
                            let cdst = ((di * h + hh) * ng + bi) * dh;
                            ks[cdst..cdst + dh]
                                .copy_from_slice(&lc.k_scale[csrc..csrc + dh]);
                            kz[cdst..cdst + dh]
                                .copy_from_slice(&lc.k_zero[csrc..csrc + dh]);
                            // per-token value scales
                            let ssrc = (id * h + hh) * p;
                            let sdst = (di * h + hh) * s + bi * p;
                            vs[sdst..sdst + rows]
                                .copy_from_slice(&lc.v_scale[ssrc..ssrc + rows]);
                            vz[sdst..sdst + rows]
                                .copy_from_slice(&lc.v_zero[ssrc..ssrc + rows]);
                        }
                    }
                    // residual ring is per-slot and contiguous
                    let n = h * r * dh;
                    kr[di * n..(di + 1) * n].copy_from_slice(&lc.k_res[slot * n..(slot + 1) * n]);
                    vr[di * n..(di + 1) * n].copy_from_slice(&lc.v_res[slot * n..(slot + 1) * n]);
                }
                Ok(vec![
                    Tensor::u8(&[b, h, s, kp], kc),
                    Tensor::f32(&[b, h, ng, dh], ks),
                    Tensor::f32(&[b, h, ng, dh], kz),
                    Tensor::u8(&[b, h, s, vp], vc),
                    Tensor::f32(&[b, h, s], vs),
                    Tensor::f32(&[b, h, s], vz),
                    Tensor::f32(&[b, h, r, dh], kr),
                    Tensor::f32(&[b, h, r, dh], vr),
                ])
            }
        }
    }

    /// Gathered cache tensors for one slot / the whole batch (host form; the
    /// trait wraps these into literals). Public for equivalence tests.
    pub fn gather_slot(&self, layer: usize, slot: usize) -> Result<Vec<Tensor>> {
        self.gather_layer(layer, &[slot])
    }

    pub fn gather_batch(&self, layer: usize) -> Result<Vec<Tensor>> {
        let slots: Vec<usize> = (0..self.batch).collect();
        self.gather_layer(layer, &slots)
    }
}

impl CacheBackend for PagedKvCache {
    fn batch(&self) -> usize {
        self.batch
    }

    fn s_max(&self) -> usize {
        self.s_max
    }

    fn pos(&self, slot: usize) -> i32 {
        self.pos[slot]
    }

    fn advance_pos(&mut self, slot: usize, by: usize) {
        self.pos[slot] += by as i32;
    }

    fn cache_len(&self, layer: usize, slot: usize) -> i32 {
        self.layers[layer].cache_len[slot]
    }

    fn res_len(&self, layer: usize, slot: usize) -> i32 {
        self.layers[layer].res_len[slot]
    }

    #[cfg(feature = "xla")]
    fn layer_literals(&self, layer: usize) -> Result<Vec<Literal>> {
        self.gather_batch(layer)?.iter().map(|t| t.to_literal()).collect()
    }

    #[cfg(feature = "xla")]
    fn slot_literals(&self, layer: usize, slot: usize) -> Result<Vec<Literal>> {
        self.gather_slot(layer, slot)?.iter().map(|t| t.to_literal()).collect()
    }

    /// Block-table-direct view: the whole per-layer arenas plus this slot's
    /// block table — the native attention kernel reads pages in place, so
    /// no gather-to-dense staging copy happens on this path.
    fn kv_view(&self, layer: usize, slot: usize) -> Result<KvView<'_>> {
        let lc = &self.layers[layer];
        let rn = self.h * self.residual * self.dh;
        let empty_f: &[f32] = &[];
        let (k_res, v_res) = if lc.spec.mode == Mode::Kivi {
            (
                &lc.k_res[slot * rn..(slot + 1) * rn],
                &lc.v_res[slot * rn..(slot + 1) * rn],
            )
        } else {
            (empty_f, empty_f)
        };
        Ok(KvView {
            spec: lc.spec,
            h: self.h,
            dh: self.dh,
            kp: lc.kp,
            vp: lc.vp,
            page: self.page,
            cache_len: lc.cache_len[slot] as usize,
            res_len: lc.res_len[slot] as usize,
            addr: PageAddr::Paged { table: &self.tables[slot] },
            k_codes: &lc.k_codes,
            k_scale: &lc.k_scale,
            k_zero: &lc.k_zero,
            v_codes: &lc.v_codes,
            v_scale: &lc.v_scale,
            v_zero: &lc.v_zero,
            k_fp: &lc.k_fp,
            v_fp: &lc.v_fp,
            k_res,
            v_res,
            res_cap: self.residual,
        })
    }

    /// Bytes one gather-to-dense staging copy of `n_slots` slots moves for
    /// this layer — exactly the buffers `gather_layer` allocates (dense
    /// artifact shapes, valid or not: the staging cost is O(s_max), which
    /// is the point the block-direct kernel makes).
    fn staged_bytes(&self, layer: usize, n_slots: usize) -> usize {
        let lc = &self.layers[layer];
        let (h, s, dh, r) = (self.h, self.s_max, self.dh, self.residual);
        let b = n_slots;
        match lc.spec.mode {
            Mode::Fp => 2 * b * h * s * dh * 4,
            Mode::Token => b * h * s * (lc.kp + lc.vp) + 4 * b * h * s * 4,
            Mode::Kivi => {
                let ng = s / self.page;
                b * h * s * (lc.kp + lc.vp)
                    + 2 * b * h * ng * dh * 4
                    + 2 * b * h * s * 4
                    + 2 * b * h * r * dh * 4
            }
        }
    }

    fn append_token_outputs(
        &mut self,
        layer: usize,
        slot0: usize,
        outs: &[Tensor],
        valid: &[usize],
    ) -> Result<()> {
        debug_assert_eq!(self.layers[layer].spec.mode, Mode::Token);
        let (h, p) = (self.h, self.page);
        let t = outs[0].shape[2];
        let b_exec = outs[0].shape[0];
        let (kp, vp) = (outs[0].shape[3], outs[3].shape[3]);
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = self.layers[layer].cache_len[slot] as usize;
            self.ensure_capacity(slot, start + nv)?;
            for ti in 0..nv {
                let tok = start + ti;
                let id = self.ensure_writable(slot, tok / p)? as usize;
                let row = tok % p;
                let lc = &mut self.layers[layer];
                for hh in 0..h {
                    let src = ((bi * h + hh) * t + ti) * kp;
                    let dst = ((id * h + hh) * p + row) * kp;
                    lc.k_codes[dst..dst + kp].copy_from_slice(&outs[0].as_u8()?[src..src + kp]);
                    let srcv = ((bi * h + hh) * t + ti) * vp;
                    let dstv = ((id * h + hh) * p + row) * vp;
                    lc.v_codes[dstv..dstv + vp]
                        .copy_from_slice(&outs[3].as_u8()?[srcv..srcv + vp]);
                    let ssrc = (bi * h + hh) * t + ti;
                    let sdst = (id * h + hh) * p + row;
                    lc.k_scale[sdst] = outs[1].as_f32()?[ssrc];
                    lc.k_zero[sdst] = outs[2].as_f32()?[ssrc];
                    lc.v_scale[sdst] = outs[4].as_f32()?[ssrc];
                    lc.v_zero[sdst] = outs[5].as_f32()?[ssrc];
                }
            }
            self.layers[layer].cache_len[slot] += nv as i32;
        }
        Ok(())
    }

    fn append_kivi_residual(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<Vec<bool>> {
        debug_assert_eq!(self.layers[layer].spec.mode, Mode::Kivi);
        let (h, dh, r, g) = (self.h, self.dh, self.residual, self.group);
        let t = k_new.shape[2];
        let b_exec = k_new.shape[0];
        let mut need_commit = vec![false; b_exec];
        let lc = &mut self.layers[layer];
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = lc.res_len[slot] as usize;
            anyhow::ensure!(start + nv <= r, "residual overflow (slot {slot})");
            for hh in 0..h {
                for ti in 0..nv {
                    let src = ((bi * h + hh) * t + ti) * dh;
                    let dst = ((slot * h + hh) * r + start + ti) * dh;
                    lc.k_res[dst..dst + dh].copy_from_slice(&k_new.as_f32()?[src..src + dh]);
                    lc.v_res[dst..dst + dh].copy_from_slice(&v_new.as_f32()?[src..src + dh]);
                }
            }
            lc.res_len[slot] += nv as i32;
            need_commit[bi] = lc.res_len[slot] as usize >= g;
        }
        Ok(need_commit)
    }

    fn residual_chunk(&self, layer: usize, slot: usize) -> Result<(Tensor, Tensor)> {
        let lc = &self.layers[layer];
        let (h, dh, r, g) = (self.h, self.dh, self.residual, self.group);
        anyhow::ensure!(lc.res_len[slot] as usize >= g, "residual not full");
        let mut k = vec![0f32; h * g * dh];
        let mut v = vec![0f32; h * g * dh];
        for hh in 0..h {
            let src = ((slot * h + hh) * r) * dh;
            let dst = hh * g * dh;
            k[dst..dst + g * dh].copy_from_slice(&lc.k_res[src..src + g * dh]);
            v[dst..dst + g * dh].copy_from_slice(&lc.v_res[src..src + g * dh]);
        }
        Ok((Tensor::f32(&[1, h, g, dh], k), Tensor::f32(&[1, h, g, dh], v)))
    }

    fn commit_kivi_chunk(
        &mut self,
        layer: usize,
        slot: usize,
        k_outs: &[Tensor],
        v_outs: &[Tensor],
    ) -> Result<()> {
        let (h, dh, r, g, p) = (self.h, self.dh, self.residual, self.group, self.page);
        let start = self.layers[layer].cache_len[slot] as usize;
        anyhow::ensure!(start % g == 0, "kivi cache_len must be group-aligned");
        self.ensure_capacity(slot, start + g)?;
        let id = self.ensure_writable(slot, start / p)? as usize;
        let (kp, vp) = (k_outs[0].shape[3], v_outs[0].shape[3]);
        let lc = &mut self.layers[layer];
        for hh in 0..h {
            // key codes + per-channel scale/zero (page row 0, one vector/page)
            let src = (hh * g) * kp;
            let dst = ((id * h + hh) * p) * kp;
            lc.k_codes[dst..dst + g * kp].copy_from_slice(&k_outs[0].as_u8()?[src..src + g * kp]);
            let ssrc = hh * dh;
            let sdst = (id * h + hh) * dh;
            lc.k_scale[sdst..sdst + dh].copy_from_slice(&k_outs[1].as_f32()?[ssrc..ssrc + dh]);
            lc.k_zero[sdst..sdst + dh].copy_from_slice(&k_outs[2].as_f32()?[ssrc..ssrc + dh]);
            // value codes + per-token scale/zero
            let vsrc = (hh * g) * vp;
            let vdst = ((id * h + hh) * p) * vp;
            lc.v_codes[vdst..vdst + g * vp]
                .copy_from_slice(&v_outs[0].as_u8()?[vsrc..vsrc + g * vp]);
            let tsrc = hh * g;
            let tdst = (id * h + hh) * p;
            lc.v_scale[tdst..tdst + g].copy_from_slice(&v_outs[1].as_f32()?[tsrc..tsrc + g]);
            lc.v_zero[tdst..tdst + g].copy_from_slice(&v_outs[2].as_f32()?[tsrc..tsrc + g]);
        }
        // drain the committed group out of the residual ring
        let drained = lc.res_len[slot] as usize - g;
        if drained > 0 {
            for hh in 0..h {
                let base = ((slot * h + hh) * r) * dh;
                lc.k_res.copy_within(base + g * dh..base + (g + drained) * dh, base);
                lc.v_res.copy_within(base + g * dh..base + (g + drained) * dh, base);
            }
        }
        lc.res_len[slot] = drained as i32;
        lc.cache_len[slot] += g as i32;
        Ok(())
    }

    fn append_fp(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<()> {
        debug_assert_eq!(self.layers[layer].spec.mode, Mode::Fp);
        let (h, dh, p) = (self.h, self.dh, self.page);
        let t = k_new.shape[2];
        let b_exec = k_new.shape[0];
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = self.layers[layer].cache_len[slot] as usize;
            self.ensure_capacity(slot, start + nv)?;
            for ti in 0..nv {
                let tok = start + ti;
                let id = self.ensure_writable(slot, tok / p)? as usize;
                let row = tok % p;
                let lc = &mut self.layers[layer];
                for hh in 0..h {
                    let src = ((bi * h + hh) * t + ti) * dh;
                    let dst = ((id * h + hh) * p + row) * dh;
                    lc.k_fp[dst..dst + dh].copy_from_slice(&k_new.as_f32()?[src..src + dh]);
                    lc.v_fp[dst..dst + dh].copy_from_slice(&v_new.as_f32()?[src..src + dh]);
                }
            }
            self.layers[layer].cache_len[slot] += nv as i32;
        }
        Ok(())
    }

    fn reset_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
        for id in std::mem::take(&mut self.tables[slot]) {
            self.pool.decref(id);
        }
        for l in &mut self.layers {
            l.cache_len[slot] = 0;
            l.res_len[slot] = 0;
        }
    }

    fn kv_bytes(&self) -> usize {
        let arena = self.pool.total() * self.block_bytes_all;
        let res: usize = self.layers.iter().map(|l| l.residual_bytes()).sum();
        let tables: usize = self.tables.iter().map(|t| t.len() * 4).sum();
        arena + res + tables
    }

    fn equivalent_bits(&self) -> f64 {
        LayerSpec::equivalent_bits(&self.layers.iter().map(|l| l.spec).collect::<Vec<_>>())
    }

    fn remaining(&self, slot: usize) -> usize {
        self.s_max - self.pos[slot] as usize
    }

    fn synthetic_fill(&mut self, slot: usize, input_len: usize) -> Result<()> {
        anyhow::ensure!(input_len <= self.s_max, "synthetic fill beyond s_max");
        let g = self.group;
        let mut max_tokens = 0usize;
        for l in 0..self.layers.len() {
            let (cl, rl) = match self.layers[l].spec.mode {
                Mode::Kivi => ((input_len / g) * g, input_len % g),
                _ => (input_len, 0),
            };
            let lc = &mut self.layers[l];
            lc.cache_len[slot] = lc.cache_len[slot].max(cl as i32);
            lc.res_len[slot] = lc.res_len[slot].max(rl as i32);
            max_tokens = max_tokens.max(lc.cache_len[slot] as usize);
        }
        self.pos[slot] = self.pos[slot].max(input_len as i32);
        self.ensure_capacity(slot, max_tokens)
    }

    fn mem_stats(&self) -> MemStats {
        let blocks_live = self.pool.live_count();
        let live_block_bytes = blocks_live * self.block_bytes_all;
        // live tokens, weighted by each layer's per-token page cost
        let mut live_token_bytes = 0usize;
        let mut res_live = 0usize;
        for l in &self.layers {
            let per_tok = l.block_bytes / self.page;
            let toks: usize = l.cache_len.iter().map(|&c| c as usize).sum();
            live_token_bytes += toks * per_tok;
            let rrows: usize = l.res_len.iter().map(|&c| c as usize).sum();
            res_live += rrows * self.h * self.dh * 4 * 2;
        }
        MemStats {
            bytes_total: self.kv_bytes(),
            bytes_live: live_block_bytes + res_live,
            // shared pages are counted once on the block side but per-slot on
            // the token side, hence the saturation
            frag_bytes: live_block_bytes.saturating_sub(live_token_bytes),
            blocks_total: self.pool.total(),
            blocks_live,
            blocks_free: self.pool.free_count(),
            host_bytes_total: self.swap.as_ref().map(|a| a.bytes_total()).unwrap_or(0),
            host_bytes_used: self.swap.as_ref().map(|a| a.bytes_used()).unwrap_or(0),
        }
    }

    fn layer_kv_live(&self) -> Vec<usize> {
        // per-layer token-weighted live bytes (the token side of mem_stats:
        // committed tokens at each layer's per-token page cost, plus fp32
        // residual rows) — the per-precision-pair memory split the profiler
        // reports
        self.layers
            .iter()
            .map(|l| {
                let per_tok = l.block_bytes / self.page;
                let toks: usize = l.cache_len.iter().map(|&c| c as usize).sum();
                let rrows: usize = l.res_len.iter().map(|&c| c as usize).sum();
                toks * per_tok + rrows * self.h * self.dh * 4 * 2
            })
            .collect()
    }

    fn is_paged(&self) -> bool {
        true
    }

    fn can_admit(&self, prompt_len: usize, _max_new_tokens: usize) -> bool {
        // prompt pages + one decode page of headroom; generation growth is
        // deliberately unreserved (oversubscription, covered by preemption)
        self.pool.free_count() >= self.blocks_for(prompt_len) + 1
    }

    fn decode_block_shortfall(&self, active: &[usize]) -> usize {
        let p = self.page;
        let mut need = 0usize;
        for &slot in active {
            let cap = self.tables[slot].len() * p;
            let mut max_after = 0usize;
            for lc in &self.layers {
                let len = lc.cache_len[slot] as usize;
                let after = match lc.spec.mode {
                    Mode::Kivi => {
                        // one more token commits a whole group when the
                        // residual is about to fill
                        len + if lc.res_len[slot] as usize + 1 >= self.group {
                            self.group
                        } else {
                            0
                        }
                    }
                    _ => len + 1,
                };
                max_after = max_after.max(after);
            }
            let max_after = max_after.min(self.s_max);
            if max_after > cap {
                need += (max_after - cap + p - 1) / p;
            }
        }
        need.saturating_sub(self.pool.free_count())
    }

    fn prefill_reuse(&mut self, slot: usize, prompt: &[i32]) -> usize {
        let p = self.page;
        debug_assert!(self.tables[slot].is_empty(), "prefill_reuse needs a fresh slot");
        if prompt.len() <= p {
            return 0; // a full page plus ≥1 suffix token is required
        }
        let shareable_pages = (prompt.len() - 1) / p;
        let mut parent = PREFIX_SEED;
        let mut blocks: Vec<BlockId> = Vec::new();
        for i in 0..shareable_pages {
            let toks = &prompt[i * p..(i + 1) * p];
            let hsh = chain_hash(parent, toks);
            let verified = self.index.get(&hsh).copied().filter(|&id| {
                self.block_tokens[id as usize]
                    .as_ref()
                    .map(|(par, t)| *par == parent && t.as_slice() == toks)
                    .unwrap_or(false)
            });
            match verified {
                Some(id) => {
                    blocks.push(id);
                    parent = hsh;
                }
                None => break,
            }
        }
        if blocks.is_empty() {
            return 0;
        }
        for &id in &blocks {
            if !self.pool.resurrect(id) {
                self.pool.incref(id);
            }
        }
        let matched = blocks.len() * p;
        self.tables[slot] = blocks;
        for lc in &mut self.layers {
            lc.cache_len[slot] = matched as i32;
            lc.res_len[slot] = 0;
        }
        self.pos[slot] = matched as i32;
        self.prefix_hits += 1;
        self.prefix_tokens_reused += matched as u64;
        matched
    }

    fn register_prefix(&mut self, slot: usize, prompt: &[i32]) {
        let p = self.page;
        let full = (prompt.len() / p).min(self.tables[slot].len());
        let mut parent = PREFIX_SEED;
        for i in 0..full {
            let toks = &prompt[i * p..(i + 1) * p];
            let hsh = chain_hash(parent, toks);
            let id = self.tables[slot][i];
            if self.block_hash[id as usize].is_none() && !self.index.contains_key(&hsh) {
                self.block_hash[id as usize] = Some(hsh);
                self.block_tokens[id as usize] = Some((parent, toks.to_vec()));
                self.index.insert(hsh, id);
            }
            parent = hsh;
        }
    }

    // ---- host swap tier ----

    fn swap_enabled(&self) -> bool {
        self.swap.is_some()
    }

    fn slot_pages(&self, slot: usize) -> usize {
        self.tables[slot].len()
    }

    fn swap_out_bytes(&self, slot: usize) -> usize {
        let host_pages = self
            .tables[slot]
            .iter()
            .filter(|&&id| !self.can_relink(id))
            .count();
        host_pages * self.block_bytes_all + self.residual_slot_bytes()
    }

    fn per_token_kv_bytes(&self) -> usize {
        (self.block_bytes_all / self.page).max(1)
    }

    fn swap_out(&mut self, slot: usize) -> Result<SwapHandle> {
        anyhow::ensure!(self.swap.is_some(), "no host swap tier (--swap-mib)");
        // classify pages: prefix-indexed pages that another resident
        // sequence keeps live are recorded by chain hash only (re-linked at
        // swap-in); everything else — private pages, and indexed pages this
        // victim is the last holder of — is copied into a host slot
        let table = self.tables[slot].clone();
        let mut pages: Vec<SwapPage> = Vec::with_capacity(table.len());
        let mut need_host = 0usize;
        for &id in &table {
            if self.can_relink(id) {
                let hash = self.block_hash[id as usize].unwrap();
                let (parent, tokens) = self.block_tokens[id as usize].clone().unwrap();
                pages.push(SwapPage::Linked { hash, parent, tokens });
            } else {
                need_host += 1;
                pages.push(SwapPage::Host(u32::MAX)); // slot filled below
            }
        }
        // the byte budget covers the residual blobs too, so host_bytes_used
        // can never exceed host_bytes_total
        let res_bytes = self.residual_slot_bytes();
        {
            let arena = self.swap.as_mut().unwrap();
            if !arena.can_hold(need_host, res_bytes) {
                arena.stats.swap_out_rejected += 1;
                return Err(anyhow::Error::new(HostArenaFull));
            }
        }
        // kivi residual rings ride along inside the handle (full ring region
        // for bit-exactness; res_len masks validity exactly as on device)
        let mut residual: Vec<u8> = Vec::new();
        let rn = self.h * self.residual * self.dh;
        for l in &self.layers {
            if l.spec.mode == Mode::Kivi {
                swap::append_f32s(&mut residual, &l.k_res[slot * rn..(slot + 1) * rn]);
                swap::append_f32s(&mut residual, &l.v_res[slot * rn..(slot + 1) * rn]);
            }
        }
        // commit: copy private pages out, then drop every device reference
        let (h, p, dh) = (self.h, self.page, self.dh);
        let mut copied = 0u64;
        for (bi, pg) in pages.iter_mut().enumerate() {
            let id = table[bi];
            if let SwapPage::Host(hs) = pg {
                let arena = self.swap.as_mut().unwrap();
                *hs = arena.alloc().expect("free_slots checked above");
                let dst = arena.slot_mut(*hs);
                serialize_page(&self.layers, h, p, dh, id as usize, dst);
                copied += 1;
            }
            self.pool.decref(id);
        }
        self.tables[slot].clear();
        let handle = SwapHandle {
            pos: self.pos[slot],
            cache_len: self.layers.iter().map(|l| l.cache_len[slot]).collect(),
            res_len: self.layers.iter().map(|l| l.res_len[slot]).collect(),
            host_bytes: copied as usize * self.block_bytes_all + residual.len(),
            payload: SwapPayload::Paged { pages, residual },
        };
        for l in &mut self.layers {
            l.cache_len[slot] = 0;
            l.res_len[slot] = 0;
        }
        self.pos[slot] = 0;
        let arena = self.swap.as_mut().unwrap();
        arena.add_residual_bytes(match &handle.payload {
            SwapPayload::Paged { residual, .. } => residual.len(),
            _ => 0,
        });
        arena.stats.swap_outs += 1;
        arena.stats.bytes_out += handle.host_bytes as u64;
        arena.stats.pages_copied_out += copied;
        Ok(handle)
    }

    fn can_swap_in(&self, sh: &SwapHandle) -> bool {
        let SwapPayload::Paged { pages, .. } = &sh.payload else {
            return false;
        };
        // pages that will consume a free-list entry: host copies (fresh
        // alloc) and linked pages whose block is currently free (resurrect);
        // a lost link is counted like a fresh page so the attempt proceeds
        // and the SwapLost fallback fires instead of stalling forever
        let mut need_free = 0usize;
        for pg in pages {
            match pg {
                SwapPage::Host(_) => need_free += 1,
                SwapPage::Linked { hash, parent, tokens } => {
                    match self.lookup_linked(*hash, *parent, tokens) {
                        Some(id) if !self.pool.is_free(id) => {}
                        _ => need_free += 1,
                    }
                }
            }
        }
        // one decode page of headroom, mirroring `can_admit`
        self.pool.free_count() >= need_free + 1
    }

    fn swap_in(&mut self, slot: usize, sh: &SwapHandle) -> Result<()> {
        let SwapPayload::Paged { pages, residual } = &sh.payload else {
            bail!("dense swap handle offered to the paged arm");
        };
        anyhow::ensure!(
            sh.cache_len.len() == self.layers.len(),
            "swap handle layer count mismatch"
        );
        anyhow::ensure!(self.tables[slot].is_empty(), "swap_in needs a fresh slot");
        // validate before mutating: every linked page must still resolve
        let mut resolved: Vec<Option<BlockId>> = Vec::with_capacity(pages.len());
        let mut need_free = 0usize;
        for pg in pages {
            match pg {
                SwapPage::Host(_) => {
                    resolved.push(None);
                    need_free += 1;
                }
                SwapPage::Linked { hash, parent, tokens } => {
                    match self.lookup_linked(*hash, *parent, tokens) {
                        Some(id) => {
                            if self.pool.is_free(id) {
                                need_free += 1;
                            }
                            resolved.push(Some(id));
                        }
                        None => {
                            if let Some(a) = self.swap.as_mut() {
                                a.stats.swap_in_lost += 1;
                            }
                            return Err(anyhow::Error::new(SwapLost));
                        }
                    }
                }
            }
        }
        if self.pool.free_count() < need_free {
            return Err(anyhow::Error::new(OutOfPages));
        }
        // commit pass 1: pin every linked page (resurrect/incref) so pass 2
        // allocations cannot recycle them out from under this handle
        let mut new_table: Vec<BlockId> = vec![0; pages.len()];
        let mut relinked = 0u64;
        for (bi, r) in resolved.iter().enumerate() {
            if let Some(id) = *r {
                if !self.pool.resurrect(id) {
                    self.pool.incref(id);
                }
                new_table[bi] = id;
                relinked += 1;
            }
        }
        // commit pass 2: copy host pages into fresh device pages (cannot
        // fail: free_count was checked and pass 1 pinned the linked pages)
        let (h, p, dh) = (self.h, self.page, self.dh);
        let mut copied = 0u64;
        for (bi, pg) in pages.iter().enumerate() {
            if let SwapPage::Host(hs) = pg {
                let id = self.alloc_block()?;
                let arena = self.swap.as_ref().unwrap();
                let src = arena.slot(*hs);
                deserialize_page(&mut self.layers, h, p, dh, id as usize, src);
                new_table[bi] = id;
                copied += 1;
            }
        }
        self.tables[slot] = new_table;
        for (l, lc) in self.layers.iter_mut().enumerate() {
            lc.cache_len[slot] = sh.cache_len[l];
            lc.res_len[slot] = sh.res_len[l];
        }
        self.pos[slot] = sh.pos;
        let rn = self.h * self.residual * self.dh;
        let mut off = 0usize;
        for l in &mut self.layers {
            if l.spec.mode == Mode::Kivi {
                swap::read_f32s(residual, &mut off, &mut l.k_res[slot * rn..(slot + 1) * rn]);
                swap::read_f32s(residual, &mut off, &mut l.v_res[slot * rn..(slot + 1) * rn]);
            }
        }
        debug_assert_eq!(off, residual.len());
        let arena = self.swap.as_mut().unwrap();
        arena.stats.swap_ins += 1;
        arena.stats.bytes_in += sh.host_bytes as u64;
        arena.stats.pages_copied_in += copied;
        arena.stats.pages_relinked += relinked;
        Ok(())
    }

    fn release_swap(&mut self, sh: SwapHandle) {
        if let SwapPayload::Paged { pages, residual } = &sh.payload {
            if let Some(arena) = self.swap.as_mut() {
                for pg in pages {
                    if let SwapPage::Host(hs) = pg {
                        arena.release(*hs);
                    }
                }
                arena.sub_residual_bytes(residual.len());
            }
        }
    }

    fn swap_stats(&self) -> SwapStats {
        self.swap.as_ref().map(|a| a.stats.clone()).unwrap_or_default()
    }
}
