//! Mixed-precision batched KV cache manager.
//!
//! Buffers are laid out exactly as the layer-step artifacts expect them
//! (batch outermost, so a single slot's region is contiguous and B=1 prefill
//! executables can slice it without repacking):
//!
//! * token mode:  packed codes `[B, H, S, DhP]` + per-token scale/zero
//!   `[B, H, S]`; new tokens arrive already quantized from the layer step.
//! * kivi mode:   packed key codes + per-channel scale/zero `[B, H, S/G, Dh]`,
//!   per-token value scale/zero, plus fp residual rings `[B, H, R, Dh]`;
//!   commits go through the `quant_*` PJRT executables.
//! * fp mode:     raw `[B, H, S, Dh]` buffers (the KV16 reference arm).
//!
//! Memory accounting (`kv_bytes`, `equivalent_bits`) is what Table 8's
//! memory-traffic story rests on: the buffers genuinely shrink with the
//! precision map.

pub mod backend;
pub mod block;
pub mod paged;
pub mod swap;
pub mod view;

use anyhow::{bail, Result};

use crate::config::{LayerSpec, Mode, ModelConfig};
use crate::quant::packed_width;
use crate::tensor::Tensor;

pub use backend::{CacheBackend, MemStats, OutOfPages, PagedOptions};
pub use block::{BlockId, BlockPool};
pub use paged::PagedKvCache;
pub use swap::{
    HostArenaFull, HostSwapArena, SwapHandle, SwapLost, SwapPage, SwapPayload, SwapPolicy,
    SwapStats,
};
pub use view::{KvView, PageAddr};

/// The tensors of one layer in dense swap serialization order (every
/// allocated buffer; unset modes contribute nothing). One macro generates
/// the shared-`&` and `&mut` variants, so swap-out and swap-in can never
/// disagree on the byte order — a reorder of equal-size tensors would
/// otherwise slip past the blob-length check.
/// `swap_tensor_list!(lc)` -> `[&Option<Tensor>; 10]`,
/// `swap_tensor_list!(lc, mut)` -> `[&mut Option<Tensor>; 10]`.
macro_rules! swap_tensor_list {
    ($lc:expr $(, $mt:tt)?) => {
        [
            & $($mt)? $lc.k_codes, & $($mt)? $lc.k_scale, & $($mt)? $lc.k_zero,
            & $($mt)? $lc.v_codes, & $($mt)? $lc.v_scale, & $($mt)? $lc.v_zero,
            & $($mt)? $lc.k_res, & $($mt)? $lc.v_res,
            & $($mt)? $lc.k_fp, & $($mt)? $lc.v_fp,
        ]
    };
}

/// Per-layer cache buffers for a batch of `b` slots.
#[derive(Debug, Clone)]
pub struct LayerCacheBuf {
    pub spec: LayerSpec,
    // quantized path (token/kivi)
    pub k_codes: Option<Tensor>,
    pub k_scale: Option<Tensor>,
    pub k_zero: Option<Tensor>,
    pub v_codes: Option<Tensor>,
    pub v_scale: Option<Tensor>,
    pub v_zero: Option<Tensor>,
    // kivi fp residual
    pub k_res: Option<Tensor>,
    pub v_res: Option<Tensor>,
    // fp path
    pub k_fp: Option<Tensor>,
    pub v_fp: Option<Tensor>,
    /// Committed (quantized or fp-stored) tokens per slot.
    pub cache_len: Vec<i32>,
    /// Valid fp residual tokens per slot (kivi only; 0 otherwise).
    pub res_len: Vec<i32>,
}

impl LayerCacheBuf {
    pub fn new(cfg: &ModelConfig, spec: LayerSpec, b: usize, s_max: usize) -> Result<Self> {
        let (h, dh, g, r) = (cfg.n_kv_heads, cfg.head_dim, cfg.group, cfg.residual);
        if spec.mode == Mode::Kivi && s_max % g != 0 {
            // `ng = s_max / g` would truncate and undersize k_scale/k_zero;
            // the AOT artifacts only emit group-aligned buckets anyway.
            bail!(
                "kivi layers require s_max ({s_max}) to be a multiple of the \
                 quantization group ({g})"
            );
        }
        let mut buf = LayerCacheBuf {
            spec,
            k_codes: None, k_scale: None, k_zero: None,
            v_codes: None, v_scale: None, v_zero: None,
            k_res: None, v_res: None, k_fp: None, v_fp: None,
            cache_len: vec![0; b],
            res_len: vec![0; b],
        };
        match spec.mode {
            Mode::Fp => {
                buf.k_fp = Some(Tensor::zeros_f32(&[b, h, s_max, dh]));
                buf.v_fp = Some(Tensor::zeros_f32(&[b, h, s_max, dh]));
            }
            Mode::Token => {
                let (kp, vp) = (packed_width(dh, spec.pair.k_bits)?, packed_width(dh, spec.pair.v_bits)?);
                buf.k_codes = Some(Tensor::zeros_u8(&[b, h, s_max, kp]));
                buf.k_scale = Some(Tensor::f32(&[b, h, s_max], vec![1.0; b * h * s_max]));
                buf.k_zero = Some(Tensor::zeros_f32(&[b, h, s_max]));
                buf.v_codes = Some(Tensor::zeros_u8(&[b, h, s_max, vp]));
                buf.v_scale = Some(Tensor::f32(&[b, h, s_max], vec![1.0; b * h * s_max]));
                buf.v_zero = Some(Tensor::zeros_f32(&[b, h, s_max]));
            }
            Mode::Kivi => {
                let (kp, vp) = (packed_width(dh, spec.pair.k_bits)?, packed_width(dh, spec.pair.v_bits)?);
                let ng = s_max / g;
                buf.k_codes = Some(Tensor::zeros_u8(&[b, h, s_max, kp]));
                buf.k_scale = Some(Tensor::f32(&[b, h, ng, dh], vec![1.0; b * h * ng * dh]));
                buf.k_zero = Some(Tensor::zeros_f32(&[b, h, ng, dh]));
                buf.v_codes = Some(Tensor::zeros_u8(&[b, h, s_max, vp]));
                buf.v_scale = Some(Tensor::f32(&[b, h, s_max], vec![1.0; b * h * s_max]));
                buf.v_zero = Some(Tensor::zeros_f32(&[b, h, s_max]));
                buf.k_res = Some(Tensor::zeros_f32(&[b, h, r, dh]));
                buf.v_res = Some(Tensor::zeros_f32(&[b, h, r, dh]));
            }
        }
        Ok(buf)
    }

    /// Cache-tensor list in the layer artifact's argument order.
    pub fn artifact_inputs(&self) -> Vec<&Tensor> {
        match self.spec.mode {
            Mode::Fp => vec![self.k_fp.as_ref().unwrap(), self.v_fp.as_ref().unwrap()],
            Mode::Token => vec![
                self.k_codes.as_ref().unwrap(), self.k_scale.as_ref().unwrap(), self.k_zero.as_ref().unwrap(),
                self.v_codes.as_ref().unwrap(), self.v_scale.as_ref().unwrap(), self.v_zero.as_ref().unwrap(),
            ],
            Mode::Kivi => vec![
                self.k_codes.as_ref().unwrap(), self.k_scale.as_ref().unwrap(), self.k_zero.as_ref().unwrap(),
                self.v_codes.as_ref().unwrap(), self.v_scale.as_ref().unwrap(), self.v_zero.as_ref().unwrap(),
                self.k_res.as_ref().unwrap(), self.v_res.as_ref().unwrap(),
            ],
        }
    }

    /// Slice one slot out of every cache tensor (for B=1 prefill executables).
    /// Slot regions are contiguous because batch is the outermost dim.
    pub fn slot_inputs(&self, slot: usize) -> Vec<Tensor> {
        self.artifact_inputs()
            .into_iter()
            .map(|t| {
                let per = t.numel() / self.cache_len.len();
                let mut shape = t.shape.clone();
                shape[0] = 1;
                match &t.data {
                    crate::tensor::Data::F32(v) => Tensor::f32(&shape, v[slot * per..(slot + 1) * per].to_vec()),
                    crate::tensor::Data::U8(v) => Tensor::u8(&shape, v[slot * per..(slot + 1) * per].to_vec()),
                    crate::tensor::Data::I32(v) => Tensor::i32(&shape, v[slot * per..(slot + 1) * per].to_vec()),
                }
            })
            .collect()
    }

    pub fn kv_bytes(&self) -> usize {
        [
            &self.k_codes, &self.k_scale, &self.k_zero,
            &self.v_codes, &self.v_scale, &self.v_zero,
            &self.k_res, &self.v_res, &self.k_fp, &self.v_fp,
        ]
        .iter()
        .filter_map(|o| o.as_ref().map(|t| t.size_bytes()))
        .sum()
    }

    pub fn reset_slot(&mut self, slot: usize) {
        self.cache_len[slot] = 0;
        self.res_len[slot] = 0;
    }
}

/// Whole-model cache: one `LayerCacheBuf` per layer + per-slot positions.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub layers: Vec<LayerCacheBuf>,
    /// Absolute position per slot (= tokens seen; same across layers).
    pub pos: Vec<i32>,
    pub batch: usize,
    pub s_max: usize,
    group: usize,
    residual: usize,
    n_kv_heads: usize,
    head_dim: usize,
    /// Host-tier bytes pinned by outstanding swap handles (the dense arm's
    /// swap tier is unbounded: slot regions are serialized into the handle).
    swap_bytes_used: usize,
    swap_stats: SwapStats,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, specs: &[LayerSpec], batch: usize, s_max: usize) -> Result<KvCache> {
        if specs.len() != cfg.n_layers {
            bail!("{} specs for {} layers", specs.len(), cfg.n_layers);
        }
        let layers = specs
            .iter()
            .map(|&sp| LayerCacheBuf::new(cfg, sp, batch, s_max))
            .collect::<Result<Vec<_>>>()?;
        Ok(KvCache {
            layers,
            pos: vec![0; batch],
            batch,
            s_max,
            group: cfg.group,
            residual: cfg.residual,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim,
            swap_bytes_used: 0,
            swap_stats: SwapStats::default(),
        })
    }


    pub fn reset_slot(&mut self, slot: usize) {
        self.pos[slot] = 0;
        for l in &mut self.layers {
            l.reset_slot(slot);
        }
    }

    pub fn kv_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.kv_bytes()).sum()
    }

    /// Mean equivalent KV bits across layers — the paper's `f_m`.
    pub fn equivalent_bits(&self) -> f64 {
        LayerSpec::equivalent_bits(
            &self.layers.iter().map(|l| l.spec).collect::<Vec<_>>(),
        )
    }

    /// Remaining capacity for a slot before the committed cache overflows.
    pub fn remaining(&self, slot: usize) -> usize {
        self.s_max + self.residual_room() - self.pos[slot] as usize
    }

    fn residual_room(&self) -> usize {
        0 // committed cache bound is s_max; residual always drains into it
    }

    /// Write token-mode quantized outputs (from a layer step) into the cache.
    /// outs = (k_codes [b,h,T,kp], k_scale [b,h,T], k_zero, v_codes, v_scale,
    /// v_zero); `valid` = number of real tokens per covered slot; `slot0` is
    /// the first slot this (possibly B=1) execution covers.
    pub fn append_token_outputs(
        &mut self,
        layer: usize,
        slot0: usize,
        outs: &[Tensor],
        valid: &[usize],
    ) -> Result<()> {
        let lc = &mut self.layers[layer];
        debug_assert_eq!(lc.spec.mode, Mode::Token);
        let (h, s) = (self.n_kv_heads, self.s_max);
        let t = outs[0].shape[2];
        let b_exec = outs[0].shape[0];
        let (kp, vp) = (outs[0].shape[3], outs[3].shape[3]);
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = lc.cache_len[slot] as usize;
            anyhow::ensure!(start + nv <= s, "token cache overflow (slot {slot})");
            for hh in 0..h {
                for ti in 0..nv {
                    // codes
                    let src = ((bi * h + hh) * t + ti) * kp;
                    let dst = ((slot * h + hh) * s + start + ti) * kp;
                    lc.k_codes.as_mut().unwrap().as_u8_mut()?[dst..dst + kp]
                        .copy_from_slice(&outs[0].as_u8()?[src..src + kp]);
                    let srcv = ((bi * h + hh) * t + ti) * vp;
                    let dstv = ((slot * h + hh) * s + start + ti) * vp;
                    lc.v_codes.as_mut().unwrap().as_u8_mut()?[dstv..dstv + vp]
                        .copy_from_slice(&outs[3].as_u8()?[srcv..srcv + vp]);
                    // scales/zeros
                    let ssrc = (bi * h + hh) * t + ti;
                    let sdst = (slot * h + hh) * s + start + ti;
                    lc.k_scale.as_mut().unwrap().as_f32_mut()?[sdst] = outs[1].as_f32()?[ssrc];
                    lc.k_zero.as_mut().unwrap().as_f32_mut()?[sdst] = outs[2].as_f32()?[ssrc];
                    lc.v_scale.as_mut().unwrap().as_f32_mut()?[sdst] = outs[4].as_f32()?[ssrc];
                    lc.v_zero.as_mut().unwrap().as_f32_mut()?[sdst] = outs[5].as_f32()?[ssrc];
                }
            }
            lc.cache_len[slot] += nv as i32;
        }
        Ok(())
    }

    /// Append fp new-token K/V (kivi layer step outputs) into the residual
    /// ring. Returns, per covered slot, `true` when the residual has filled a
    /// whole group and needs a commit.
    pub fn append_kivi_residual(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor, // [b,h,T,Dh]
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<Vec<bool>> {
        let lc = &mut self.layers[layer];
        debug_assert_eq!(lc.spec.mode, Mode::Kivi);
        let (h, dh, r) = (self.n_kv_heads, self.head_dim, self.residual);
        let t = k_new.shape[2];
        let b_exec = k_new.shape[0];
        let mut need_commit = vec![false; b_exec];
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = lc.res_len[slot] as usize;
            anyhow::ensure!(start + nv <= r, "residual overflow (slot {slot})");
            for hh in 0..h {
                for ti in 0..nv {
                    let src = ((bi * h + hh) * t + ti) * dh;
                    let dst = ((slot * h + hh) * r + start + ti) * dh;
                    lc.k_res.as_mut().unwrap().as_f32_mut()?[dst..dst + dh]
                        .copy_from_slice(&k_new.as_f32()?[src..src + dh]);
                    lc.v_res.as_mut().unwrap().as_f32_mut()?[dst..dst + dh]
                        .copy_from_slice(&v_new.as_f32()?[src..src + dh]);
                }
            }
            lc.res_len[slot] += nv as i32;
            need_commit[bi] = lc.res_len[slot] as usize >= self.group;
        }
        Ok(need_commit)
    }

    /// Extract the first `group` residual tokens of a slot as a [1,h,G,Dh]
    /// chunk (input to the quant_* executables).
    pub fn residual_chunk(&self, layer: usize, slot: usize) -> Result<(Tensor, Tensor)> {
        let lc = &self.layers[layer];
        let (h, dh, r, g) = (self.n_kv_heads, self.head_dim, self.residual, self.group);
        anyhow::ensure!(lc.res_len[slot] as usize >= g, "residual not full");
        let mut k = vec![0f32; h * g * dh];
        let mut v = vec![0f32; h * g * dh];
        for hh in 0..h {
            let src = ((slot * h + hh) * r) * dh;
            let dst = hh * g * dh;
            k[dst..dst + g * dh].copy_from_slice(&lc.k_res.as_ref().unwrap().as_f32()?[src..src + g * dh]);
            v[dst..dst + g * dh].copy_from_slice(&lc.v_res.as_ref().unwrap().as_f32()?[src..src + g * dh]);
        }
        Ok((Tensor::f32(&[1, h, g, dh], k), Tensor::f32(&[1, h, g, dh], v)))
    }

    /// Commit quantized chunk outputs (from quant_* executables) into the
    /// main cache and drain the residual.
    /// k_outs = (codes [1,h,G,kp], scale [1,h,Dh], zero) — per-channel;
    /// v_outs = (codes [1,h,G,vp], scale [1,h,G], zero) — per-token.
    pub fn commit_kivi_chunk(
        &mut self,
        layer: usize,
        slot: usize,
        k_outs: &[Tensor],
        v_outs: &[Tensor],
    ) -> Result<()> {
        let g = self.group;
        let lc = &mut self.layers[layer];
        let (h, dh, s, r) = (self.n_kv_heads, self.head_dim, self.s_max, self.residual);
        let start = lc.cache_len[slot] as usize;
        anyhow::ensure!(start % g == 0, "kivi cache_len must be group-aligned");
        anyhow::ensure!(start + g <= s, "kivi cache overflow (slot {slot})");
        let gi = start / g;
        let ng = s / g;
        let (kp, vp) = (k_outs[0].shape[3], v_outs[0].shape[3]);
        for hh in 0..h {
            // key codes + per-channel scale/zero
            let src = (hh * g) * kp;
            let dst = ((slot * h + hh) * s + start) * kp;
            lc.k_codes.as_mut().unwrap().as_u8_mut()?[dst..dst + g * kp]
                .copy_from_slice(&k_outs[0].as_u8()?[src..src + g * kp]);
            let ssrc = hh * dh;
            let sdst = ((slot * h + hh) * ng + gi) * dh;
            lc.k_scale.as_mut().unwrap().as_f32_mut()?[sdst..sdst + dh]
                .copy_from_slice(&k_outs[1].as_f32()?[ssrc..ssrc + dh]);
            lc.k_zero.as_mut().unwrap().as_f32_mut()?[sdst..sdst + dh]
                .copy_from_slice(&k_outs[2].as_f32()?[ssrc..ssrc + dh]);
            // value codes + per-token scale/zero
            let vsrc = (hh * g) * vp;
            let vdst = ((slot * h + hh) * s + start) * vp;
            lc.v_codes.as_mut().unwrap().as_u8_mut()?[vdst..vdst + g * vp]
                .copy_from_slice(&v_outs[0].as_u8()?[vsrc..vsrc + g * vp]);
            let tsrc = hh * g;
            let tdst = (slot * h + hh) * s + start;
            lc.v_scale.as_mut().unwrap().as_f32_mut()?[tdst..tdst + g]
                .copy_from_slice(&v_outs[1].as_f32()?[tsrc..tsrc + g]);
            lc.v_zero.as_mut().unwrap().as_f32_mut()?[tdst..tdst + g]
                .copy_from_slice(&v_outs[2].as_f32()?[tsrc..tsrc + g]);
        }
        // drain the committed group out of the residual ring
        let drained = lc.res_len[slot] as usize - g;
        if drained > 0 {
            for hh in 0..h {
                let base = ((slot * h + hh) * r) * dh;
                let kres = lc.k_res.as_mut().unwrap().as_f32_mut()?;
                kres.copy_within(base + g * dh..base + (g + drained) * dh, base);
                let vres = lc.v_res.as_mut().unwrap().as_f32_mut()?;
                vres.copy_within(base + g * dh..base + (g + drained) * dh, base);
            }
        }
        lc.res_len[slot] = drained as i32;
        lc.cache_len[slot] += g as i32;
        Ok(())
    }

    /// Write fp new-token K/V into an fp-mode layer's cache.
    pub fn append_fp(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor, // [b,h,T,Dh]
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<()> {
        let lc = &mut self.layers[layer];
        debug_assert_eq!(lc.spec.mode, Mode::Fp);
        let (h, dh, s) = (self.n_kv_heads, self.head_dim, self.s_max);
        let t = k_new.shape[2];
        let b_exec = k_new.shape[0];
        for (bi, &nv) in valid.iter().enumerate().take(b_exec) {
            let slot = slot0 + bi;
            let start = lc.cache_len[slot] as usize;
            anyhow::ensure!(start + nv <= s, "fp cache overflow (slot {slot})");
            for hh in 0..h {
                for ti in 0..nv {
                    let src = ((bi * h + hh) * t + ti) * dh;
                    let dst = ((slot * h + hh) * s + start + ti) * dh;
                    lc.k_fp.as_mut().unwrap().as_f32_mut()?[dst..dst + dh]
                        .copy_from_slice(&k_new.as_f32()?[src..src + dh]);
                    lc.v_fp.as_mut().unwrap().as_f32_mut()?[dst..dst + dh]
                        .copy_from_slice(&v_new.as_f32()?[src..src + dh]);
                }
            }
            lc.cache_len[slot] += nv as i32;
        }
        Ok(())
    }
}

/// The dense arm is the reference `CacheBackend`: every method forwards to
/// the existing buffer layout, and the paged-only hooks keep their no-op
/// defaults (slot admission, no preemption, no prefix sharing).
impl CacheBackend for KvCache {
    fn batch(&self) -> usize {
        self.batch
    }

    fn s_max(&self) -> usize {
        self.s_max
    }

    fn pos(&self, slot: usize) -> i32 {
        self.pos[slot]
    }

    fn advance_pos(&mut self, slot: usize, by: usize) {
        self.pos[slot] += by as i32;
    }

    fn cache_len(&self, layer: usize, slot: usize) -> i32 {
        self.layers[layer].cache_len[slot]
    }

    fn res_len(&self, layer: usize, slot: usize) -> i32 {
        self.layers[layer].res_len[slot]
    }

    #[cfg(feature = "xla")]
    fn layer_literals(&self, layer: usize) -> Result<Vec<xla::Literal>> {
        self.layers[layer]
            .artifact_inputs()
            .into_iter()
            .map(|t| t.to_literal())
            .collect()
    }

    #[cfg(feature = "xla")]
    fn slot_literals(&self, layer: usize, slot: usize) -> Result<Vec<xla::Literal>> {
        self.layers[layer]
            .slot_inputs(slot)
            .iter()
            .map(|t| t.to_literal())
            .collect()
    }

    /// Dense view: the resident `[B, H, S_max, ·]` buffers with the slot
    /// baked into the addressing; page granularity is the quant group so
    /// kivi per-channel scales present one vector per page, same as the
    /// paged arm.
    fn kv_view(&self, layer: usize, slot: usize) -> Result<view::KvView<'_>> {
        let lc = &self.layers[layer];
        let (h, dh) = (self.n_kv_heads, self.head_dim);
        let page = self.group.max(1);
        let empty_f: &[f32] = &[];
        let empty_u: &[u8] = &[];
        let (kp, vp) = match lc.spec.mode {
            Mode::Fp => (0, 0),
            _ => (
                packed_width(dh, lc.spec.pair.k_bits)?,
                packed_width(dh, lc.spec.pair.v_bits)?,
            ),
        };
        let rn = h * self.residual * dh;
        let (k_res, v_res) = if lc.spec.mode == Mode::Kivi {
            let kr = lc.k_res.as_ref().unwrap().as_f32()?;
            let vr = lc.v_res.as_ref().unwrap().as_f32()?;
            (&kr[slot * rn..(slot + 1) * rn], &vr[slot * rn..(slot + 1) * rn])
        } else {
            (empty_f, empty_f)
        };
        let (k_fp, v_fp) = match lc.spec.mode {
            Mode::Fp => (
                lc.k_fp.as_ref().unwrap().as_f32()?,
                lc.v_fp.as_ref().unwrap().as_f32()?,
            ),
            _ => (empty_f, empty_f),
        };
        let (k_codes, k_scale, k_zero, v_codes, v_scale, v_zero) = match lc.spec.mode {
            Mode::Fp => (empty_u, empty_f, empty_f, empty_u, empty_f, empty_f),
            _ => (
                lc.k_codes.as_ref().unwrap().as_u8()?,
                lc.k_scale.as_ref().unwrap().as_f32()?,
                lc.k_zero.as_ref().unwrap().as_f32()?,
                lc.v_codes.as_ref().unwrap().as_u8()?,
                lc.v_scale.as_ref().unwrap().as_f32()?,
                lc.v_zero.as_ref().unwrap().as_f32()?,
            ),
        };
        Ok(view::KvView {
            spec: lc.spec,
            h,
            dh,
            kp,
            vp,
            page,
            cache_len: lc.cache_len[slot] as usize,
            res_len: lc.res_len[slot] as usize,
            addr: view::PageAddr::Dense { slot, s_max: self.s_max },
            k_codes,
            k_scale,
            k_zero,
            v_codes,
            v_scale,
            v_zero,
            k_fp,
            v_fp,
            k_res,
            v_res,
            res_cap: self.residual,
        })
    }

    fn append_token_outputs(
        &mut self,
        layer: usize,
        slot0: usize,
        outs: &[Tensor],
        valid: &[usize],
    ) -> Result<()> {
        KvCache::append_token_outputs(self, layer, slot0, outs, valid)
    }

    fn append_kivi_residual(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<Vec<bool>> {
        KvCache::append_kivi_residual(self, layer, slot0, k_new, v_new, valid)
    }

    fn residual_chunk(&self, layer: usize, slot: usize) -> Result<(Tensor, Tensor)> {
        KvCache::residual_chunk(self, layer, slot)
    }

    fn commit_kivi_chunk(
        &mut self,
        layer: usize,
        slot: usize,
        k_outs: &[Tensor],
        v_outs: &[Tensor],
    ) -> Result<()> {
        KvCache::commit_kivi_chunk(self, layer, slot, k_outs, v_outs)
    }

    fn append_fp(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<()> {
        KvCache::append_fp(self, layer, slot0, k_new, v_new, valid)
    }

    fn reset_slot(&mut self, slot: usize) {
        KvCache::reset_slot(self, slot)
    }

    fn kv_bytes(&self) -> usize {
        KvCache::kv_bytes(self)
    }

    fn equivalent_bits(&self) -> f64 {
        KvCache::equivalent_bits(self)
    }

    fn remaining(&self, slot: usize) -> usize {
        KvCache::remaining(self, slot)
    }

    fn synthetic_fill(&mut self, slot: usize, input_len: usize) -> Result<()> {
        anyhow::ensure!(input_len <= self.s_max, "synthetic fill beyond s_max");
        let g = self.group;
        self.pos[slot] = self.pos[slot].max(input_len as i32);
        for lc in &mut self.layers {
            match lc.spec.mode {
                Mode::Kivi => {
                    let committed = (input_len / g) * g;
                    lc.cache_len[slot] = lc.cache_len[slot].max(committed as i32);
                    lc.res_len[slot] = lc.res_len[slot].max((input_len - committed) as i32);
                }
                _ => lc.cache_len[slot] = lc.cache_len[slot].max(input_len as i32),
            }
        }
        Ok(())
    }

    fn mem_stats(&self) -> MemStats {
        let total = KvCache::kv_bytes(self);
        let mut live = 0f64;
        for lc in &self.layers {
            let res: usize = [&lc.k_res, &lc.v_res]
                .iter()
                .filter_map(|o| o.as_ref().map(|t| t.size_bytes()))
                .sum();
            let main = lc.kv_bytes() - res;
            let toks: usize = lc.cache_len.iter().map(|&c| c as usize).sum();
            live += main as f64 * toks as f64 / (self.batch * self.s_max) as f64;
            if res > 0 {
                let rrows: usize = lc.res_len.iter().map(|&c| c as usize).sum();
                live += res as f64 * rrows as f64 / (self.batch * self.residual) as f64;
            }
        }
        let bytes_live = live as usize;
        MemStats {
            bytes_total: total,
            bytes_live,
            // dense "fragmentation" is the pre-reserved [len, s_max) tail
            frag_bytes: total.saturating_sub(bytes_live),
            blocks_total: 0,
            blocks_live: 0,
            blocks_free: 0,
            // dense swap tier is unbounded: the reservation IS the usage
            host_bytes_total: self.swap_bytes_used,
            host_bytes_used: self.swap_bytes_used,
        }
    }

    fn layer_kv_live(&self) -> Vec<usize> {
        // per-layer split of mem_stats().bytes_live: committed rows scale
        // with cache_len over the full [batch, s_max] reservation, residual
        // rows with res_len over the [batch, residual] window
        self.layers
            .iter()
            .map(|lc| {
                let res: usize = [&lc.k_res, &lc.v_res]
                    .iter()
                    .filter_map(|o| o.as_ref().map(|t| t.size_bytes()))
                    .sum();
                let main = lc.kv_bytes() - res;
                let toks: usize = lc.cache_len.iter().map(|&c| c as usize).sum();
                let mut live = main as f64 * toks as f64 / (self.batch * self.s_max) as f64;
                if res > 0 {
                    let rrows: usize = lc.res_len.iter().map(|&c| c as usize).sum();
                    live += res as f64 * rrows as f64 / (self.batch * self.residual) as f64;
                }
                live as usize
            })
            .collect()
    }

    // ---- host swap tier (dense reference arm) ----
    //
    // The dense arm never preempts (its capacity is pre-reserved), but it
    // implements swap so the two arms stay behaviorally interchangeable and
    // swap round-trips can be verified against the reference layout. A
    // slot's entire per-layer regions are serialized into the handle.

    fn swap_enabled(&self) -> bool {
        true
    }

    fn swap_out_bytes(&self, _slot: usize) -> usize {
        self.layers.iter().map(|l| l.kv_bytes()).sum::<usize>() / self.batch
    }

    fn swap_out(&mut self, slot: usize) -> Result<SwapHandle> {
        let batch = self.batch;
        let mut blob: Vec<u8> = Vec::new();
        for lc in &self.layers {
            for t in swap_tensor_list!(lc).iter().filter_map(|o| o.as_ref()) {
                let per = t.numel() / batch;
                match &t.data {
                    crate::tensor::Data::F32(v) => {
                        swap::append_f32s(&mut blob, &v[slot * per..(slot + 1) * per])
                    }
                    crate::tensor::Data::U8(v) => {
                        blob.extend_from_slice(&v[slot * per..(slot + 1) * per])
                    }
                    crate::tensor::Data::I32(v) => {
                        swap::append_i32s(&mut blob, &v[slot * per..(slot + 1) * per])
                    }
                }
            }
        }
        let handle = SwapHandle {
            pos: self.pos[slot],
            cache_len: self.layers.iter().map(|l| l.cache_len[slot]).collect(),
            res_len: self.layers.iter().map(|l| l.res_len[slot]).collect(),
            host_bytes: blob.len(),
            payload: SwapPayload::Dense(blob),
        };
        self.reset_slot(slot);
        self.swap_bytes_used += handle.host_bytes;
        self.swap_stats.swap_outs += 1;
        self.swap_stats.bytes_out += handle.host_bytes as u64;
        Ok(handle)
    }

    fn can_swap_in(&self, h: &SwapHandle) -> bool {
        matches!(h.payload, SwapPayload::Dense(_))
    }

    fn swap_in(&mut self, slot: usize, h: &SwapHandle) -> Result<()> {
        let SwapPayload::Dense(blob) = &h.payload else {
            bail!("paged swap handle offered to the dense arm");
        };
        anyhow::ensure!(
            h.cache_len.len() == self.layers.len(),
            "swap handle layer count mismatch"
        );
        // validate the byte layout before touching anything
        let batch = self.batch;
        let mut expected = 0usize;
        for lc in &self.layers {
            for t in swap_tensor_list!(lc).iter().filter_map(|o| o.as_ref()) {
                let per = t.numel() / batch;
                expected += match &t.data {
                    crate::tensor::Data::U8(_) => per,
                    _ => per * 4,
                };
            }
        }
        anyhow::ensure!(
            blob.len() == expected,
            "swap handle holds {} bytes but this cache's slot region is {expected}",
            blob.len()
        );
        let mut off = 0usize;
        for lc in &mut self.layers {
            for t in swap_tensor_list!(lc, mut).into_iter().filter_map(|o| o.as_mut()) {
                let per = t.numel() / batch;
                match &mut t.data {
                    crate::tensor::Data::F32(v) => {
                        swap::read_f32s(blob, &mut off, &mut v[slot * per..(slot + 1) * per])
                    }
                    crate::tensor::Data::U8(v) => {
                        swap::read_u8s(blob, &mut off, &mut v[slot * per..(slot + 1) * per])
                    }
                    crate::tensor::Data::I32(v) => {
                        swap::read_i32s(blob, &mut off, &mut v[slot * per..(slot + 1) * per])
                    }
                }
            }
        }
        debug_assert_eq!(off, blob.len());
        for (l, lc) in self.layers.iter_mut().enumerate() {
            lc.cache_len[slot] = h.cache_len[l];
            lc.res_len[slot] = h.res_len[l];
        }
        self.pos[slot] = h.pos;
        self.swap_stats.swap_ins += 1;
        self.swap_stats.bytes_in += h.host_bytes as u64;
        Ok(())
    }

    fn release_swap(&mut self, h: SwapHandle) {
        if let SwapPayload::Dense(blob) = &h.payload {
            self.swap_bytes_used = self.swap_bytes_used.saturating_sub(blob.len());
        }
    }

    fn swap_stats(&self) -> SwapStats {
        self.swap_stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, PrecisionPair};

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            n_layers: 2, d_model: 64, n_heads: 2, n_kv_heads: 2, head_dim: 32,
            d_ff: 128, vocab: 64, rope_theta: 10000.0, group: 32, residual: 32,
            rms_eps: 1e-5,
        }
    }

    #[test]
    fn memory_scales_with_precision() {
        let c = cfg();
        let spec = |k, v| vec![LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(k, v) }; 2];
        let b8 = KvCache::new(&c, &spec(8, 8), 2, 256).unwrap().kv_bytes();
        let b4 = KvCache::new(&c, &spec(4, 4), 2, 256).unwrap().kv_bytes();
        let b2 = KvCache::new(&c, &spec(2, 2), 2, 256).unwrap().kv_bytes();
        assert!(b8 > b4 && b4 > b2, "{b8} {b4} {b2}");
        // codes dominate: 8-bit codes are 4x the 2-bit codes
        let fp = KvCache::new(&c, &LayerSpec::uniform(Mode::Fp, PrecisionPair::FP, 2), 2, 256)
            .unwrap()
            .kv_bytes();
        assert!(fp > b8);
    }

    #[test]
    fn equivalent_bits_mixed() {
        let c = cfg();
        let specs = vec![
            LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 4) },
            LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(4, 2) },
        ];
        let kc = KvCache::new(&c, &specs, 1, 256).unwrap();
        assert_eq!(kc.equivalent_bits(), 4.5);
    }

    #[test]
    fn token_append_and_reset() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), 2);
        let mut kc = KvCache::new(&c, &specs, 2, 256).unwrap();
        let t = 1;
        let outs = vec![
            Tensor::u8(&[2, 2, t, 16], vec![7; 2 * 2 * t * 16]),
            Tensor::f32(&[2, 2, t], vec![0.5; 4]),
            Tensor::f32(&[2, 2, t], vec![0.1; 4]),
            Tensor::u8(&[2, 2, t, 16], vec![3; 2 * 2 * t * 16]),
            Tensor::f32(&[2, 2, t], vec![0.5; 4]),
            Tensor::f32(&[2, 2, t], vec![0.1; 4]),
        ];
        kc.append_token_outputs(0, 0, &outs, &[1, 1]).unwrap();
        assert_eq!(kc.layers[0].cache_len, vec![1, 1]);
        // slot 1 row 0 of codes written
        let codes = kc.layers[0].k_codes.as_ref().unwrap().as_u8().unwrap();
        assert_eq!(codes[(1 * 2 + 0) * 256 * 16], 7);
        kc.reset_slot(1);
        assert_eq!(kc.layers[0].cache_len, vec![1, 0]);
    }

    #[test]
    fn kivi_misaligned_s_max_rejected() {
        let c = cfg(); // group = 32
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), 2);
        let err = KvCache::new(&c, &specs, 1, 250);
        assert!(err.is_err(), "s_max=250 with group=32 must be rejected");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("multiple of"), "unclear error: {msg}");
        // token/fp layers don't care about alignment
        let specs = LayerSpec::uniform(Mode::Token, PrecisionPair::new(4, 4), 2);
        assert!(KvCache::new(&c, &specs, 1, 250).is_ok());
    }

    #[test]
    fn dense_synthetic_fill_and_mem_stats() {
        let c = cfg();
        let specs = vec![
            LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(8, 4) },
            LayerSpec { mode: Mode::Kivi, pair: PrecisionPair::new(4, 2) },
        ];
        let mut kc = KvCache::new(&c, &specs, 2, 256).unwrap();
        CacheBackend::synthetic_fill(&mut kc, 0, 100).unwrap();
        assert_eq!(kc.pos[0], 100);
        assert_eq!(kc.layers[0].cache_len[0], 100);
        assert_eq!(kc.layers[1].cache_len[0], 96, "kivi commits whole groups");
        assert_eq!(kc.layers[1].res_len[0], 4);
        let st = CacheBackend::mem_stats(&kc);
        assert_eq!(st.bytes_total, kc.kv_bytes());
        assert!(st.bytes_live > 0 && st.bytes_live < st.bytes_total);
        assert_eq!(st.bytes_total, st.bytes_live + st.frag_bytes);
    }

    #[test]
    fn kivi_residual_fill_and_drain() {
        let c = cfg();
        let specs = LayerSpec::uniform(Mode::Kivi, PrecisionPair::new(4, 2), 2);
        let mut kc = KvCache::new(&c, &specs, 1, 256).unwrap();
        let mk = |val: f32| Tensor::f32(&[1, 2, 1, 32], vec![val; 64]);
        for i in 0..31 {
            let nc = kc.append_kivi_residual(0, 0, &mk(i as f32), &mk(0.0), &[1]).unwrap();
            assert!(!nc[0]);
        }
        let nc = kc.append_kivi_residual(0, 0, &mk(31.0), &mk(0.0), &[1]).unwrap();
        assert!(nc[0]);
        let (kchunk, _v) = kc.residual_chunk(0, 0).unwrap();
        // chunk token ti has value ti
        let kf = kchunk.as_f32().unwrap();
        assert_eq!(kf[0], 0.0);
        assert_eq!(kf[5 * 32], 5.0);
    }
}
