//! Host-tier swap arena: the cold half of a two-level KV cache hierarchy.
//!
//! When the scheduler preempts a sequence it can now *swap* instead of
//! recompute: every device page the sequence holds is copied — still in its
//! packed quantized form, with the same per-layer per-precision strides as
//! the device arenas — into a host page slot, EXCEPT prefix-indexed pages
//! that another resident sequence keeps live (refcount > 1 after the
//! victim's decref): those are recorded by their chain hash only, because
//! the co-holder pins them in the pool. Swap-in is therefore a byte copy
//! for copied pages and a *re-link* (resurrect / incref through the prefix
//! index) for shared ones, which makes a swapped-and-resumed sequence
//! bit-exact with one that was never evicted: no dequantize/requantize
//! round trip, no re-prefill. (Merely-indexed refcount-0 pages are NOT
//! linked: they sit on the free list, and the same pool pressure that
//! forced the preemption would recycle them before the resume.)
//!
//! The arena is a flat `n_slots x slot_bytes` buffer (slot = one `BlockId`'s
//! bytes summed over all layers) with a free list, sized by `--swap-mib`.
//! Kivi residual rings live outside the page pool on the device side and ride
//! along inside the `SwapHandle` on the host side; they are not
//! slot-granular, but `can_hold` charges them against the same byte budget,
//! so `bytes_used` never exceeds `bytes_total`.
//!
//! Failure handling is explicitly two-sided:
//! * swap-out can fail (`HostArenaFull`) — the caller falls back to
//!   recompute preemption, the slot untouched.
//! * swap-in can fail (`SwapLost`) when a re-linkable page was recycled out
//!   of the prefix index while the sequence was away — the caller releases
//!   the handle and falls back to re-prefill (prompt + generated so far).

use anyhow::{bail, Result};

/// Where one logical page of a swapped sequence lives.
#[derive(Debug, Clone, PartialEq)]
pub enum SwapPage {
    /// Copied into the host arena at this slot index.
    Host(u32),
    /// Left addressable through the device prefix index: the page's chain
    /// hash plus its (parent hash, tokens) for exact verification at
    /// swap-in, mirroring `prefill_reuse`'s collision check.
    Linked { hash: u64, parent: u64, tokens: Vec<i32> },
}

/// Backend-specific payload of a swapped sequence.
#[derive(Debug, Clone, PartialEq)]
pub enum SwapPayload {
    /// Dense arm: the slot's full per-layer buffer regions, serialized in
    /// layer order (the dense reference arm has no pages to speak of).
    Dense(Vec<u8>),
    /// Paged arm: one entry per logical page, plus the kivi fp residual
    /// rings (serialized k_res then v_res per kivi layer).
    Paged { pages: Vec<SwapPage>, residual: Vec<u8> },
}

/// Everything needed to restore a preempted sequence into any free slot:
/// per-layer committed/residual lengths, the absolute position, and the
/// page payload. Produced by `CacheBackend::swap_out`, consumed (by
/// reference) by `swap_in`, and finally freed with `release_swap`.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapHandle {
    pub pos: i32,
    /// Committed tokens per layer at swap-out.
    pub cache_len: Vec<i32>,
    /// Residual tokens per layer at swap-out (kivi only).
    pub res_len: Vec<i32>,
    /// Host bytes this handle pins (arena page slots + residual/blob bytes);
    /// what the swap counters report as moved per direction.
    pub host_bytes: usize,
    pub payload: SwapPayload,
}

/// Scheduler policy for preemption eviction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SwapPolicy {
    /// Recompute-only preemption (PR 1 behavior, minus youngest-first).
    #[default]
    Off,
    /// Swap every victim that fits in the host arena.
    Always,
    /// Per-victim cost model: swap when moving the bytes beats re-running
    /// the prefill (see `choose_preempt_action`).
    Auto,
}

impl SwapPolicy {
    pub fn parse(s: &str) -> Result<SwapPolicy> {
        match s {
            "off" => Ok(SwapPolicy::Off),
            "always" => Ok(SwapPolicy::Always),
            "auto" => Ok(SwapPolicy::Auto),
            other => bail!("unknown swap policy {other:?} (expected off|always|auto)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SwapPolicy::Off => "off",
            SwapPolicy::Always => "always",
            SwapPolicy::Auto => "auto",
        }
    }
}

/// Typed marker: the host arena has no free page slots for a swap-out.
/// Callers fall back to recompute preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostArenaFull;

impl std::fmt::Display for HostArenaFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "host swap arena exhausted")
    }
}

impl std::error::Error for HostArenaFull {}

/// Typed marker: a re-linkable prefix page was recycled out of the index
/// while the sequence was swapped out; the swapped state is unrecoverable
/// and the caller must fall back to re-prefill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapLost;

impl std::fmt::Display for SwapLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "swapped prefix pages were recycled from the device index")
    }
}

impl std::error::Error for SwapLost {}

/// Host-tier traffic and outcome counters, reported by
/// `CacheBackend::swap_stats`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SwapStats {
    pub swap_outs: u64,
    pub swap_ins: u64,
    /// Bytes moved device -> host / host -> device (re-linked pages move 0).
    pub bytes_out: u64,
    pub bytes_in: u64,
    pub pages_copied_out: u64,
    pub pages_copied_in: u64,
    /// Pages restored by prefix-index re-link (resurrect/incref), no copy.
    pub pages_relinked: u64,
    /// Swap-outs refused because the host arena was full.
    pub swap_out_rejected: u64,
    /// Swap-ins that failed because linked pages were recycled.
    pub swap_in_lost: u64,
}

/// The host arena proper: `n_slots` page slots of `slot_bytes` each, a free
/// list, and the traffic counters. One slot holds one `BlockId`'s bytes
/// across every layer (the device pool's `block_bytes_all`).
#[derive(Debug)]
pub struct HostSwapArena {
    data: Vec<u8>,
    slot_bytes: usize,
    free: Vec<u32>,
    /// Handle-owned residual/blob bytes outstanding (outside the slot grid).
    residual_bytes: usize,
    pub stats: SwapStats,
}

impl HostSwapArena {
    pub fn new(slot_bytes: usize, budget_mib: f64) -> Result<HostSwapArena> {
        anyhow::ensure!(slot_bytes > 0, "host arena slot size must be > 0");
        let budget = (budget_mib * 1024.0 * 1024.0) as usize;
        let n_slots = budget / slot_bytes;
        if n_slots == 0 {
            bail!(
                "swap budget too small: one page slot costs {slot_bytes} bytes \
                 across all layers"
            );
        }
        Ok(HostSwapArena {
            data: vec![0u8; n_slots * slot_bytes],
            slot_bytes,
            free: (0..n_slots as u32).rev().collect(),
            residual_bytes: 0,
            stats: SwapStats::default(),
        })
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn total_slots(&self) -> usize {
        self.data.len() / self.slot_bytes
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Whether `host_pages` page copies plus `residual_bytes` of
    /// handle-owned blob fit inside the configured budget right now.
    /// Residual rings ride outside the slot grid, so the byte bound — not
    /// just the free-slot count — is what keeps `bytes_used` under
    /// `bytes_total`.
    pub fn can_hold(&self, host_pages: usize, residual_bytes: usize) -> bool {
        self.free_slots() >= host_pages
            && self.bytes_used() + host_pages * self.slot_bytes + residual_bytes
                <= self.bytes_total()
    }

    pub fn alloc(&mut self) -> Option<u32> {
        self.free.pop()
    }

    pub fn release(&mut self, id: u32) {
        debug_assert!(!self.free.contains(&id), "double release of host slot {id}");
        self.free.push(id);
    }

    pub fn slot(&self, id: u32) -> &[u8] {
        let i = id as usize;
        &self.data[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }

    pub fn slot_mut(&mut self, id: u32) -> &mut [u8] {
        let i = id as usize;
        &mut self.data[i * self.slot_bytes..(i + 1) * self.slot_bytes]
    }

    pub fn add_residual_bytes(&mut self, n: usize) {
        self.residual_bytes += n;
    }

    pub fn sub_residual_bytes(&mut self, n: usize) {
        self.residual_bytes = self.residual_bytes.saturating_sub(n);
    }

    /// Host tier reservation (the slot grid).
    pub fn bytes_total(&self) -> usize {
        self.data.len()
    }

    /// Host bytes pinned right now: occupied slots plus handle-owned
    /// residual bytes (which ride outside the slot grid).
    pub fn bytes_used(&self) -> usize {
        (self.total_slots() - self.free_slots()) * self.slot_bytes + self.residual_bytes
    }
}

// ---- byte (de)serialization helpers ----
//
// f32 <-> little-endian bytes round-trips bit patterns exactly (including
// NaN payloads), so host copies are bit-identical to the device arenas.

pub(crate) fn append_f32s(dst: &mut Vec<u8>, src: &[f32]) {
    for v in src {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn append_i32s(dst: &mut Vec<u8>, src: &[i32]) {
    for v in src {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn write_f32s(dst: &mut [u8], off: &mut usize, src: &[f32]) {
    for v in src {
        dst[*off..*off + 4].copy_from_slice(&v.to_le_bytes());
        *off += 4;
    }
}

pub(crate) fn write_u8s(dst: &mut [u8], off: &mut usize, src: &[u8]) {
    dst[*off..*off + src.len()].copy_from_slice(src);
    *off += src.len();
}

pub(crate) fn read_f32s(src: &[u8], off: &mut usize, dst: &mut [f32]) {
    for d in dst.iter_mut() {
        *d = f32::from_le_bytes(src[*off..*off + 4].try_into().unwrap());
        *off += 4;
    }
}

pub(crate) fn read_i32s(src: &[u8], off: &mut usize, dst: &mut [i32]) {
    for d in dst.iter_mut() {
        *d = i32::from_le_bytes(src[*off..*off + 4].try_into().unwrap());
        *off += 4;
    }
}

pub(crate) fn read_u8s(src: &[u8], off: &mut usize, dst: &mut [u8]) {
    dst.copy_from_slice(&src[*off..*off + dst.len()]);
    *off += dst.len();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_alloc_release_and_accounting() {
        let mut a = HostSwapArena::new(1024, 4096.0 / (1024.0 * 1024.0)).unwrap();
        assert_eq!(a.total_slots(), 4);
        assert_eq!(a.bytes_total(), 4096);
        let s0 = a.alloc().unwrap();
        let s1 = a.alloc().unwrap();
        assert_ne!(s0, s1);
        assert_eq!(a.free_slots(), 2);
        a.add_residual_bytes(100);
        assert_eq!(a.bytes_used(), 2 * 1024 + 100);
        a.slot_mut(s0)[0] = 0xAB;
        assert_eq!(a.slot(s0)[0], 0xAB);
        assert_eq!(a.slot(s1)[0], 0);
        a.release(s0);
        a.sub_residual_bytes(100);
        assert_eq!(a.bytes_used(), 1024);
        assert_eq!(a.free_slots(), 3);
    }

    #[test]
    fn arena_budget_too_small_rejected() {
        assert!(HostSwapArena::new(1 << 20, 0.5).is_err());
        assert!(HostSwapArena::new(0, 1.0).is_err());
    }

    #[test]
    fn f32_bytes_round_trip_bit_exact() {
        let src = vec![0.0f32, -0.0, 1.5, f32::NAN, f32::INFINITY, 1e-38];
        let mut blob = Vec::new();
        append_f32s(&mut blob, &src);
        let mut back = vec![0f32; src.len()];
        let mut off = 0;
        read_f32s(&blob, &mut off, &mut back);
        assert_eq!(off, blob.len());
        for (a, b) in src.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn policy_parse() {
        assert_eq!(SwapPolicy::parse("off").unwrap(), SwapPolicy::Off);
        assert_eq!(SwapPolicy::parse("always").unwrap(), SwapPolicy::Always);
        assert_eq!(SwapPolicy::parse("auto").unwrap(), SwapPolicy::Auto);
        assert!(SwapPolicy::parse("sometimes").is_err());
        assert_eq!(SwapPolicy::default(), SwapPolicy::Off);
    }
}
