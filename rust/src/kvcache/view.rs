//! Read-only page/scale views over one layer's KV state for one slot — the
//! API the native attention kernel consumes *instead of* `gather_layer`.
//!
//! A `KvView` names where every committed token's codes/scales/fp rows live
//! without copying anything: the paged arm exposes its block table plus the
//! whole per-layer arenas (token block `i` lives in physical page
//! `table[i]`), the dense arm exposes its `[B, H, S_max, ·]` buffers with
//! the slot baked into the addressing. Both arms present the same
//! page-of-`group`-tokens geometry, so per-channel kivi key scales are
//! always exactly one `[Dh]` vector per page and the kernel never needs to
//! know which arm it is reading.
//!
//! `dequant_k_into` / `dequant_v_into` apply the exact
//! `code as f32 * scale + zero` expression `QuantChunk::dequantize_into`
//! uses, which is what makes the view bit-exact against a
//! `gather_layer`-then-dequantize round trip (pinned in
//! `tests/native_backend.rs`).

use crate::config::{LayerSpec, Mode};
use crate::quant::unpack_row;

use super::block::BlockId;

/// How token blocks map to physical storage.
pub enum PageAddr<'a> {
    /// Paged arm: token block `i` of the slot lives in arena page `table[i]`.
    Paged { table: &'a [BlockId] },
    /// Dense arm: one contiguous `[H, S_max, ·]` region per slot; token
    /// block `i` starts at row `i * page` of the slot's region.
    Dense { slot: usize, s_max: usize },
}

/// Zero-copy view of one (layer, slot)'s committed + residual KV state.
/// Unused arenas for the layer's mode are empty slices.
pub struct KvView<'a> {
    pub spec: LayerSpec,
    pub h: usize,
    pub dh: usize,
    /// Packed code widths (0 for fp mode).
    pub kp: usize,
    pub vp: usize,
    /// Tokens per page (= the quantization group on both arms).
    pub page: usize,
    /// Committed (quantized or fp-stored) tokens.
    pub cache_len: usize,
    /// Valid fp residual tokens (kivi only).
    pub res_len: usize,
    pub addr: PageAddr<'a>,
    pub k_codes: &'a [u8],
    pub k_scale: &'a [f32],
    pub k_zero: &'a [f32],
    pub v_codes: &'a [u8],
    pub v_scale: &'a [f32],
    pub v_zero: &'a [f32],
    pub k_fp: &'a [f32],
    pub v_fp: &'a [f32],
    /// The slot's kivi fp residual ring regions, `[H, res_cap, Dh]`.
    pub k_res: &'a [f32],
    pub v_res: &'a [f32],
    pub res_cap: usize,
}

impl<'a> KvView<'a> {
    /// Pages holding committed tokens (the last may be partial).
    pub fn n_pages(&self) -> usize {
        (self.cache_len + self.page - 1) / self.page
    }

    /// Committed rows in page `pi`.
    pub fn page_rows(&self, pi: usize) -> usize {
        (self.cache_len - pi * self.page).min(self.page)
    }

    /// Total tokens attention sees (committed + residual).
    pub fn seq_len(&self) -> usize {
        self.cache_len + self.res_len
    }

    #[inline]
    fn row_off(&self, pi: usize, hh: usize, row: usize, width: usize) -> usize {
        match &self.addr {
            PageAddr::Paged { table } => {
                ((table[pi] as usize * self.h + hh) * self.page + row) * width
            }
            PageAddr::Dense { slot, s_max } => {
                ((slot * self.h + hh) * s_max + pi * self.page + row) * width
            }
        }
    }

    #[inline]
    pub fn k_code_row(&self, pi: usize, hh: usize, row: usize) -> &'a [u8] {
        let o = self.row_off(pi, hh, row, self.kp);
        &self.k_codes[o..o + self.kp]
    }

    #[inline]
    pub fn v_code_row(&self, pi: usize, hh: usize, row: usize) -> &'a [u8] {
        let o = self.row_off(pi, hh, row, self.vp);
        &self.v_codes[o..o + self.vp]
    }

    #[inline]
    pub fn k_fp_row(&self, pi: usize, hh: usize, row: usize) -> &'a [f32] {
        let o = self.row_off(pi, hh, row, self.dh);
        &self.k_fp[o..o + self.dh]
    }

    #[inline]
    pub fn v_fp_row(&self, pi: usize, hh: usize, row: usize) -> &'a [f32] {
        let o = self.row_off(pi, hh, row, self.dh);
        &self.v_fp[o..o + self.dh]
    }

    /// Kivi per-channel key (scale, zero) vectors for one page ([Dh] each).
    /// Page-aligned by construction: the paged arm stores exactly one vector
    /// per physical page, the dense arm one per group `pi` of the slot.
    #[inline]
    pub fn k_page_scale(&self, pi: usize, hh: usize) -> (&'a [f32], &'a [f32]) {
        let o = match &self.addr {
            PageAddr::Paged { table } => (table[pi] as usize * self.h + hh) * self.dh,
            PageAddr::Dense { slot, s_max } => {
                let ng = s_max / self.page;
                ((slot * self.h + hh) * ng + pi) * self.dh
            }
        };
        (&self.k_scale[o..o + self.dh], &self.k_zero[o..o + self.dh])
    }

    /// Per-token key (scale, zero) — token mode.
    #[inline]
    pub fn k_tok_scale(&self, pi: usize, hh: usize, row: usize) -> (f32, f32) {
        let o = self.row_off(pi, hh, row, 1);
        (self.k_scale[o], self.k_zero[o])
    }

    /// Per-token value (scale, zero) — token and kivi modes.
    #[inline]
    pub fn v_tok_scale(&self, pi: usize, hh: usize, row: usize) -> (f32, f32) {
        let o = self.row_off(pi, hh, row, 1);
        (self.v_scale[o], self.v_zero[o])
    }

    /// Residual-ring fp rows (kivi only), token `i` of head `hh`.
    #[inline]
    pub fn res_k_row(&self, hh: usize, i: usize) -> &'a [f32] {
        let o = (hh * self.res_cap + i) * self.dh;
        &self.k_res[o..o + self.dh]
    }

    #[inline]
    pub fn res_v_row(&self, hh: usize, i: usize) -> &'a [f32] {
        let o = (hh * self.res_cap + i) * self.dh;
        &self.v_res[o..o + self.dh]
    }

    /// Dequantize head `hh`'s committed keys into `out` (`[cache_len, dh]`),
    /// applying exactly `code as f32 * scale + zero` per element — the
    /// bit-exactness oracle against `gather_layer`'s dense output.
    pub fn dequant_k_into(&self, hh: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cache_len * self.dh);
        let dh = self.dh;
        let mut row_codes = vec![0u8; dh];
        for j in 0..self.cache_len {
            let (pi, row) = (j / self.page, j % self.page);
            let o = &mut out[j * dh..(j + 1) * dh];
            match self.spec.mode {
                Mode::Fp => o.copy_from_slice(self.k_fp_row(pi, hh, row)),
                Mode::Token => {
                    unpack_row(self.k_code_row(pi, hh, row), self.spec.pair.k_bits, &mut row_codes);
                    let (s, z) = self.k_tok_scale(pi, hh, row);
                    for d in 0..dh {
                        o[d] = row_codes[d] as f32 * s + z;
                    }
                }
                Mode::Kivi => {
                    unpack_row(self.k_code_row(pi, hh, row), self.spec.pair.k_bits, &mut row_codes);
                    let (ks, kz) = self.k_page_scale(pi, hh);
                    for d in 0..dh {
                        o[d] = row_codes[d] as f32 * ks[d] + kz[d];
                    }
                }
            }
        }
    }

    /// Dequantize head `hh`'s committed values into `out` (`[cache_len, dh]`).
    pub fn dequant_v_into(&self, hh: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cache_len * self.dh);
        let dh = self.dh;
        let mut row_codes = vec![0u8; dh];
        for j in 0..self.cache_len {
            let (pi, row) = (j / self.page, j % self.page);
            let o = &mut out[j * dh..(j + 1) * dh];
            match self.spec.mode {
                Mode::Fp => o.copy_from_slice(self.v_fp_row(pi, hh, row)),
                Mode::Token | Mode::Kivi => {
                    unpack_row(self.v_code_row(pi, hh, row), self.spec.pair.v_bits, &mut row_codes);
                    let (s, z) = self.v_tok_scale(pi, hh, row);
                    for d in 0..dh {
                        o[d] = row_codes[d] as f32 * s + z;
                    }
                }
            }
        }
    }
}
