//! Block pool: refcounted physical pages with free-list recycling.
//!
//! A `BlockId` is one fixed-size token page *across all layers* — each
//! layer's arena is indexed by the same id (every layer caches every token,
//! so per-sequence block tables are shared layer-wide, vLLM-style). Bytes per
//! block differ per layer with the precision map; the pool only tracks ids,
//! refcounts and the free list.
//!
//! Freed blocks keep their content addressable until recycled: the paged
//! cache leaves a completed request's prompt pages in the prefix index and
//! "resurrects" them on a later prefix hit. The free list is FIFO, so the
//! least-recently-freed cached page is evicted first.

use std::collections::VecDeque;

pub type BlockId = u32;

#[derive(Debug)]
pub struct BlockPool {
    refc: Vec<u32>,
    /// FIFO of freed blocks. May hold stale entries for blocks resurrected
    /// out of turn; `in_free` is authoritative and `alloc` skips stale
    /// entries lazily, keeping `resurrect` O(1) instead of O(free list).
    free: VecDeque<BlockId>,
    in_free: Vec<bool>,
    n_free: usize,
    /// Total successful allocations over the pool's lifetime.
    pub alloc_count: u64,
}

impl BlockPool {
    pub fn new(n_blocks: usize) -> BlockPool {
        BlockPool {
            refc: vec![0; n_blocks],
            free: (0..n_blocks as BlockId).collect(),
            in_free: vec![true; n_blocks],
            n_free: n_blocks,
            alloc_count: 0,
        }
    }

    pub fn total(&self) -> usize {
        self.refc.len()
    }

    /// Blocks available for allocation (includes cached prefix pages, which
    /// are recycled on demand).
    pub fn free_count(&self) -> usize {
        self.n_free
    }

    pub fn live_count(&self) -> usize {
        self.total() - self.n_free
    }

    /// Pop the least-recently-freed block; `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        loop {
            let id = self.free.pop_front()?;
            if !self.in_free[id as usize] {
                continue; // stale entry left behind by resurrect
            }
            self.in_free[id as usize] = false;
            self.n_free -= 1;
            self.refc[id as usize] = 1;
            self.alloc_count += 1;
            return Some(id);
        }
    }

    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refc[id as usize]
    }

    /// Whether a block currently sits on the free list (refcount 0, content
    /// still addressable until recycled). Swap-in uses this to count how
    /// many free-list entries a re-link pass will consume via `resurrect`.
    pub fn is_free(&self, id: BlockId) -> bool {
        self.in_free[id as usize]
    }

    pub fn incref(&mut self, id: BlockId) {
        debug_assert!(!self.in_free[id as usize], "incref on a free block");
        self.refc[id as usize] += 1;
    }

    /// Drop one reference; at zero the block returns to the free list (its
    /// content stays addressable for prefix resurrection until recycled).
    pub fn decref(&mut self, id: BlockId) {
        let i = id as usize;
        debug_assert!(self.refc[i] > 0, "decref on an unreferenced block");
        self.refc[i] -= 1;
        if self.refc[i] == 0 {
            self.free.push_back(id);
            self.in_free[i] = true;
            self.n_free += 1;
        }
    }

    /// Reclaim a refcount-0 block from the free list (prefix-cache hit on a
    /// completed sequence's page). Returns false when the block is live —
    /// callers share live blocks with `incref` instead. O(1): the block's
    /// deque entry goes stale and is skipped by a later `alloc`.
    pub fn resurrect(&mut self, id: BlockId) -> bool {
        let i = id as usize;
        if !self.in_free[i] {
            return false;
        }
        self.in_free[i] = false;
        self.n_free -= 1;
        self.refc[i] = 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_recycle() {
        let mut p = BlockPool::new(2);
        assert_eq!(p.free_count(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none(), "pool exhausted");
        p.decref(a);
        assert_eq!(p.free_count(), 1);
        // FIFO recycle hands back the freed block
        assert_eq!(p.alloc().unwrap(), a);
        assert_eq!(p.live_count(), 2);
    }

    #[test]
    fn refcount_sharing() {
        let mut p = BlockPool::new(1);
        let a = p.alloc().unwrap();
        p.incref(a);
        assert_eq!(p.ref_count(a), 2);
        p.decref(a);
        assert_eq!(p.free_count(), 0, "still referenced");
        p.decref(a);
        assert_eq!(p.free_count(), 1);
    }

    #[test]
    fn resurrect_cached_block() {
        let mut p = BlockPool::new(2);
        let a = p.alloc().unwrap();
        assert!(!p.resurrect(a), "live block cannot be resurrected");
        p.decref(a);
        assert!(p.resurrect(a));
        assert_eq!(p.ref_count(a), 1);
        assert_eq!(p.free_count(), 1, "only the never-allocated block is free");
    }

    #[test]
    fn stale_free_entries_are_skipped() {
        let mut p = BlockPool::new(1);
        let a = p.alloc().unwrap();
        p.decref(a);
        assert!(p.resurrect(a)); // leaves a stale deque entry behind
        p.decref(a); // freed again: deque now holds a duplicate
        assert_eq!(p.free_count(), 1);
        assert_eq!(p.alloc().unwrap(), a, "stale entry skipped, real one served");
        assert_eq!(p.free_count(), 0);
        assert!(p.alloc().is_none(), "leftover stale duplicate is not allocatable");
    }
}
