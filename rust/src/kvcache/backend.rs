//! `CacheBackend`: the interface the engine and the serving coordinator use
//! to talk to a KV cache arm. Two implementations exist:
//!
//! * `KvCache` (dense) — the reference arm: per-slot `[B, H, S_max, ·]`
//!   regions pre-allocated at engine build, exactly the layout the PJRT
//!   layer-step artifacts consume.
//! * `PagedKvCache` — a block-pool arm: fixed-size token pages allocated
//!   lazily as sequences grow, recycled through a free list, and shared
//!   across requests via hash-based prefix matching. Pages are gathered into
//!   the dense artifact layout at each layer step, so no Python-side
//!   artifact changes are required.
//!
//! The paged-only hooks (`can_admit`, `decode_block_shortfall`,
//! `prefill_reuse`, `register_prefix`) default to dense no-ops: a dense
//! engine admits purely by free slots and never preempts.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use xla::Literal;

use super::swap::{SwapHandle, SwapPolicy, SwapStats};
use super::view::KvView;
use crate::tensor::Tensor;

/// Pool sizing for the paged arm. Precedence: `total_blocks`, then
/// `budget_mib`, then a dense-equivalent default (`batch * ceil(s_max/page)`
/// blocks — same token capacity as the dense arm, so oversubscription comes
/// from running more scheduler slots than the pool could hold at full
/// length).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PagedOptions {
    /// Explicit pool size in pages.
    pub total_blocks: Option<usize>,
    /// Pool byte budget; converted to pages at construction.
    pub budget_mib: Option<f64>,
    /// Host swap-tier byte budget; `Some` attaches a `HostSwapArena` so the
    /// scheduler can evict by swap-out instead of recompute.
    pub swap_mib: Option<f64>,
    /// Scheduler eviction policy (only meaningful with a swap tier).
    pub swap_policy: SwapPolicy,
}

/// Memory accounting snapshot. `bytes_total` is the *device* resident
/// footprint (pre-allocated pool for the paged arm, full buffers for dense);
/// `bytes_live` is the portion referenced by in-flight sequences;
/// `frag_bytes` is allocated-but-unfilled space (partial tail pages for
/// paged, the unreached `[len, s_max)` tail for dense). The host tier is
/// accounted separately — `kv_bytes()` stays device-only so capacity benches
/// can report both tiers without double counting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemStats {
    pub bytes_total: usize,
    pub bytes_live: usize,
    pub frag_bytes: usize,
    pub blocks_total: usize,
    pub blocks_live: usize,
    pub blocks_free: usize,
    /// Host swap-tier reservation (0 when no swap tier is configured).
    pub host_bytes_total: usize,
    /// Host swap-tier bytes pinned by outstanding `SwapHandle`s.
    pub host_bytes_used: usize,
}

/// Typed marker for page-pool exhaustion. The scheduler downcasts prefill
/// errors to this to requeue (rather than fail) a request when pages will
/// free up as in-flight work completes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfPages;

impl std::fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv page pool exhausted")
    }
}

impl std::error::Error for OutOfPages {}

pub trait CacheBackend {
    fn batch(&self) -> usize;
    fn s_max(&self) -> usize;
    /// Absolute position of a slot (= tokens seen; same across layers).
    fn pos(&self, slot: usize) -> i32;
    fn advance_pos(&mut self, slot: usize, by: usize);
    /// Committed (quantized or fp-stored) tokens for one layer's slot.
    fn cache_len(&self, layer: usize, slot: usize) -> i32;
    /// Valid fp residual tokens for one layer's slot (kivi only).
    fn res_len(&self, layer: usize, slot: usize) -> i32;
    /// Cache tensors for a full-batch layer step, in artifact argument order
    /// (XLA backend only — this is the gather-to-dense staging copy the
    /// native backend's block-direct kernel eliminates).
    #[cfg(feature = "xla")]
    fn layer_literals(&self, layer: usize) -> Result<Vec<Literal>>;
    /// Cache tensors for one slot (B=1 prefill executables).
    #[cfg(feature = "xla")]
    fn slot_literals(&self, layer: usize, slot: usize) -> Result<Vec<Literal>>;
    /// Zero-copy page/scale view of one (layer, slot) for the native
    /// dequant-on-read attention kernel — no staging buffer is built.
    fn kv_view(&self, layer: usize, slot: usize) -> Result<KvView<'_>>;
    /// Bytes a gather-to-dense staging copy of `n_slots` slots moves for
    /// this layer (0 for the dense arm: its resident buffers already ARE
    /// the artifact layout). Feeds the `gather_bytes` serving metric and
    /// `table10_kernel`'s staged-vs-direct comparison.
    fn staged_bytes(&self, _layer: usize, _n_slots: usize) -> usize {
        0
    }
    fn append_token_outputs(
        &mut self,
        layer: usize,
        slot0: usize,
        outs: &[Tensor],
        valid: &[usize],
    ) -> Result<()>;
    fn append_kivi_residual(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<Vec<bool>>;
    fn residual_chunk(&self, layer: usize, slot: usize) -> Result<(Tensor, Tensor)>;
    fn commit_kivi_chunk(
        &mut self,
        layer: usize,
        slot: usize,
        k_outs: &[Tensor],
        v_outs: &[Tensor],
    ) -> Result<()>;
    fn append_fp(
        &mut self,
        layer: usize,
        slot0: usize,
        k_new: &Tensor,
        v_new: &Tensor,
        valid: &[usize],
    ) -> Result<()>;
    /// Release a slot's state (and, for paged, its pages back to the pool).
    fn reset_slot(&mut self, slot: usize);
    fn kv_bytes(&self) -> usize;
    fn equivalent_bits(&self) -> f64;
    /// Remaining capacity for a slot before the committed cache overflows.
    fn remaining(&self, slot: usize) -> usize;
    /// Mark a slot as holding `input_len` tokens without writing data
    /// (throughput benches: identical memory traffic, no honest prefill).
    /// Grows lengths/pages; never shrinks.
    fn synthetic_fill(&mut self, slot: usize, input_len: usize) -> Result<()>;
    fn mem_stats(&self) -> MemStats;

    /// Live KV bytes per layer (the per-layer split of
    /// `mem_stats().bytes_live`): what in-flight sequences actually hold in
    /// each layer right now, so the profiler can show where the precision
    /// map puts the memory. Empty = backend doesn't break live bytes down.
    fn layer_kv_live(&self) -> Vec<usize> {
        Vec::new()
    }

    // ---- paged admission / preemption / prefix hooks (dense no-ops) ----

    fn is_paged(&self) -> bool {
        false
    }

    /// Whether a request with this prompt length can be admitted now.
    /// Dense: always (a free slot implies reserved capacity). Paged: enough
    /// free pages for the prompt plus one decode page of headroom —
    /// deliberately NOT the full `max_new_tokens` reservation, which is what
    /// lets the pool oversubscribe.
    fn can_admit(&self, _prompt_len: usize, _max_new_tokens: usize) -> bool {
        true
    }

    /// Number of pages missing for the next decode step over `active` slots
    /// (0 = the step is safe). The scheduler preempts until this reaches 0.
    fn decode_block_shortfall(&self, _active: &[usize]) -> usize {
        0
    }

    /// Try to serve a prompt prefix from shared pages. Returns the number of
    /// prompt tokens now present in the slot's cache (0 = no reuse); the
    /// caller prefills only `prompt[reused..]`. At least one suffix token is
    /// always left for prefill.
    fn prefill_reuse(&mut self, _slot: usize, _prompt: &[i32]) -> usize {
        0
    }

    /// Publish a slot's full prompt pages into the prefix index so later
    /// requests with the same prefix can reuse them.
    fn register_prefix(&mut self, _slot: usize, _prompt: &[i32]) {}

    // ---- host swap tier (two-level cache hierarchy) ----

    /// Whether this backend has a host swap tier to evict into. Dense: true
    /// (the reference arm swaps whole slot regions, unbounded — it never
    /// preempts, so this exists for parity and tests). Paged: true when a
    /// `HostSwapArena` was configured via `swap_mib`.
    fn swap_enabled(&self) -> bool {
        false
    }

    /// Device pages a slot currently holds (cost-model input; 0 for dense).
    fn slot_pages(&self, _slot: usize) -> usize {
        0
    }

    /// Bytes a `swap_out` of this slot would move to the host right now
    /// (prefix-index-linked pages move nothing). Cost-model input.
    fn swap_out_bytes(&self, _slot: usize) -> usize {
        0
    }

    /// Mean device bytes one cached token costs across layers (cost-model
    /// input for comparing swap traffic against re-prefill work).
    fn per_token_kv_bytes(&self) -> usize {
        self.kv_bytes() / (self.batch() * self.s_max()).max(1)
    }

    /// Evict a slot's KV state to the host tier, freeing its device pages.
    /// On `HostArenaFull` the slot is left intact and the caller falls back
    /// to recompute preemption.
    fn swap_out(&mut self, _slot: usize) -> Result<SwapHandle> {
        bail!("this cache backend has no swap tier")
    }

    /// Whether a swapped sequence's device pages fit right now (pages that
    /// must be allocated or resurrected, plus one decode page of headroom —
    /// the swap-aware admission gate).
    fn can_swap_in(&self, _h: &SwapHandle) -> bool {
        false
    }

    /// Restore a swapped sequence into a fresh slot: host pages are copied
    /// back, prefix-index-linked pages are re-linked (resurrect/incref).
    /// Validates before mutating; on `SwapLost` the cache is unchanged and
    /// the caller should `release_swap` and re-prefill instead.
    fn swap_in(&mut self, _slot: usize, _h: &SwapHandle) -> Result<()> {
        bail!("this cache backend has no swap tier")
    }

    /// Free the handle's host-tier bytes (after a successful `swap_in`, or
    /// when abandoning the handle for the recompute fallback).
    fn release_swap(&mut self, _h: SwapHandle) {}

    /// Host-tier traffic counters.
    fn swap_stats(&self) -> SwapStats {
        SwapStats::default()
    }
}
