//! Pure-Rust reference engine: the same transformer as the JAX/Pallas model
//! (matched numerics: RMSNorm, split-half RoPE, GQA, tanh-approx GELU), with
//! *fake-quant-at-storage* KV caching per layer spec.
//!
//! Three jobs:
//! 1. The KVTuner offline pipeline's evaluation substrate — error
//!    accumulation semantics identical to the PJRT engine, but cheap enough
//!    to run hundreds of MOO evaluations (and it exposes per-layer Q/K/V for
//!    the profiler, which the AOT executables do not).
//! 2. The FP reference arm of the fidelity accuracy metric.
//! 3. Parity oracle for the PJRT engine (integration tests diff the two).

use anyhow::Result;

use crate::config::{LayerSpec, Mode, ModelConfig};
use crate::quant::error::LayerCapture;
use crate::quant::{quantize_per_channel, quantize_per_token};

use super::weights::Weights;

/// Per-layer KV cache with quantize-at-commit semantics.
struct LayerCache {
    k: Vec<f32>, // [Hkv, S_cap, Dh], rows beyond `len` undefined
    v: Vec<f32>,
    len: usize,
    committed: usize, // tokens already fake-quantized (kivi group commits)
}

pub struct RefEngine<'w> {
    pub cfg: ModelConfig,
    weights: &'w Weights,
    pub specs: Vec<LayerSpec>,
    caches: Vec<LayerCache>,
    capacity: usize,
    x_scratch: Vec<f32>,
    /// When set, per-layer Q/K/V captures are recorded (pre-quantization).
    pub capture: Option<Vec<LayerCapture>>,
    /// Logits of the most recent step (for perplexity-style evals).
    pub last_logits: Vec<f32>,
}

fn rmsnorm(x: &[f32], g: &[f32], eps: f32, out: &mut [f32]) {
    let d = x.len();
    let ms = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for i in 0..d {
        out[i] = x[i] * r * g[i];
    }
}

/// y[j] += sum_i x[i] * w[i, j]  (w: [d_in, d_out] row-major)
fn matvec_acc(x: &[f32], w: &[f32], d_in: usize, d_out: usize, y: &mut [f32]) {
    debug_assert_eq!(w.len(), d_in * d_out);
    for i in 0..d_in {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * d_out..(i + 1) * d_out];
        for j in 0..d_out {
            y[j] += xi * row[j];
        }
    }
}

fn gelu_tanh(x: f32) -> f32 {
    // jax.nn.gelu default (approximate=True)
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Split-half RoPE matching model.py::apply_rope.
fn apply_rope(x: &mut [f32], pos: usize, head_dim: usize, theta: f64) {
    let half = head_dim / 2;
    for i in 0..half {
        let freq = (theta as f32).powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (s, c) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * c - b * s;
        x[i + half] = a * s + b * c;
    }
}

impl<'w> RefEngine<'w> {
    pub fn new(cfg: &ModelConfig, weights: &'w Weights, specs: Vec<LayerSpec>, capacity: usize) -> Result<RefEngine<'w>> {
        anyhow::ensure!(specs.len() == cfg.n_layers, "one spec per layer");
        let hkv = cfg.n_kv_heads;
        let caches = (0..cfg.n_layers)
            .map(|_| LayerCache {
                k: vec![0.0; hkv * capacity * cfg.head_dim],
                v: vec![0.0; hkv * capacity * cfg.head_dim],
                len: 0,
                committed: 0,
            })
            .collect();
        Ok(RefEngine {
            cfg: cfg.clone(),
            weights,
            specs,
            caches,
            capacity,
            x_scratch: vec![0.0; cfg.d_model],
            capture: None,
            last_logits: vec![0.0; cfg.vocab],
        })
    }

    pub fn enable_capture(&mut self) {
        let c = &self.cfg;
        self.capture = Some(
            (0..c.n_layers)
                .map(|_| LayerCapture {
                    q: Vec::new(),
                    k: Vec::new(),
                    v: Vec::new(),
                    s: 0,
                    n_heads: c.n_heads,
                    n_kv_heads: c.n_kv_heads,
                    head_dim: c.head_dim,
                })
                .collect(),
        );
    }

    /// Finalize captures: reshape the appended per-token K/V into [Hkv, S, Dh].
    pub fn take_capture(&mut self) -> Option<Vec<LayerCapture>> {
        let caps = self.capture.take()?;
        let (hkv, dh) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        Some(
            caps.into_iter()
                .map(|mut c| {
                    // stored as [S, Hkv, Dh] during append; transpose to [Hkv, S, Dh]
                    let s = c.k.len() / (hkv * dh);
                    let mut k = vec![0.0; c.k.len()];
                    let mut v = vec![0.0; c.v.len()];
                    for t in 0..s {
                        for h in 0..hkv {
                            let src = (t * hkv + h) * dh;
                            let dst = (h * s + t) * dh;
                            k[dst..dst + dh].copy_from_slice(&c.k[src..src + dh]);
                            v[dst..dst + dh].copy_from_slice(&c.v[src..src + dh]);
                        }
                    }
                    c.k = k;
                    c.v = v;
                    c.s = s;
                    c
                })
                .collect(),
        )
    }

    pub fn reset(&mut self) {
        for c in &mut self.caches {
            c.len = 0;
            c.committed = 0;
        }
        if self.capture.is_some() {
            self.enable_capture();
        }
    }

    pub fn cache_len(&self) -> usize {
        self.caches[0].len
    }

    /// Process one token; returns the logits-argmax (the next token).
    pub fn step(&mut self, token: i32) -> Result<i32> {
        let cfg = self.cfg.clone();
        let (d, hq, hkv, dh, ff) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff);
        let gqa = hq / hkv;
        let eps = cfg.rms_eps as f32;
        let pos = self.caches[0].len;
        anyhow::ensure!(pos < self.capacity, "cache capacity {} exceeded", self.capacity);

        // embed
        let emb = self.weights.embed()?.as_f32()?;
        let mut x = emb[(token as usize) * d..(token as usize + 1) * d].to_vec();

        let mut h = vec![0f32; d];
        let mut q = vec![0f32; hq * dh];
        let mut k = vec![0f32; hkv * dh];
        let mut v = vec![0f32; hkv * dh];
        let mut attn_out = vec![0f32; hq * dh];
        let mut mlp_h = vec![0f32; ff];

        for l in 0..cfg.n_layers {
            let lw = self.weights.layer(l)?;
            let (ln1, wq, wk, wv, wo, ln2, w1, w2) = (
                lw[0].as_f32()?, lw[1].as_f32()?, lw[2].as_f32()?, lw[3].as_f32()?,
                lw[4].as_f32()?, lw[5].as_f32()?, lw[6].as_f32()?, lw[7].as_f32()?,
            );
            rmsnorm(&x, ln1, eps, &mut h);
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            matvec_acc(&h, wq, d, hq * dh, &mut q);
            matvec_acc(&h, wk, d, hkv * dh, &mut k);
            matvec_acc(&h, wv, d, hkv * dh, &mut v);
            for hh in 0..hq {
                apply_rope(&mut q[hh * dh..(hh + 1) * dh], pos, dh, cfg.rope_theta);
            }
            for hh in 0..hkv {
                apply_rope(&mut k[hh * dh..(hh + 1) * dh], pos, dh, cfg.rope_theta);
            }

            if let Some(caps) = &mut self.capture {
                caps[l].q.extend_from_slice(&q);
                caps[l].k.extend_from_slice(&k);
                caps[l].v.extend_from_slice(&v);
            }

            // append to cache (fp now; quantized at commit below)
            {
                let cache = &mut self.caches[l];
                for hh in 0..hkv {
                    let dst = (hh * self.capacity + pos) * dh;
                    cache.k[dst..dst + dh].copy_from_slice(&k[hh * dh..(hh + 1) * dh]);
                    cache.v[dst..dst + dh].copy_from_slice(&v[hh * dh..(hh + 1) * dh]);
                }
                cache.len = pos + 1;
            }
            self.commit_layer(l)?;

            // attention over the (possibly quantized-at-storage) cache
            let cache = &self.caches[l];
            let s_len = cache.len;
            let scale = 1.0 / (dh as f32).sqrt();
            let mut scores = vec![0f32; s_len];
            for hh in 0..hq {
                let kv = hh / gqa;
                let qh = &q[hh * dh..(hh + 1) * dh];
                let mut maxs = f32::NEG_INFINITY;
                for j in 0..s_len {
                    let kj = &cache.k[(kv * self.capacity + j) * dh..(kv * self.capacity + j) * dh + dh];
                    let mut dot = 0f32;
                    for dd in 0..dh {
                        dot += qh[dd] * kj[dd];
                    }
                    scores[j] = dot * scale;
                    maxs = maxs.max(scores[j]);
                }
                let mut denom = 0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                let o = &mut attn_out[hh * dh..(hh + 1) * dh];
                o.fill(0.0);
                for j in 0..s_len {
                    let p = scores[j] / denom;
                    let vj = &cache.v[(kv * self.capacity + j) * dh..(kv * self.capacity + j) * dh + dh];
                    for dd in 0..dh {
                        o[dd] += p * vj[dd];
                    }
                }
            }

            // output proj + residual
            self.x_scratch.fill(0.0);
            matvec_acc(&attn_out, wo, hq * dh, d, &mut self.x_scratch);
            for i in 0..d {
                x[i] += self.x_scratch[i];
            }

            // MLP
            rmsnorm(&x, ln2, eps, &mut h);
            mlp_h.fill(0.0);
            matvec_acc(&h, w1, d, ff, &mut mlp_h);
            for m in mlp_h.iter_mut() {
                *m = gelu_tanh(*m);
            }
            self.x_scratch.fill(0.0);
            matvec_acc(&mlp_h, w2, ff, d, &mut self.x_scratch);
            for i in 0..d {
                x[i] += self.x_scratch[i];
            }
        }

        // lm head (tied embedding)
        rmsnorm(&x, self.weights.ln_f()?.as_f32()?, eps, &mut h);
        let vsize = cfg.vocab;
        let mut best = (0usize, f32::NEG_INFINITY);
        for t in 0..vsize {
            let row = &emb[t * d..(t + 1) * d];
            let mut dot = 0f32;
            for i in 0..d {
                dot += h[i] * row[i];
            }
            self.last_logits[t] = dot;
            if dot > best.1 {
                best = (t, dot);
            }
        }
        Ok(best.0 as i32)
    }

    /// Storage-quantization commit for layer `l` per its spec.
    fn commit_layer(&mut self, l: usize) -> Result<()> {
        let spec = self.specs[l];
        let (hkv, dh, group) = (self.cfg.n_kv_heads, self.cfg.head_dim, self.cfg.group);
        let cap = self.capacity;
        let cache = &mut self.caches[l];
        match spec.mode {
            Mode::Fp => {}
            Mode::Token => {
                // quantize the just-appended token immediately (no residual)
                let t = cache.len - 1;
                for hh in 0..hkv {
                    let o = (hh * cap + t) * dh;
                    if spec.pair.k_bits < 16 {
                        let q = quantize_per_token(&cache.k[o..o + dh], 1, dh, spec.pair.k_bits)?;
                        q.dequantize_into(&mut cache.k[o..o + dh]);
                    }
                    if spec.pair.v_bits < 16 {
                        let q = quantize_per_token(&cache.v[o..o + dh], 1, dh, spec.pair.v_bits)?;
                        q.dequantize_into(&mut cache.v[o..o + dh]);
                    }
                }
                cache.committed = cache.len;
            }
            Mode::Kivi => {
                // residual ring: commit whole groups once `group` tokens queue up
                while cache.len - cache.committed >= group {
                    let t0 = cache.committed;
                    for hh in 0..hkv {
                        let o = (hh * cap + t0) * dh;
                        if spec.pair.k_bits < 16 {
                            let q = quantize_per_channel(
                                &cache.k[o..o + group * dh], group, dh, spec.pair.k_bits)?;
                            q.dequantize_into(&mut cache.k[o..o + group * dh]);
                        }
                        if spec.pair.v_bits < 16 {
                            let q = quantize_per_token(
                                &cache.v[o..o + group * dh], group, dh, spec.pair.v_bits)?;
                            q.dequantize_into(&mut cache.v[o..o + group * dh]);
                        }
                    }
                    cache.committed += group;
                }
            }
        }
        Ok(())
    }

    /// Prefill a prompt token-by-token (error accumulation enabled, matching
    /// the paper's calibration design), then greedily decode `max_new`.
    pub fn generate(&mut self, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        self.reset();
        let mut next = 0i32;
        for &t in prompt {
            next = self.step(t)?;
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            out.push(next);
            if self.cache_len() >= self.capacity {
                break;
            }
            next = self.step(next)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // RefEngine correctness is covered by integration tests that diff it
    // against the PJRT engine (rust/tests/integration.rs) — building a
    // weights fixture here would duplicate that.
}
