//! Weight store: loads `weights-<model>.bin` (flat little-endian f32) using
//! the tensor index from the manifest, or generates a deterministic
//! synthetic checkpoint for artifact-free tests and benches.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{Manifest, ModelConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const LAYER_WEIGHT_NAMES: [&str; 8] = ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2"];

#[derive(Debug, Clone)]
pub struct Weights {
    pub model_name: String,
    tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(manifest: &Manifest, model_name: &str) -> Result<Weights> {
        let entry = manifest.model(model_name)?;
        let path = manifest.dir.join(&entry.weights_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("weights file {path:?} not a multiple of 4 bytes");
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut tensors = BTreeMap::new();
        for (name, te) in &entry.tensors {
            let n: usize = te.shape.iter().product();
            if te.offset + n > floats.len() {
                bail!("tensor {name} out of bounds in {path:?}");
            }
            tensors.insert(
                name.clone(),
                Tensor::f32(&te.shape, floats[te.offset..te.offset + n].to_vec()),
            );
        }
        Ok(Weights { model_name: model_name.to_string(), tensors })
    }

    /// Deterministic synthetic weights for `cfg` — the artifact-free path:
    /// lets the native engine, its parity tests and the kernel benches run
    /// on machines with neither AOT artifacts nor a weights file. Matmul
    /// weights are ~N(0, 1/d_in) so activations stay O(1); norm gains are 1.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut r = Rng::seed(seed);
        let (d, hq, hkv, dh, ff) = (
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.head_dim,
            cfg.d_ff,
        );
        let mut mat = |d_in: usize, d_out: usize| -> Tensor {
            let s = 1.0 / (d_in as f64).sqrt();
            Tensor::f32(
                &[d_in, d_out],
                (0..d_in * d_out).map(|_| (r.normal() * s) as f32).collect(),
            )
        };
        let mut tensors = BTreeMap::new();
        tensors.insert("embed".to_string(), mat(cfg.vocab, d));
        for l in 0..cfg.n_layers {
            tensors.insert(format!("layer{l}.ln1"), Tensor::f32(&[d], vec![1.0; d]));
            tensors.insert(format!("layer{l}.wq"), mat(d, hq * dh));
            tensors.insert(format!("layer{l}.wk"), mat(d, hkv * dh));
            tensors.insert(format!("layer{l}.wv"), mat(d, hkv * dh));
            tensors.insert(format!("layer{l}.wo"), mat(hq * dh, d));
            tensors.insert(format!("layer{l}.ln2"), Tensor::f32(&[d], vec![1.0; d]));
            tensors.insert(format!("layer{l}.w1"), mat(d, ff));
            tensors.insert(format!("layer{l}.w2"), mat(ff, d));
        }
        tensors.insert("ln_f".to_string(), Tensor::f32(&[d], vec![1.0; d]));
        Weights { model_name: format!("synthetic-{seed}"), tensors }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("missing weight tensor {name:?}"))
    }

    pub fn embed(&self) -> Result<&Tensor> {
        self.get("embed")
    }

    pub fn ln_f(&self) -> Result<&Tensor> {
        self.get("ln_f")
    }

    /// The 8 per-layer tensors in artifact argument order.
    pub fn layer(&self, l: usize) -> Result<Vec<&Tensor>> {
        LAYER_WEIGHT_NAMES
            .iter()
            .map(|nm| self.get(&format!("layer{l}.{nm}")))
            .collect()
    }

    pub fn validate(&self, cfg: &ModelConfig) -> Result<()> {
        let d = cfg.d_model;
        if self.embed()?.shape != [cfg.vocab, d] {
            bail!("embed shape mismatch");
        }
        for l in 0..cfg.n_layers {
            let lw = self.layer(l)?;
            if lw[1].shape != [d, cfg.n_heads * cfg.head_dim] {
                bail!("layer {l} wq shape mismatch");
            }
            if lw[2].shape != [d, cfg.n_kv_heads * cfg.head_dim] {
                bail!("layer {l} wk shape mismatch");
            }
        }
        Ok(())
    }
}
