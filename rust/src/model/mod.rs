//! Model substrate: weight store + the pure-Rust reference engine.

pub mod ref_engine;
pub mod weights;

pub use ref_engine::RefEngine;
pub use weights::{Weights, LAYER_WEIGHT_NAMES};
