//! Attention-pattern analysis (paper Sec. 4.4 / App. E): classifies heads as
//! streaming (sparse, concentrated — robust to KV quantization per Lemma 1)
//! vs retrieval (diffuse — sensitive), and produces the token-level
//! attention-shift rows behind Fig. 2/4 and the block maps behind Fig. 11/12.

use anyhow::Result;

use crate::config::LayerSpec;
use crate::quant::error::{attention_probs, LayerCapture};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeadClass {
    /// Concentrated attention (sink/recent-window); dominated key tokens.
    Streaming,
    /// Diffuse, dynamic attention over many keys.
    Retrieval,
    Mixed,
}

impl HeadClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            HeadClass::Streaming => "streaming",
            HeadClass::Retrieval => "retrieval",
            HeadClass::Mixed => "mixed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct HeadPattern {
    pub layer: usize,
    pub head: usize,
    /// Mean top-1 attention mass over queries (concentration).
    pub top1_mass: f64,
    /// Mean normalized entropy over queries (1 = uniform, 0 = delta).
    pub entropy: f64,
    pub class: HeadClass,
}

/// Classify one layer's heads from its fp attention probabilities.
pub fn classify_layer(cap: &LayerCapture, layer: usize, group: usize) -> Result<Vec<HeadPattern>> {
    let probs = attention_probs(cap, LayerSpec::fp(), group)?;
    let (s, hq) = (cap.s, cap.n_heads);
    let mut out = Vec::with_capacity(hq);
    for h in 0..hq {
        let mut top1 = 0f64;
        let mut ent = 0f64;
        let mut n = 0usize;
        for i in 1..s {
            let row = &probs[(h * s + i) * s..(h * s + i) * s + i + 1];
            let mx = row.iter().cloned().fold(0f32, f32::max) as f64;
            let mut e = 0f64;
            for &p in row {
                if p > 1e-9 {
                    e -= (p as f64) * (p as f64).ln();
                }
            }
            let norm = ((i + 1) as f64).ln().max(1e-9);
            top1 += mx;
            ent += e / norm;
            n += 1;
        }
        let top1_mass = top1 / n as f64;
        let entropy = ent / n as f64;
        let class = if top1_mass > 0.5 && entropy < 0.5 {
            HeadClass::Streaming
        } else if top1_mass < 0.25 && entropy > 0.7 {
            HeadClass::Retrieval
        } else {
            HeadClass::Mixed
        };
        out.push(HeadPattern { layer, head: h, top1_mass, entropy, class });
    }
    Ok(out)
}

/// Token-level attention row of one (head, query) under fp vs a quantized
/// spec — Fig. 2/4's "distribution shift" series. Returns (fp_row, q_row).
pub fn attention_shift_row(
    cap: &LayerCapture,
    head: usize,
    query: usize,
    spec: LayerSpec,
    group: usize,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let s = cap.s;
    anyhow::ensure!(query < s && head < cap.n_heads);
    let fp = attention_probs(cap, LayerSpec::fp(), group)?;
    let q = attention_probs(cap, spec, group)?;
    let row = |p: &[f32]| p[(head * s + query) * s..(head * s + query) * s + query + 1].to_vec();
    Ok((row(&fp), row(&q)))
}

/// Block-averaged attention map for one head (Fig. 11/12's coarse maps):
/// returns a (S/bs) x (S/bs) row-major grid of mean probabilities.
pub fn block_map(
    cap: &LayerCapture,
    head: usize,
    block: usize,
    group: usize,
) -> Result<Vec<f64>> {
    let s = cap.s;
    let nb = s / block;
    let probs = attention_probs(cap, LayerSpec::fp(), group)?;
    let mut grid = vec![0f64; nb * nb];
    let mut counts = vec![0usize; nb * nb];
    for i in 0..nb * block {
        for j in 0..=i {
            let cell = (i / block) * nb + j / block;
            grid[cell] += probs[(head * s + i) * s + j] as f64;
            counts[cell] += 1;
        }
    }
    for (g, c) in grid.iter_mut().zip(counts) {
        if c > 0 {
            *g /= c as f64;
        }
    }
    Ok(grid)
}

/// Mean total-variation distance between fp and quantized attention rows,
/// per head — the quantitative form of Fig. 2's shift.
pub fn head_shift_scores(
    cap: &LayerCapture,
    spec: LayerSpec,
    group: usize,
) -> Result<Vec<f64>> {
    let (s, hq) = (cap.s, cap.n_heads);
    let fp = attention_probs(cap, LayerSpec::fp(), group)?;
    let q = attention_probs(cap, spec, group)?;
    let mut out = Vec::with_capacity(hq);
    for h in 0..hq {
        let mut tv = 0f64;
        let mut n = 0usize;
        for i in 1..s {
            for j in 0..=i {
                tv += (fp[(h * s + i) * s + j] - q[(h * s + i) * s + j]).abs() as f64;
            }
            n += 1;
        }
        out.push(tv / (2.0 * n as f64));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, PrecisionPair};
    use crate::util::rng::Rng;

    fn capture(sharp: f32, s: usize) -> LayerCapture {
        let (hq, hkv, dh) = (2, 1, 16);
        let mut r = Rng::seed(9);
        let mut gen = |n: usize, sc: f32| (0..n).map(|_| r.normal() as f32 * sc).collect::<Vec<f32>>();
        LayerCapture {
            q: gen(s * hq * dh, sharp),
            k: gen(hkv * s * dh, 1.0),
            v: gen(hkv * s * dh, 1.0),
            s,
            n_heads: hq,
            n_kv_heads: hkv,
            head_dim: dh,
        }
    }

    #[test]
    fn sharp_queries_classify_concentrated() {
        let sharp = classify_layer(&capture(8.0, 48), 0, 32).unwrap();
        let diffuse = classify_layer(&capture(0.05, 48), 0, 32).unwrap();
        assert!(sharp[0].top1_mass > diffuse[0].top1_mass);
        assert!(sharp[0].entropy < diffuse[0].entropy);
        assert_eq!(diffuse[0].class, HeadClass::Retrieval);
    }

    #[test]
    fn shift_scores_grow_with_lower_bits() {
        let cap = capture(2.0, 64);
        let spec = |k| LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(k, 8) };
        let s8: f64 = head_shift_scores(&cap, spec(8), 32).unwrap().iter().sum();
        let s2: f64 = head_shift_scores(&cap, spec(2), 32).unwrap().iter().sum();
        assert!(s2 > s8, "{s2} vs {s8}");
    }

    #[test]
    fn block_map_rows_bounded() {
        let cap = capture(1.0, 32);
        let grid = block_map(&cap, 0, 8, 32).unwrap();
        assert_eq!(grid.len(), 16);
        assert!(grid.iter().all(|&g| (0.0..=1.0).contains(&g)));
    }

    #[test]
    fn shift_row_shapes() {
        let cap = capture(1.0, 32);
        let spec = LayerSpec { mode: Mode::Token, pair: PrecisionPair::new(2, 2) };
        let (f, q) = attention_shift_row(&cap, 1, 20, spec, 32).unwrap();
        assert_eq!(f.len(), 21);
        assert_eq!(q.len(), 21);
        let sf: f32 = f.iter().sum();
        assert!((sf - 1.0).abs() < 1e-3);
    }
}
