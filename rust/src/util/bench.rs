//! Tiny benchmark harness (criterion is unavailable offline). Benches are
//! `harness = false` mains that call [`bench`] / [`Table`].

use std::time::Instant;

use crate::util::json::{arr, obj, s, Json};

/// Time `f` for `iters` iterations after `warmup` runs; report stats.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let stats = BenchStats::from_samples(name, samples);
    println!("{stats}");
    stats
}

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub iters: usize,
}

impl BenchStats {
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchStats {
            name: name.to_string(),
            mean,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
            iters: n,
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:40} mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}  (n={})",
            self.name,
            fmt_secs(self.mean),
            fmt_secs(self.p50),
            fmt_secs(self.p95),
            fmt_secs(self.min),
            self.iters
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}

/// Markdown-ish table printer for the paper-reproduction benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table::with_headers(title, header.iter().map(|s| s.to_string()).collect())
    }

    pub fn with_headers(title: &str, header: Vec<String>) -> Self {
        Table { title: title.to_string(), header, rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Machine-diffable form: `{"title", "header", "rows"}` with every cell
    /// as the string the table printed (benches emit this as a single
    /// `BENCH_JSON` line alongside the human table).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", s(self.title.as_str())),
            ("header", arr(self.header.iter().map(|h| s(h.as_str())))),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| arr(r.iter().map(|c| s(c.as_str()))))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.header);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = BenchStats::from_samples("t", vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_secs(2.5e-3), "2.500ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500us");
    }
}
