//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `kvtuner <subcommand> [--flag value | --switch] ...`

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`; `switch_names` lists valueless flags. An empty
    /// argv or a flags-only argv yields an empty `subcommand` — the caller
    /// decides how to fail (the CLI prints usage and exits nonzero);
    /// nothing here can panic (regression: the old peek-then-`unwrap`
    /// pattern was one refactor away from panicking on a missing
    /// subcommand).
    pub fn parse(argv: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.next_if(|a| !a.starts_with("--")) {
            out.subcommand = first.clone();
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    match it.next() {
                        Some(v) => {
                            out.flags.insert(name.to_string(), v.clone());
                        }
                        None => bail!("flag --{name} expects a value"),
                    }
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env(switch_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, switch_names)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Comma-separated list flag.
    pub fn list(&self, name: &str, default: &str) -> Vec<String> {
        self.str(name, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(
            &v(&["tune", "--model", "tiny", "--iters=50", "--no-prune", "extra"]),
            &["no-prune"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "tune");
        assert_eq!(a.str("model", "x"), "tiny");
        assert_eq!(a.usize("iters", 0).unwrap(), 50);
        assert!(a.switch("no-prune"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["x", "--flag"]), &[]).is_err());
    }

    #[test]
    fn missing_subcommand_does_not_panic() {
        // regression: empty argv and flags-only argv must parse cleanly with
        // an empty subcommand (cli_main then prints usage and exits nonzero)
        let a = Args::parse(&[], &[]).unwrap();
        assert!(a.subcommand.is_empty());
        let a = Args::parse(&v(&["--paged", "--model", "tiny"]), &["paged"]).unwrap();
        assert!(a.subcommand.is_empty(), "a flag is not a subcommand");
        assert!(a.switch("paged"));
        assert_eq!(a.str("model", ""), "tiny");
    }

    #[test]
    fn lists() {
        let a = Args::parse(&v(&["x", "--pairs", "8:4,4:2"]), &[]).unwrap();
        assert_eq!(a.list("pairs", ""), vec!["8:4", "4:2"]);
    }
}
