//! Small in-tree substrates (JSON, PRNG, CLI, bench harness) — the offline
//! crate set has only the `xla` closure + `anyhow`, so these are built here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
